"""Concurrent runtime for :class:`repro.serve.factorized.FactorizedService`.

The service's scheduler is a synchronous ``drain()`` loop; this module
supplies the threads and the failure vocabulary that turn it into a
long-running server:

* :class:`ServiceRuntime` — ``service.start()`` spawns it: a **drain
  worker** that serves queued requests as they arrive (woken by
  submissions, with a polling fallback), and a **low-priority fold
  thread** that services the store's pending-delta debt
  (``DeltaLog.debt``) only in idle windows, so sustained writers get
  warm caches without ever stealing a foreground traversal's cycle.
  ``service.stop()`` runs the clean-shutdown protocol: stop admission,
  optionally drain what's queued within a budget, fail every leftover
  ticket with :class:`ServiceStopped`, join both threads.  No ticket is
  ever left unresolved.

* Typed failures — :class:`ServiceTimeout` (deadline / ``result``
  timeout), :class:`ServiceOverloaded` (bounded-queue backpressure),
  :class:`ServiceStopped` (shutdown), :class:`TransientFault` (the base
  class retry policies act on).  All derive from :class:`ServiceError`.

* :class:`RetryPolicy` — bounded retry with exponential backoff for
  transient faults.  The service requeues a failed request with a
  ``not_before`` stamp instead of sleeping, so retries never block the
  drain worker.

Both threads treat ANY exception escaping a cycle as a runtime bug to
record (``ServiceRuntime.errors``), never as a reason to die: a wedged
worker would strand every future ticket, which is the one invariant this
layer exists to protect.

This module deliberately does not import the service (no cycle): the
runtime drives it through the narrow ``pending()`` / ``drain()`` /
``fold_debt_rows()`` / ``flush()`` surface.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional, Tuple, Type

__all__ = [
    "RetryPolicy",
    "RuntimeConfig",
    "ServiceError",
    "ServiceOverloaded",
    "ServiceRuntime",
    "ServiceStopped",
    "ServiceTimeout",
    "TransientFault",
]


class ServiceError(RuntimeError):
    """Base class of every failure the serving layer itself raises."""


class ServiceTimeout(ServiceError, TimeoutError):
    """A request deadline expired, or ``Ticket.result(timeout=)`` ran out
    of patience before the request was served."""


class ServiceOverloaded(ServiceError):
    """The bounded admission queue rejected or shed a request."""


class ServiceStopped(ServiceError):
    """The service was stopped before (or while) the request was queued."""


class TransientFault(ServiceError):
    """A fault worth retrying: the same request may succeed on a fresh
    attempt (I/O hiccup, poisoned fold already quarantined, injected
    test fault).  Retry policies match on this type by default."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff for transient read faults.

    ``max_attempts`` counts total tries (1 = never retry).  Attempt ``n``
    (1-based retry index) is deferred by ``backoff * multiplier**(n-1)``
    seconds, capped at ``max_backoff``.  Only exceptions matching
    ``retry_on`` are retried; anything else fails the ticket at once.
    """

    max_attempts: int = 3
    backoff: float = 0.01
    multiplier: float = 2.0
    max_backoff: float = 1.0
    retry_on: Tuple[Type[BaseException], ...] = (TransientFault,)

    def delay(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        return min(
            self.backoff * self.multiplier ** max(attempt - 1, 0),
            self.max_backoff,
        )


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Knobs of the threaded front-end.

    ``poll_interval``   drain-worker wake granularity when no submission
                        signal arrives (submissions wake it immediately).
    ``fold_interval``   cadence of the background fold thread's idle
                        probe — NOT a fold rate cap; the thread folds at
                        most once per probe and only when the service has
                        no queued work.
    ``fold_min_rows``   minimum pending delta rows worth a background
                        fold (tiny debts are cheaper to fold at the next
                        read barrier).
    ``drain_timeout``   default budget of ``stop(drain=True)``.
    """

    poll_interval: float = 0.02
    fold_interval: float = 0.05
    fold_min_rows: int = 1
    drain_timeout: float = 30.0


class ServiceRuntime:
    """Drain-worker + background-fold threads around one service."""

    def __init__(self, service, config: Optional[RuntimeConfig] = None):
        self.service = service
        self.config = config or RuntimeConfig()
        self._stop_event = threading.Event()
        self._wake = threading.Event()
        self._drain_thread = threading.Thread(
            target=self._drain_loop, name="factorized-drain", daemon=True
        )
        self._fold_thread = threading.Thread(
            target=self._fold_loop, name="factorized-fold", daemon=True
        )
        #: runtime bugs recorded instead of killing a worker (bounded)
        self.errors: "deque" = deque(maxlen=32)

    def start(self) -> None:
        self._drain_thread.start()
        self._fold_thread.start()

    def notify(self) -> None:
        """Wake the drain worker now (called on every submission)."""
        self._wake.set()

    def _drain_loop(self) -> None:
        svc = self.service
        while not self._stop_event.is_set():
            self._wake.wait(self.config.poll_interval)
            self._wake.clear()
            try:
                while svc.pending() and not self._stop_event.is_set():
                    if svc.drain() == 0:
                        # only deferred retries remain — back off until
                        # their not_before stamps pass
                        break
            except Exception as err:  # pragma: no cover - runtime bug trap
                self.errors.append(err)

    def _fold_loop(self) -> None:
        svc = self.service
        while not self._stop_event.wait(self.config.fold_interval):
            try:
                if svc.pending():
                    continue  # low priority: foreground work goes first
                if svc.fold_debt_rows() >= self.config.fold_min_rows:
                    svc.flush()
            except Exception as err:  # pragma: no cover - runtime bug trap
                self.errors.append(err)

    def stop(self, drain: bool = True, timeout: Optional[float] = None):
        """Shutdown: optionally help drain queued work within the budget,
        then stop and join both threads.  The *service* fails whatever is
        left afterwards — by the time this returns no thread is running,
        so that sweep cannot race a cycle."""
        budget = self.config.drain_timeout if timeout is None else timeout
        if drain:
            deadline = time.monotonic() + budget
            while self.service.pending() and time.monotonic() < deadline:
                # compete with the worker for cycles (drain() serializes
                # internally) so shutdown needn't wait for its poll tick
                if self.service.drain() == 0:
                    time.sleep(0.002)  # deferred retries pending
        self._stop_event.set()
        self._wake.set()
        join_by = time.monotonic() + max(budget, 1.0)
        for t in (self._drain_thread, self._fold_thread):
            if t.is_alive():
                t.join(timeout=max(join_by - time.monotonic(), 0.1))
