"""Multi-tenant factorized training service: one shared store, coalesced
aggregate traversals, snapshot-isolated reads.

The paper's 100x comes from sharing aggregate work *within* one training
run; AC/DC (Abo Khamis et al. 2018) shares it within one optimization
batch.  This layer shares it across **concurrent tenants**: requests
(train / score / cofactor / aggregate) from different clients against one
:class:`repro.core.store.Store` queue up, and each drain cycle

1. groups queued reads by (variable-order signature, backend, dtype),
2. coalesces every group with :func:`repro.core.factorize.merge_batches` —
   feature lists union, same-GROUP-BY queries dedupe at the max degree —
   into ONE ``run_batch`` traversal per group,
3. scatters the shared blocks back per request
   (:func:`repro.core.factorize.scatter_results`: pure slicing, Prop. 4.1
   projection commutativity), then finishes each request's own
   post-processing (closed-form solve for train, SSE quadratic form for
   score),
4. applies queued ``append`` writes and publishes a fresh
   :class:`repro.core.store.StoreSnapshot` for the next cycle,
5. optionally folds the store's pending-delta log during the idle window
   (``flush_policy``), so the next cycle's readers find warm caches.

Streaming ingest: under the store's default lazy maintenance, step 4 is
O(delta) per write — appends push onto the pending-delta log and return,
bounding write latency regardless of cache population.  The folding work
moves to step 5 (``flush_policy="idle"``, the default: fold when no reads
remain queued; ``"always"``: fold every cycle; ``"never"``: leave folding
to the next reader's engine-construction barrier) and is charged to the
tenants whose writes queued the deltas.

Isolation: every read in a cycle runs against the cycle's frozen snapshot
— the store's copy-on-write mutation discipline means a write landing
between (or during) cycles can never change what an admitted reader
observes.  Reads admitted in the same cycle as a write therefore see the
pre-write catalog; the write is visible from the next cycle on (snapshot
isolation with writes serialized between read windows).  Draining pending
deltas folds caches without changing data, so it never invalidates the
published snapshot.

Accounting: shared traversals are attributed back to tenants with an exact
integer fair-split (first-come remainder), so per-tenant ``passes`` /
``node_visits`` / view-cache counters in :meth:`FactorizedService.cache_info`
**sum to the store-level totals exactly** — the audit the multi-tenant
story is held to in tests.  Reads are charged the *store-level* counter
deltas of their group (traversal plus any read-barrier fold their engine
triggered); idle-window folds are charged to the writers.

Fault tolerance (see also ``repro.serve.runtime``):

* **Threaded front-end** — :meth:`FactorizedService.start` spawns a drain
  worker plus a low-priority background fold thread;
  :meth:`FactorizedService.stop` resolves or fails every in-flight
  ticket before returning.  Two locks split the scheduler: ``_lock``
  guards the admission queues (held briefly by submitters and the
  cycle's pop), ``_cycle_lock`` serializes whole drain cycles / flushes
  / introspection (lock order: cycle before queue, never the reverse).
* **Deadlines & backpressure** — requests carry optional deadlines
  (expired ones fail with ``ServiceTimeout`` at admission to a cycle,
  without touching the rest of their window); ``max_queue`` bounds
  admission with ``block`` / ``reject`` / ``shed_oldest`` policies.
* **Graceful degradation** — when a merged traversal raises, the window
  is bisected until the poisoned request is isolated: it alone fails
  (and is quarantined in ``cache_info()['quarantined']``), every other
  rider re-runs and gets its answer.  With a ``RetryPolicy``, transient
  faults requeue the lone request with a backoff stamp instead of
  failing it.
* **Fold failures** — an idle-window fold that raises is absorbed (the
  store's drain exception safety already invalidated the covered
  entries and cleared the logs); readers recompute from the merged
  catalog, which mutates only at append time and is never corrupted by
  a failed fold.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.factorize import (
    AggregateBlock,
    AggregateQuery,
    BatchPart,
    Cofactors,
    FactorizedEngine,
    merge_batches,
    scatter_results,
)
from ..core.gd import solve_cofactor
from ..core.relation import Relation
from ..core.scaling import compute_scale_factors, rescale_theta
from ..core.store import Store, StoreSnapshot
from ..core.variable_order import VariableOrder
from .runtime import (
    RetryPolicy,
    RuntimeConfig,
    ServiceOverloaded,
    ServiceRuntime,
    ServiceStopped,
    ServiceTimeout,
)

__all__ = [
    "FactorizedService",
    "ScoreResult",
    "TenantStats",
    "Ticket",
    "TrainResult",
]


@dataclasses.dataclass
class TenantStats:
    """Per-tenant share of the store's cumulative counters.

    Shared coalesced traversals are split across the participating
    requests with an exact integer fair-split, so summing any field over
    all tenants reproduces the store-level total for that field.
    """

    requests: int = 0  # read requests served
    appends: int = 0  # writes applied
    batches: int = 0  # coalesced traversals this tenant rode in
    failures: int = 0  # tickets failed (fault, deadline, shutdown, shed)
    retries: int = 0  # transient-fault requeues under the retry policy
    passes: int = 0
    node_visits: int = 0
    vc_hits: int = 0
    vc_misses: int = 0
    vc_bytes: int = 0  # net view-cache byte growth attributed


@dataclasses.dataclass
class TrainResult:
    """Closed-form ridge fit from coalesced cofactors (θ in original
    units, ordered [intercept, features..., −1 on the label])."""

    theta: np.ndarray
    theta_conv: np.ndarray
    features: List[str]
    label: str

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.theta[0] + x @ self.theta[1 : 1 + x.shape[1]]


@dataclasses.dataclass
class ScoreResult:
    """SSE of a θ vector over the (factorized) join, via the quadratic
    form aᵀCa with a = [θ₀, θ_feats..., −1] — no data rescan."""

    sse: float
    count: float

    @property
    def mse(self) -> float:
        return self.sse / self.count if self.count else float("nan")

    @property
    def rmse(self) -> float:
        return float(np.sqrt(self.mse))


class Ticket:
    """Handle for a queued request: resolved by a drain cycle.

    ``result(timeout=None)`` semantics:

    * resolved → return the value (or raise the recorded error);
    * ``timeout`` given → wait up to that many seconds, then raise
      :class:`~repro.serve.runtime.ServiceTimeout`;
    * no timeout, service running threaded → wait until resolved (the
      runtime's shutdown protocol guarantees resolution — no ticket is
      ever wedged);
    * no timeout, synchronous service → raise ``RuntimeError``
      immediately (waiting would deadlock: nothing else will drain).
    """

    __slots__ = ("_done", "_value", "_error", "_event", "_blocking")

    def __init__(self) -> None:
        self._done = False
        self._value = None
        self._error: Optional[BaseException] = None
        self._event = threading.Event()
        self._blocking = False  # True once a runtime thread owns draining

    @property
    def done(self) -> bool:
        return self._done

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until resolved (or ``timeout`` elapses); True if done."""
        return self._event.wait(timeout)

    def result(self, timeout: Optional[float] = None):
        if not self._done:
            if timeout is not None:
                if not self._event.wait(timeout):
                    raise ServiceTimeout(
                        f"request not served within {timeout:g}s"
                    )
            elif self._blocking:
                self._event.wait()
            else:
                raise RuntimeError(
                    "request not served yet — call FactorizedService."
                    "drain() or run()"
                )
        if self._error is not None:
            raise self._error
        return self._value

    def _resolve(self, value) -> None:
        if self._done:
            return
        self._value = value
        self._done = True
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        if self._done:
            return
        self._error = err
        self._done = True
        self._event.set()


@dataclasses.dataclass
class _Read:
    tenant: str
    kind: str  # "cofactors" | "aggregates" | "train" | "score"
    vorder: VariableOrder
    features: Tuple[str, ...]  # the tenant's requested feature order
    queries: Tuple[AggregateQuery, ...]
    backend: str
    ticket: Ticket
    seq: int  # admission order, the BatchPart rid
    label: Optional[str] = None
    theta: Optional[np.ndarray] = None
    ridge: float = 0.006
    dtype: Optional[object] = None
    deadline: Optional[float] = None  # absolute time.monotonic()
    not_before: float = 0.0  # retry backoff stamp (monotonic)
    attempts: int = 0  # failed attempts so far


@dataclasses.dataclass
class _Write:
    tenant: str
    name: str
    delta: Relation
    ticket: Ticket
    seq: int


def _fair_split(total: int, k: int) -> List[int]:
    """Split an integer across k shares exactly: earlier shares absorb the
    remainder, sum(result) == total (negatives split symmetrically)."""
    if k <= 0:
        return []
    if total < 0:
        return [-s for s in _fair_split(-total, k)]
    base, rem = divmod(total, k)
    return [base + (1 if i < rem else 0) for i in range(k)]


class FactorizedService:
    """Queue-and-drain scheduler over one shared :class:`Store`.

    ``coalesce=False`` runs the same admission/snapshot machinery but
    gives every request its own engine and traversal — the fair baseline
    ``benchmarks/bench_serve.py`` measures the coalescing win against.
    ``window`` caps how many queued reads one drain cycle admits
    (``None`` = drain everything queued at entry).  ``flush_policy``
    schedules the store's pending-delta folds: ``"idle"`` (default) folds
    at the end of a cycle that leaves no reads queued, ``"always"`` folds
    every cycle that applied writes, ``"never"`` leaves folding to the
    read barrier of the next engine construction.

    Robustness knobs (all optional; defaults preserve the synchronous
    PR 6/7 behavior):

    ``max_queue`` bounds total queued requests; when full, admission
    follows ``backpressure``: ``"block"`` waits for capacity (up to
    ``admission_timeout`` seconds, then ``ServiceOverloaded``; ``None``
    waits forever — only sensible with the threaded runtime),
    ``"reject"`` raises ``ServiceOverloaded`` at submit, and
    ``"shed_oldest"`` fails the oldest queued *read*'s ticket to make
    room (queued writes are never shed — data loss is worse than
    latency).  ``retry`` is a :class:`~repro.serve.runtime.RetryPolicy`
    applied to transient read faults.  ``default_deadline`` (seconds)
    applies to reads submitted without an explicit deadline.

    ``start()`` / ``stop()`` attach the threaded runtime
    (:class:`~repro.serve.runtime.ServiceRuntime`): a drain worker plus
    a background fold thread; ``stop()`` resolves or fails every
    in-flight ticket — no ticket is ever left unresolved.
    """

    def __init__(
        self,
        store: Store,
        coalesce: bool = True,
        backend: str = "numpy",
        window: Optional[int] = None,
        flush_policy: str = "idle",
        max_queue: Optional[int] = None,
        backpressure: str = "block",
        admission_timeout: Optional[float] = 30.0,
        retry: Optional[RetryPolicy] = None,
        default_deadline: Optional[float] = None,
    ) -> None:
        if flush_policy not in ("idle", "always", "never"):
            raise ValueError(f"unknown flush_policy {flush_policy!r}")
        if backpressure not in ("block", "reject", "shed_oldest"):
            raise ValueError(f"unknown backpressure {backpressure!r}")
        self.store = store
        self.coalesce = coalesce
        self.backend = backend
        self.window = window
        self.flush_policy = flush_policy
        self.max_queue = max_queue
        self.backpressure = backpressure
        self.admission_timeout = admission_timeout
        self.retry = retry
        self.default_deadline = default_deadline
        self._snapshot: StoreSnapshot = store.snapshot()
        self._reads: Deque[_Read] = deque()
        self._writes: Deque[_Write] = deque()
        self._tenants: Dict[str, TenantStats] = {}
        self._seq = 0
        self._batches = 0  # coalesced traversals run
        self._coalesced_requests = 0  # reads that shared a traversal
        self._writers_since_flush: List[str] = []  # fold-cost attribution
        # queue lock: admission queues + seq + runtime handle.  Held for
        # O(1) critical sections only; condition variable for "block".
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        # cycle lock: serializes drain cycles, flushes, shutdown sweeps,
        # and cache_info() snapshots.  Held across traversals.  Lock
        # order is ALWAYS cycle -> queue.
        self._cycle_lock = threading.RLock()
        # leaf lock for per-tenant counter mutation: taken by drain-side
        # charging AND submitter-side shed accounting; nothing else is
        # ever acquired while holding it.
        self._stats_lock = threading.RLock()
        self._runtime: Optional[ServiceRuntime] = None
        self._accepting = True
        self._quarantined: Deque[Dict[str, object]] = deque(maxlen=64)
        self._retries = 0  # transient-fault requeues (service-wide)
        self._shed = 0  # tickets failed by shed_oldest backpressure
        self._fold_failures = 0  # idle-window folds that raised
        # sanitizer seam (see Store.access_hook): when set, called as
        # hook("FactorizedService._reads", kind) at queue/stats touches.
        self.access_hook: Optional[Callable[[str, str], None]] = None

    # -- request submission ----------------------------------------------------
    def cofactors(
        self,
        tenant: str,
        vorder: VariableOrder,
        features: Sequence[str],
        backend: Optional[str] = None,
        dtype=None,
        deadline: Optional[float] = None,
    ) -> Ticket:
        """Queue an unscaled-cofactors request → ``Cofactors``.
        ``deadline`` (here and on every read submitter) is seconds from
        now; a request still queued when it expires fails with
        ``ServiceTimeout`` instead of running."""
        return self._submit_read(
            tenant,
            "cofactors",
            vorder,
            tuple(features),
            (AggregateQuery("cof", (), 2),),
            backend,
            deadline,
            dtype=dtype,
        )

    def aggregates(
        self,
        tenant: str,
        vorder: VariableOrder,
        features: Sequence[str],
        queries: Sequence[AggregateQuery],
        backend: Optional[str] = None,
        dtype=None,
        deadline: Optional[float] = None,
    ) -> Ticket:
        """Queue a raw aggregate batch → ``{name: AggregateBlock}``."""
        return self._submit_read(
            tenant,
            "aggregates",
            vorder,
            tuple(features),
            tuple(queries),
            backend,
            deadline,
            dtype=dtype,
        )

    def train(
        self,
        tenant: str,
        vorder: VariableOrder,
        features: Sequence[str],
        label: str,
        ridge: float = 0.006,
        backend: Optional[str] = None,
        deadline: Optional[float] = None,
    ) -> Ticket:
        """Queue a closed-form ridge train → ``TrainResult`` (semantics of
        ``linear_regression(..., VERSIONS['closed'], use_cache=True)``:
        unscaled cofactors, lazy §4.2 rescale, exact θ₀ recovery)."""
        return self._submit_read(
            tenant,
            "train",
            vorder,
            tuple(features) + (label,),
            (AggregateQuery("cof", (), 2),),
            backend,
            deadline,
            label=label,
            ridge=ridge,
        )

    def score(
        self,
        tenant: str,
        vorder: VariableOrder,
        features: Sequence[str],
        label: str,
        theta: np.ndarray,
        backend: Optional[str] = None,
        deadline: Optional[float] = None,
    ) -> Ticket:
        """Queue an SSE evaluation of ``theta`` (original units, as
        returned by :meth:`train`) → ``ScoreResult``."""
        return self._submit_read(
            tenant,
            "score",
            vorder,
            tuple(features) + (label,),
            (AggregateQuery("cof", (), 2),),
            backend,
            deadline,
            label=label,
            theta=np.asarray(theta, dtype=np.float64),
        )

    def append(self, tenant: str, name: str, delta: Relation) -> Ticket:
        """Queue a row append, applied after the current read window →
        the merged ``Relation``.  Visible to reads from the next cycle."""
        with self._lock:
            self._admit()
            self._access("FactorizedService._writes", "write")
            ticket = Ticket()
            ticket._blocking = self._runtime is not None
            self._writes.append(
                _Write(tenant, name, delta, ticket, self._next_seq())
            )
        self._notify()
        return ticket

    def _submit_read(
        self,
        tenant: str,
        kind: str,
        vorder: VariableOrder,
        features: Tuple[str, ...],
        queries: Tuple[AggregateQuery, ...],
        backend: Optional[str],
        deadline: Optional[float],
        **extra,
    ) -> Ticket:
        if deadline is None:
            deadline = self.default_deadline
        abs_deadline = (
            time.monotonic() + deadline if deadline is not None else None
        )
        with self._lock:
            self._admit()
            self._access("FactorizedService._reads", "write")
            ticket = Ticket()
            ticket._blocking = self._runtime is not None
            self._reads.append(
                _Read(
                    tenant=tenant,
                    kind=kind,
                    vorder=vorder,
                    features=features,
                    queries=queries,
                    backend=backend or self.backend,
                    ticket=ticket,
                    seq=self._next_seq(),
                    deadline=abs_deadline,
                    **extra,
                )
            )
        self._notify()
        return ticket

    def _admit(self) -> None:
        """Admission control (``self._lock`` held): refuse after stop,
        then apply the backpressure policy while the queue is full."""
        if not self._accepting:
            raise ServiceStopped(
                "service stopped — not accepting new requests"
            )
        if self.max_queue is None:
            return
        start = time.monotonic()
        while len(self._reads) + len(self._writes) >= self.max_queue:
            if self.backpressure == "reject":
                raise ServiceOverloaded(
                    f"admission queue full ({self.max_queue})"
                )
            if self.backpressure == "shed_oldest":
                if not self._reads:
                    # only writes queued: never shed data — refuse instead
                    raise ServiceOverloaded(
                        f"admission queue full ({self.max_queue}) with "
                        "writes only — refusing to shed"
                    )
                self._access("FactorizedService._reads", "write")
                victim = self._reads.popleft()
                victim.ticket._fail(
                    ServiceOverloaded("shed under backpressure")
                )
                self._shed += 1
                with self._stats_lock:
                    self._stats(victim.tenant).failures += 1
                continue
            # "block": wait for a cycle to pop the queues
            remaining = None
            if self.admission_timeout is not None:
                remaining = self.admission_timeout - (
                    time.monotonic() - start
                )
                if remaining <= 0:
                    raise ServiceOverloaded(
                        "admission blocked longer than "
                        f"{self.admission_timeout:g}s"
                    )
            self._not_full.wait(remaining)
            if not self._accepting:
                raise ServiceStopped(
                    "service stopped — not accepting new requests"
                )

    def _notify(self) -> None:
        # lockcheck: lock-free pointer read of _runtime is the design —
        # stop() nulls it under the lock, a stale non-None wakes an already
        # stopping runtime harmlessly.
        rt = self._runtime
        if rt is not None:
            rt.notify()

    def _access(self, field: str, kind: str) -> None:
        """Sanitizer seam twin of ``Store._access`` (no-op uninstalled)."""
        hook = self.access_hook
        if hook is not None:
            hook(field, kind)

    def _next_seq(self) -> int:
        self._access("FactorizedService._seq", "write")
        self._seq += 1
        return self._seq

    def _stats(self, tenant: str) -> TenantStats:
        with self._stats_lock:
            self._access("FactorizedService._tenants", "write")
            st = self._tenants.get(tenant)
            if st is None:
                st = self._tenants[tenant] = TenantStats()
            return st

    # -- drain cycle -----------------------------------------------------------
    def drain(self) -> int:
        """Serve one cycle: a window of queued reads against the current
        snapshot (coalesced per engine group), then all queued writes,
        then publish a fresh snapshot.  Returns requests completed.
        Thread-safe: cycles are serialized, the queue lock is held only
        while popping the window."""
        with self._cycle_lock:
            return self._drain_cycle()

    def _drain_cycle(self) -> int:
        now = time.monotonic()
        expired: List[_Read] = []
        reads: List[_Read] = []
        with self._lock:
            self._access("FactorizedService._reads", "write")
            self._access("FactorizedService._writes", "write")
            take = len(self._reads) if self.window is None else self.window
            deferred: List[_Read] = []
            while self._reads and len(reads) < take:
                r = self._reads.popleft()
                if r.deadline is not None and now >= r.deadline:
                    expired.append(r)
                elif r.not_before > now:
                    deferred.append(r)  # retry backoff not elapsed yet
                else:
                    reads.append(r)
            # deferred retries keep their queue position, in order
            for r in reversed(deferred):
                self._reads.appendleft(r)
            writes = list(self._writes)
            self._writes.clear()
            self._not_full.notify_all()

        done = 0
        # an expired deadline fails ITS ticket only — the rest of the
        # window runs untouched
        for r in expired:
            self._fail_read(
                r,
                ServiceTimeout(
                    f"deadline expired before service (tenant {r.tenant!r})"
                ),
                quarantine=False,
            )
            done += 1
        # engine group = everything one traversal can legally share
        groups: Dict[tuple, List[_Read]] = {}
        for r in reads:
            dt = np.dtype(r.dtype).name if r.dtype is not None else None
            gkey = (r.vorder.signature(), r.backend, dt)
            groups.setdefault(gkey, []).append(r)
        for members in groups.values():
            batches = (
                [members] if self.coalesce else [[r] for r in members]
            )
            for batch in batches:
                done += self._run_batch_group(batch)

        for w in writes:
            self._apply_write(w)
            done += 1
        if writes:
            self._access("FactorizedService._snapshot", "write")
            self._snapshot = self.store.snapshot()
        with self._lock:
            idle = not self._reads
        if self._writers_since_flush and (
            self.flush_policy == "always"
            or (self.flush_policy == "idle" and idle)
        ):
            self._flush_pending()
        return done

    def pending(self) -> int:
        """Queued (unserved) requests right now — reads plus writes."""
        with self._lock:
            return len(self._reads) + len(self._writes)

    def fold_debt_rows(self) -> int:
        """Pending delta rows in the store's log — the background fold
        thread's should-I-run probe (0 for stores without a log)."""
        log = getattr(self.store, "_delta_log", None)
        return log.debt()[1] if log is not None else 0

    def run(self) -> int:
        """Drain until both queues are empty; returns requests completed.
        Waits out retry backoffs (a cycle that completes nothing while
        work is queued means every queued read is a deferred retry)."""
        total = 0
        while self.pending():
            n = self.drain()
            total += n
            if n == 0:
                time.sleep(0.001)
        return total

    def flush(self) -> Dict[str, int]:
        """Fold the store's pending-delta log NOW (between drain cycles) —
        the explicit idle-window pass, also what the background fold
        thread calls.  Returns the store's drain stats; fold cost is
        charged to the writers whose appends queued the deltas."""
        with self._cycle_lock:
            return self._flush_pending()

    # -- threaded runtime ------------------------------------------------------
    def start(
        self, config: Optional[RuntimeConfig] = None
    ) -> "FactorizedService":
        """Attach the threaded runtime: a drain worker serving queued
        requests as they arrive plus a low-priority fold thread servicing
        delta-log debt in idle windows.  Returns ``self`` (chainable)."""
        with self._lock:
            if self._runtime is not None:
                raise RuntimeError("service already started")
            self._accepting = True
            rt = self._runtime = ServiceRuntime(self, config)
        rt.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = None):
        """Clean shutdown.  Stops admission immediately; with
        ``drain=True`` (default) serves what is already queued within
        ``timeout`` seconds (runtime default 30).  ANY request still
        queued afterwards — drain disabled, budget exhausted, or retries
        still deferred — fails with ``ServiceStopped``.  Every ticket
        ever admitted is resolved or failed when this returns.  Safe to
        call on a never-started service (drains synchronously)."""
        with self._lock:
            self._accepting = False
            rt = self._runtime
            self._runtime = None
            # unblock submitters parked on backpressure so they see the
            # stop instead of waiting out their admission timeout
            self._not_full.notify_all()
        if rt is not None:
            rt.stop(drain=drain, timeout=timeout)
            if rt.errors:
                # Under the cycle lock like every other quarantine write: a
                # drain cycle the runtime failed to join could still be
                # appending bisection results.
                with self._cycle_lock:
                    for err in rt.errors:
                        self._quarantined.append(
                            {"kind": "runtime", "error": repr(err)}
                        )
        elif drain:
            self.run()
        self._fail_pending(
            ServiceStopped("service stopped before the request was served")
        )

    @property
    def running(self) -> bool:
        return self._runtime is not None

    def _fail_pending(self, err: Exception) -> None:
        """Fail every queued request (shutdown sweep).  Takes the cycle
        lock so it cannot race an in-flight cycle's window."""
        with self._cycle_lock:
            with self._lock:
                self._access("FactorizedService._reads", "write")
                self._access("FactorizedService._writes", "write")
                items = list(self._reads) + list(self._writes)
                self._reads.clear()
                self._writes.clear()
                self._not_full.notify_all()
            for it in items:
                it.ticket._fail(err)
                with self._stats_lock:
                    self._stats(it.tenant).failures += 1

    # -- internals -------------------------------------------------------------
    def _run_batch_group(self, batch: List[_Read]) -> int:
        parts = [
            BatchPart(rid=r.seq, features=r.features, queries=r.queries)
            for r in batch
        ]
        # charge by store-level counter deltas, captured BEFORE engine
        # construction: the engine's init is the lazy read barrier and may
        # fold pending deltas, work that lands in store counters only.
        store = self.store
        vc = store.view_cache
        before = (store.passes, store.node_visits, vc.hits, vc.misses, vc.bytes)
        tenants = [r.tenant for r in batch]
        self._access("FactorizedService._snapshot", "read")
        try:
            merged = merge_batches(parts)
            first = batch[0]
            dtype = np.dtype(first.dtype) if first.dtype is not None else None
            engine = FactorizedEngine(
                self._snapshot,
                first.vorder,
                merged.features,
                backend=first.backend,
                dtype=dtype,
            )
            results = engine.run_batch(merged.queries)
            per_rid = scatter_results(merged, parts, results)
        except Exception as err:
            # whatever partial work happened is still real store work —
            # charge it to this sub-batch before degrading
            self._charge_store_delta(tenants, before)
            if len(batch) > 1:
                # graceful degradation: bisect the window to isolate the
                # poisoned request — its co-riders must still get answers
                mid = len(batch) // 2
                return self._run_batch_group(
                    batch[:mid]
                ) + self._run_batch_group(batch[mid:])
            return self._fail_or_retry(batch[0], err)
        self._charge_store_delta(tenants, before)
        if len(batch) > 1:
            self._batches += 1
            self._coalesced_requests += len(batch)
        for r in batch:
            with self._stats_lock:
                st = self._stats(r.tenant)
                st.requests += 1
                st.batches += 1
            try:
                r.ticket._resolve(self._finish(r, per_rid[r.seq]))
            except Exception as err:
                # per-request post-processing (solve/score) failed: the
                # traversal was healthy, so no bisect/retry — just fail
                self._fail_read(r, err, quarantine=False)
        return len(batch)

    def _fail_or_retry(self, r: _Read, err: BaseException) -> int:
        """A single isolated request failed.  Transient fault + retry
        policy + deadline headroom → requeue with a backoff stamp (counts
        as 0 completed); otherwise fail + quarantine the request."""
        policy = self.retry
        now = time.monotonic()
        if (
            policy is not None
            and isinstance(err, policy.retry_on)
            and r.attempts + 1 < policy.max_attempts
            and (r.deadline is None or now < r.deadline)
        ):
            r.attempts += 1
            r.not_before = now + policy.delay(r.attempts)
            with self._stats_lock:
                self._stats(r.tenant).retries += 1
            self._retries += 1
            with self._lock:
                self._reads.append(r)
            self._notify()
            return 0
        self._fail_read(r, err, quarantine=True)
        return 1

    def _fail_read(
        self, r: _Read, err: BaseException, quarantine: bool
    ) -> None:
        r.ticket._fail(err)
        with self._stats_lock:
            self._stats(r.tenant).failures += 1
        if quarantine:
            self._quarantined.append(
                {
                    "kind": r.kind,
                    "tenant": r.tenant,
                    "seq": r.seq,
                    "attempts": r.attempts + 1,
                    "error": repr(err),
                }
            )

    def _flush_pending(self) -> Dict[str, int]:
        """Fold pending deltas, charging the fold across the writers that
        queued them (all known tenants as fallback).  Runs under the
        cycle lock — called from inside a cycle or the public
        :meth:`flush`.

        A fold that raises is absorbed here: the store's drain exception
        safety has already invalidated the covered entries and cleared
        the logs, so the catalog stays correct and the next reader
        recomputes cold.  The failure is surfaced via
        ``cache_info()['fold_failures']`` and the quarantine log."""
        store = self.store
        flush = getattr(store, "flush", None)
        if not callable(flush):
            self._writers_since_flush.clear()
            return {"relations": 0, "rows": 0, "appends": 0}
        payers = list(self._writers_since_flush)
        if not payers:
            with self._stats_lock:  # _tenants is stats-lock state
                payers = sorted(self._tenants)
        vc = store.view_cache
        before = (store.passes, store.node_visits, vc.hits, vc.misses, vc.bytes)
        try:
            stats = flush()
        except Exception as err:
            self._fold_failures += 1
            self._quarantined.append(
                {"kind": "fold", "tenants": payers, "error": repr(err)}
            )
            stats = {"relations": 0, "rows": 0, "appends": 0}
        if payers:
            self._charge_store_delta(payers, before)
        self._writers_since_flush.clear()
        return stats

    def _charge_store_delta(
        self, tenants: List[str], before: Tuple[int, int, int, int, int]
    ) -> None:
        """Fair-split the store-level counter growth since ``before``
        across ``tenants``."""
        store = self.store
        vc = store.view_cache
        self._charge(
            tenants,
            passes=store.passes - before[0],
            node_visits=store.node_visits - before[1],
            vc_hits=vc.hits - before[2],
            vc_misses=vc.misses - before[3],
            vc_bytes=vc.bytes - before[4],
        )

    def _charge(self, tenants: List[str], **counters: int) -> None:
        """Attribute one shared traversal's counters across its riders —
        exact integer fair-split in admission order, so per-tenant sums
        equal the store-level deltas to the unit."""
        k = len(tenants)
        with self._stats_lock:
            for field, total in counters.items():
                for tenant, share in zip(tenants, _fair_split(int(total), k)):
                    st = self._stats(tenant)
                    setattr(st, field, getattr(st, field) + share)

    def _finish(self, r: _Read, blocks: Dict[str, AggregateBlock]):
        if r.kind == "aggregates":
            return blocks
        blk = blocks["cof"]
        if blk.num_groups != 1:
            raise AssertionError(
                f"root view must have exactly one row, got {blk.num_groups}"
            )
        cof = Cofactors(
            count=float(blk.count[0]),
            lin=np.asarray(blk.lin[0], dtype=np.float64),
            quad=np.asarray(blk.quad[0], dtype=np.float64),
            features=list(r.features),
        )
        if r.kind == "cofactors":
            return cof
        feats = [f for f in r.features if f != r.label]
        if r.kind == "score":
            a = r.theta
            if a.shape[0] != len(r.features) + 1:
                raise ValueError(
                    f"theta has {a.shape[0]} entries, expected "
                    f"{len(r.features) + 1} ([intercept] + features + label)"
                )
            mat = cof.matrix()
            return ScoreResult(sse=float(a @ mat @ a), count=cof.count)
        # train: the warm-retrain semantics of linear_regression(
        # VERSIONS["closed"], use_cache=True) — unscaled cofactors +
        # lazy rescale + closed-form solve + exact θ₀ recovery.
        factors = compute_scale_factors(self._snapshot, feats, r.label)
        theta_conv = solve_cofactor(
            cof.rescale(factors).matrix(), ridge=r.ridge
        )
        theta = rescale_theta(theta_conv, factors, mode="exact")
        return TrainResult(
            theta=theta,
            theta_conv=theta_conv,
            features=feats,
            label=r.label,
        )

    def _apply_write(self, w: _Write) -> None:
        store = self.store
        vc = store.view_cache
        before = (store.passes, store.node_visits, vc.hits, vc.misses, vc.bytes)
        failed = None
        try:
            merged = store.append(w.name, w.delta)
        except Exception as err:
            failed = err
            w.ticket._fail(err)
        else:
            w.ticket._resolve(merged)
            # lazy maintenance: this tenant's delta may now be pending —
            # remember who to charge when the idle-window fold runs
            self._writers_since_flush.append(w.tenant)
        with self._stats_lock:
            st = self._stats(w.tenant)
            st.appends += 1
            if failed is not None:
                st.failures += 1
            # delta maintenance ran on the writer's behalf — attribute it
            # whole
            st.passes += store.passes - before[0]
            st.node_visits += store.node_visits - before[1]
            st.vc_hits += vc.hits - before[2]
            st.vc_misses += vc.misses - before[3]
            st.vc_bytes += vc.bytes - before[4]

    # -- introspection ---------------------------------------------------------
    def cache_info(self) -> Dict[str, object]:
        """Store-level ``cache_info`` plus the service's per-tenant shares
        (``tenants[name]`` sums to the store totals), coalescing counters,
        and robustness counters.  Snapshot-under-lock: taken between
        cycles (cycle lock), so store totals and per-tenant shares are
        mutually consistent even while worker threads run."""
        with self._cycle_lock:
            info: Dict[str, object] = dict(self.store.cache_info())
            with self._stats_lock:
                info["tenants"] = {
                    name: dataclasses.asdict(st)
                    for name, st in sorted(self._tenants.items())
                }
            info["coalesced_batches"] = self._batches
            info["coalesced_requests"] = self._coalesced_requests
            with self._lock:
                self._access("FactorizedService._reads", "read")
                info["queued_reads"] = len(self._reads)
                info["queued_writes"] = len(self._writes)
            info["running"] = self.running
            info["retries"] = self._retries
            info["shed"] = self._shed
            info["fold_failures"] = self._fold_failures
            info["quarantined"] = len(self._quarantined)
            return info

    def quarantined(self) -> List[Dict[str, object]]:
        """Recent quarantine records (poisoned requests isolated by the
        window bisection, failed folds, runtime errors) — newest last."""
        with self._cycle_lock:
            return list(self._quarantined)
