"""Multi-tenant factorized training service: one shared store, coalesced
aggregate traversals, snapshot-isolated reads.

The paper's 100x comes from sharing aggregate work *within* one training
run; AC/DC (Abo Khamis et al. 2018) shares it within one optimization
batch.  This layer shares it across **concurrent tenants**: requests
(train / score / cofactor / aggregate) from different clients against one
:class:`repro.core.store.Store` queue up, and each drain cycle

1. groups queued reads by (variable-order signature, backend, dtype),
2. coalesces every group with :func:`repro.core.factorize.merge_batches` —
   feature lists union, same-GROUP-BY queries dedupe at the max degree —
   into ONE ``run_batch`` traversal per group,
3. scatters the shared blocks back per request
   (:func:`repro.core.factorize.scatter_results`: pure slicing, Prop. 4.1
   projection commutativity), then finishes each request's own
   post-processing (closed-form solve for train, SSE quadratic form for
   score),
4. applies queued ``append`` writes and publishes a fresh
   :class:`repro.core.store.StoreSnapshot` for the next cycle,
5. optionally folds the store's pending-delta log during the idle window
   (``flush_policy``), so the next cycle's readers find warm caches.

Streaming ingest: under the store's default lazy maintenance, step 4 is
O(delta) per write — appends push onto the pending-delta log and return,
bounding write latency regardless of cache population.  The folding work
moves to step 5 (``flush_policy="idle"``, the default: fold when no reads
remain queued; ``"always"``: fold every cycle; ``"never"``: leave folding
to the next reader's engine-construction barrier) and is charged to the
tenants whose writes queued the deltas.

Isolation: every read in a cycle runs against the cycle's frozen snapshot
— the store's copy-on-write mutation discipline means a write landing
between (or during) cycles can never change what an admitted reader
observes.  Reads admitted in the same cycle as a write therefore see the
pre-write catalog; the write is visible from the next cycle on (snapshot
isolation with writes serialized between read windows).  Draining pending
deltas folds caches without changing data, so it never invalidates the
published snapshot.

Accounting: shared traversals are attributed back to tenants with an exact
integer fair-split (first-come remainder), so per-tenant ``passes`` /
``node_visits`` / view-cache counters in :meth:`FactorizedService.cache_info`
**sum to the store-level totals exactly** — the audit the multi-tenant
story is held to in tests.  Reads are charged the *store-level* counter
deltas of their group (traversal plus any read-barrier fold their engine
triggered); idle-window folds are charged to the writers.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.factorize import (
    AggregateBlock,
    AggregateQuery,
    BatchPart,
    Cofactors,
    FactorizedEngine,
    merge_batches,
    scatter_results,
)
from ..core.gd import solve_cofactor
from ..core.relation import Relation
from ..core.scaling import compute_scale_factors, rescale_theta
from ..core.store import Store, StoreSnapshot
from ..core.variable_order import VariableOrder

__all__ = [
    "FactorizedService",
    "ScoreResult",
    "TenantStats",
    "Ticket",
    "TrainResult",
]


@dataclasses.dataclass
class TenantStats:
    """Per-tenant share of the store's cumulative counters.

    Shared coalesced traversals are split across the participating
    requests with an exact integer fair-split, so summing any field over
    all tenants reproduces the store-level total for that field.
    """

    requests: int = 0  # read requests served
    appends: int = 0  # writes applied
    batches: int = 0  # coalesced traversals this tenant rode in
    passes: int = 0
    node_visits: int = 0
    vc_hits: int = 0
    vc_misses: int = 0
    vc_bytes: int = 0  # net view-cache byte growth attributed


@dataclasses.dataclass
class TrainResult:
    """Closed-form ridge fit from coalesced cofactors (θ in original
    units, ordered [intercept, features..., −1 on the label])."""

    theta: np.ndarray
    theta_conv: np.ndarray
    features: List[str]
    label: str

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.theta[0] + x @ self.theta[1 : 1 + x.shape[1]]


@dataclasses.dataclass
class ScoreResult:
    """SSE of a θ vector over the (factorized) join, via the quadratic
    form aᵀCa with a = [θ₀, θ_feats..., −1] — no data rescan."""

    sse: float
    count: float

    @property
    def mse(self) -> float:
        return self.sse / self.count if self.count else float("nan")

    @property
    def rmse(self) -> float:
        return float(np.sqrt(self.mse))


class Ticket:
    """Handle for a queued request: resolved during the next drain cycle."""

    __slots__ = ("_done", "_value", "_error")

    def __init__(self) -> None:
        self._done = False
        self._value = None
        self._error: Optional[BaseException] = None

    @property
    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            raise RuntimeError(
                "request not served yet — call FactorizedService.drain() "
                "or run()"
            )
        if self._error is not None:
            raise self._error
        return self._value

    def _resolve(self, value) -> None:
        self._value = value
        self._done = True

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._done = True


@dataclasses.dataclass
class _Read:
    tenant: str
    kind: str  # "cofactors" | "aggregates" | "train" | "score"
    vorder: VariableOrder
    features: Tuple[str, ...]  # the tenant's requested feature order
    queries: Tuple[AggregateQuery, ...]
    backend: str
    ticket: Ticket
    seq: int  # admission order, the BatchPart rid
    label: Optional[str] = None
    theta: Optional[np.ndarray] = None
    ridge: float = 0.006
    dtype: Optional[object] = None


@dataclasses.dataclass
class _Write:
    tenant: str
    name: str
    delta: Relation
    ticket: Ticket
    seq: int


def _fair_split(total: int, k: int) -> List[int]:
    """Split an integer across k shares exactly: earlier shares absorb the
    remainder, sum(result) == total (negatives split symmetrically)."""
    if k <= 0:
        return []
    if total < 0:
        return [-s for s in _fair_split(-total, k)]
    base, rem = divmod(total, k)
    return [base + (1 if i < rem else 0) for i in range(k)]


class FactorizedService:
    """Queue-and-drain scheduler over one shared :class:`Store`.

    ``coalesce=False`` runs the same admission/snapshot machinery but
    gives every request its own engine and traversal — the fair baseline
    ``benchmarks/bench_serve.py`` measures the coalescing win against.
    ``window`` caps how many queued reads one drain cycle admits
    (``None`` = drain everything queued at entry).  ``flush_policy``
    schedules the store's pending-delta folds: ``"idle"`` (default) folds
    at the end of a cycle that leaves no reads queued, ``"always"`` folds
    every cycle that applied writes, ``"never"`` leaves folding to the
    read barrier of the next engine construction.
    """

    def __init__(
        self,
        store: Store,
        coalesce: bool = True,
        backend: str = "numpy",
        window: Optional[int] = None,
        flush_policy: str = "idle",
    ) -> None:
        if flush_policy not in ("idle", "always", "never"):
            raise ValueError(f"unknown flush_policy {flush_policy!r}")
        self.store = store
        self.coalesce = coalesce
        self.backend = backend
        self.window = window
        self.flush_policy = flush_policy
        self._snapshot: StoreSnapshot = store.snapshot()
        self._reads: Deque[_Read] = deque()
        self._writes: Deque[_Write] = deque()
        self._tenants: Dict[str, TenantStats] = {}
        self._seq = 0
        self._batches = 0  # coalesced traversals run
        self._coalesced_requests = 0  # reads that shared a traversal
        self._writers_since_flush: List[str] = []  # fold-cost attribution
        self._lock = threading.Lock()

    # -- request submission ----------------------------------------------------
    def cofactors(
        self,
        tenant: str,
        vorder: VariableOrder,
        features: Sequence[str],
        backend: Optional[str] = None,
        dtype=None,
    ) -> Ticket:
        """Queue an unscaled-cofactors request → ``Cofactors``."""
        return self._submit_read(
            tenant,
            "cofactors",
            vorder,
            tuple(features),
            (AggregateQuery("cof", (), 2),),
            backend,
            dtype=dtype,
        )

    def aggregates(
        self,
        tenant: str,
        vorder: VariableOrder,
        features: Sequence[str],
        queries: Sequence[AggregateQuery],
        backend: Optional[str] = None,
        dtype=None,
    ) -> Ticket:
        """Queue a raw aggregate batch → ``{name: AggregateBlock}``."""
        return self._submit_read(
            tenant,
            "aggregates",
            vorder,
            tuple(features),
            tuple(queries),
            backend,
            dtype=dtype,
        )

    def train(
        self,
        tenant: str,
        vorder: VariableOrder,
        features: Sequence[str],
        label: str,
        ridge: float = 0.006,
        backend: Optional[str] = None,
    ) -> Ticket:
        """Queue a closed-form ridge train → ``TrainResult`` (semantics of
        ``linear_regression(..., VERSIONS['closed'], use_cache=True)``:
        unscaled cofactors, lazy §4.2 rescale, exact θ₀ recovery)."""
        return self._submit_read(
            tenant,
            "train",
            vorder,
            tuple(features) + (label,),
            (AggregateQuery("cof", (), 2),),
            backend,
            label=label,
            ridge=ridge,
        )

    def score(
        self,
        tenant: str,
        vorder: VariableOrder,
        features: Sequence[str],
        label: str,
        theta: np.ndarray,
        backend: Optional[str] = None,
    ) -> Ticket:
        """Queue an SSE evaluation of ``theta`` (original units, as
        returned by :meth:`train`) → ``ScoreResult``."""
        return self._submit_read(
            tenant,
            "score",
            vorder,
            tuple(features) + (label,),
            (AggregateQuery("cof", (), 2),),
            backend,
            label=label,
            theta=np.asarray(theta, dtype=np.float64),
        )

    def append(self, tenant: str, name: str, delta: Relation) -> Ticket:
        """Queue a row append, applied after the current read window →
        the merged ``Relation``.  Visible to reads from the next cycle."""
        with self._lock:
            ticket = Ticket()
            self._writes.append(
                _Write(tenant, name, delta, ticket, self._next_seq())
            )
            return ticket

    def _submit_read(
        self,
        tenant: str,
        kind: str,
        vorder: VariableOrder,
        features: Tuple[str, ...],
        queries: Tuple[AggregateQuery, ...],
        backend: Optional[str],
        **extra,
    ) -> Ticket:
        with self._lock:
            ticket = Ticket()
            self._reads.append(
                _Read(
                    tenant=tenant,
                    kind=kind,
                    vorder=vorder,
                    features=features,
                    queries=queries,
                    backend=backend or self.backend,
                    ticket=ticket,
                    seq=self._next_seq(),
                    **extra,
                )
            )
            return ticket

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _stats(self, tenant: str) -> TenantStats:
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = TenantStats()
        return st

    # -- drain cycle -----------------------------------------------------------
    def drain(self) -> int:
        """Serve one cycle: a window of queued reads against the current
        snapshot (coalesced per engine group), then all queued writes,
        then publish a fresh snapshot.  Returns requests completed."""
        with self._lock:
            take = len(self._reads) if self.window is None else self.window
            reads = [
                self._reads.popleft()
                for _ in range(min(take, len(self._reads)))
            ]
            writes = list(self._writes)
            self._writes.clear()

            done = 0
            # engine group = everything one traversal can legally share
            groups: Dict[tuple, List[_Read]] = {}
            for r in reads:
                dt = np.dtype(r.dtype).name if r.dtype is not None else None
                gkey = (r.vorder.signature(), r.backend, dt)
                groups.setdefault(gkey, []).append(r)
            for members in groups.values():
                batches = (
                    [members] if self.coalesce else [[r] for r in members]
                )
                for batch in batches:
                    done += self._run_batch_group(batch)

            for w in writes:
                self._apply_write(w)
                done += 1
            if writes:
                self._snapshot = self.store.snapshot()
            if self._writers_since_flush and (
                self.flush_policy == "always"
                or (self.flush_policy == "idle" and not self._reads)
            ):
                self._flush_pending()
            return done

    def run(self) -> int:
        """Drain until both queues are empty; returns requests completed."""
        total = 0
        while self._reads or self._writes:
            total += self.drain()
        return total

    def flush(self) -> Dict[str, int]:
        """Fold the store's pending-delta log NOW (between drain cycles) —
        the explicit idle-window pass.  Returns the store's drain stats;
        fold cost is charged to the writers whose appends queued the
        deltas."""
        with self._lock:
            return self._flush_pending()

    # -- internals -------------------------------------------------------------
    def _run_batch_group(self, batch: List[_Read]) -> int:
        parts = [
            BatchPart(rid=r.seq, features=r.features, queries=r.queries)
            for r in batch
        ]
        # charge by store-level counter deltas, captured BEFORE engine
        # construction: the engine's init is the lazy read barrier and may
        # fold pending deltas, work that lands in store counters only.
        store = self.store
        vc = store.view_cache
        before = (store.passes, store.node_visits, vc.hits, vc.misses, vc.bytes)
        tenants = [r.tenant for r in batch]
        try:
            merged = merge_batches(parts)
            first = batch[0]
            dtype = np.dtype(first.dtype) if first.dtype is not None else None
            engine = FactorizedEngine(
                self._snapshot,
                first.vorder,
                merged.features,
                backend=first.backend,
                dtype=dtype,
            )
            results = engine.run_batch(merged.queries)
            per_rid = scatter_results(merged, parts, results)
        except Exception as err:
            self._charge_store_delta(tenants, before)
            for r in batch:
                r.ticket._fail(err)
            return len(batch)
        self._charge_store_delta(tenants, before)
        if len(batch) > 1:
            self._batches += 1
            self._coalesced_requests += len(batch)
        for r in batch:
            st = self._stats(r.tenant)
            st.requests += 1
            st.batches += 1
            try:
                r.ticket._resolve(self._finish(r, per_rid[r.seq]))
            except Exception as err:
                r.ticket._fail(err)
        return len(batch)

    def _flush_pending(self) -> Dict[str, int]:
        """Fold pending deltas, charging the fold across the writers that
        queued them (all known tenants as fallback).  Lock-free — called
        from inside :meth:`drain` which already holds the lock; the public
        :meth:`flush` wraps it."""
        store = self.store
        flush = getattr(store, "flush", None)
        if not callable(flush):
            self._writers_since_flush.clear()
            return {"relations": 0, "rows": 0, "appends": 0}
        payers = list(self._writers_since_flush) or sorted(self._tenants)
        vc = store.view_cache
        before = (store.passes, store.node_visits, vc.hits, vc.misses, vc.bytes)
        stats = flush()
        if payers:
            self._charge_store_delta(payers, before)
        self._writers_since_flush.clear()
        return stats

    def _charge_store_delta(
        self, tenants: List[str], before: Tuple[int, int, int, int, int]
    ) -> None:
        """Fair-split the store-level counter growth since ``before``
        across ``tenants``."""
        store = self.store
        vc = store.view_cache
        self._charge(
            tenants,
            passes=store.passes - before[0],
            node_visits=store.node_visits - before[1],
            vc_hits=vc.hits - before[2],
            vc_misses=vc.misses - before[3],
            vc_bytes=vc.bytes - before[4],
        )

    def _charge(self, tenants: List[str], **counters: int) -> None:
        """Attribute one shared traversal's counters across its riders —
        exact integer fair-split in admission order, so per-tenant sums
        equal the store-level deltas to the unit."""
        k = len(tenants)
        for field, total in counters.items():
            for tenant, share in zip(tenants, _fair_split(int(total), k)):
                st = self._stats(tenant)
                setattr(st, field, getattr(st, field) + share)

    def _finish(self, r: _Read, blocks: Dict[str, AggregateBlock]):
        if r.kind == "aggregates":
            return blocks
        blk = blocks["cof"]
        if blk.num_groups != 1:
            raise AssertionError(
                f"root view must have exactly one row, got {blk.num_groups}"
            )
        cof = Cofactors(
            count=float(blk.count[0]),
            lin=np.asarray(blk.lin[0], dtype=np.float64),
            quad=np.asarray(blk.quad[0], dtype=np.float64),
            features=list(r.features),
        )
        if r.kind == "cofactors":
            return cof
        feats = [f for f in r.features if f != r.label]
        if r.kind == "score":
            a = r.theta
            if a.shape[0] != len(r.features) + 1:
                raise ValueError(
                    f"theta has {a.shape[0]} entries, expected "
                    f"{len(r.features) + 1} ([intercept] + features + label)"
                )
            mat = cof.matrix()
            return ScoreResult(sse=float(a @ mat @ a), count=cof.count)
        # train: the warm-retrain semantics of linear_regression(
        # VERSIONS["closed"], use_cache=True) — unscaled cofactors +
        # lazy rescale + closed-form solve + exact θ₀ recovery.
        factors = compute_scale_factors(self._snapshot, feats, r.label)
        theta_conv = solve_cofactor(
            cof.rescale(factors).matrix(), ridge=r.ridge
        )
        theta = rescale_theta(theta_conv, factors, mode="exact")
        return TrainResult(
            theta=theta,
            theta_conv=theta_conv,
            features=feats,
            label=r.label,
        )

    def _apply_write(self, w: _Write) -> None:
        store = self.store
        vc = store.view_cache
        before = (store.passes, store.node_visits, vc.hits, vc.misses, vc.bytes)
        try:
            merged = store.append(w.name, w.delta)
        except Exception as err:
            w.ticket._fail(err)
        else:
            w.ticket._resolve(merged)
            # lazy maintenance: this tenant's delta may now be pending —
            # remember who to charge when the idle-window fold runs
            self._writers_since_flush.append(w.tenant)
        st = self._stats(w.tenant)
        st.appends += 1
        # delta maintenance ran on the writer's behalf — attribute it whole
        st.passes += store.passes - before[0]
        st.node_visits += store.node_visits - before[1]
        st.vc_hits += vc.hits - before[2]
        st.vc_misses += vc.misses - before[3]
        st.vc_bytes += vc.bytes - before[4]

    # -- introspection ---------------------------------------------------------
    def cache_info(self) -> Dict[str, object]:
        """Store-level ``cache_info`` plus the service's per-tenant shares
        (``tenants[name]`` sums to the store totals) and coalescing
        counters."""
        info: Dict[str, object] = dict(self.store.cache_info())
        info["tenants"] = {
            name: dataclasses.asdict(st)
            for name, st in sorted(self._tenants.items())
        }
        info["coalesced_batches"] = self._batches
        info["coalesced_requests"] = self._coalesced_requests
        info["queued_reads"] = len(self._reads)
        info["queued_writes"] = len(self._writes)
        return info
