"""Batched serving engine: continuous batching over prefill + decode steps.

The step functions come from ``repro.models.model`` (``prefill`` /
``decode_step``); this module adds the scheduling layer a serving deployment
needs:

* **slot-based continuous batching** — a fixed decode batch of ``slots``;
  finished sequences free their slot, queued requests are prefillied into
  the vacant slot's cache lines (cache surgery via ``jax.tree.map`` on the
  batch axis);
* **two compiled programs** only (one prefill shape, one decode shape) so
  serving never recompiles mid-flight — requests are right-padded to the
  prefill length;
* greedy / temperature sampling;
* per-request max-token and EOS stopping.

On a mesh the same engine runs with the decode batch sharded over ``data``
and the cache sequence-sharded over ``model`` (SERVE_RULES); the CPU tests
run it unsharded.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as model_lib

__all__ = ["Request", "Result", "ServeConfig", "Engine"]


@dataclasses.dataclass
class Request:
    uid: int
    tokens: List[int]  # prompt
    max_new_tokens: int = 16
    eos: Optional[int] = None


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List[int]  # generated continuation
    prompt_len: int
    latency_s: float


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4  # decode batch size
    prefill_len: int = 64  # compiled prefill shape (prompts right-padded)
    max_len: int = 256  # KV-cache capacity
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


class Engine:
    """Single-program continuous-batching engine around one model."""

    def __init__(self, params, cfg, scfg: ServeConfig) -> None:
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self._queue: Deque[Request] = deque()
        self._results: List[Result] = []
        self._rng = jax.random.key(scfg.seed)

        # slot bookkeeping (host side)
        self._slot_req: List[Optional[Request]] = [None] * scfg.slots
        self._slot_pos: np.ndarray = np.zeros(scfg.slots, np.int32)
        self._slot_new: List[List[int]] = [[] for _ in range(scfg.slots)]
        self._slot_t0: List[float] = [0.0] * scfg.slots
        self._last_tok = np.zeros(scfg.slots, np.int32)

        self.cache = model_lib.init_cache(cfg, scfg.slots, scfg.max_len)

        # SSM/hybrid mixers carry recurrent state: right-padding a prompt
        # would push pad tokens through the recurrence, so those archs
        # prefill at the exact prompt length (one compile per distinct
        # length); attention-only archs use the single padded prefill shape
        # (pad KV entries are masked until overwritten by real tokens).
        self.exact_prefill = any(b.mixer != "attn" for b in cfg.pattern)

        self._prefill_one = jax.jit(
            lambda p, b: model_lib.prefill(p, b, cfg, scfg.max_len)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: model_lib.decode_step(p, t, c, pos, cfg)
        )

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def run(self) -> List[Result]:
        """Drive to completion; returns results in finish order."""
        while self._queue or any(r is not None for r in self._slot_req):
            self._admit()
            self._decode_tick()
        out, self._results = self._results, []
        return out

    # -- internals -----------------------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.scfg.slots):
            if self._slot_req[slot] is not None or not self._queue:
                continue
            req = self._queue.popleft()
            self._slot_t0[slot] = time.perf_counter()
            if self.exact_prefill:
                toks = np.asarray([req.tokens], np.int32)
            else:
                toks = np.full((1, self.scfg.prefill_len), 0, np.int32)
                toks[0, : len(req.tokens)] = req.tokens
            batch = {"tokens": jnp.asarray(toks)}
            logits, cache1 = self._prefill_one(self.params, batch)
            # place the prefilled cache lines into this slot
            self.cache = jax.tree.map(
                lambda full, one, slot=slot: full.at[:, slot].set(one[:, 0]),
                self.cache,
                cache1,
            )
            self._slot_req[slot] = req
            self._slot_new[slot] = []
            if self.exact_prefill:
                # recurrence consumed the prompt exactly once; the first new
                # token comes straight from the prefill logits.
                tok0 = int(self._sample(logits)[0])
                self._slot_pos[slot] = len(req.tokens)
                self._last_tok[slot] = tok0
                self._slot_new[slot].append(tok0)
                if req.max_new_tokens <= 1 or tok0 == req.eos:
                    self._finish_slot(slot)
            else:
                # attention caches are idempotent under re-write: the first
                # decode tick re-emits the last prompt token's KV and samples
                # the next token; pad KV entries stay masked until real
                # tokens overwrite their slots.
                self._slot_pos[slot] = len(req.tokens) - 1
                self._last_tok[slot] = req.tokens[-1]

    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        logits = logits[:, : self.cfg.vocab]  # drop padded vocab tail
        if self.scfg.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._rng, k = jax.random.split(self._rng)
        return np.asarray(
            jax.random.categorical(k, logits / self.scfg.temperature), np.int32
        )

    def _decode_tick(self) -> None:
        active = [s for s in range(self.scfg.slots) if self._slot_req[s] is not None]
        if not active:
            return
        # the compiled decode program is batch-uniform in cur_pos; slots may
        # differ -> run per distinct position group (rare; prompts are padded
        # to similar lengths in practice).
        positions = {int(self._slot_pos[s]) for s in active}
        for pos in sorted(positions):
            group = [s for s in active if int(self._slot_pos[s]) == pos]
            toks = jnp.asarray(self._last_tok[:, None], jnp.int32)
            logits, new_cache = self._decode(
                self.params, toks, self.cache, jnp.asarray(pos, jnp.int32)
            )
            # only the group's slots advance; others keep their cache rows
            keep = np.zeros(self.scfg.slots, bool)
            keep[group] = True
            keep_dev = jnp.asarray(keep)

            def merge(new, old, keep_dev=keep_dev):
                mask = keep_dev.reshape(
                    (1, self.scfg.slots) + (1,) * (new.ndim - 2)
                )
                return jnp.where(mask, new, old)

            self.cache = jax.tree.map(merge, new_cache, self.cache)
            nxt = self._sample(logits)
            for s in group:
                self._advance_slot(s, int(nxt[s]))

    def _advance_slot(self, slot: int, tok: int) -> None:
        req = self._slot_req[slot]
        assert req is not None
        self._slot_new[slot].append(tok)
        self._slot_pos[slot] += 1
        self._last_tok[slot] = tok
        if len(self._slot_new[slot]) >= req.max_new_tokens or (
            req.eos is not None and tok == req.eos
        ):
            self._finish_slot(slot)

    def _finish_slot(self, slot: int) -> None:
        req = self._slot_req[slot]
        assert req is not None
        self._results.append(
            Result(
                uid=req.uid,
                tokens=list(self._slot_new[slot]),
                prompt_len=len(req.tokens),
                latency_s=time.perf_counter() - self._slot_t0[slot],
            )
        )
        self._slot_req[slot] = None
