"""Deterministic seeded fault injection over the ``StoreReads`` surface.

:class:`FaultInjector` wraps a :class:`repro.core.store.Store` and is
handed to :class:`~repro.serve.factorized.FactorizedService` in the
store's place.  It delegates everything, with three seams armed on
demand:

* **Node-visit faults** — the engine attributes every traversal node to
  the store by incrementing ``node_visits`` (through the snapshot's
  counter-forwarding properties, which is why :meth:`snapshot` wraps the
  injector itself).  The injector's ``node_visits`` setter forwards the
  increment FIRST — counter audits stay exact even for aborted
  traversals — then fires any armed trap: an explicit "raise at the Nth
  visit from now" (:meth:`fail_at_node_visit`) or a seeded per-visit
  hazard with geometrically-distributed gaps
  (:meth:`arm_random_node_faults`, the bench sweep's fault-rate knob).
  The engine increments *before* computing the node's view, so an
  aborted traversal never publishes a partial view.

* **Fold poison** — ``Store.fault_hook`` is called at the top of every
  delta fold (``Store._fold_relation``): :meth:`fail_next_fold` makes
  the Nth upcoming fold raise, exercising the store's drain exception
  safety (covered entries invalidated, logs cleared, error surfaces to
  the reader) on both the lazy drain and eager append paths.

* **Eviction storms** — :meth:`arm_eviction_storms` evicts the ENTIRE
  view cache every Nth snapshot (``ViewCache.evict_all``), forcing cold
  recomputes mid-workload to prove results never depend on cache
  residency.

Faults raise :class:`InjectedFault`; ``transient=True`` (the default)
raises the :class:`TransientInjectedFault` subtype, which derives from
:class:`repro.serve.runtime.TransientFault` so service retry policies
engage.  Every firing is recorded in :attr:`FaultInjector.fired` for
test assertions.  All randomness flows from one seeded generator —
identical arming on an identical workload replays identical faults.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from ..core.store import Store, StoreSnapshot
from .runtime import TransientFault

__all__ = ["FaultInjector", "InjectedFault", "TransientInjectedFault"]


class InjectedFault(RuntimeError):
    """A fault raised by :class:`FaultInjector` (terminal by default)."""


class TransientInjectedFault(InjectedFault, TransientFault):
    """An injected fault that retry policies are allowed to retry."""


def _raise(transient: bool, msg: str):
    if transient:
        raise TransientInjectedFault(msg)
    raise InjectedFault(msg)


class FaultInjector:
    """Transparent ``StoreReads`` wrapper with armable, seeded faults.

    Use it exactly like the store it wraps::

        store = Store(relations)
        inj = FaultInjector(store, seed=7)
        svc = FactorizedService(inj, retry=RetryPolicy())
        inj.fail_at_node_visit(3)          # third visit from now raises
        inj.arm_random_node_faults(0.01)   # plus a 1% per-visit hazard

    The injector is also valid as a bare engine data source — every
    ``StoreReads`` method resolves via delegation, and ``isinstance(inj,
    StoreReads)`` holds (the protocol is runtime-checkable by method
    presence).
    """

    def __init__(self, store: Store, seed: int = 0) -> None:
        self._store = store
        self._rng = np.random.default_rng(seed)
        self._visit_count = 0
        # explicit one-shot traps: absolute visit thresholds, sorted
        self._visit_traps: List[Tuple[int, bool]] = []
        # seeded hazard: per-visit fault probability + next firing visit
        self._hazard = 0.0
        self._hazard_transient = True
        self._next_hazard_visit: Optional[int] = None
        # fold traps: [countdown, transient], consumed in arming order
        self._fold_traps: List[List[object]] = []
        self._storm_every = 0
        self._snapshots = 0
        #: log of (kind, detail) tuples, one per fired fault
        self.fired: List[Tuple[str, object]] = []
        store.fault_hook = self._fold_hook

    # -- delegation ------------------------------------------------------------
    def __getattr__(self, name):
        return getattr(object.__getattribute__(self, "_store"), name)

    @property
    def store(self) -> Store:
        """The wrapped store (for assertions on the real object)."""
        return self._store

    # -- counter forwarding (the node-visit seam) ------------------------------
    # Explicit data descriptors: plain attribute *assignment* on the
    # injector would otherwise land in the injector's __dict__ instead of
    # the store's, silently forking the counters.
    @property
    def passes(self) -> int:
        return self._store.passes

    @passes.setter
    def passes(self, v: int) -> None:
        self._store.passes = v

    @property
    def cat_passes(self) -> int:
        return self._store.cat_passes

    @cat_passes.setter
    def cat_passes(self, v: int) -> None:
        self._store.cat_passes = v

    @property
    def cat_node_visits(self) -> int:
        return self._store.cat_node_visits

    @cat_node_visits.setter
    def cat_node_visits(self, v: int) -> None:
        self._store.cat_node_visits = v

    @property
    def node_visits(self) -> int:
        return self._store.node_visits

    @node_visits.setter
    def node_visits(self, v: int) -> None:
        delta = v - self._store.node_visits
        self._store.node_visits = v  # forward FIRST: audits stay exact
        if delta > 0:
            self._visit_count += delta
            self._check_visit_traps()

    def _check_visit_traps(self) -> None:
        n = self._visit_count
        if self._visit_traps and n >= self._visit_traps[0][0]:
            _, transient = self._visit_traps.pop(0)
            self.fired.append(("node_visit", n))
            _raise(transient, f"injected node-visit fault at visit {n}")
        if self._next_hazard_visit is not None and n >= self._next_hazard_visit:
            self._schedule_hazard()
            self.fired.append(("node_visit_random", n))
            _raise(
                self._hazard_transient,
                f"injected random node-visit fault at visit {n}",
            )

    def _schedule_hazard(self) -> None:
        if self._hazard > 0.0:
            gap = int(self._rng.geometric(self._hazard))
            self._next_hazard_visit = self._visit_count + gap
        else:
            self._next_hazard_visit = None

    # -- arming ----------------------------------------------------------------
    def fail_at_node_visit(self, n: int, transient: bool = True) -> None:
        """Arm a one-shot fault at the ``n``-th node visit from now."""
        if n < 1:
            raise ValueError("n must be >= 1")
        self._visit_traps.append((self._visit_count + n, transient))
        self._visit_traps.sort()

    def arm_random_node_faults(
        self, rate: float, transient: bool = True
    ) -> None:
        """Arm a seeded per-visit fault hazard (``rate`` in [0, 1)); the
        gaps between firings are geometric, so a replay with the same
        seed and workload faults at the same visits.  ``rate=0``
        disarms."""
        if not 0.0 <= rate < 1.0:
            raise ValueError("rate must be in [0, 1)")
        self._hazard = rate
        self._hazard_transient = transient
        self._schedule_hazard()

    def fail_next_fold(self, nth: int = 1, transient: bool = True) -> None:
        """Arm a fault in the ``nth`` upcoming delta fold (any relation,
        lazy drain or eager append path)."""
        if nth < 1:
            raise ValueError("nth must be >= 1")
        self._fold_traps.append([nth, transient])

    def arm_eviction_storms(self, every_snapshots: int = 1) -> None:
        """Evict the entire view cache every ``every_snapshots``-th
        snapshot (0 disarms) — the cache-pressure fault class."""
        self._storm_every = int(every_snapshots)

    def disarm(self) -> None:
        """Drop every armed fault (the log of fired faults is kept)."""
        self._visit_traps.clear()
        self._hazard = 0.0
        self._next_hazard_visit = None
        self._fold_traps.clear()
        self._storm_every = 0

    # -- seams -----------------------------------------------------------------
    def _fold_hook(self, kind: str, name: str) -> None:
        if not self._fold_traps:
            return
        trap = self._fold_traps[0]
        trap[0] -= 1  # type: ignore[operator]
        if trap[0] <= 0:  # type: ignore[operator]
            self._fold_traps.pop(0)
            self.fired.append(("fold", name))
            _raise(bool(trap[1]), f"injected fold fault on {name!r}")

    def snapshot(self) -> StoreSnapshot:
        """A snapshot whose counter writes route back through the
        injector — this is what puts the node-visit seam on the engine's
        path (engines read/write counters via their snapshot)."""
        self._snapshots += 1
        if self._storm_every and self._snapshots % self._storm_every == 0:
            n = self._store.view_cache.evict_all()
            self.fired.append(("evict_storm", n))
        return StoreSnapshot(self)

    def evict_storm(self) -> int:
        """Evict the whole view cache NOW; returns entries evicted."""
        n = self._store.view_cache.evict_all()
        self.fired.append(("evict_storm", n))
        return n
