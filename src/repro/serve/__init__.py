"""Serving substrate.

Two engines live here:

* ``engine`` — continuous-batching LM inference (slot management, prefill/
  decode scheduling, sampling) over ``repro.models``;
* ``factorized`` — the multi-tenant factorized *training* service: queued
  train/score/cofactor/aggregate requests from many tenants against one
  shared ``Store``, coalesced into shared traversals and served from
  immutable catalog snapshots (see ``repro.serve.factorized``).
"""

from . import engine, factorized
from .engine import Engine, Request, Result, ServeConfig
from .factorized import (
    FactorizedService,
    ScoreResult,
    TenantStats,
    Ticket,
    TrainResult,
)

__all__ = [
    "Engine",
    "FactorizedService",
    "Request",
    "Result",
    "ScoreResult",
    "ServeConfig",
    "TenantStats",
    "Ticket",
    "TrainResult",
    "engine",
    "factorized",
]
