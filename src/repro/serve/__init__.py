"""Serving substrate: continuous-batching engine over prefill/decode steps.

The per-layer KV/state cache structures live with their mixers in
``repro.models`` (ring-buffer SWA cache, Mamba/xLSTM recurrent state); this
package adds request scheduling, slot management and sampling.
"""

from . import engine
from .engine import Engine, Request, Result, ServeConfig

__all__ = ["Engine", "Request", "Result", "ServeConfig", "engine"]
