"""Serving substrate.

Engines and their runtime live here:

* ``engine`` — continuous-batching LM inference (slot management, prefill/
  decode scheduling, sampling) over ``repro.models``;
* ``factorized`` — the multi-tenant factorized *training* service: queued
  train/score/cofactor/aggregate requests from many tenants against one
  shared ``Store``, coalesced into shared traversals and served from
  immutable catalog snapshots (see ``repro.serve.factorized``);
* ``runtime`` — the concurrent front-end for the factorized service
  (drain worker + background fold thread, typed failures, retry
  policies);
* ``faults`` — the deterministic seeded fault-injection harness
  (``FaultInjector``) the robustness suite drives the service with.
"""

from . import engine, factorized, faults, runtime
from .engine import Engine, Request, Result, ServeConfig
from .factorized import (
    FactorizedService,
    ScoreResult,
    TenantStats,
    Ticket,
    TrainResult,
)
from .faults import FaultInjector, InjectedFault, TransientInjectedFault
from .runtime import (
    RetryPolicy,
    RuntimeConfig,
    ServiceError,
    ServiceOverloaded,
    ServiceRuntime,
    ServiceStopped,
    ServiceTimeout,
    TransientFault,
)

__all__ = [
    "Engine",
    "FactorizedService",
    "FaultInjector",
    "InjectedFault",
    "Request",
    "Result",
    "RetryPolicy",
    "RuntimeConfig",
    "ScoreResult",
    "ServeConfig",
    "ServiceError",
    "ServiceOverloaded",
    "ServiceRuntime",
    "ServiceStopped",
    "ServiceTimeout",
    "TenantStats",
    "Ticket",
    "TrainResult",
    "TransientFault",
    "TransientInjectedFault",
    "engine",
    "factorized",
    "faults",
    "runtime",
]
