"""deepseek-67b [dense] — llama-arch.

95 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=102400
[arXiv:2401.02954; hf].  RMSNorm, SwiGLU, RoPE.

Adafactor by default at this scale (AdamW fp32 state = 804 GB; see
DESIGN.md §Mesh).  Pure full attention -> ``long_500k`` skipped.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    microbatches=16,
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    pattern=(Block("attn", "mlp"),),
    optimizer="adafactor",
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab=512,
    pattern=(Block("attn", "mlp"),),
    optimizer="adafactor",
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
    skip_shapes=("long_500k",),
)
