"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7, MoE 16e top-2.

72 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=24576, vocab=65536
[arXiv:2403.19887; hf].  Each 8-layer Jamba block has ONE attention layer
(index 4) and seven Mamba layers; MoE (16 experts, top-2, expert
d_ff=24576) replaces the MLP on every second layer.  RMSNorm.  Mamba layers
carry position information -> no RoPE (pos="none"), matching the paper.

Decode state is O(1) for Mamba layers and 9 KV caches total ->
``long_500k`` RUNS.  Adafactor at 398B (AdamW fp32 state would need
4.8 TB; see DESIGN.md §Mesh).
"""

from .base import Block, ModelConfig

_PATTERN = (
    Block("mamba", "mlp"),
    Block("mamba", "moe"),
    Block("mamba", "mlp"),
    Block("mamba", "moe"),
    Block("attn", "mlp"),
    Block("mamba", "moe"),
    Block("mamba", "mlp"),
    Block("mamba", "moe"),
)

CONFIG = ModelConfig(
    microbatches=16,
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    pattern=_PATTERN,
    pos="none",
    moe_experts=16,
    moe_topk=2,
    moe_ff=24576,
    mamba_d_state=16,
    mamba_expand=2,
    optimizer="adafactor",
)

SMOKE = ModelConfig(
    moe_capacity=4.0,
    moe_capacity_serve=4.0,
    name="jamba-1.5-large-398b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    pattern=(
        Block("mamba", "mlp"),
        Block("mamba", "moe"),
        Block("attn", "mlp"),
        Block("mamba", "moe"),
    ),
    pos="none",
    moe_experts=4,
    moe_topk=2,
    moe_ff=96,
    mamba_d_state=8,
    mamba_expand=2,
    optimizer="adafactor",
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
)
