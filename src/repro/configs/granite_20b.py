"""granite-20b [dense] — gpt-bigcode-style code model with MQA.

52 layers, d_model=6144, 48 heads with **kv=1 (multi-query)**, d_ff=24576,
vocab=49152 [arXiv:2405.04324; hf].  LayerNorm, GELU MLP, learned absolute
positions.  MQA means the KV cache is 48x smaller than MHA — but kv_heads=1
cannot be tensor-sharded, so decode shards the cache sequence dim instead
(SP; see sharding rules).

Pure full attention -> ``long_500k`` skipped.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    microbatches=8,
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    pattern=(Block("attn", "mlp"),),
    norm="ln",
    mlp="gelu",
    pos="learned",
    max_pos=32_768,
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="granite-20b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=1,
    d_ff=128,
    vocab=512,
    pattern=(Block("attn", "mlp"),),
    norm="ln",
    mlp="gelu",
    pos="learned",
    max_pos=128,
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
    skip_shapes=("long_500k",),
)
