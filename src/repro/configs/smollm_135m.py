"""smollm-135m [dense] — llama-arch small.

30 layers, d_model=576, 9 heads (GQA kv=3), d_ff=1536, vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf].  RMSNorm, SwiGLU, RoPE, tied embeddings.

Pure full attention -> ``long_500k`` skipped.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    microbatches=4,
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    pattern=(Block("attn", "mlp"),),
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    pattern=(Block("attn", "mlp"),),
    tie_embeddings=True,
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
    skip_shapes=("long_500k",),
)
