"""xlstm-1.3b [ssm] — sLSTM + mLSTM block stack.

48 layers, d_model=2048, 4 heads, d_ff=0 (xLSTM blocks carry their own
up/down projection via ``xlstm_proj_factor``), vocab=50304
[arXiv:2405.04517; unverified].  The xLSTM[7:1] layout interleaves one sLSTM
block per seven mLSTM blocks -> an 8-block pattern tiled 6 times.

Recurrent state is O(1) per token -> ``long_500k`` RUNS.
"""

from .base import Block, ModelConfig

_PATTERN = (Block("slstm", "none"),) + (Block("mlstm", "none"),) * 7

CONFIG = ModelConfig(
    microbatches=4,
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=_PATTERN,
    norm="ln",
    pos="none",
    xlstm_proj_factor=2,
    xlstm_chunk=256,
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab=512,
    pattern=(Block("slstm", "none"), Block("mlstm", "none")),
    norm="ln",
    pos="none",
    xlstm_proj_factor=2,
    xlstm_chunk=16,
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
)
