"""Architecture registry: the 10 assigned architectures × 4 input shapes.

``get_config("mixtral-8x7b")`` returns the full published config;
``get_config("mixtral-8x7b", smoke=True)`` the reduced same-family variant
used by CPU smoke tests.  ``input_specs(cfg, shape)`` builds the
ShapeDtypeStruct stand-ins for every model input of a (arch × shape) cell —
weak-type-correct, shardable, never allocating — which is what the multi-pod
dry-run lowers against.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .base import SHAPES, Block, ModelConfig, ShapeConfig
from . import (
    deepseek_67b,
    granite_20b,
    jamba_1_5_large_398b,
    llava_next_mistral_7b,
    mixtral_8x7b,
    olmo_1b,
    qwen2_moe_a2_7b,
    smollm_135m,
    whisper_medium,
    xlstm_1_3b,
)

__all__ = [
    "ARCHS",
    "SHAPES",
    "Block",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "input_specs",
    "paper_arch",
]

_MODULES = {
    "whisper-medium": whisper_medium,
    "smollm-135m": smollm_135m,
    "deepseek-67b": deepseek_67b,
    "olmo-1b": olmo_1b,
    "granite-20b": granite_20b,
    "xlstm-1.3b": xlstm_1_3b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "mixtral-8x7b": mixtral_8x7b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
}

ARCHS: Dict[str, ModelConfig] = {
    name: mod.CONFIG for name, mod in _MODULES.items()
}

SMOKE_ARCHS: Dict[str, ModelConfig] = {
    name: mod.SMOKE for name, mod in _MODULES.items()
}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE_ARCHS if smoke else ARCHS
    if name not in table:
        raise KeyError(
            f"unknown architecture {name!r}; available: {sorted(table)}"
        )
    return table[name]


def paper_arch() -> ModelConfig:
    """The ~100M decoder used by the end-to-end training example — llama
    family, sized so a few hundred steps run on CPU/laptop scale."""
    return ModelConfig(
        name="repro-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab=32768,
        pattern=(Block("attn", "mlp"),),
        tie_embeddings=True,
        dtype_name="float32",
        param_dtype_name="float32",
        remat=False,
        skip_shapes=("long_500k",),
    )


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, batch_override: Optional[int] = None
):
    """ShapeDtypeStructs for every input of one (arch × shape) cell.

    * train:    {tokens, labels} (+ frames / patches stubs)
    * prefill:  {tokens} (+ frames / patches)
    * decode:   {token, cur_pos}; the KV/state cache ShapeDtypeStructs come
      from ``jax.eval_shape(model.init_cache, ...)`` in the dry-run driver.
    """
    b = batch_override or shape.global_batch
    i32 = jnp.int32
    act = cfg.dtype

    def tok(s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "decode":
        return {
            "token": jax.ShapeDtypeStruct((b, 1), i32),
            "cur_pos": jax.ShapeDtypeStruct((), i32),
        }

    s_text = cfg.text_len(shape.seq_len)
    if s_text <= 0:
        raise ValueError(
            f"{cfg.name}: modality prefix {cfg.n_patches} exceeds "
            f"seq_len {shape.seq_len}"
        )
    specs = {"tokens": tok(s_text)}
    if cfg.is_encoder_decoder:
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frames, cfg.d_model), act
        )
    if cfg.n_patches:
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), act
        )
    if shape.kind == "train":
        specs["labels"] = tok(s_text)
    return specs
