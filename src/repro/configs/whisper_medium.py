"""whisper-medium [audio] — encoder-decoder, conv frontend stubbed.

24 decoder + 24 encoder layers, d_model=1024, 16 heads (kv=16), d_ff=4096,
vocab=51865 [arXiv:2212.04356; unverified].  GELU MLPs, LayerNorm, learned
decoder positions (table extended to 32k to cover the assigned decode_32k
shape; the released model stops at 448 — noted in DESIGN.md).  The audio
frontend (2×conv) is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, 1500, 1024].

Pure full attention -> ``long_500k`` skipped.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    microbatches=4,
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    pattern=(Block("attn", "mlp"),),
    norm="ln",
    mlp="gelu",
    pos="learned",
    max_pos=32_768,
    enc_layers=24,
    n_frames=1500,
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    pattern=(Block("attn", "mlp"),),
    norm="ln",
    mlp="gelu",
    pos="learned",
    max_pos=128,
    enc_layers=2,
    n_frames=16,
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
    skip_shapes=("long_500k",),
)
