"""Model / shape configuration system.

``ModelConfig`` describes one architecture declaratively; the model assembly
(`repro.models.model`) interprets it.  Heterogeneous stacks (jamba, xlstm)
are expressed as a **block pattern**: one period of (mixer, ffn) pairs that
tiles the depth — the assembly scans over periods so the compiled HLO stays
O(pattern), not O(depth).

Every architecture provides a ``smoke()`` reduction (same family, tiny dims)
used by CPU tests; full configs are only ever lowered via ShapeDtypeStructs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "Block"]


@dataclasses.dataclass(frozen=True)
class Block:
    """One position of the depth pattern."""

    mixer: str  # "attn" | "mamba" | "mlstm" | "slstm"
    ffn: str  # "mlp" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


#: The assigned input-shape set (LM family).
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # block pattern: tiles depth; default = homogeneous attention+mlp
    pattern: Tuple[Block, ...] = (Block("attn", "mlp"),)
    # styles
    norm: str = "rms"  # rms | ln | np_ln
    mlp: str = "swiglu"  # swiglu | gelu
    pos: str = "rope"  # rope | learned | sinusoidal
    rope_theta: float = 10_000.0
    window: Optional[int] = None  # sliding-window attention (mixtral)
    tie_embeddings: bool = False
    max_pos: int = 32_768  # learned position table size
    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_ff: int = 0
    moe_shared_ff: int = 0
    moe_capacity: float = 1.25
    # row-local dispatch groups: routing capacity per batch row, keeping
    # all gather/scatter indices shard-local (kills the global dispatch's
    # cross-shard all-gather/all-reduce; see models/moe.py + §Perf)
    moe_row_local: bool = False
    # serving capacity factor (prefill/decode): higher than training's so
    # generation rarely drops tokens; smoke configs use 4.0 = dropless at
    # test sizes, making decode-vs-forward equivalence exact.
    moe_capacity_serve: float = 2.0
    router_aux: float = 0.01
    # Mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_chunk: int = 128  # sequence chunk of the selective-scan blocking
    # xLSTM
    xlstm_proj_factor: int = 2
    xlstm_chunk: int = 256
    # encoder-decoder (whisper): n_layers counts DECODER layers
    enc_layers: int = 0
    n_frames: int = 0  # stub audio frontend: precomputed frame embeddings
    # vlm (llava): stub vision frontend: precomputed patch embeddings
    n_patches: int = 0
    # dtypes (strings so configs stay hashable/serializable)
    dtype_name: str = "bfloat16"
    param_dtype_name: str = "bfloat16"
    # training
    remat: bool = True
    microbatches: int = 1  # gradient-accumulation splits of the global batch
    optimizer: str = "adamw"  # adamw | adafactor | sgd (adafactor: 398B-scale)
    # fully unroll depth/microbatch scans: used by the dry-run cost pass
    # (XLA cost analysis counts a while-loop body once; unrolled compiles
    # make HLO_FLOPs exact).  Production form keeps the scans.
    scan_unroll: bool = False
    # inner-scan unroll knobs (sLSTM steps, mLSTM chunks, mamba chunks,
    # chunked-attention q/kv sweeps).  1 = plain while loop (production).
    # The dry-run cost pass compiles each knob at 2 and uses the delta —
    # exactly one extra loop body — to extrapolate the true per-iteration
    # FLOPs/bytes (XLA cost analysis counts a while body once; see
    # launch/dryrun.py §inner-scan corrections).
    slstm_unroll: int = 1
    mlstm_unroll: int = 1
    mamba_unroll: int = 1
    attn_q_unroll: int = 1
    attn_kv_unroll: int = 1
    # force the O(S²)-memory dense attention path (debug/ablation only)
    dense_attention: bool = False
    # which shapes this arch skips (e.g. long_500k for pure full attention)
    skip_shapes: Tuple[str, ...] = ()

    # -- derived -------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.pattern) == 0, (
            self.n_layers,
            len(self.pattern),
        )

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    @property
    def param_dtype(self):
        return jnp.dtype(self.param_dtype_name)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))  # ceil(d/16), mamba default

    @property
    def xlstm_d_inner(self) -> int:
        return self.xlstm_proj_factor * self.d_model

    @property
    def xlstm_head_dim(self) -> int:
        return self.xlstm_d_inner // self.n_heads

    @property
    def is_encoder_decoder(self) -> bool:
        return self.enc_layers > 0

    def runnable_shapes(self):
        return [s for s in SHAPES.values() if s.name not in self.skip_shapes]

    def text_len(self, seq_len: int) -> int:
        """Decoder-token count for a given total sequence budget (vlm archs
        spend ``n_patches`` of the budget on the image prefix)."""
        return seq_len - self.n_patches if self.n_patches else seq_len

    # -- parameter counting (roofline MODEL_FLOPS) ----------------------------
    def param_counts(self) -> Dict[str, float]:
        """Analytic total vs *active* (per-token) parameter counts."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        h, kh, hd = self.n_heads, self.n_kv_heads, self.head_dim
        di, n, r = self.mamba_d_inner, self.mamba_d_state, self.mamba_dt_rank
        xdi = self.xlstm_d_inner
        mixer_p = {
            "attn": d * hd * (h + kh) * 2,
            "mamba": d * 2 * di + di * (r + 2 * n) + r * di + di * d
            + 4 * di + 2 * di + di * n,
            "mlstm": 2 * d * xdi + 4 * xdi + 3 * xdi * self.xlstm_head_dim
            * self.n_heads // 1 + xdi * 2 * self.n_heads + xdi * d,
            "slstm": d * 4 * d + self.n_heads * (d // self.n_heads) * 4
            * (d // self.n_heads) + d * d,
        }
        ffn_total = {
            "mlp": (3 if self.mlp == "swiglu" else 2) * d * ff,
            "moe": self.moe_experts * 3 * d * self.moe_ff
            + d * self.moe_experts + 3 * d * self.moe_shared_ff,
            "none": 0,
        }
        ffn_active = {
            "mlp": ffn_total["mlp"],
            "moe": self.moe_topk * 3 * d * self.moe_ff
            + d * self.moe_experts + 3 * d * self.moe_shared_ff,
            "none": 0,
        }
        total = active = 0.0
        for blk in self.pattern:
            total += mixer_p[blk.mixer] + ffn_total[blk.ffn]
            active += mixer_p[blk.mixer] + ffn_active[blk.ffn]
        total *= self.n_periods
        active *= self.n_periods
        enc = self.enc_layers * (mixer_p["attn"] + ffn_total["mlp"])
        emb = v * d * (1 if self.tie_embeddings else 2)
        return {
            "total": total + enc + emb,
            "active": active + enc + emb,
        }
