"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts.

24 layers, d_model=2048, 16 heads (kv=16), per-expert d_ff=1408,
vocab=151936 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].  RMSNorm, RoPE.  The 4 shared
experts run densely (5632 = 4×1408 hidden) alongside the routed top-4.

60 experts do not divide the 16-way model axis — the sharding policy's
divisibility fallback shards the expert *hidden* dim instead (TP within
experts), documented in DESIGN.md §Mesh.

Pure full attention -> ``long_500k`` skipped.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    microbatches=4,
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    pattern=(Block("attn", "moe"),),
    moe_capacity_serve=1.25,
    moe_experts=60,
    moe_topk=4,
    moe_ff=1408,
    moe_shared_ff=5632,
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    moe_capacity=4.0,
    moe_capacity_serve=4.0,
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab=512,
    pattern=(Block("attn", "moe"),),
    moe_experts=6,
    moe_topk=2,
    moe_ff=96,
    moe_shared_ff=128,
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
    skip_shapes=("long_500k",),
)
