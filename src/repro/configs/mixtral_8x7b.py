"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32 layers, d_model=4096, 32 heads (GQA kv=8), per-expert d_ff=14336,
vocab=32000 [arXiv:2401.04088; hf].  RMSNorm, SwiGLU experts, RoPE,
**sliding window 4096**: decode keeps a ring-buffer KV cache of 4096 slots
regardless of context length, so ``long_500k`` RUNS (sub-quadratic by
windowing).
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    microbatches=8,
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    pattern=(Block("attn", "moe"),),
    window=4096,
    moe_experts=8,
    moe_topk=2,
    moe_ff=14336,
)

SMOKE = ModelConfig(
    moe_capacity=4.0,
    moe_capacity_serve=4.0,
    name="mixtral-8x7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    pattern=(Block("attn", "moe"),),
    window=16,
    moe_experts=4,
    moe_topk=2,
    moe_ff=128,
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
)
