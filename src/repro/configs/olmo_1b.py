"""olmo-1b [dense] — non-parametric LayerNorm.

16 layers, d_model=2048, 16 heads (kv=16), d_ff=8192, vocab=50304
[arXiv:2402.00838; hf].  OLMo's distinguishing choice is **non-parametric**
LayerNorm (no scale/bias) -> ``norm="np_ln"``; SwiGLU, RoPE, tied embeddings.

Pure full attention -> ``long_500k`` skipped.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    microbatches=4,
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    pattern=(Block("attn", "mlp"),),
    norm="np_ln",
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="olmo-1b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    pattern=(Block("attn", "mlp"),),
    norm="np_ln",
    tie_embeddings=True,
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
    skip_shapes=("long_500k",),
)
