"""llava-next-mistral-7b [vlm] — mistral-7B backbone, anyres vision stub.

32 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].  RMSNorm, SwiGLU, RoPE.
The anyres tiling vision tower is a stub per the assignment:
``input_specs`` provides precomputed patch embeddings [B, 2880, 4096]
(2880 = anyres 4-tile + base-image token budget); a learned ``mm_proj``
projects them into the text stream.  Sequence budget = 2880 image +
(seq_len − 2880) text tokens; loss is computed on text positions only.

Pure full attention -> ``long_500k`` skipped.
"""

from .base import Block, ModelConfig

CONFIG = ModelConfig(
    microbatches=8,
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    pattern=(Block("attn", "mlp"),),
    n_patches=2880,
    skip_shapes=("long_500k",),
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    pattern=(Block("attn", "mlp"),),
    n_patches=8,
    dtype_name="float32",
    param_dtype_name="float32",
    remat=False,
    skip_shapes=("long_500k",),
)
