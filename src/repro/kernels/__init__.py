"""Pallas TPU kernels for the paper's compute hot-spots (cofactor/aggregate
computation).  Each kernel module documents its BlockSpec/VMEM design;
``ops`` holds the jit'd public wrappers and ``ref`` the pure-jnp oracles."""

from . import ops, ref

__all__ = ["ops", "ref"]
