"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Tests sweep shapes and dtypes and assert the kernels (interpret mode on CPU,
compiled on TPU) match these to float tolerance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "gram_ref",
    "segment_gram_ref",
    "segment_view_ref",
    "segment_blocks_ref",
    "moments_ref",
    "flash_ref",
]


def gram_ref(x: jnp.ndarray) -> jnp.ndarray:
    """out = X^T X in fp32."""
    x32 = x.astype(jnp.float32)
    return x32.T @ x32


def segment_gram_ref(
    x: jnp.ndarray, seg: jnp.ndarray, num_groups: int
) -> jnp.ndarray:
    """out[g] = Σ_{m: seg[m]=g} x_m x_m^T in fp32 (scatter-add formulation)."""
    x32 = x.astype(jnp.float32)
    outer = x32[:, :, None] * x32[:, None, :]
    out = jnp.zeros((num_groups,) + outer.shape[1:], dtype=jnp.float32)
    return out.at[seg].add(outer, mode="drop")


def segment_view_ref(c, x, l, q, seg, num_groups: int, degree: int = 2):
    """Unfused oracle for the fused extend-and-group node: materialize the
    extended blocks (exactly ``FactorizedEngine._extend_with_feature``), then
    scatter-add each per segment.  Returns ``(c_new [G], l_new [G, k+1],
    q_new [G, k+1, k+1] | None)`` in the inputs' dtype."""
    c, x, l = jnp.asarray(c), jnp.asarray(x), jnp.asarray(l)
    l_ext = jnp.concatenate([(x * c)[:, None], l], axis=1)
    zeros = functools.partial(jnp.zeros, dtype=c.dtype)
    c_new = zeros((num_groups,)).at[seg].add(c, mode="drop")
    l_new = zeros((num_groups,) + l_ext.shape[1:]).at[seg].add(
        l_ext, mode="drop"
    )
    if degree != 2:
        return c_new, l_new, None
    q = jnp.asarray(q)
    xl = x[:, None] * l
    top = jnp.concatenate([(x * x * c)[:, None, None], xl[:, None, :]], axis=2)
    bot = jnp.concatenate([xl[:, :, None], q], axis=2)
    q_ext = jnp.concatenate([top, bot], axis=1)
    q_new = zeros((num_groups,) + q_ext.shape[1:]).at[seg].add(
        q_ext, mode="drop"
    )
    return c_new, l_new, q_new


def segment_blocks_ref(c, l, q, seg, num_groups: int, degree: int = 2):
    """Per-block scatter-add oracle for the multi-block segment reduce:
    ``(Σc, Σl, Σq)`` per group, Nones past ``degree``."""
    c = jnp.asarray(c)
    zeros = functools.partial(jnp.zeros, dtype=c.dtype)
    c_new = zeros((num_groups,)).at[seg].add(c, mode="drop")
    l_new = q_new = None
    if degree >= 1:
        l = jnp.asarray(l)
        l_new = zeros((num_groups,) + l.shape[1:]).at[seg].add(l, mode="drop")
    if degree == 2:
        q = jnp.asarray(q)
        q_new = zeros((num_groups,) + q.shape[1:]).at[seg].add(q, mode="drop")
    return c_new, l_new, q_new


def moments_ref(x: jnp.ndarray):
    """(Σx, max|x|, count) in fp32 / int."""
    x32 = x.astype(jnp.float32)
    return jnp.sum(x32), jnp.max(jnp.abs(x32)), x.shape[0]


def flash_ref(q, k, v, *, causal=True, window=None, kv_len=None):
    """Dense softmax attention oracle: q [BH, Sq, D], k/v [BH, Sk, D]."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    kv_len = sk if kv_len is None else kv_len
    s = jnp.einsum(
        "hqd,hkd->hqk", q, k, preferred_element_type=jnp.float32
    ) * (d**-0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = kpos < kv_len
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask[None], -1, keepdims=True), p, 0.0)
    return jnp.einsum(
        "hqk,hkd->hqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)
