"""Blocked Gram / cofactor kernel: out = X^T X  (the paper's hot aggregate).

The non-factorized ("noPre") cofactor computation and the per-relation leaf
cofactors are Gram matrices over tall-skinny design matrices.  On TPU the
natural blocking is:

  * grid (nk_i, nk_j, nm): output tile (i, j) of shape [bk, bk] stays
    resident in VMEM while the kernel streams [bm, bk] input tiles of X
    from HBM, accumulating partial products on the MXU,
  * bk is a multiple of 128 (MXU lane width) and bm a multiple of 8
    (sublane), so ``x_i^T @ x_j`` maps onto full systolic passes,
  * accumulation is always fp32 (``preferred_element_type``), independent of
    the input dtype (bf16 inputs hit the MXU's native mixed-precision path).

VMEM working set per step: 2·bm·bk·dtype + bk·bk·4 bytes — with the default
bm=512, bk=128 and bf16 inputs that is 2·512·128·2 + 128·128·4 ≈ 0.33 MiB,
far under the ~16 MiB VMEM budget, leaving room for double buffering.

The wrapper (`ops.gram`) zero-pads M and K to block multiples — zero rows or
columns contribute nothing to X^T X, so no in-kernel masking is needed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gram_kernel_call"]

DEFAULT_BM = 512
DEFAULT_BK = 128


def _gram_kernel(x_i_ref, x_j_ref, out_ref):
    """One (i, j, m) grid step: out[i, j] += x[m, i]^T @ x[m, j]."""
    m = pl.program_id(2)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x_i = x_i_ref[...]
    x_j = x_j_ref[...]
    out_ref[...] += jax.lax.dot_general(
        x_i,
        x_j,
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over rows
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def gram_kernel_call(
    x: jnp.ndarray,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call on an already-padded [M, K] matrix (M % bm == 0,
    K % bk == 0).  Returns fp32 [K, K].  Use ``ops.gram`` for arbitrary
    shapes."""
    m, k = x.shape
    assert m % bm == 0 and k % bk == 0, (m, k, bm, bk)
    nm, nk = m // bm, k // bk
    return pl.pallas_call(
        _gram_kernel,
        grid=(nk, nk, nm),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, mm: (mm, i)),
            pl.BlockSpec((bm, bk), lambda i, j, mm: (mm, j)),
        ],
        out_specs=pl.BlockSpec((bk, bk), lambda i, j, mm: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        interpret=interpret,
    )(x, x)
