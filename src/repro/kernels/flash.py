"""Flash attention (online softmax) as a Pallas TPU kernel.

The chunked-attention path in ``repro.models.attention`` implements the
online-softmax recurrence in pure jnp (lax.scan) — portable, but each
chunk's scores round-trip through HBM.  This kernel fuses the whole
recurrence: one (head, q-block) output tile stays resident in VMEM while
K/V tiles stream past, so the O(S²) score matrix never touches HBM — the
standard TPU adaptation of FlashAttention (block-tiled for the MXU rather
than warp-tiled as on GPU).

Tiling:

  grid = (BH, NQ, NK)   — kv blocks innermost so the (m, l, acc) running
                          state lives in VMEM scratch across the NK sweep
  q    : [1, bq, D]  tile, revisited for every j
  k, v : [1, bk, D]  tiles, streamed
  out  : [1, bq, D]  tile, written once at j == NK-1
  scratch: m [bq, 1], l [bq, 1], acc [bq, D]  — fp32

``bq``/``bk`` default to 512/512 and D is the head dim (usually 64/128):
VMEM per step = (bq + 2·bk)·D·2B + bq·D·4B + scores bq·bk·4B ≈ 1.6 MiB at
defaults — room for double buffering in the ~16 MiB budget.  All matmuls
hit the MXU with fp32 accumulation.

Masking supports causal and sliding-window (mixtral) via absolute q/k
positions derived from block ids, plus a kv-length bound for padding.
GQA: the wrapper broadcasts KV heads to query heads before the call (the
score matrix is per-q-head regardless; only HBM traffic for K/V grows, and
the wrapper notes this trade-off).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_kernel_call", "DEFAULT_BQ", "DEFAULT_BK"]

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: Optional[int], kv_len: int,
    bq: int, bk: int,
):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [bq, D]
    k = k_ref[0]  # [bk, D]
    s = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        * scale
    )  # [bq, bk]

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len  # padding bound
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # exp(NEG_INF - NEG_INF) would poison fully-masked rows: re-mask p.
    p = jnp.exp(s - m_new) * mask
    corr = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "kv_len", "bq", "bk", "interpret"),
)
def flash_kernel_call(
    q: jnp.ndarray,  # [BH, Sq, D]  (Sq % bq == 0)
    k: jnp.ndarray,  # [BH, Sk, D]  (Sk % bk == 0)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_len: Optional[int] = None,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    kv_len = sk if kv_len is None else kv_len
    scale = d**-0.5
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        kv_len=kv_len,
        bq=bq,
        bk=bk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            # fp32 running state, persistent across the kv sweep
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
