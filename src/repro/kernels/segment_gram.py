"""Segmented Gram kernel: per-group cofactors out[g] = Σ_{seg(m)=g} x_m x_m^T.

This is the factorized engine's leaf-level hot op: a relation sorted by its
group key contributes, per distinct key, the [K, K] monomial block
(count / linear / quadratic in one shot when the wrapper appends an
all-ones column — u = [1, x] makes u·u^T carry c, l and q together).

TPU adaptation of the SQL ``GROUP BY``: scatter-add is hostile to the MXU,
so the kernel uses the canonical **one-hot matmul** formulation —

    onehot[m, g] = (seg[m] == g)
    out         += onehot^T @ flatten(x_m x_m^T)

which turns the grouped reduction into two dense ops: a [bm, K]×[bm, K]
row-wise outer product (VPU) and a [G, bm]@[bm, K²] matmul (MXU).  Rows are
streamed in [bm] blocks along a 1-D grid; the [G, K, K] accumulator stays
resident in VMEM across grid steps (requires G·K²·4 bytes ≤ VMEM — the
wrapper asserts ≤ 8 MiB and falls back to chunking groups otherwise).

Padding trick: the wrapper pads rows with ``seg = G`` (out of range), whose
one-hot row is all zeros, so padded rows contribute nothing — no masking
branch in the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["multi_segment_gram_kernel_call", "segment_gram_kernel_call"]

DEFAULT_BM = 256
VMEM_ACC_BYTES = 8 * 1024 * 1024


def _segment_gram_kernel(x_ref, seg_ref, out_ref, *, num_groups: int):
    m = pl.program_id(0)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # [bm, k]
    seg = seg_ref[...]  # [bm, 1] int32
    bm, k = x.shape
    onehot = (
        seg == jax.lax.broadcasted_iota(jnp.int32, (bm, num_groups), 1)
    ).astype(jnp.float32)
    cross = (x[:, :, None] * x[:, None, :]).reshape(bm, k * k)
    acc = jax.lax.dot_general(
        onehot,
        cross,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc.reshape(num_groups, k, k)


@functools.partial(
    jax.jit, static_argnames=("num_groups", "bm", "interpret")
)
def segment_gram_kernel_call(
    x: jnp.ndarray,
    seg: jnp.ndarray,
    num_groups: int,
    bm: int = DEFAULT_BM,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call on padded inputs: x [M, K] (M % bm == 0), seg [M, 1]
    int32 sorted ascending with padding rows set to ``num_groups``.
    Returns fp32 [num_groups, K, K].  Use ``ops.segment_gram`` generally."""
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    assert seg.shape == (m, 1), seg.shape
    assert num_groups * k * k * 4 <= VMEM_ACC_BYTES, (
        f"accumulator {num_groups}x{k}x{k} exceeds VMEM budget — "
        "chunk groups in the wrapper"
    )
    nm = m // bm
    kernel = functools.partial(_segment_gram_kernel, num_groups=num_groups)
    return pl.pallas_call(
        kernel,
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, 1), lambda mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec(
            (num_groups, k, k), lambda mm: (0, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((num_groups, k, k), jnp.float32),
        interpret=interpret,
    )(x, seg)


def _multi_segment_gram_kernel(
    x_ref, seg_ref, out_ref, *, num_groups: int, n_seg: int
):
    """Batched variant: ``n_seg`` segment-id columns share one read of x.

    Each segment column's ids are pre-offset into a disjoint band of
    ``[0, num_groups)``, so the *sum* of the per-column one-hots is a
    multi-hot matrix H with ``n_seg`` ones per row — and H^T @ cross
    scatters the SAME row-wise outer products into every column's group
    band in one MXU matmul.  This is what makes cofactor extraction flat
    in the number of categorical attributes: the data block streams from
    HBM once, not once per attribute.
    """
    m = pl.program_id(0)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]  # [bm, k]
    seg = seg_ref[...]  # [bm, n_seg] int32, band-offset
    bm, k = x.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bm, num_groups), 1)
    hot = jnp.zeros((bm, num_groups), dtype=jnp.float32)
    for i in range(n_seg):  # static unroll — n_seg is a Python int
        hot += (seg[:, i, None] == iota).astype(jnp.float32)
    cross = (x[:, :, None] * x[:, None, :]).reshape(bm, k * k)
    acc = jax.lax.dot_general(
        hot,
        cross,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc.reshape(num_groups, k, k)


@functools.partial(
    jax.jit, static_argnames=("num_groups", "n_seg", "bm", "interpret")
)
def multi_segment_gram_kernel_call(
    x: jnp.ndarray,
    seg: jnp.ndarray,
    num_groups: int,
    n_seg: int,
    bm: int = DEFAULT_BM,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call on padded inputs: x [M, K] (M % bm == 0), seg
    [M, n_seg] int32 with each column's ids offset into its own band of
    ``[0, num_groups)`` and padding rows set to ``num_groups`` (out of
    range ⇒ zero one-hot row).  Returns fp32 [num_groups, K, K] — the
    per-column grouped Grams concatenated along the group axis.  Use
    ``ops.multi_segment_gram`` generally."""
    m, k = x.shape
    assert m % bm == 0, (m, bm)
    assert seg.shape == (m, n_seg), (seg.shape, n_seg)
    assert num_groups * k * k * 4 <= VMEM_ACC_BYTES, (
        f"accumulator {num_groups}x{k}x{k} exceeds VMEM budget — "
        "fall back to per-column chunked segment_gram in the wrapper"
    )
    nm = m // bm
    kernel = functools.partial(
        _multi_segment_gram_kernel, num_groups=num_groups, n_seg=n_seg
    )
    return pl.pallas_call(
        kernel,
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, n_seg), lambda mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec(
            (num_groups, k, k), lambda mm: (0, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((num_groups, k, k), jnp.float32),
        interpret=interpret,
    )(x, seg)
