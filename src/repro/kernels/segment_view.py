"""Fused traversal-node kernels: extend-with-feature + GROUP BY in ONE pass.

The factorized engine's per-node hot loop (``_extend_with_feature`` followed
by ``_aggregate_out`` in ``repro.core.factorize``) used to cost 3+ dispatches
and an ``[N, k+1, k+1]`` HBM intermediate: materialize the extended quad
tensor ``[[x²c, (x·l)ᵀ], [x·l, q]]``, then scatter-add each of the c/l/q
blocks separately.  These kernels fuse the whole node: each row's extended
cofactor matrix is assembled **in registers/VMEM** and accumulated straight
into the ``[num_groups, k+2, k+2]`` output via the one-hot matmul trick of
``segment_gram`` — the extended tensor never touches HBM.

Packed layout (degree 2).  For a view row with blocks (c, l[k], q[k, k]) and
feature value x, the bordered (k+2)×(k+2) matrix

    E = | c    x·c   lᵀ     |
        | x·c  x²·c  (x·l)ᵀ |
        | l    x·l   q      |

segment-sums to exactly the extend-then-group result: the new view's blocks
are slices of ``out = Σ_{seg(m)=g} E_m``::

    c_new = out[:, 0, 0]      l_new = out[:, 1:, 0]      q_new = out[:, 1:, 1:]

(degree 1 drops the quad rows: E = [c, x·c, lᵀ] of width k+2 and
``l_new = out[:, 1:]``).  ``segment_reduce_kernel_call`` is the plain
multi-block companion: one kernel call segment-reduces an arbitrary
``[M, W]`` payload (the wrapper packs c|l|q side by side), replacing one
scatter dispatch per block at non-feature nodes and delta folds.

Grid/VMEM design mirrors ``segment_gram``: rows stream in ``[bm]`` blocks
along a 1-D grid, the ``[G, ...]`` accumulator stays VMEM-resident across
grid steps (wrapper chunks groups against ``vmem_budget`` otherwise), and
padding rows carry the out-of-range segment id ``G`` so their one-hot row is
all zeros — no masking branch in the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "segment_reduce_kernel_call",
    "segment_view1_kernel_call",
    "segment_view_kernel_call",
]

DEFAULT_BM = 256
VMEM_ACC_BYTES = 8 * 1024 * 1024


def _onehot(seg, num_groups: int):
    bm = seg.shape[0]
    return (
        seg == jax.lax.broadcasted_iota(jnp.int32, (bm, num_groups), 1)
    ).astype(jnp.float32)


def _segment_view_kernel(
    c_ref, x_ref, l_ref, q_ref, seg_ref, out_ref, *, num_groups: int
):
    m = pl.program_id(0)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = c_ref[...].astype(jnp.float32)  # [bm, 1]
    x = x_ref[...].astype(jnp.float32)  # [bm, 1]
    l = l_ref[...].astype(jnp.float32)  # [bm, k]
    q = q_ref[...].astype(jnp.float32)  # [bm, k*k]
    bm, k = l.shape
    xc = x * c
    xl = x * l
    # assemble the bordered (k+2)x(k+2) row matrices entirely on-chip
    row0 = jnp.concatenate([c, xc, l], axis=1)  # [bm, k+2]
    row1 = jnp.concatenate([xc, x * xc, xl], axis=1)  # [bm, k+2]
    rest = jnp.concatenate(
        [l[:, :, None], xl[:, :, None], q.reshape(bm, k, k)], axis=2
    )  # [bm, k, k+2]
    ext = jnp.concatenate(
        [row0[:, None, :], row1[:, None, :], rest], axis=1
    ).reshape(bm, (k + 2) * (k + 2))
    acc = jax.lax.dot_general(
        _onehot(seg_ref[...], num_groups),
        ext,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc.reshape(num_groups, k + 2, k + 2)


@functools.partial(jax.jit, static_argnames=("num_groups", "bm", "interpret"))
def segment_view_kernel_call(
    c: jnp.ndarray,
    x: jnp.ndarray,
    l: jnp.ndarray,
    q: jnp.ndarray,
    seg: jnp.ndarray,
    num_groups: int,
    bm: int = DEFAULT_BM,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call on padded inputs: c/x [M, 1], l [M, K] (K ≥ 1),
    q [M, K·K] row-major, seg [M, 1] int32 with padding rows set to
    ``num_groups``; M % bm == 0.  Returns fp32 [num_groups, K+2, K+2] in the
    packed layout above.  Use ``ops.segment_view`` generally."""
    m, k = l.shape
    assert m % bm == 0, (m, bm)
    assert c.shape == (m, 1) and x.shape == (m, 1), (c.shape, x.shape)
    assert q.shape == (m, k * k), (q.shape, k)
    assert seg.shape == (m, 1), seg.shape
    w = (k + 2) * (k + 2)
    assert num_groups * w * 4 <= VMEM_ACC_BYTES, (
        f"accumulator {num_groups}x{k + 2}x{k + 2} exceeds VMEM budget — "
        "chunk groups in the wrapper"
    )
    nm = m // bm
    kernel = functools.partial(_segment_view_kernel, num_groups=num_groups)
    return pl.pallas_call(
        kernel,
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, 1), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, k), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, k * k), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, 1), lambda mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((num_groups, k + 2, k + 2), lambda mm: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, k + 2, k + 2), jnp.float32),
        interpret=interpret,
    )(c, x, l, q, seg)


def _segment_view1_kernel(
    c_ref, x_ref, l_ref, seg_ref, out_ref, *, num_groups: int
):
    m = pl.program_id(0)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = c_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    l = l_ref[...].astype(jnp.float32)
    ext = jnp.concatenate([c, x * c, l], axis=1)  # [bm, k+2] = [c, x·c, l]
    out_ref[...] += jax.lax.dot_general(
        _onehot(seg_ref[...], num_groups),
        ext,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("num_groups", "bm", "interpret"))
def segment_view1_kernel_call(
    c: jnp.ndarray,
    x: jnp.ndarray,
    l: jnp.ndarray,
    seg: jnp.ndarray,
    num_groups: int,
    bm: int = DEFAULT_BM,
    interpret: bool = True,
) -> jnp.ndarray:
    """Degree-1 variant: packed [num_groups, K+2] rows [c, x·c, l]."""
    m, k = l.shape
    assert m % bm == 0, (m, bm)
    assert c.shape == (m, 1) and x.shape == (m, 1), (c.shape, x.shape)
    assert seg.shape == (m, 1), seg.shape
    assert num_groups * (k + 2) * 4 <= VMEM_ACC_BYTES, (
        f"accumulator {num_groups}x{k + 2} exceeds VMEM budget — "
        "chunk groups in the wrapper"
    )
    nm = m // bm
    kernel = functools.partial(_segment_view1_kernel, num_groups=num_groups)
    return pl.pallas_call(
        kernel,
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, 1), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, k), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, 1), lambda mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((num_groups, k + 2), lambda mm: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, k + 2), jnp.float32),
        interpret=interpret,
    )(c, x, l, seg)


def _segment_reduce_kernel(data_ref, seg_ref, out_ref, *, num_groups: int):
    m = pl.program_id(0)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        _onehot(seg_ref[...], num_groups),
        data_ref[...].astype(jnp.float32),  # [bm, w] — packed c|l|q payload
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("num_groups", "bm", "interpret"))
def segment_reduce_kernel_call(
    data: jnp.ndarray,
    seg: jnp.ndarray,
    num_groups: int,
    bm: int = DEFAULT_BM,
    interpret: bool = True,
) -> jnp.ndarray:
    """Multi-block segment reduce: data [M, W] (all of a view's c/l/q blocks
    packed side by side by the wrapper), seg [M, 1] int32 with padding rows
    set to ``num_groups``; M % bm == 0.  Returns fp32 [num_groups, W] — ONE
    kernel call in place of one scatter dispatch per block.  Use
    ``ops.segment_blocks`` generally."""
    m, w = data.shape
    assert m % bm == 0, (m, bm)
    assert seg.shape == (m, 1), seg.shape
    assert num_groups * w * 4 <= VMEM_ACC_BYTES, (
        f"accumulator {num_groups}x{w} exceeds VMEM budget — "
        "chunk groups in the wrapper"
    )
    nm = m // bm
    kernel = functools.partial(_segment_reduce_kernel, num_groups=num_groups)
    return pl.pallas_call(
        kernel,
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, w), lambda mm: (mm, 0)),
            pl.BlockSpec((bm, 1), lambda mm: (mm, 0)),
        ],
        out_specs=pl.BlockSpec((num_groups, w), lambda mm: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, w), jnp.float32),
        interpret=interpret,
    )(data, seg)
