"""Fused feature-scaling moments kernel: one pass → (Σx, max|x|).

Paper §4.2 runs two SQL aggregates (AVG, MAX(ABS)) per feature over the
union of relations containing it.  A memory-bound op like this should touch
HBM exactly once, so the kernel fuses both reductions into a single stream:
each [bm, 1] block is reduced on the VPU and folded into two scalar
accumulators held in VMEM across the 1-D grid.

Padding: the wrapper zero-pads to a block multiple — zeros do not change the
sum, and max(|x|, 0) = max|x| since |·| ≥ 0.  The true element count is
returned by the wrapper (it is static), completing the AVG.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["moments_kernel_call"]

DEFAULT_BM = 1024


def _moments_kernel(x_ref, sum_ref, max_ref):
    m = pl.program_id(0)

    @pl.when(m == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        max_ref[...] = jnp.zeros_like(max_ref)

    x = x_ref[...]  # [bm, 1]
    sum_ref[0, 0] += jnp.sum(x)
    max_ref[0, 0] = jnp.maximum(max_ref[0, 0], jnp.max(jnp.abs(x)))


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def moments_kernel_call(
    x: jnp.ndarray, bm: int = DEFAULT_BM, interpret: bool = True
):
    """Raw pallas_call on a padded [M, 1] column (M % bm == 0).
    Returns (sum [1,1], maxabs [1,1]) fp32.  Use ``ops.moments``."""
    m, one = x.shape
    assert one == 1 and m % bm == 0, x.shape
    nm = m // bm
    return pl.pallas_call(
        _moments_kernel,
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, 1), lambda mm: (mm, 0))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda mm: (0, 0)),
            pl.BlockSpec((1, 1), lambda mm: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
