"""Public jit'd wrappers around the Pallas kernels.

Handle padding/blocking so callers pass arbitrary shapes; select interpret
mode automatically off-TPU (the kernels TARGET TPU; interpret=True executes
the kernel body in Python for CPU validation, per the repo's dry-run-first
methodology).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .flash import DEFAULT_BK as FL_BK, DEFAULT_BQ as FL_BQ, flash_kernel_call
from .gram import DEFAULT_BK, DEFAULT_BM, gram_kernel_call
from .moments import DEFAULT_BM as MOM_BM, moments_kernel_call
from .segment_gram import (
    DEFAULT_BM as SEG_BM,
    VMEM_ACC_BYTES,
    multi_segment_gram_kernel_call,
    segment_gram_kernel_call,
)

__all__ = [
    "gram",
    "segment_gram",
    "multi_segment_gram",
    "moments",
    "flash_attention",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def gram(
    x: jnp.ndarray,
    bm: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """X^T X for any [M, K]; fp32 result. Pads to block multiples with zeros
    (zero rows/cols are Gram-neutral) and slices the result back."""
    if interpret is None:
        interpret = not on_tpu()
    m, k = x.shape
    bm = bm or min(DEFAULT_BM, _round_up(max(m, 1), 8))
    bk = bk or min(DEFAULT_BK, _round_up(max(k, 1), 128))
    mp, kp = _round_up(max(m, 1), bm), _round_up(max(k, 1), bk)
    xp = jnp.zeros((mp, kp), dtype=x.dtype).at[:m, :k].set(x)
    out = gram_kernel_call(xp, bm=bm, bk=bk, interpret=interpret)
    return out[:k, :k]


def segment_gram(
    x: jnp.ndarray,
    seg: jnp.ndarray,
    num_groups: int,
    bm: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
) -> jnp.ndarray:
    """Per-group Gram for any [M, K] + int seg [M]; fp32 [G, K, K].

    Pads rows with out-of-range segment id (one-hot row of zeros ⇒ no
    contribution).  If the [G, K, K] accumulator would exceed the VMEM
    budget (``vmem_budget``, default ``VMEM_ACC_BYTES``; override only to
    force the chunked path, e.g. in tests), groups are processed in chunks
    with ids rebased per chunk.
    """
    if interpret is None:
        interpret = not on_tpu()
    budget = min(vmem_budget or VMEM_ACC_BYTES, VMEM_ACC_BYTES)
    m, k = x.shape
    bm = bm or min(SEG_BM, _round_up(max(m, 1), 8))
    mp = _round_up(max(m, 1), bm)
    xp = jnp.zeros((mp, k), dtype=x.dtype).at[:m, :].set(x)

    # -1 leaves room for the +1 out-of-chunk pad group in the chunked path
    g_chunk = max(1, min(num_groups, budget // max(k * k * 4, 1) - 1))
    if g_chunk >= num_groups:
        segp = jnp.full((mp, 1), num_groups, dtype=jnp.int32)
        segp = segp.at[:m, 0].set(seg.astype(jnp.int32))
        return segment_gram_kernel_call(
            xp, segp, num_groups, bm=bm, interpret=interpret
        )
    outs = []
    for g0 in range(0, num_groups, g_chunk):
        gn = min(g_chunk, num_groups - g0)
        rebased = seg.astype(jnp.int32) - g0
        rebased = jnp.where((rebased >= 0) & (rebased < gn), rebased, gn)
        segp = jnp.full((mp, 1), gn, dtype=jnp.int32)
        segp = segp.at[:m, 0].set(rebased)
        # kernel with gn+? : out-of-chunk rows map to id gn -> pad group;
        # allocate gn+1 groups and drop the last.
        out = segment_gram_kernel_call(
            xp, segp, gn + 1, bm=bm, interpret=interpret
        )
        outs.append(out[:gn])
    return jnp.concatenate(outs, axis=0)


def multi_segment_gram(
    x: jnp.ndarray,
    segs: jnp.ndarray,
    num_groups,
    bm: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
):
    """Per-group Grams for SEVERAL segment-id columns in one fused pass.

    ``x`` is any [M, K]; ``segs`` is [M, n_seg] int with column ``i``'s ids
    in ``[0, num_groups[i])``.  Returns a list of fp32 [G_i, K, K] — one
    grouped Gram per segment column — while streaming the data block from
    memory ONCE, instead of re-reading x per column as n_seg separate
    ``segment_gram`` calls would.  Ids are offset into disjoint bands of a
    single [ΣG, K, K] accumulator; padding rows get the out-of-range id ΣG
    (zero one-hot row ⇒ no contribution).  If the fused accumulator would
    exceed the VMEM budget, falls back to per-column ``segment_gram``
    (which chunks groups internally) — correctness never depends on the
    fused path fitting.
    """
    if interpret is None:
        interpret = not on_tpu()
    budget = min(vmem_budget or VMEM_ACC_BYTES, VMEM_ACC_BYTES)
    m, k = x.shape
    num_groups = [int(g) for g in num_groups]
    n_seg = segs.shape[1]
    assert n_seg == len(num_groups), (segs.shape, num_groups)
    if n_seg == 0:
        return []
    total = sum(num_groups)
    if total * k * k * 4 > budget:
        return [
            segment_gram(
                x, segs[:, i], num_groups[i],
                bm=bm, interpret=interpret, vmem_budget=vmem_budget,
            )
            for i in range(n_seg)
        ]
    bm = bm or min(SEG_BM, _round_up(max(m, 1), 8))
    mp = _round_up(max(m, 1), bm)
    xp = jnp.zeros((mp, k), dtype=x.dtype).at[:m, :].set(x)
    offs = np.concatenate([[0], np.cumsum(num_groups)]).astype(np.int32)
    segp = jnp.full((mp, n_seg), total, dtype=jnp.int32)
    segp = segp.at[:m, :].set(
        segs.astype(jnp.int32) + jnp.asarray(offs[:-1])[None, :]
    )
    out = multi_segment_gram_kernel_call(
        xp, segp, total, n_seg, bm=bm, interpret=interpret
    )
    return [out[offs[i] : offs[i + 1]] for i in range(n_seg)]


def flash_attention(
    q: jnp.ndarray,  # [B, Sq, H, D]
    k: jnp.ndarray,  # [B, Sk, KH, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    bq: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused online-softmax attention for arbitrary shapes; returns
    [B, Sq, H, D].  Pads Sq/Sk to block multiples (padding keys are masked
    via ``kv_len``; padding queries are sliced off).  GQA KV heads are
    broadcast to query heads before the call — the kernel streams the
    (repeated) K/V tiles from HBM, trading the GQA bandwidth saving for a
    single uniform kernel (measured trade-off documented in
    EXPERIMENTS.md §Perf)."""
    if interpret is None:
        interpret = not on_tpu()
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    bq = bq or min(FL_BQ, _round_up(max(sq, 1), 8))
    bk = bk or min(FL_BK, _round_up(max(sk, 1), 8))
    sqp, skp = _round_up(sq, bq), _round_up(sk, bk)
    qp = jnp.zeros((b * h, sqp, d), qf.dtype).at[:, :sq].set(qf)
    kp = jnp.zeros((b * h, skp, d), kf.dtype).at[:, :sk].set(kf)
    vp = jnp.zeros((b * h, skp, d), vf.dtype).at[:, :sk].set(vf)
    out = flash_kernel_call(
        qp, kp, vp, causal=causal, window=window, kv_len=sk,
        bq=bq, bk=bk, interpret=interpret,
    )
    out = out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    return out


def moments(x: jnp.ndarray, bm: int | None = None, interpret: bool | None = None):
    """(Σx, max|x|, count) for a 1-D column in one fused pass."""
    if interpret is None:
        interpret = not on_tpu()
    (m,) = x.shape
    bm = bm or min(MOM_BM, _round_up(max(m, 1), 8))
    mp = _round_up(max(m, 1), bm)
    xp = jnp.zeros((mp, 1), dtype=x.dtype).at[:m, 0].set(x)
    s, mx = moments_kernel_call(xp, bm=bm, interpret=interpret)
    return s[0, 0], mx[0, 0], m
