"""Public jit'd wrappers around the Pallas kernels.

Handle padding/blocking so callers pass arbitrary shapes; select interpret
mode automatically off-TPU (the kernels TARGET TPU; interpret=True executes
the kernel body in Python for CPU validation, per the repo's dry-run-first
methodology).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .flash import DEFAULT_BK as FL_BK, DEFAULT_BQ as FL_BQ, flash_kernel_call
from .gram import DEFAULT_BK, DEFAULT_BM, gram_kernel_call
from .moments import DEFAULT_BM as MOM_BM, moments_kernel_call
from .segment_gram import (
    DEFAULT_BM as SEG_BM,
    VMEM_ACC_BYTES,
    multi_segment_gram_kernel_call,
    segment_gram_kernel_call,
)
from .segment_view import (
    DEFAULT_BM as SV_BM,
    segment_reduce_kernel_call,
    segment_view1_kernel_call,
    segment_view_kernel_call,
)

__all__ = [
    "gram",
    "segment_gram",
    "multi_segment_gram",
    "segment_view",
    "segment_blocks",
    "group_ids_device",
    "fast_device_grouping",
    "moments",
    "flash_attention",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def gram(
    x: jnp.ndarray,
    bm: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """X^T X for any [M, K]; fp32 result. Pads to block multiples with zeros
    (zero rows/cols are Gram-neutral) and slices the result back."""
    if interpret is None:
        interpret = not on_tpu()
    m, k = x.shape
    bm = bm or min(DEFAULT_BM, _round_up(max(m, 1), 8))
    bk = bk or min(DEFAULT_BK, _round_up(max(k, 1), 128))
    mp, kp = _round_up(max(m, 1), bm), _round_up(max(k, 1), bk)
    xp = jnp.zeros((mp, kp), dtype=x.dtype).at[:m, :k].set(x)
    out = gram_kernel_call(xp, bm=bm, bk=bk, interpret=interpret)
    return out[:k, :k]


def segment_gram(
    x: jnp.ndarray,
    seg: jnp.ndarray,
    num_groups: int,
    bm: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
) -> jnp.ndarray:
    """Per-group Gram for any [M, K] + int seg [M]; fp32 [G, K, K].

    Pads rows with out-of-range segment id (one-hot row of zeros ⇒ no
    contribution).  If the [G, K, K] accumulator would exceed the VMEM
    budget (``vmem_budget``, default ``VMEM_ACC_BYTES``; override only to
    force the chunked path, e.g. in tests), groups are processed in chunks
    with ids rebased per chunk.
    """
    if interpret is None:
        interpret = not on_tpu()
    budget = min(vmem_budget or VMEM_ACC_BYTES, VMEM_ACC_BYTES)
    m, k = x.shape
    bm = bm or min(SEG_BM, _round_up(max(m, 1), 8))
    mp = _round_up(max(m, 1), bm)
    xp = jnp.zeros((mp, k), dtype=x.dtype).at[:m, :].set(x)

    # -1 leaves room for the +1 out-of-chunk pad group in the chunked path
    g_chunk = max(1, min(num_groups, budget // max(k * k * 4, 1) - 1))
    if g_chunk >= num_groups:
        segp = jnp.full((mp, 1), num_groups, dtype=jnp.int32)
        segp = segp.at[:m, 0].set(seg.astype(jnp.int32))
        return segment_gram_kernel_call(
            xp, segp, num_groups, bm=bm, interpret=interpret
        )
    outs = []
    for g0 in range(0, num_groups, g_chunk):
        gn = min(g_chunk, num_groups - g0)
        rebased = seg.astype(jnp.int32) - g0
        rebased = jnp.where((rebased >= 0) & (rebased < gn), rebased, gn)
        segp = jnp.full((mp, 1), gn, dtype=jnp.int32)
        segp = segp.at[:m, 0].set(rebased)
        # kernel with gn+? : out-of-chunk rows map to id gn -> pad group;
        # allocate gn+1 groups and drop the last.
        out = segment_gram_kernel_call(
            xp, segp, gn + 1, bm=bm, interpret=interpret
        )
        outs.append(out[:gn])
    return jnp.concatenate(outs, axis=0)


def multi_segment_gram(
    x: jnp.ndarray,
    segs: jnp.ndarray,
    num_groups,
    bm: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
):
    """Per-group Grams for SEVERAL segment-id columns in one fused pass.

    ``x`` is any [M, K]; ``segs`` is [M, n_seg] int with column ``i``'s ids
    in ``[0, num_groups[i])``.  Returns a list of fp32 [G_i, K, K] — one
    grouped Gram per segment column — while streaming the data block from
    memory ONCE, instead of re-reading x per column as n_seg separate
    ``segment_gram`` calls would.  Ids are offset into disjoint bands of a
    single [ΣG, K, K] accumulator; padding rows get the out-of-range id ΣG
    (zero one-hot row ⇒ no contribution).  If the fused accumulator would
    exceed the VMEM budget, falls back to per-column ``segment_gram``
    (which chunks groups internally) — correctness never depends on the
    fused path fitting.
    """
    if interpret is None:
        interpret = not on_tpu()
    budget = min(vmem_budget or VMEM_ACC_BYTES, VMEM_ACC_BYTES)
    m, k = x.shape
    num_groups = [int(g) for g in num_groups]
    n_seg = segs.shape[1]
    assert n_seg == len(num_groups), (segs.shape, num_groups)
    if n_seg == 0:
        return []
    total = sum(num_groups)
    if total * k * k * 4 > budget:
        return [
            segment_gram(
                x, segs[:, i], num_groups[i],
                bm=bm, interpret=interpret, vmem_budget=vmem_budget,
            )
            for i in range(n_seg)
        ]
    bm = bm or min(SEG_BM, _round_up(max(m, 1), 8))
    mp = _round_up(max(m, 1), bm)
    xp = jnp.zeros((mp, k), dtype=x.dtype).at[:m, :].set(x)
    offs = np.concatenate([[0], np.cumsum(num_groups)]).astype(np.int32)
    segp = jnp.full((mp, n_seg), total, dtype=jnp.int32)
    segp = segp.at[:m, :].set(
        segs.astype(jnp.int32) + jnp.asarray(offs[:-1])[None, :]
    )
    out = multi_segment_gram_kernel_call(
        xp, segp, total, n_seg, bm=bm, interpret=interpret
    )
    return [out[offs[i] : offs[i + 1]] for i in range(n_seg)]


@functools.partial(jax.jit, static_argnames=("num_groups",))
def _sv_xla_deg1(c, x, l, seg, num_groups: int):
    ext = jnp.concatenate([c[:, None], (x * c)[:, None], l], axis=1)
    return jax.ops.segment_sum(ext, seg, num_segments=num_groups)


@functools.partial(jax.jit, static_argnames=("num_groups",))
def _sv_xla_deg2(c, x, l, q, seg, num_groups: int):
    # compact payload: the packed [k+2, k+2] matrix is symmetric with
    # duplicated borders, so only the 3 + 2k + k² distinct sums go through
    # the row-sized assemble + scatter; the packed form is rebuilt from
    # the [G]-sized sums afterwards (G ≪ N — negligible traffic).
    n, k = l.shape
    xc = x * c
    xl = x[:, None] * l
    payload = jnp.concatenate(
        [
            c[:, None],
            xc[:, None],
            (x * xc)[:, None],
            l,
            xl,
            q.reshape(n, k * k),
        ],
        axis=1,
    )
    s = jax.ops.segment_sum(payload, seg, num_segments=num_groups)
    sc, sxc, sx2c = s[:, :1], s[:, 1:2], s[:, 2:3]
    sl = s[:, 3 : 3 + k]
    sxl = s[:, 3 + k : 3 + 2 * k]
    sq = s[:, 3 + 2 * k :].reshape(num_groups, k, k)
    row0 = jnp.concatenate([sc, sxc, sl], axis=1)
    row1 = jnp.concatenate([sxc, sx2c, sxl], axis=1)
    rest = jnp.concatenate([sl[:, :, None], sxl[:, :, None], sq], axis=2)
    return jnp.concatenate(
        [row0[:, None, :], row1[:, None, :], rest], axis=1
    )


def _sv_packed(c, x, l, q, seg, gcount, degree, impl, bm, interpret):
    """One chunk of the fused extend-and-group, in the packed layout of
    ``segment_view_kernel_call``; ``seg`` ids ≥ ``gcount`` contribute
    nothing (scatter drop / zero one-hot row)."""
    if impl == "xla":
        if degree == 1:
            return _sv_xla_deg1(c, x, l, seg, gcount)
        return _sv_xla_deg2(c, x, l, q, seg, gcount)
    m, k = l.shape
    # Pallas BlockSpecs reject zero-width blocks: pad k=0 views with one
    # zero feature column (Gram-neutral) and slice the packed result back.
    ke = max(k, 1)
    bmv = bm or min(SV_BM, _round_up(max(m, 1), 8))
    mp = _round_up(max(m, 1), bmv)
    cp = jnp.zeros((mp, 1), c.dtype).at[:m, 0].set(c)
    xv = jnp.zeros((mp, 1), x.dtype).at[:m, 0].set(x)
    lp = jnp.zeros((mp, ke), l.dtype).at[:m, :k].set(l)
    segp = jnp.full((mp, 1), gcount, jnp.int32).at[:m, 0].set(seg)
    if degree == 1:
        out = segment_view1_kernel_call(
            cp, xv, lp, segp, gcount, bm=bmv, interpret=interpret
        )
        return out[:, : k + 2]
    qp = jnp.zeros((mp, ke * ke), q.dtype).at[:m, : k * k].set(
        q.reshape(m, k * k)
    )
    out = segment_view_kernel_call(
        cp, xv, lp, qp, segp, gcount, bm=bmv, interpret=interpret
    )
    return out[:, : k + 2, : k + 2]


def segment_view(
    c: jnp.ndarray,
    x: jnp.ndarray,
    l: jnp.ndarray,
    q: jnp.ndarray | None,
    seg: jnp.ndarray,
    num_groups: int,
    *,
    degree: int = 2,
    bm: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
    impl: str | None = None,
):
    """Fused traversal node: extend a view's blocks with feature ``x`` AND
    GROUP BY in one pass — ``(c [M], l [M, k], q [M, k, k])`` plus seg ids
    become ``(c' [G], l' [G, k+1], q' [G, k+1, k+1])`` with the feature
    prepended, and the extended ``[M, k+1, k+1]`` tensor never hits HBM.

    ``impl='pallas'`` is the TPU kernel (default on TPU; interpret mode
    elsewhere is for validation only).  ``impl='xla'`` (default off-TPU) is
    the same one-dispatch fusion expressed as a jitted assemble +
    ``jax.ops.segment_sum`` — the honest compiled fallback this container
    benchmarks.  If the packed ``[G, k+2, k+2]`` accumulator exceeds
    ``vmem_budget`` groups are processed in chunks with ids rebased per
    chunk, exactly like ``segment_gram``.  Returns blocks in ``c``'s dtype.
    """
    if degree not in (1, 2):
        raise ValueError(f"segment_view needs degree 1 or 2, got {degree}")
    if impl is None:
        impl = "pallas" if on_tpu() else "xla"
    if interpret is None:
        interpret = not on_tpu()
    budget = min(vmem_budget or VMEM_ACC_BYTES, VMEM_ACC_BYTES)
    c, x, l = jnp.asarray(c), jnp.asarray(x), jnp.asarray(l)
    q = jnp.asarray(q) if degree == 2 else None
    k = l.shape[1]
    width = (k + 2) * (k + 2) if degree == 2 else (k + 2)
    seg = jnp.asarray(seg).astype(jnp.int32)
    # -1 leaves room for the +1 out-of-chunk pad group in the chunked path
    g_chunk = max(1, min(num_groups, budget // max(width * 4, 1) - 1))
    if g_chunk >= num_groups:
        packed = _sv_packed(
            c, x, l, q, seg, num_groups, degree, impl, bm, interpret
        )
    else:
        outs = []
        for g0 in range(0, num_groups, g_chunk):
            gn = min(g_chunk, num_groups - g0)
            rebased = seg - g0
            rebased = jnp.where((rebased >= 0) & (rebased < gn), rebased, gn)
            out = _sv_packed(
                c, x, l, q, rebased, gn + 1, degree, impl, bm, interpret
            )
            outs.append(out[:gn])
        packed = jnp.concatenate(outs, axis=0)
    packed = packed.astype(c.dtype)
    if degree == 2:
        return packed[:, 0, 0], packed[:, 1:, 0], packed[:, 1:, 1:]
    return packed[:, 0], packed[:, 1:], None


def segment_blocks(
    c: jnp.ndarray,
    l: jnp.ndarray | None,
    q: jnp.ndarray | None,
    seg: jnp.ndarray,
    num_groups: int,
    *,
    degree: int = 2,
    bm: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
    impl: str | None = None,
):
    """Segment-reduce ALL of a view's blocks in one call: c [M] (+ l [M, k]
    + q [M, k, k] per ``degree``) packed side by side through a single
    kernel dispatch instead of one scatter per block.  Same impl/chunking
    contract as :func:`segment_view`; returns ``(c', l', q')`` with Nones
    past ``degree``, in ``c``'s dtype."""
    if impl is None:
        impl = "pallas" if on_tpu() else "xla"
    if interpret is None:
        interpret = not on_tpu()
    budget = min(vmem_budget or VMEM_ACC_BYTES, VMEM_ACC_BYTES)
    c = jnp.asarray(c)
    m = c.shape[0]
    k = l.shape[1] if degree >= 1 else 0
    parts = [c[:, None]]
    if degree >= 1:
        parts.append(jnp.asarray(l))
    if degree == 2:
        parts.append(jnp.asarray(q).reshape(m, k * k))
    data = jnp.concatenate(parts, axis=1)
    w = data.shape[1]
    seg = jnp.asarray(seg).astype(jnp.int32)
    g_chunk = max(1, min(num_groups, budget // max(w * 4, 1) - 1))

    def reduce_chunk(ids, gcount):
        if impl == "xla":
            return jax.ops.segment_sum(data, ids, num_segments=gcount)
        bmv = bm or min(SV_BM, _round_up(max(m, 1), 8))
        mp = _round_up(max(m, 1), bmv)
        dp = jnp.zeros((mp, w), data.dtype).at[:m].set(data)
        segp = jnp.full((mp, 1), gcount, jnp.int32).at[:m, 0].set(ids)
        return segment_reduce_kernel_call(
            dp, segp, gcount, bm=bmv, interpret=interpret
        )

    if g_chunk >= num_groups:
        out = reduce_chunk(seg, num_groups)
    else:
        outs = []
        for g0 in range(0, num_groups, g_chunk):
            gn = min(g_chunk, num_groups - g0)
            rebased = seg - g0
            rebased = jnp.where((rebased >= 0) & (rebased < gn), rebased, gn)
            outs.append(reduce_chunk(rebased, gn + 1)[:gn])
        out = jnp.concatenate(outs, axis=0)
    out = out.astype(c.dtype)
    c_new = out[:, 0]
    l_new = out[:, 1 : 1 + k] if degree >= 1 else None
    q_new = (
        out[:, 1 + k :].reshape(num_groups, k, k) if degree == 2 else None
    )
    return c_new, l_new, q_new


def fast_device_grouping() -> bool:
    """Whether :func:`group_ids_device` beats host ``np.unique`` here.
    XLA's CPU sort is single-threaded and measurably slower than numpy's —
    the device path pays off only where the sort actually runs on an
    accelerator (and the ids would otherwise round-trip to the host)."""
    return jax.default_backend() != "cpu"


@jax.jit
def _group_ids_jit(key):
    order = jnp.argsort(key, stable=True)
    sk = jnp.take(key, order)
    start = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sk[1:] != sk[:-1]]
    )
    gid = jnp.cumsum(start.astype(jnp.int32)) - 1
    inv = jnp.zeros_like(gid).at[order].set(gid)
    return order, start, inv


def group_ids_device(key) -> tuple:
    """Device-resident GROUP BY ids: stable sort + adjacent-difference run
    detection instead of host ``np.unique``.  Returns ``(seg, num_groups,
    first)`` bit-compatible with ``np.unique(key, return_index=True,
    return_inverse=True)`` — groups numbered in ascending key order, and
    ``first`` (host int array) the first occurrence of each group, ready to
    gather host key columns.  ``seg`` stays on device, feeding
    :func:`segment_view` / :func:`segment_blocks` without a host round-trip
    of the per-row ids."""
    key = jnp.asarray(key)
    if key.shape[0] == 0:
        return jnp.zeros((0,), jnp.int32), 0, np.zeros((0,), np.int64)
    order, start, inv = _group_ids_jit(key)
    first = np.asarray(order)[np.asarray(start)].astype(np.int64)
    return inv, int(first.shape[0]), first


def flash_attention(
    q: jnp.ndarray,  # [B, Sq, H, D]
    k: jnp.ndarray,  # [B, Sk, KH, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    bq: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused online-softmax attention for arbitrary shapes; returns
    [B, Sq, H, D].  Pads Sq/Sk to block multiples (padding keys are masked
    via ``kv_len``; padding queries are sliced off).  GQA KV heads are
    broadcast to query heads before the call — the kernel streams the
    (repeated) K/V tiles from HBM, trading the GQA bandwidth saving for a
    single uniform kernel (measured trade-off documented in
    EXPERIMENTS.md §Perf)."""
    if interpret is None:
        interpret = not on_tpu()
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    bq = bq or min(FL_BQ, _round_up(max(sq, 1), 8))
    bk = bk or min(FL_BK, _round_up(max(sk, 1), 8))
    sqp, skp = _round_up(sq, bq), _round_up(sk, bk)
    qp = jnp.zeros((b * h, sqp, d), qf.dtype).at[:, :sq].set(qf)
    kp = jnp.zeros((b * h, skp, d), kf.dtype).at[:, :sk].set(kf)
    vp = jnp.zeros((b * h, skp, d), vf.dtype).at[:, :sk].set(vf)
    out = flash_kernel_call(
        qp, kp, vp, causal=causal, window=window, kv_len=sk,
        bq=bq, bk=bk, interpret=interpret,
    )
    out = out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    return out


def moments(x: jnp.ndarray, bm: int | None = None, interpret: bool | None = None):
    """(Σx, max|x|, count) for a 1-D column in one fused pass."""
    if interpret is None:
        interpret = not on_tpu()
    (m,) = x.shape
    bm = bm or min(MOM_BM, _round_up(max(m, 1), 8))
    mp = _round_up(max(m, 1), bm)
    xp = jnp.zeros((mp, 1), dtype=x.dtype).at[:m, 0].set(x)
    s, mx = moments_kernel_call(xp, bm=bm, interpret=interpret)
    return s[0, 0], mx[0, 0], m
