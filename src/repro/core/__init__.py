"""Core factorized-learning engine — the paper's primary contribution.

Layering (paper section in parentheses):

* ``relation`` / ``store``      — columnar in-memory database (§4, HyPer role)
* ``variable_order``            — extended variable orders (§2.2, §4.1)
* ``factorize``                 — degree-≤2 aggregate pushdown (§2.3, §4.3)
* ``cofactor``                  — factorized vs materialized cofactors (§3.4)
* ``gd``                        — BGD on cofactor matrices (§4.4)
* ``scaling``                   — feature scaling + θ rescale (§3.3, §4.2)
* ``regression``                — the full pipeline + Table-2 versions (§4.5)
* ``fd``                        — functional dependencies: catalog,
                                  FD-reduced solving, closed-form recovery
* ``categorical``               — sparse categorical cofactors (AC/DC-style)
* ``glm``                       — logistic/Poisson over the compressed join
* ``polynomial``                — beyond-paper degree-d extension (§6 outlook)
* ``distributed``               — union-commutativity as data parallelism
* ``view_cache``                — persistent cross-batch per-node view cache
                                  (store-owned, delta-maintained under append)
* ``delta_log``                 — pending-append log behind lazy maintenance
                                  (O(delta) writes, read-time draining)
* ``api``                       — the ``StoreReads`` Protocol: the explicit
                                  Store/StoreSnapshot read contract
"""

from .api import StoreReads
from .categorical import (
    CatCofactors,
    SparseCounts,
    cat_cofactors_factorized,
    cat_cofactors_from_arrays,
    cat_cofactors_materialized,
    cat_cofactors_per_pass,
    onehot_design_matrix,
)
from .cofactor import (
    Cofactors,
    cofactors_factorized,
    cofactors_from_matrix,
    cofactors_grouped,
    cofactors_materialized,
    cofactors_row_engine,
    cofactors_streaming,
    design_matrix,
    iter_design_chunks,
)
from .factorize import (
    AggregateBlock,
    AggregateQuery,
    FactorizedEngine,
    GroupedView,
    grouped_cofactors_factorized,
)
from .fd import (
    FDReduction,
    FunctionalDependency,
    expand_cat_cofactors,
    penalty_blocks,
    recover_blocks,
)
from .delta_log import DeltaLog, RelationLog
from .gd import GDConfig, GDResult, bgd_cofactor, bgd_data, solve_cofactor
from .glm import (
    CompressedDesign,
    GLMConfig,
    GLMResult,
    compressed_design_factorized,
    compressed_design_materialized,
    fit_glm,
    fit_glm_onehot,
    glm_regression,
)
from .regression import (
    VERSIONS,
    RegressionConfig,
    RegressionResult,
    linear_regression,
)
from .relation import Dictionary, Relation
from .scaling import (
    ScaleFactors,
    compute_scale_factors,
    predict,
    rescale_theta,
)
from .store import Store, StoreSnapshot
from .variable_order import (
    INTERCEPT,
    VariableOrder,
    validate,
    variable_order_from_store,
)
from .view_cache import ViewCache, ViewKey

__all__ = [
    "AggregateBlock",
    "AggregateQuery",
    "CatCofactors",
    "Cofactors",
    "CompressedDesign",
    "DeltaLog",
    "Dictionary",
    "FactorizedEngine",
    "FDReduction",
    "FunctionalDependency",
    "GDConfig",
    "GDResult",
    "GLMConfig",
    "GLMResult",
    "GroupedView",
    "INTERCEPT",
    "Relation",
    "RegressionConfig",
    "RegressionResult",
    "RelationLog",
    "ScaleFactors",
    "SparseCounts",
    "Store",
    "StoreReads",
    "StoreSnapshot",
    "VariableOrder",
    "VERSIONS",
    "ViewCache",
    "ViewKey",
    "bgd_cofactor",
    "bgd_data",
    "cat_cofactors_factorized",
    "cat_cofactors_from_arrays",
    "cat_cofactors_materialized",
    "cat_cofactors_per_pass",
    "cofactors_factorized",
    "compressed_design_factorized",
    "compressed_design_materialized",
    "expand_cat_cofactors",
    "fit_glm",
    "fit_glm_onehot",
    "glm_regression",
    "grouped_cofactors_factorized",
    "penalty_blocks",
    "recover_blocks",
    "onehot_design_matrix",
    "cofactors_from_matrix",
    "cofactors_grouped",
    "cofactors_materialized",
    "cofactors_row_engine",
    "cofactors_streaming",
    "compute_scale_factors",
    "design_matrix",
    "iter_design_chunks",
    "linear_regression",
    "predict",
    "rescale_theta",
    "solve_cofactor",
    "validate",
    "variable_order_from_store",
]
