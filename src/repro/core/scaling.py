"""Feature scaling on a variable order (paper §3.3, §4.2) and θ rescaling.

``compute_scale_factors`` mirrors the paper's ``scaleFeatures(...)``:

* For every feature column, the average and max-absolute value are computed
  over the **union of all relations containing that column** (not over the
  join!) so every occurrence is scaled by the same factors and equi-joins
  survive rescaling (x = y  ⇔  (x−a)/b = (y−a)/b).
* The paper creates rescaled SQL *views* over the base tables; the exact
  analogue here is **lazy transformation**: base columns (and dictionary-
  encoded key ids) are never rewritten — consumers apply
  ``ScaleFactors.transform`` at value-access time (the factorized engine at
  feature extension, the materialized path at design-matrix extraction).
* The paper runs one SQL query per feature in parallel via OpenMP; here each
  union reduction is a vectorized pass (optionally the fused Pallas
  ``moments`` kernel), and cross-chip the same reduction is a ``psum``.

Label convention (reconstructed from the paper's Table 2 — documented in
DESIGN.md): the label is **mean-centered but not max-scaled**.  This makes
the paper's version-1 rescaling (θ_j = θ_j,conv / max_j and
θ₀ = avg_label − Σ θ_j·avg_j) agree with the exact closed-form inversion of
§3.3, and makes versions 5/6 (which replace avg_label with θ₀,conv) produce
the "huge error" the paper reports — θ₀ is then off by roughly the label
mean.

θ ordering everywhere: [intercept, features..., label].
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from .store import Store

__all__ = ["ScaleFactors", "compute_scale_factors", "rescale_theta", "predict"]


@dataclasses.dataclass
class ScaleFactors:
    """Per-column (avg, max|·|) — the paper's ``scaleFactors`` struct."""

    avg: Dict[str, float]
    max: Dict[str, float]
    features: List[str]
    label: str

    def __contains__(self, attr: str) -> bool:
        return attr in self.avg

    def transform(self, attr: str, x: np.ndarray):
        """Apply (x − avg)/max — the paper's x_conv (lazy view semantics)."""
        if attr not in self.avg:
            return x
        return (x - self.avg[attr]) / self.max[attr]


def _union_moments(store: Store, col: str, use_kernel: bool = False):
    """avg and max|x| of ``col`` over the union of relations containing it.

    Key attributes participate through their dense numeric encoding (the
    paper numerically encodes categorical-ish columns like ``date``).  The
    default path reads the store's maintained moments cache (O(1) after
    appends); ``use_kernel`` forces a fresh fused-pass reduction through the
    Pallas ``moments`` kernel."""
    if not use_kernel:
        s, mx, cnt = store.column_moments(col)
        return s / cnt, mx
    chunks = [
        rel.column(col).astype(np.float64)
        for rel in store.relations()
        if col in rel.values or col in rel.keys
    ]
    if not chunks:
        raise ValueError(f"column {col} not found in any relation")
    allv = np.concatenate(chunks)
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    s, mx, cnt = kops.moments(jnp.asarray(allv, dtype=jnp.float32))
    return float(s) / float(cnt), float(mx)


def compute_scale_factors(
    store: Store,
    features: Sequence[str],
    label: str,
    use_kernel: bool = False,
) -> ScaleFactors:
    """Compute per-feature scale factors (paper §4.2).  One union-reduction
    per column; the intercept is never rescaled; the label is centered only."""
    avg: Dict[str, float] = {}
    mx: Dict[str, float] = {}
    for col in list(features) + [label]:
        a, m = _union_moments(store, col, use_kernel=use_kernel)
        avg[col] = a
        mx[col] = m if (m > 0 and col != label) else 1.0
    return ScaleFactors(avg=avg, max=mx, features=list(features), label=label)


def rescale_theta(
    theta_conv: np.ndarray, factors: ScaleFactors, mode: str = "exact"
) -> np.ndarray:
    """Invert feature scaling on converged θ (paper §3.3 / §4.5).

    Modes:
      * ``exact``       — closed-form inversion of §3.3 (beyond-paper check):
                          θ_j = θ_j,conv / max_j;
                          θ₀ = avg_y + θ₀,conv − Σ θ_j·avg_j.
      * ``avg_label``   — paper versions 1–4: θ₀ = avg_y − Σ θ_j·avg_j
                          (drops θ₀,conv, which is ≈0 at convergence).
      * ``theta0_conv`` — paper versions 5/6: θ₀ = θ₀,conv − Σ θ_j·avg_j
                          (drops avg_y → the "huge error" variant).
    """
    theta_conv = np.asarray(theta_conv, dtype=np.float64)
    feats = factors.features
    theta = theta_conv.copy()
    for j, f in enumerate(feats):
        theta[1 + j] = theta_conv[1 + j] / factors.max[f]
    correction = sum(
        theta[1 + j] * factors.avg[f] for j, f in enumerate(feats)
    )
    avg_y = factors.avg[factors.label]
    if mode == "exact":
        theta[0] = avg_y + theta_conv[0] - correction
    elif mode == "avg_label":
        theta[0] = avg_y - correction
    elif mode == "theta0_conv":
        theta[0] = theta_conv[0] - correction
    else:
        raise ValueError(f"unknown rescale mode {mode}")
    return theta


def predict(x: np.ndarray, theta: np.ndarray) -> np.ndarray:
    """h_θ(x) for a [m, n] feature matrix and θ = [intercept, feats..., label]."""
    n = x.shape[1]
    return theta[0] + x @ theta[1 : 1 + n]
