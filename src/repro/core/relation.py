"""Columnar in-memory relations — the storage layer of the factorized engine.

The paper's "in-memory database system" (HyPer) becomes, on TPU, a columnar
store of dense device arrays:

  * join-key attributes are **dictionary encoded** to contiguous int32 ids
    (the domain is materialized once per attribute, like a DB dictionary),
  * numeric feature attributes are float arrays,
  * multi-attribute keys are packed into a single int64 **composite key**
    with mixed-radix encoding so joins and group-bys reduce to 1-D integer
    sort / searchsorted problems (sort-merge join), which vectorize cleanly.

Structural index computation (join indices, group ids) runs on the host with
numpy — this is the query-plan/executor role the DBMS plays in the paper —
while all value aggregation runs as vectorized jnp ops (XLA), optionally via
the Pallas kernels in ``repro.kernels``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Dictionary",
    "Relation",
    "composite_key",
    "group_key",
    "hash_join_keys",
    "join_keys",
    "radix_fits",
    "sort_merge_join",
    "group_ids",
]


class Dictionary:
    """Dictionary encoding of one key attribute (value <-> dense int id)."""

    def __init__(self, values: Sequence) -> None:
        uniq = sorted(set(values))
        self._val_to_id = {v: i for i, v in enumerate(uniq)}
        self._id_to_val = list(uniq)

    def __len__(self) -> int:
        return len(self._id_to_val)

    def encode(self, values: Sequence) -> np.ndarray:
        return np.asarray([self._val_to_id[v] for v in values], dtype=np.int32)

    def decode(self, ids: Iterable[int]) -> list:
        return [self._id_to_val[int(i)] for i in ids]


@dataclasses.dataclass
class Relation:
    """A named columnar relation.

    ``keys``     : attr -> int32 array [n]   (dictionary-encoded join keys)
    ``values``   : attr -> float array  [n]  (numeric attributes / features)
    ``domains``  : attr -> domain size (for composite-key radix packing)
    """

    name: str
    keys: Dict[str, np.ndarray]
    values: Dict[str, np.ndarray]
    domains: Dict[str, int]

    def __post_init__(self) -> None:
        n = self.num_rows
        for attr, col in {**self.keys, **self.values}.items():
            if len(col) != n:
                raise ValueError(
                    f"relation {self.name}: column {attr} has {len(col)} rows, "
                    f"expected {n}"
                )
        for attr, col in self.keys.items():
            if attr not in self.domains:
                self.domains[attr] = int(col.max()) + 1 if len(col) else 1

    # -- construction -------------------------------------------------------
    @staticmethod
    def from_columns(
        name: str,
        key_cols: Mapping[str, Sequence],
        value_cols: Mapping[str, Sequence],
        domains: Optional[Mapping[str, int]] = None,
    ) -> "Relation":
        keys = {
            a: np.asarray(c, dtype=np.int32) for a, c in key_cols.items()
        }
        values = {
            a: np.asarray(c, dtype=np.float64) for a, c in value_cols.items()
        }
        doms = dict(domains or {})
        return Relation(name=name, keys=keys, values=values, domains=doms)

    # -- basic properties ----------------------------------------------------
    @property
    def num_rows(self) -> int:
        for col in self.keys.values():
            return len(col)
        for col in self.values.values():
            return len(col)
        return 0

    @property
    def attributes(self) -> List[str]:
        return list(self.keys) + list(self.values)

    def column(self, attr: str) -> np.ndarray:
        if attr in self.keys:
            return self.keys[attr]
        return self.values[attr]

    def select(self, idx: np.ndarray) -> "Relation":
        return Relation(
            name=self.name,
            keys={a: c[idx] for a, c in self.keys.items()},
            values={a: c[idx] for a, c in self.values.items()},
            domains=dict(self.domains),
        )

    def concat(self, other: "Relation") -> "Relation":
        """Row-wise union with ``other`` (same attribute sets required).

        Domains merge per attribute with ``max`` so existing dictionary ids
        stay valid and new ids from ``other`` extend the domain — the
        building block of ``Store.append``.
        """
        if set(other.keys) != set(self.keys) or set(other.values) != set(
            self.values
        ):
            raise ValueError(
                f"cannot concat {other.name} into {self.name}: attribute "
                f"sets differ ({sorted(other.attributes)} vs "
                f"{sorted(self.attributes)})"
            )
        keys = {
            a: np.concatenate([c, other.keys[a]]) for a, c in self.keys.items()
        }
        values = {
            a: np.concatenate([c, other.values[a]])
            for a, c in self.values.items()
        }
        domains = {
            a: max(self.domains.get(a, 0), other.domains.get(a, 0))
            for a in set(self.domains) | set(other.domains)
        }
        return Relation(self.name, keys, values, domains)

    def with_value(self, attr: str, col: np.ndarray) -> "Relation":
        values = dict(self.values)
        values[attr] = np.asarray(col, dtype=np.float64)
        return Relation(self.name, dict(self.keys), values, dict(self.domains))

    def rows(self) -> np.ndarray:
        """Materialize all columns as a dense [n, n_attr] float matrix."""
        cols = [self.column(a).astype(np.float64) for a in self.attributes]
        if not cols:
            return np.zeros((0, 0))
        return np.stack(cols, axis=1)


# ---------------------------------------------------------------------------
# Composite keys, joins, group-by: the host-side "query executor".
# ---------------------------------------------------------------------------

def composite_key(
    cols: Sequence[np.ndarray], domains: Sequence[int]
) -> np.ndarray:
    """Pack multiple int key columns into one int64 via mixed-radix encoding."""
    if not cols:
        # A zero-attribute key: every row in the same (single) group.
        raise ValueError("composite_key requires at least one column")
    if not radix_fits(domains):
        raise OverflowError("composite key domain exceeds int64 range")
    out = np.zeros_like(cols[0], dtype=np.int64)
    for col, dom in zip(cols, domains):
        out = out * max(int(dom), 1) + col.astype(np.int64)
    return out


def radix_fits(domains: Sequence[int]) -> bool:
    """Whether the mixed-radix domain product stays inside the int64 budget
    (``max // 4`` headroom) — the single overflow rule: ``composite_key``
    raises when this is False, ``join_keys`` switches to the hash join."""
    total = 1
    limit = np.iinfo(np.int64).max // 4
    for d in domains:
        total *= max(int(d), 1)
        if total > limit:
            return False
    return True


def hash_join_keys(
    left_cols: Sequence[np.ndarray], right_cols: Sequence[np.ndarray]
) -> Tuple[np.ndarray, np.ndarray]:
    """Dictionary-encoded join keys with no radix limit.

    Densifies the *concatenation* of both sides' key tuples to their
    observed uniques (the hash-join build side, vectorized as np.unique),
    so equal tuples receive equal codes **across both inputs** — exactly
    the contract an equi-join needs, which the within-call-only
    :func:`group_key` cannot give for two separately-coded inputs.  Codes
    are call-local: never mix keys from different calls.
    """
    if not left_cols:
        raise ValueError("hash_join_keys requires at least one column")
    nl = len(left_cols[0])
    cols, doms = [], []
    for lc, rc in zip(left_cols, right_cols):
        col = np.concatenate([lc, rc]).astype(np.int64)
        cols.append(col)
        doms.append(int(col.max()) + 1 if len(col) else 1)
    # group_key's within-call-only contract is exactly satisfied: both
    # sides are coded in this one call, so equal tuples share a code.
    key = group_key(cols, doms)
    return key[:nl], key[nl:]


def join_keys(
    left_cols: Sequence[np.ndarray],
    right_cols: Sequence[np.ndarray],
    domains: Sequence[int],
) -> Tuple[np.ndarray, np.ndarray]:
    """Join keys for a two-sided equi-join on the same attribute list.

    Strict mixed-radix :func:`composite_key` while the domain product fits
    int64 (cheapest, and codes are globally stable); automatic
    :func:`hash_join_keys` fallback past the limit — many/wide shared
    attributes no longer die with ``OverflowError``.
    """
    if radix_fits(domains):
        return (
            composite_key(left_cols, domains),
            composite_key(right_cols, domains),
        )
    return hash_join_keys(left_cols, right_cols)


def group_key(
    cols: Sequence[np.ndarray], domains: Sequence[int]
) -> np.ndarray:
    """Injective-within-call key over multiple int columns.

    Like :func:`composite_key`, but only guarantees that equal tuples get
    equal codes *within this call* — the contract a GROUP BY needs — so
    when the mixed-radix product would overflow int64 (views keyed by many
    wide attributes, e.g. a fact table with 16 categorical keys) it
    re-densifies the accumulated code to its observed uniques and keeps
    packing.  After densification the accumulated size is bounded by the
    row count, so ``rows · next_domain`` always fits int64.  NOT usable
    for joins: two calls may assign different codes to the same tuple —
    joins must keep :func:`composite_key` (their shared-attribute radix
    products are small).
    """
    if not cols:
        raise ValueError("group_key requires at least one column")
    limit = np.iinfo(np.int64).max // 4
    out = cols[0].astype(np.int64)
    size = max(int(domains[0]), 1)
    for col, dom in zip(cols[1:], domains[1:]):
        dom = max(int(dom), 1)
        if size > limit // dom:
            uniq, inv = np.unique(out, return_inverse=True)
            out = inv.astype(np.int64)
            size = max(len(uniq), 1)
        out = out * dom + col.astype(np.int64)
        size *= dom
    return out


def sort_merge_join(
    left_key: np.ndarray, right_key: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Equi-join two composite key columns.

    Returns index arrays ``(il, ir)`` of equal length M such that
    ``left_key[il] == right_key[ir]`` enumerates every matching pair —
    the classic sort + searchsorted merge join, fully vectorized.
    """
    order = np.argsort(right_key, kind="stable")
    rsorted = right_key[order]
    lo = np.searchsorted(rsorted, left_key, side="left")
    hi = np.searchsorted(rsorted, left_key, side="right")
    cnt = hi - lo
    il = np.repeat(np.arange(len(left_key)), cnt)
    if len(il) == 0:
        return il.astype(np.int64), il.astype(np.int64)
    starts = np.cumsum(cnt) - cnt
    within = np.arange(len(il)) - np.repeat(starts, cnt)
    ir = order[np.repeat(lo, cnt) + within]
    return il.astype(np.int64), ir.astype(np.int64)


def group_ids(key: np.ndarray) -> Tuple[np.ndarray, np.ndarray, int]:
    """Group rows by composite key.

    Returns (unique_keys, inverse_ids, num_groups); ``inverse_ids`` maps each
    row to its dense group id — the segment ids consumed by ``segment_sum`` /
    the Pallas segment-gram kernel.
    """
    uniq, inv = np.unique(key, return_inverse=True)
    return uniq, inv.astype(np.int32), len(uniq)


def segment_sum_np(data: np.ndarray, seg: np.ndarray, num: int) -> np.ndarray:
    """Host-side segment sum (used by the slow row-engine proxy)."""
    out = np.zeros((num,) + data.shape[1:], dtype=data.dtype)
    np.add.at(out, seg, data)
    return out


def segment_sum_jnp(data, seg, num: int):
    """Device-side segment sum over the leading axis."""
    data = jnp.asarray(data)
    seg = jnp.asarray(seg)
    out = jnp.zeros((num,) + data.shape[1:], dtype=data.dtype)
    return out.at[seg].add(data)
