"""Sparse categorical cofactors — group-by aggregates instead of one-hot.

AC/DC-style treatment of categorical features (Abo Khamis et al.; see
PAPERS.md): a categorical attribute c with domain D_c conceptually enters
the model as D_c one-hot columns, but every cofactor entry those columns
would produce is a **group-by aggregate** over the join —

    intercept × c        SUM(1)            GROUP BY c     → counts [D_c]
    continuous f × c     SUM(x_f)          GROUP BY c     → sums   [D_c]
    c × c (diagonal)     SUM(1)            GROUP BY c     → the same counts
    c × d (c ≠ d)        SUM(1)            GROUP BY c, d  → sparse counts

so the full one-hot cofactor matrix is assembled from a handful of small
grouped arrays plus a sparse co-occurrence tensor, **without ever
materializing the [m, Σ D_c] one-hot design matrix**.  Nonzeros of the c×d
block are bounded by the join size (and usually far below D_c·D_d).

Four computation paths, mirroring ``cofactor.py``'s engine matrix:

* ``cat_cofactors_factorized``   — ONE fused multi-output engine pass: the
  ungrouped Gram block, every GROUP BY c vector and every GROUP BY (c, d)
  co-occurrence ride a single traversal of the variable order
  (``FactorizedEngine.run_batch``), sharing the join descent and the
  per-node view cache AC/DC-style; O(factorization), the flat join never
  materializes, and cofactor time is roughly flat in |cat|.
* ``cat_cofactors_per_pass``     — the pre-fusion baseline: one grouped
  engine traversal per categorical attribute plus one per pair
  (O(1 + |cat| + |cat|²) passes).  Kept as the benchmark baseline and the
  equivalence oracle for the fused plan.
* ``cat_cofactors_materialized`` — flat join, then grouped Gram blocks via
  the Pallas ``segment_gram`` kernel (``use_kernel=True``, one fused
  multi-segment pass over all categorical columns) or fp64 host scatters;
  the "noPre-but-not-one-hot" middle path.
* ``onehot_design_matrix`` + ``cofactors_from_matrix`` — the fully dense
  one-hot baseline, used as the oracle in tests and the slow side of
  ``benchmarks/bench_categorical.py``.

``CatCofactors`` supports ``__add__`` (union commutativity, Prop. 4.1 — the
same algebra the store's incremental ``append`` maintenance and the sharded
reduction use), with domain growth handled by zero-padding, so cache entries
stay valid when an append introduces unseen category ids.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .factorize import AggregateQuery, FactorizedEngine
from .relation import Relation
from .store import Store
from .variable_order import VariableOrder

__all__ = [
    "CatCofactors",
    "SparseCounts",
    "cat_cofactors_factorized",
    "cat_cofactors_from_arrays",
    "cat_cofactors_materialized",
    "cat_cofactors_per_pass",
    "onehot_design_matrix",
]


@dataclasses.dataclass
class SparseCounts:
    """COO sparse matrix of co-occurrence counts for one cat×cat block."""

    rows: np.ndarray  # int64 [nnz]
    cols: np.ndarray  # int64 [nnz]
    vals: np.ndarray  # float64 [nnz]
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(len(self.vals))

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.float64)
        np.add.at(out, (self.rows, self.cols), self.vals)
        return out

    def pad(self, shape: Tuple[int, int]) -> "SparseCounts":
        if shape[0] < self.shape[0] or shape[1] < self.shape[1]:
            raise ValueError(f"cannot shrink {self.shape} to {shape}")
        return SparseCounts(self.rows, self.cols, self.vals, shape)

    def __add__(self, other: "SparseCounts") -> "SparseCounts":
        shape = (
            max(self.shape[0], other.shape[0]),
            max(self.shape[1], other.shape[1]),
        )
        rows = np.concatenate([self.rows, other.rows])
        cols = np.concatenate([self.cols, other.cols])
        vals = np.concatenate([self.vals, other.vals])
        return coalesce_counts(rows, cols, vals, shape)

    @staticmethod
    def from_dense(dense: np.ndarray) -> "SparseCounts":
        rows, cols = np.nonzero(dense)
        return SparseCounts(
            rows.astype(np.int64),
            cols.astype(np.int64),
            dense[rows, cols].astype(np.float64),
            dense.shape,
        )


def coalesce_counts(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: Tuple[int, int],
) -> SparseCounts:
    """Sum duplicate (row, col) coordinates into a canonical sorted COO."""
    if len(vals) == 0:
        return SparseCounts(
            np.zeros(0, np.int64), np.zeros(0, np.int64),
            np.zeros(0, np.float64), shape,
        )
    flat = rows.astype(np.int64) * shape[1] + cols.astype(np.int64)
    uniq, inv = np.unique(flat, return_inverse=True)
    out = np.zeros(len(uniq), dtype=np.float64)
    np.add.at(out, inv, vals.astype(np.float64))
    return SparseCounts(uniq // shape[1], uniq % shape[1], out, shape)


@dataclasses.dataclass
class CatCofactors:
    """Cofactors of a feature set with continuous AND categorical columns.

    ``cont`` lists the continuous columns (callers training a linear model
    append the label here, as in ``Cofactors``); ``cat`` lists categorical
    attributes, which must be dictionary-encoded key columns.  Block layout
    (see module docstring): dense continuous count/lin/quad, per-category
    count and continuous-sum arrays, and sparse cat×cat counts keyed by
    ``(cat[i], cat[j])`` with i < j in ``cat`` order.
    """

    count: float
    lin: np.ndarray  # [k] continuous sums
    quad: np.ndarray  # [k, k] continuous Gram
    cont: List[str]
    cat: List[str]
    domains: Dict[str, int]  # cat attr -> domain size D_c
    cat_count: Dict[str, np.ndarray]  # c -> [D_c] per-category counts
    cat_cont: Dict[str, np.ndarray]  # c -> [D_c, k] per-category cont sums
    cat_cat: Dict[Tuple[str, str], SparseCounts]  # (c, d) -> sparse counts

    # -- shape / layout -------------------------------------------------------
    @property
    def num_params(self) -> int:
        """Width of the assembled one-hot cofactor matrix (incl. intercept)."""
        return 1 + len(self.cont) + sum(self.domains[c] for c in self.cat)

    def column_names(self) -> List[str]:
        """Assembled column order: [intercept, cont..., c=0..c=D_c-1, ...]."""
        names = ["intercept"] + list(self.cont)
        for c in self.cat:
            names.extend(f"{c}={g}" for g in range(self.domains[c]))
        return names

    def nnz(self) -> int:
        """Stored entries — the compressed size the one-hot path can't beat."""
        k = len(self.cont)
        n = 1 + k + k * k
        for c in self.cat:
            n += self.cat_count[c].size + self.cat_cont[c].size
        for coo in self.cat_cat.values():
            n += 3 * coo.nnz
        return n

    # -- assembly -------------------------------------------------------------
    def matrix(self) -> np.ndarray:
        """Dense one-hot cofactor matrix in ``column_names()`` order.

        Equals ``[1 | X_cont | onehot(cat)]^T @ [1 | X_cont | onehot(cat)]``
        over the join result — assembled from the grouped aggregates, never
        from the one-hot matrix itself.
        """
        k = len(self.cont)
        p = self.num_params
        out = np.zeros((p, p), dtype=np.float64)
        out[0, 0] = self.count
        out[0, 1 : 1 + k] = self.lin
        out[1 : 1 + k, 1 : 1 + k] = self.quad
        off = {}
        o = 1 + k
        for c in self.cat:
            off[c] = o
            d = self.domains[c]
            sl = slice(o, o + d)
            out[0, sl] = self.cat_count[c]
            out[sl, sl] = np.diag(self.cat_count[c])
            out[1 : 1 + k, sl] = self.cat_cont[c].T
            o += d
        for (c, d_), coo in self.cat_cat.items():
            block = np.zeros((self.domains[c], self.domains[d_]))
            np.add.at(block, (coo.rows, coo.cols), coo.vals)
            out[off[c] : off[c] + self.domains[c],
                off[d_] : off[d_] + self.domains[d_]] = block
        return np.where(
            np.arange(p)[:, None] <= np.arange(p)[None, :], out, out.T
        )

    def regression_matrix(self, label: str) -> Tuple[np.ndarray, List[str]]:
        """Assembled matrix permuted to the solver convention: the label
        column moved last ([intercept, cont\\label, cats..., label]), the
        ordering ``gd.bgd_cofactor`` / ``solve_cofactor`` expect."""
        if label not in self.cont:
            raise ValueError(f"label {label!r} not among continuous columns")
        names = self.column_names()
        li = 1 + self.cont.index(label)
        perm = [i for i in range(len(names)) if i != li] + [li]
        mat = self.matrix()[np.ix_(perm, perm)]
        return mat, [names[i] for i in perm]

    # -- algebra (Prop. 4.1) ---------------------------------------------------
    def project(
        self, cont_keep: Sequence[str], cat_keep: Sequence[str]
    ) -> "CatCofactors":
        """Commutativity with projection: restrict to a feature subset
        without recomputation — the delta-sharing rule ``Store.append``
        uses (one delta factorization over the union feature set, each
        cache entry derives its own view).  Pair blocks transpose when the
        kept ``cat`` order reverses a stored pair."""
        cont_keep, cat_keep = list(cont_keep), list(cat_keep)
        idx = [self.cont.index(f) for f in cont_keep]
        cat_cat = {}
        for i in range(len(cat_keep)):
            for j in range(i + 1, len(cat_keep)):
                c, d_ = cat_keep[i], cat_keep[j]
                if (c, d_) in self.cat_cat:
                    cat_cat[(c, d_)] = self.cat_cat[(c, d_)]
                else:
                    coo = self.cat_cat[(d_, c)]  # stored transposed
                    cat_cat[(c, d_)] = SparseCounts(
                        coo.cols.copy(), coo.rows.copy(), coo.vals.copy(),
                        (coo.shape[1], coo.shape[0]),
                    )
        return CatCofactors(
            count=self.count,
            lin=self.lin[idx],
            quad=self.quad[np.ix_(idx, idx)],
            cont=cont_keep,
            cat=cat_keep,
            domains={c: self.domains[c] for c in cat_keep},
            cat_count={c: self.cat_count[c] for c in cat_keep},
            cat_cont={c: self.cat_cont[c][:, idx] for c in cat_keep},
            cat_cat=cat_cat,
        )

    def __add__(self, other: "CatCofactors") -> "CatCofactors":
        """Union commutativity: cofactors of a disjoint partition sum block
        by block.  Domains may differ (an append can introduce unseen
        category ids); smaller blocks zero-pad to the larger domain."""
        if self.cont != other.cont or self.cat != other.cat:
            raise ValueError("feature sets differ — cannot add CatCofactors")
        domains = {
            c: max(self.domains[c], other.domains[c]) for c in self.cat
        }

        def _pad(a: np.ndarray, d: int) -> np.ndarray:
            if a.shape[0] == d:
                return a
            widths = [(0, d - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
            return np.pad(a, widths)

        cat_count = {
            c: _pad(self.cat_count[c], domains[c])
            + _pad(other.cat_count[c], domains[c])
            for c in self.cat
        }
        cat_cont = {
            c: _pad(self.cat_cont[c], domains[c])
            + _pad(other.cat_cont[c], domains[c])
            for c in self.cat
        }
        cat_cat = {}
        for key in self.cat_cat:
            c, d_ = key
            shape = (domains[c], domains[d_])
            cat_cat[key] = self.cat_cat[key].pad(shape) + other.cat_cat[
                key
            ].pad(shape)
        return CatCofactors(
            count=self.count + other.count,
            lin=self.lin + other.lin,
            quad=self.quad + other.quad,
            cont=list(self.cont),
            cat=list(self.cat),
            domains=domains,
            cat_count=cat_count,
            cat_cont=cat_cont,
            cat_cat=cat_cat,
        )


# ---------------------------------------------------------------------------
# Computation paths
# ---------------------------------------------------------------------------

def _store_domains(
    store: Store,
    cat: Sequence[str],
    overrides: Optional[Dict[str, Relation]] = None,
) -> Dict[str, int]:
    """Dictionary-domain sizes from the catalog, widened by any override
    relations (a delta engine's replacement rows may carry category ids
    past the pre-merge catalog's domains)."""
    doms = {c: store.attr_domain(c) for c in cat}
    for rel in (overrides or {}).values():
        for c in cat:
            if c in rel.domains:
                doms[c] = max(doms[c], int(rel.domains[c]))
    return doms


def _checked_ids(g, attr: str, dom: int) -> np.ndarray:
    """Group ids of ``attr`` with the same loud out-of-domain rejection as
    the from-arrays/sharded paths — np.add.at would wrap negatives into the
    LAST category."""
    ids = g.ids(attr)
    if len(ids):
        lo, hi = int(ids.min()), int(ids.max())
        if lo < 0 or hi >= dom:
            raise ValueError(
                f"category ids of {attr!r} span [{lo}, {hi}], outside "
                f"domain [0, {dom})"
            )
    return ids


def cat_cofactors_factorized(
    store: Store,
    vorder: VariableOrder,
    cont: Sequence[str],
    cat: Sequence[str],
    backend: str = "numpy",
    domains: Optional[Dict[str, int]] = None,
    stats: Optional[Dict[str, int]] = None,
    overrides: Optional[Dict[str, Relation]] = None,
    use_view_cache: Optional[bool] = None,
    use_node_kernels: Optional[bool] = None,
) -> CatCofactors:
    """Categorical cofactors over the **factorized** join — ONE fused pass.

    The whole cofactor batch — the ungrouped continuous Gram block, one
    GROUP BY c count/Σx query per categorical attribute (degree 1: no
    per-group quad tensors), and one GROUP BY (c, d) count query per pair
    (degree 0: counts only) — is issued as a single multi-output plan, so
    the engine traverses the variable order exactly once and every subtree
    below the referenced attributes is evaluated once and shared across
    outputs.  O(factorization size); the flat join and the one-hot matrix
    never exist; cofactor time is roughly flat in |cat| instead of
    quadratic.  ``domains`` overrides the store-derived domain sizes (used
    by the incremental delta path, where the delta relation may not cover
    the full dictionary).  ``stats``, when given, receives the engine's
    ``passes``/``node_visits`` counters — the audit trail of the
    single-pass claim.  ``overrides`` runs the batch as a *delta engine*
    (relations replaced by their append deltas, cached sibling views
    reused); ``use_view_cache`` overrides the store's default for the
    persistent cross-batch view cache — with it on, successive batches
    over overlapping attribute sets skip finished subtree descents.
    """
    cont = list(cont)
    cat = list(cat)
    k = len(cont)
    doms = (
        dict(domains)
        if domains is not None
        else _store_domains(store, cat, overrides)
    )
    engine = FactorizedEngine(
        store,
        vorder,
        cont,
        backend=backend,
        overrides=overrides,
        use_view_cache=use_view_cache,
        use_node_kernels=use_node_kernels,
    )
    queries = [AggregateQuery("base", (), 2)]
    queries += [AggregateQuery(f"g:{c}", (c,), 1) for c in cat]
    pairs = [
        (cat[i], cat[j])
        for i in range(len(cat))
        for j in range(i + 1, len(cat))
    ]
    queries += [AggregateQuery(f"p:{c}|{d_}", (c, d_), 0) for c, d_ in pairs]
    out = engine.run_batch(queries)
    if stats is not None:
        stats["passes"] = engine.passes
        stats["node_visits"] = engine.node_visits
        stats["vc_hits"] = engine.vc_hits
        stats["vc_misses"] = engine.vc_misses

    base = out["base"]
    perm = [base.features.index(f) for f in cont]
    lin = base.lin[0][perm]
    quad = base.quad[0][np.ix_(perm, perm)]

    cat_count: Dict[str, np.ndarray] = {}
    cat_cont: Dict[str, np.ndarray] = {}
    for c in cat:
        g = out[f"g:{c}"]
        gperm = [g.features.index(f) for f in cont]
        ids = _checked_ids(g, c, doms[c])
        counts = np.zeros(doms[c], dtype=np.float64)
        sums = np.zeros((doms[c], k), dtype=np.float64)
        np.add.at(counts, ids, g.count)
        np.add.at(sums, ids, g.lin[:, gperm])
        cat_count[c] = counts
        cat_cont[c] = sums

    cat_cat: Dict[Tuple[str, str], SparseCounts] = {}
    for c, d_ in pairs:
        g = out[f"p:{c}|{d_}"]
        cat_cat[(c, d_)] = coalesce_counts(
            _checked_ids(g, c, doms[c]),
            _checked_ids(g, d_, doms[d_]),
            g.count,
            (doms[c], doms[d_]),
        )
    return CatCofactors(
        count=float(base.count[0]),
        lin=lin,
        quad=quad,
        cont=cont,
        cat=cat,
        domains=doms,
        cat_count=cat_count,
        cat_cont=cat_cont,
        cat_cat=cat_cat,
    )


def cat_cofactors_per_pass(
    store: Store,
    vorder: VariableOrder,
    cont: Sequence[str],
    cat: Sequence[str],
    backend: str = "numpy",
    domains: Optional[Dict[str, int]] = None,
) -> CatCofactors:
    """The pre-fusion baseline: one ungrouped engine pass for the continuous
    block, one GROUP BY c traversal per categorical attribute, one
    GROUP BY (c, d) traversal per pair — O(1 + |cat| + |cat|²) full
    traversals of the same factorization the fused plan covers once.  Kept
    as the benchmark baseline and the equivalence oracle for
    :func:`cat_cofactors_factorized` (they must match to 1e-12)."""
    cont = list(cont)
    cat = list(cat)
    k = len(cont)
    doms = dict(domains) if domains is not None else _store_domains(store, cat)
    base = FactorizedEngine(store, vorder, cont, backend=backend).cofactors()

    cat_count: Dict[str, np.ndarray] = {}
    cat_cont: Dict[str, np.ndarray] = {}
    for c in cat:
        g = FactorizedEngine(
            store, vorder, cont, backend=backend, group_by=[c]
        ).grouped_cofactors()
        ids = _checked_ids(g, c, doms[c])
        counts = np.zeros(doms[c], dtype=np.float64)
        sums = np.zeros((doms[c], k), dtype=np.float64)
        np.add.at(counts, ids, g.count)
        np.add.at(sums, ids, g.lin)
        cat_count[c] = counts
        cat_cont[c] = sums

    cat_cat: Dict[Tuple[str, str], SparseCounts] = {}
    for i in range(len(cat)):
        for j in range(i + 1, len(cat)):
            c, d_ = cat[i], cat[j]
            g = FactorizedEngine(
                store, vorder, [], backend=backend, group_by=[c, d_]
            ).grouped_cofactors()
            cat_cat[(c, d_)] = coalesce_counts(
                _checked_ids(g, c, doms[c]),
                _checked_ids(g, d_, doms[d_]),
                g.count,
                (doms[c], doms[d_]),
            )
    return CatCofactors(
        count=base.count,
        lin=base.lin,
        quad=base.quad,
        cont=cont,
        cat=cat,
        domains=doms,
        cat_count=cat_count,
        cat_cont=cat_cont,
        cat_cat=cat_cat,
    )


def cat_cofactors_from_arrays(
    x_cont: np.ndarray,
    cat_ids: np.ndarray,
    cont: Sequence[str],
    cat: Sequence[str],
    domains: Dict[str, int],
    use_kernel: bool = False,
) -> CatCofactors:
    """Categorical cofactors of already-extracted columns: ``x_cont`` is the
    [m, k] continuous matrix, ``cat_ids`` the [m, n_cat] dictionary ids.

    With ``use_kernel=True`` the per-category blocks of ALL categorical
    attributes run through the Pallas ``multi_segment_gram`` kernel in one
    fused pass — u = [1, x] makes each grouped block carry counts and
    continuous sums together, and the batched kernel streams u from memory
    once instead of once per attribute.  The fp64 host path (`np.add.at`)
    is the oracle.  Never builds a one-hot column.
    """
    cont = list(cont)
    cat = list(cat)
    m, k = x_cont.shape
    if cat_ids.shape != (m, len(cat)):
        raise ValueError(
            f"cat_ids shape {cat_ids.shape} != ({m}, {len(cat)})"
        )
    for i, c in enumerate(cat):
        if m == 0:
            continue
        lo, hi = int(cat_ids[:, i].min()), int(cat_ids[:, i].max())
        if lo < 0 or hi >= int(domains[c]):
            # negative ids would wrap through np.add.at into the LAST
            # category — reject both bounds loudly
            raise ValueError(
                f"category ids of {c!r} span [{lo}, {hi}], outside domain "
                f"[0, {int(domains[c])})"
            )
    ones = np.ones((m, 1), dtype=np.float64)
    u = np.concatenate([ones, x_cont.astype(np.float64)], axis=1)

    gram = u.T @ u
    cat_count: Dict[str, np.ndarray] = {}
    cat_cont: Dict[str, np.ndarray] = {}
    if use_kernel and cat:
        # one fused multi-segment pass over u = [1, x]: every attribute's
        # grouped block comes out of a single data-chunk stream — row 0 of
        # each [1+k, 1+k] block carries count and continuous sums together.
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        blocks = kops.multi_segment_gram(
            jnp.asarray(u, dtype=jnp.float32),
            jnp.asarray(cat_ids, dtype=jnp.int32),
            [int(domains[c]) for c in cat],
        )
        for i, c in enumerate(cat):
            blk = np.asarray(blocks[i], dtype=np.float64)
            cat_count[c] = blk[:, 0, 0]
            cat_cont[c] = blk[:, 0, 1:]
    else:
        # host path: bincount + scatter-add, O(m·k) — the full per-group
        # Gram would build an O(m·k²) temporary only to read row 0.
        for i, c in enumerate(cat):
            seg, num = cat_ids[:, i], int(domains[c])
            cat_count[c] = np.bincount(seg, minlength=num).astype(np.float64)
            sums = np.zeros((num, k), dtype=np.float64)
            np.add.at(sums, seg, x_cont.astype(np.float64))
            cat_cont[c] = sums
    cat_cat: Dict[Tuple[str, str], SparseCounts] = {}
    for i in range(len(cat)):
        for j in range(i + 1, len(cat)):
            c, d_ = cat[i], cat[j]
            # O(nnz) memory: coalesce the present coordinate pairs only —
            # a dense bincount over D_c·D_d would defeat the sparse design.
            cat_cat[(c, d_)] = coalesce_counts(
                cat_ids[:, i].astype(np.int64),
                cat_ids[:, j].astype(np.int64),
                np.ones(m, dtype=np.float64),
                (domains[c], domains[d_]),
            )
    return CatCofactors(
        count=float(gram[0, 0]),
        lin=np.asarray(gram[0, 1:], dtype=np.float64),
        quad=np.asarray(gram[1:, 1:], dtype=np.float64),
        cont=cont,
        cat=cat,
        domains=dict(domains),
        cat_count=cat_count,
        cat_cont=cat_cont,
        cat_cat=cat_cat,
    )


def cat_cofactors_materialized(
    store: Store,
    cont: Sequence[str],
    cat: Sequence[str],
    relations: Optional[Sequence[str]] = None,
    use_kernel: bool = False,
) -> CatCofactors:
    """Flat-join path: materialize the natural join, then grouped blocks —
    still no one-hot matrix (the grouped middle ground the benchmark pits
    against full one-hot materialization)."""
    joined = store.materialize_join(relations)
    x = np.stack(
        [joined.column(f).astype(np.float64) for f in cont], axis=1
    ) if cont else np.zeros((joined.num_rows, 0))
    ids = np.stack(
        [joined.column(c).astype(np.int64) for c in cat], axis=1
    ) if cat else np.zeros((joined.num_rows, 0), dtype=np.int64)
    return cat_cofactors_from_arrays(
        x, ids, cont, cat, _store_domains(store, cat), use_kernel=use_kernel
    )


def onehot_design_matrix(
    joined: Relation,
    cont: Sequence[str],
    cat: Sequence[str],
    domains: Dict[str, int],
) -> Tuple[np.ndarray, List[str]]:
    """The dense baseline: materialize the [m, k + Σ D_c] one-hot design
    matrix (no intercept column).  Exists to be benchmarked against and to
    serve as the oracle in tests — the factorized paths never build this."""
    m = joined.num_rows
    cols = [joined.column(f).astype(np.float64) for f in cont]
    names = list(cont)
    for c in cat:
        ids = joined.column(c).astype(np.int64)
        onehot = np.zeros((m, domains[c]), dtype=np.float64)
        onehot[np.arange(m), ids] = 1.0
        cols.append(onehot)
        names.extend(f"{c}={g}" for g in range(domains[c]))
    parts = [
        c[:, None] if c.ndim == 1 else c for c in cols
    ]
    x = np.concatenate(parts, axis=1) if parts else np.zeros((m, 0))
    return x, names
