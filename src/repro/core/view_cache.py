"""Persistent cross-batch view cache — store-owned per-node engine views.

``FactorizedEngine.run_batch`` memoizes per-node partial views for the
duration of ONE batch; this module promotes that memo to a **store-owned,
cross-batch** cache (the AC/DC direction: reuse aggregates *across* calls
and maintain them incrementally under updates).  Successive engine batches
over overlapping attribute sets — warm retrains, FD on/off comparisons,
GLM IRLS re-solves, per-attribute sweeps — reuse finished subtree descents
instead of recomputing them.

Keying.  A view is identified by :class:`ViewKey`:

  ``vorder_sig``  structural signature of the variable order (two orders
                  with the same shape share entries, whatever Python
                  objects they are),
  ``backend`` / ``dtype``  the value-math configuration (jax fp32 views
                  never alias numpy fp64 oracle views),
  ``node``        the node's *preorder index* within the order — stable
                  across engine instances, unlike ``id(node)``,
  ``feats``       the (sorted) engine features present in the node's
                  subtree — engines with different global feature lists
                  share every subtree that sees the same feature subset,
  ``keep``        the live group-attribute subset at the node,
  ``degree``      the monomial degree the view was evaluated at (a cached
                  degree-2 view serves degree-0/1 requests by trimming).

Validity.  Entries are stamped with the store version they were built (or
last folded) at, and the owning store wires its per-relation watermark map
into ``watermarks`` — an entry is valid iff its stamp is >= the watermark
of every relation its subtree covers.  That distinguishes three states:
*valid* (no covered relation mutated since the stamp), *stale but
foldable* (a covered relation has pending appended rows — the store's
drain folds the entry with a delta view, union commutativity Prop. 4.1,
and restamps it; see ``Store._maintain_view_cache``), and *invalid*
(``put`` replaced a covered relation — those entries are dropped
outright).  ``Store.append`` therefore does **not** blanket-invalidate,
and under lazy maintenance does not touch this cache at all; a
watermark-violating entry found by ``get`` is dropped on sight as the
backstop against drain-rule bugs.  Without a ``watermarks`` map the cache
falls back to exact version equality (standalone use in tests).

Eviction.  The cache is bytes-accounted (device arrays report ``nbytes``
without transfer) with LRU eviction; ``Store.cache_info()`` surfaces
``view_cache_bytes`` / ``view_cache_evictions`` so benchmarks can audit
the budget.  This module is deliberately free of engine imports — views
are opaque objects with ``keys``/``c``/``l``/``q`` array attributes.

Thread safety.  Every structural operation (get / put / replace /
discard / invalidate / eviction) and the hit/miss counters
(:meth:`ViewCache.note_hit` / :meth:`note_miss`) run under one internal
re-entrant lock, so the OrderedDict and the byte accounting stay
consistent when a mutator thread invalidates entries while a drain
thread publishes new ones — the concurrent-service scenario
(``repro.serve.runtime``).  Views themselves are immutable once stored,
so returning one outside the lock is safe.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, FrozenSet, Iterable, List, NamedTuple, Optional, Tuple

import numpy as np

__all__ = ["ViewCache", "ViewKey", "view_nbytes"]

#: Default eviction budget — generous for test/bench scale, small enough
#: that a production sweep over many variable orders cannot grow unbounded.
DEFAULT_MAX_BYTES = 256 << 20


class ViewKey(NamedTuple):
    """Identity of one cached per-node view (see module docstring)."""

    vorder_sig: tuple
    backend: str
    dtype: str
    node: int  # preorder index of the node within the variable order
    feats: Tuple[str, ...]  # sorted features present in the node's subtree
    keep: FrozenSet[str]  # live group attributes at the node
    degree: int


def _arr_nbytes(arr) -> int:
    if arr is None:
        return 0
    nbytes = getattr(arr, "nbytes", None)
    if nbytes is not None:
        return int(nbytes)
    a = np.asarray(arr)
    return int(a.size * a.dtype.itemsize)


def view_nbytes(view) -> int:
    """Approximate resident size of a ``_View`` (host + device arrays)."""
    n = 0
    for col in view.keys.values():
        n += _arr_nbytes(col)
    for arr in (view.c, view.l, view.q):
        n += _arr_nbytes(arr)
    return n


class _Entry:
    __slots__ = ("view", "relations", "version", "nbytes")

    def __init__(self, view, relations: frozenset, version: int, nbytes: int):
        self.view = view
        self.relations = relations
        self.version = version
        self.nbytes = nbytes


class ViewCache:
    """Bytes-accounted LRU cache of per-node factorized views.

    ``enabled=False`` turns the cache into a no-op sink (``get`` misses,
    ``put`` discards) without dropping already-stored entries — the
    ``use_view_cache=False`` escape hatch benchmarks use for the cold
    baseline.  Hit/miss counters are maintained by the *engine* (one
    logical probe may try several degrees); eviction counters here.
    """

    def __init__(
        self, max_bytes: int = DEFAULT_MAX_BYTES, enabled: bool = True
    ) -> None:
        self._entries: "OrderedDict[ViewKey, _Entry]" = OrderedDict()
        # re-entrant: put() discards subsumed entries while already locked
        self._mu = threading.RLock()
        self.max_bytes = int(max_bytes)
        self.enabled = enabled and self.max_bytes > 0
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        #: per-relation watermark map, aliased to the owning store's
        #: ``_rel_versions`` — when set, validity is the watermark rule
        #: (see module docstring) instead of exact version equality.
        self.watermarks: Optional[Dict[str, int]] = None
        #: sanitizer seam (see ``Store.access_hook``): when set, called as
        #: hook("ViewCache._entries", kind) on entry-map touches.
        self.access_hook = None

    def __len__(self) -> int:
        with self._mu:
            return len(self._entries)

    def _access(self, field: str, kind: str) -> None:
        hook = self.access_hook
        if hook is not None:
            hook(field, kind)

    def reset_counters(self) -> None:
        """Zero the hit/miss/eviction counters under the cache lock — the
        store's ``reset_counters`` must not race a concurrent fold's
        ``note_hit``/``note_miss`` increments."""
        with self._mu:
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    def _valid(self, entry: _Entry, version: int) -> bool:
        wm = self.watermarks
        if wm is None:
            return entry.version == version
        return all(entry.version >= wm.get(r, 0) for r in entry.relations)

    def get(self, key: ViewKey, version: int):
        """The view under ``key`` valid at store ``version``, else None.
        An entry failing the validity rule is dropped on sight (backstop
        against invalidation-rule bugs, as in the store's cofactor
        caches)."""
        with self._mu:
            self._access("ViewCache._entries", "read")
            entry = self._entries.get(key)
            if entry is None:
                return None
            if not self._valid(entry, version):
                self.discard(key)
                return None
            self._entries.move_to_end(key)
            return entry.view

    def put(
        self,
        key: ViewKey,
        view,
        relations: frozenset,
        version: int,
        nbytes: Optional[int] = None,
    ) -> None:
        if nbytes is None:
            nbytes = view_nbytes(view)
        if nbytes > self.max_bytes:
            return  # single oversized view: never worth the whole budget
        with self._mu:
            self._access("ViewCache._entries", "write")
            self.discard(key)
            # a higher-degree view subsumes the lower-degree variants —
            # drop them so the budget isn't spent twice on the same subtree
            for d in range(key.degree):
                self.discard(key._replace(degree=d))
            self._entries[key] = _Entry(view, relations, version, nbytes)
            self.bytes += nbytes
            self._evict()

    def _evict(self) -> None:
        """LRU-evict until the byte budget holds.  The most recent entry
        (tail) is never popped: ``popitem(last=False)`` takes the head and
        the loop stops once a single entry remains."""
        with self._mu:
            while self.bytes > self.max_bytes and len(self._entries) > 1:
                _, old = self._entries.popitem(last=False)
                self.bytes -= old.nbytes
                self.evictions += 1

    def replace(
        self,
        key: ViewKey,
        view,
        nbytes: Optional[int] = None,
        version: Optional[int] = None,
    ) -> None:
        """Swap the view of an existing entry in place (delta fold),
        keeping its relations; no-op if absent.  ``version`` (if given)
        restamps the entry — the fold brought it up to date with the
        covered relations' watermarks.  The entry counts as freshly used
        (moved to the LRU tail), and growth re-runs eviction so folds
        cannot creep past the byte budget."""
        with self._mu:
            entry = self._entries.get(key)
            if entry is None:
                return
            if nbytes is None:
                nbytes = view_nbytes(view)
            self.bytes += nbytes - entry.nbytes
            entry.view = view
            entry.nbytes = nbytes
            if version is not None:
                entry.version = version
            self._entries.move_to_end(key)
            self._evict()

    def discard(self, key: ViewKey) -> None:
        with self._mu:
            entry = self._entries.pop(key, None)
            if entry is not None:
                self.bytes -= entry.nbytes

    def note_hit(self) -> None:
        """Engine-side probe accounting, made atomic for threaded serving
        (a bare ``vc.hits += 1`` read-modify-write loses counts under
        concurrent engines, and the counter audits demand exactness)."""
        with self._mu:
            self.hits += 1

    def note_miss(self) -> None:
        with self._mu:
            self.misses += 1

    def items(self) -> List[Tuple[ViewKey, _Entry]]:
        """Snapshot of (key, entry) pairs — safe to mutate while iterating."""
        with self._mu:
            return list(self._entries.items())

    def invalidate_relation(self, name: str) -> None:
        """Drop every entry whose subtree covers relation ``name`` (the
        ``put`` rule).  Entries over unrelated subtrees survive."""
        with self._mu:
            for key in [
                k for k, e in self._entries.items() if name in e.relations
            ]:
                self.discard(key)

    def restamp(self, version: int, keys: Optional[Iterable[ViewKey]] = None):
        """Mark entries valid at ``version`` (after a mutation whose
        maintenance kept them correct)."""
        with self._mu:
            if keys is None:
                for entry in self._entries.values():
                    entry.version = version
            else:
                for key in keys:
                    entry = self._entries.get(key)
                    if entry is not None:
                        entry.version = version

    def clear(self) -> None:
        with self._mu:
            self._entries.clear()
            self.bytes = 0

    def evict_all(self) -> int:
        """Evict every entry, counted as evictions — the fault-injection
        harness's cache-pressure storm, and an operator pressure valve."""
        with self._mu:
            n = len(self._entries)
            self._entries.clear()
            self.bytes = 0
            self.evictions += n
            return n

    def info(self) -> Dict[str, int]:
        with self._mu:
            return {
                "entries": len(self._entries),
                "bytes": self.bytes,
                "max_bytes": self.max_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
            }
