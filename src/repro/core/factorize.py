"""Factorized aggregate pushdown over a variable order (paper §2.3, §4.3).

Computes, in **one pass over the factorized join** (never materializing the
flat result), every monomial aggregate of degree ≤ 2 over a feature set F:

    count          = SUM(1)
    lin[f]         = SUM(x_f)            for f in F
    quad[f, g]     = SUM(x_f * x_g)      for f, g in F

— exactly the cofactor entries of paper §3.4.  The paper implements this by
emitting SQL views with string ``lineage`` columns and ``POWER(x, d)``
per-row terms (Listing 4).  The TPU-native reformulation here replaces the
string machinery with **dense monomial tensors** per view:

    c : [N]        degree-0 aggregates (one row per distinct key combo)
    l : [N, k]     degree-1 aggregates over the k features below this node
    q : [N, k, k]  degree-2 aggregates (symmetric)

Views combine bottom-up with closed-form block algebra (children C1, C2):

    c = c1·c2
    l = [l1·c2, c1·l2]
    q = [[q1·c2, l1⊗l2], [l2⊗l1, c1·q2]]

and aggregating out a feature variable with values x extends the blocks by
``x·c / x²·c / x·l`` before a GROUP BY (sort + segment-sum) over the node's
remaining key attributes.  The degree-≤2 bound of the paper's
``WHERE deg <= 2`` filter is enforced *structurally* by this algebra.

Multi-output plans (AC/DC-style, Abo Khamis et al. 2018): the engine is
split into a **plan** layer and an **executor** layer so that a *batch* of
aggregate queries — the ungrouped Gram block, every ``GROUP BY c`` vector,
every ``GROUP BY (c, d)`` co-occurrence — shares ONE traversal of the
variable order.  Each :class:`AggregateQuery` names the group attributes it
carries to the root and the monomial degree it needs; the executor memoizes
per-node partial views keyed by ``(node, live-query-subset)``, where the
live subset of a query at a node is its group attributes intersected with
the node's subtree variables.  Below the deepest node that mentions any
group attribute, every query degenerates to the same ungrouped subtree view
— computed once and reused across all outputs (FDB's shared-subtree
caching, Bakibayev et al. 2012).  ``passes`` counts executor traversals
(one per :meth:`FactorizedEngine.run_batch` call, regardless of batch
size); ``node_visits`` counts distinct ``(node, live-subset)`` view
evaluations — the unit the benchmark sweeps report.

Cross-batch reuse (this layer's AC/DC step): when the store owns a
:class:`repro.core.view_cache.ViewCache` (every ``Store`` does), finished
subtree views are ALSO published to that persistent cache under a
store-agnostic key — ``(vorder signature, node preorder index, subtree
feature subset, live subset, degree, backend/dtype)`` — so a later batch
(same engine or a brand-new one) starts from the deepest changed node
instead of the leaves.  A fully-warm batch reports **zero** ``node_visits``
on unchanged subtrees; persistent hits/misses are counted separately in
``vc_hits`` / ``vc_misses``.  Engines constructed with ``overrides=`` (a
relation replaced by its append delta) are *delta engines*: they skip the
persistent cache for every node whose subtree covers an overridden
relation (those views are deltas, not totals) while still REUSING the
cached views of untouched sibling subtrees — which is what makes
retrain-after-append cost O(delta root path), not O(tree).  Stable ids
underneath both mechanisms come from the store's append-only attribute
dictionaries (``Store.attr_encoding``): an append never renumbers an
existing category, so cached views survive catalog growth.
``use_view_cache=False`` (or ``scale`` being set — scaled views are
engine-specific) opts a single engine out.

Complexity is O(size of the factorization), as in the paper.  Structural
index work (joins, group ids) runs on host numpy — the query-executor role —
and all value math is vectorized (jnp by default; numpy backend available
for float64 oracle computations).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kernel_ops
from .api import StoreReads
from .relation import Relation, group_key, join_keys, sort_merge_join
from .variable_order import INTERCEPT, VariableOrder, validate
from .view_cache import ViewKey

__all__ = [
    "AggregateBlock",
    "AggregateQuery",
    "BatchPart",
    "Cofactors",
    "FactorizedEngine",
    "GroupedView",
    "MergedBatch",
    "cofactors_factorized",
    "grouped_cofactors_factorized",
    "merge_batches",
    "scatter_results",
]


@dataclasses.dataclass
class Cofactors:
    """Degree-≤2 aggregates over the join result for feature list ``features``."""

    count: float
    lin: np.ndarray  # [k]
    quad: np.ndarray  # [k, k]
    features: List[str]

    def matrix(self) -> np.ndarray:
        """Full (k+1)×(k+1) cofactor matrix, ordered [intercept] + features.

        Cof[0,0] = m, Cof[0,j] = Σ x_j, Cof[i,j] = Σ x_i·x_j  (paper §3.4).
        """
        k = len(self.features)
        out = np.zeros((k + 1, k + 1), dtype=np.float64)
        out[0, 0] = self.count
        out[0, 1:] = self.lin
        out[1:, 0] = self.lin
        out[1:, 1:] = self.quad
        return out

    def project(self, keep: Sequence[str]) -> "Cofactors":
        """Commutativity with projection (paper Prop. 4.1): restrict the
        feature set without recomputation."""
        idx = [self.features.index(f) for f in keep]
        return Cofactors(
            count=self.count,
            lin=self.lin[idx],
            quad=self.quad[np.ix_(idx, idx)],
            features=list(keep),
        )

    def __add__(self, other: "Cofactors") -> "Cofactors":
        """Commutativity with union (paper Prop. 4.1): cofactors of a disjoint
        partition sum elementwise.  This is the distribution rule — and the
        delta-maintenance rule used by ``Store.append``."""
        assert self.features == other.features
        return Cofactors(
            count=self.count + other.count,
            lin=self.lin + other.lin,
            quad=self.quad + other.quad,
            features=list(self.features),
        )

    def rescale(self, factors) -> "Cofactors":
        """Cofactors of the affinely rescaled columns x' = (x − a)/b, derived
        from the unscaled aggregates in O(k²) — the paper's §4.2 lazy views
        lifted to the aggregate level:

            Σ x'_i        = (lin_i − a_i·m) / b_i
            Σ x'_i x'_j   = (quad_ij − a_i·lin_j − a_j·lin_i + m·a_i·a_j)
                            / (b_i·b_j)

        This is what lets ``Store``'s cache hold *unscaled* cofactors: after
        an append changes the scale factors, the warm-retrain path rescales
        the cached aggregates instead of rescanning any data.  ``factors`` is
        a ``ScaleFactors``; columns it does not cover pass through (a=0,
        b=1)."""
        a = np.array(
            [factors.avg.get(f, 0.0) for f in self.features], dtype=np.float64
        )
        b = np.array(
            [factors.max.get(f, 1.0) for f in self.features], dtype=np.float64
        )
        m = self.count
        lin = (self.lin - a * m) / b
        quad = (
            self.quad
            - np.outer(a, self.lin)
            - np.outer(self.lin, a)
            + m * np.outer(a, a)
        ) / np.outer(b, b)
        return Cofactors(
            count=m, lin=lin, quad=quad, features=list(self.features)
        )


@dataclasses.dataclass(frozen=True)
class AggregateQuery:
    """One output of a multi-output aggregate plan.

    ``group_by``  : attributes carried (as keys) to the root — the SQL
                    ``GROUP BY`` list.  Empty for global aggregates.
    ``degree``    : highest monomial degree this output reads —
                    0 = counts only, 1 = counts + Σx_f, 2 = full Gram block.
                    Lower degrees skip the corresponding block algebra, so a
                    ``GROUP BY (c, d)`` co-occurrence query never pays for
                    [N, k, k] tensors it would throw away.
    """

    name: str
    group_by: Tuple[str, ...] = ()
    degree: int = 2


@dataclasses.dataclass
class AggregateBlock:
    """One query's output: per-group aggregates keyed by the query's group
    attributes' *original dictionary values* (stable under appends).

    ``lin``/``quad`` are present only up to the query's declared degree.
    """

    keys: Dict[str, np.ndarray]  # attr -> attribute values [N] (float64)
    count: np.ndarray  # [N]
    lin: Optional[np.ndarray]  # [N, k] if degree >= 1
    quad: Optional[np.ndarray]  # [N, k, k] if degree == 2
    features: List[str]

    @property
    def num_groups(self) -> int:
        return int(self.count.shape[0])

    def ids(self, attr: str) -> np.ndarray:
        """Group keys of a dictionary-encoded attribute as int64 ids."""
        return self.keys[attr].astype(np.int64)

    def restrict(
        self, features: Sequence[str], degree: int
    ) -> "AggregateBlock":
        """Project onto a feature sublist and trim blocks above ``degree``
        (Prop. 4.1 commutativity with projection, at block granularity) —
        how a merged multi-request batch's shared output is scattered back
        to one request: pure slicing, no recomputation."""
        lin = quad = None
        feats: List[str] = []
        if degree >= 1:
            if self.lin is None:
                raise ValueError("block holds no degree-1 aggregates")
            idx = [self.features.index(f) for f in features]
            feats = list(features)
            lin = self.lin[:, idx]
            if degree == 2:
                if self.quad is None:
                    raise ValueError("block holds no degree-2 aggregates")
                quad = self.quad[:, idx][:, :, idx]
        return AggregateBlock(
            keys=dict(self.keys),
            count=self.count,
            lin=lin,
            quad=quad,
            features=feats,
        )


@dataclasses.dataclass(frozen=True)
class BatchPart:
    """One request's slice of a merged multi-request batch: the features
    and aggregate queries a single tenant asked for, tagged with a
    caller-chosen request id used to route results back."""

    rid: object  # hashable request id, unique within one merge
    features: Tuple[str, ...]
    queries: Tuple[AggregateQuery, ...]


@dataclasses.dataclass
class MergedBatch:
    """The coalescing product of :func:`merge_batches`: ONE feature union +
    ONE deduplicated query list to hand to a single ``run_batch``, plus the
    assignment map that scatters shared outputs back per request."""

    features: List[str]
    queries: List[AggregateQuery]
    # (rid, per-request query name) -> merged query name
    assignments: Dict[Tuple[object, str], str]


def merge_batches(parts: Sequence[BatchPart]) -> MergedBatch:
    """Coalesce aggregate batches from different requests into one plan.

    The engine's ``run_batch`` already shares subtree views *within* a
    batch (node memo keyed by live query subset); this is the cross-request
    step: feature lists union (a view over F ⊇ F' serves F' by projection —
    Prop. 4.1), and queries from different requests that group by the same
    attribute set collapse to a single output evaluated at the max
    requested degree.  N overlapping tenant requests become ONE traversal;
    :func:`scatter_results` slices every request's declared shape back out.
    """
    if not parts:
        raise ValueError("merge_batches needs at least one part")
    features = list(
        dict.fromkeys(f for p in parts for f in p.features)
    )
    # merged query identity: the *set* of group attributes (order does not
    # change the grouping, only key-column order; first-seen order wins)
    by_sig: Dict[FrozenSet[str], List] = {}
    order: List[FrozenSet[str]] = []
    assignments: Dict[Tuple[object, str], FrozenSet[str]] = {}
    for p in parts:
        for q in p.queries:
            akey = (p.rid, q.name)
            if akey in assignments:
                raise ValueError(
                    f"duplicate query name {q.name!r} in request {p.rid!r}"
                )
            sig = frozenset(q.group_by)
            ent = by_sig.get(sig)
            if ent is None:
                by_sig[sig] = [tuple(q.group_by), q.degree]
                order.append(sig)
            else:
                ent[1] = max(ent[1], q.degree)
            assignments[akey] = sig
    names = {sig: f"m{i}" for i, sig in enumerate(order)}
    return MergedBatch(
        features=features,
        queries=[
            AggregateQuery(names[sig], by_sig[sig][0], by_sig[sig][1])
            for sig in order
        ],
        assignments={k: names[sig] for k, sig in assignments.items()},
    )


def scatter_results(
    merged: MergedBatch,
    parts: Sequence[BatchPart],
    results: Dict[str, AggregateBlock],
) -> Dict[object, Dict[str, AggregateBlock]]:
    """Slice one merged ``run_batch`` output back into per-request results:
    ``out[rid][query name]`` is exactly the block the request would have
    received from a private engine over its own feature list (same feature
    order, same declared degree) — up to float summation order."""
    out: Dict[object, Dict[str, AggregateBlock]] = {}
    for p in parts:
        mine = out.setdefault(p.rid, {})
        for q in p.queries:
            blk = results[merged.assignments[(p.rid, q.name)]]
            mine[q.name] = blk.restrict(list(p.features), q.degree)
    return out


@dataclasses.dataclass
class GroupedView:
    """Root view of a GROUP BY evaluation: one row per distinct combination
    of the group attributes' *original dictionary ids* (not engine-internal
    ids), carrying that group's degree-≤2 aggregates.

    ``keys[attr][r]`` is the dictionary id of group row ``r`` for ``attr``;
    ``count``/``lin``/``quad`` are the per-group cofactor entries in the
    engine's requested feature order.  Summing the rows reproduces the
    global (ungrouped) cofactors — the same union-commutativity that makes
    these blocks composable under ``__add__`` and sharded reductions.
    """

    keys: Dict[str, np.ndarray]  # attr -> attribute values [N] (float64)
    count: np.ndarray  # [N]
    lin: np.ndarray  # [N, k]
    quad: np.ndarray  # [N, k, k]
    features: List[str]

    @property
    def num_groups(self) -> int:
        return int(self.count.shape[0])

    def ids(self, attr: str) -> np.ndarray:
        """Group keys of a dictionary-encoded attribute as int64 ids."""
        return self.keys[attr].astype(np.int64)


@dataclasses.dataclass
class _View:
    """One factorized view Q_A: keyed aggregate tensors (see module doc).
    ``l``/``q`` are ``None`` above the view's evaluation degree."""

    keys: Dict[str, np.ndarray]  # attr -> int32 ids [N]
    c: object  # [N]
    l: object  # [N, k] | None
    q: object  # [N, k, k] | None
    feats: List[str]
    degree: int

    @property
    def num_rows(self) -> int:
        return int(self.c.shape[0])


@dataclasses.dataclass
class _BatchPlan:
    """The analysis product of the plan layer: which ``(node, live-subset)``
    views the executor must evaluate, and at which degree.

    ``subtree_vars[id(node)]`` — attribute-node names in the subtree.
    ``need[id(node)][sig]``    — max degree over queries whose live subset
                                 at the node equals ``sig``.
    """

    queries: List[AggregateQuery]
    subtree_vars: Dict[int, FrozenSet[str]]
    need: Dict[int, Dict[FrozenSet[str], int]]


class FactorizedEngine:
    """Evaluates degree-≤2 monomial aggregates over an extended variable order.

    ``backend='jax'`` uses jnp (float32 by default) — the compiled columnar
    path.  ``backend='numpy'`` uses float64 host math — the exact oracle used
    in tests.

    Instrumentation: ``passes`` counts executor traversals (one per
    :meth:`run_batch`, however many queries the batch carries) and
    ``node_visits`` counts ``(node, live-subset)`` view evaluations — the
    currency the single-pass claim is audited in.
    """

    def __init__(
        self,
        store: StoreReads,
        vorder: VariableOrder,
        features: Sequence[str],
        backend: str = "jax",
        dtype=None,
        scale=None,  # Optional[ScaleFactors] — lazy view rescaling (§4.2)
        group_by: Sequence[str] = (),
        overrides: Optional[Dict[str, Relation]] = None,
        use_view_cache: Optional[bool] = None,
        use_node_kernels: Optional[bool] = None,
    ) -> None:
        self.store = store
        # lazy-maintenance read barrier: fold the pending-delta log of the
        # covered relations BEFORE freezing the catalog, so this engine
        # probes a warm, up-to-date view cache.  Delta engines (overrides)
        # skip it — they ARE the drain's workers, and their overridden
        # relations must keep their recorded pending state.
        if not overrides:
            flush = getattr(store, "flush", None)
            if callable(flush):
                flush(vorder.relations())
        # freeze the catalog: all *data* reads (relations, encoded columns)
        # go through an immutable snapshot, so a concurrent ``append`` /
        # ``put`` on the live store can never corrupt an in-flight
        # traversal — the engine observes bit-identical data whether or
        # not a mutation lands mid-batch.  Counters, the view cache and
        # vorder registration still route through ``self.store`` (the
        # snapshot forwards them), keeping store totals authoritative.
        snap = getattr(store, "snapshot", None)
        self.data = snap() if callable(snap) else store
        validate(vorder, self.data)
        self.vorder = vorder
        self.features = list(features)
        if backend not in ("jax", "numpy"):
            raise ValueError(f"unknown backend {backend}")
        self.backend = backend
        self.xp = jnp if backend == "jax" else np
        self.dtype = dtype or (jnp.float32 if backend == "jax" else np.float64)
        self.scale = scale
        # fused per-node kernels (repro.kernels.segment_view): extend-with-
        # feature + GROUP BY collapse into ONE dispatch per node, grouping
        # runs device-side, and all blocks of a plain regroup share one
        # segment-reduce call.  Default: on for the jax backend (Pallas on
        # TPU, the jitted XLA fusion elsewhere); the numpy oracle backend
        # never uses them.  Bit-compatible grouping (same ids, same group
        # order) keeps fused and unfused views interchangeable in the
        # shared cache.
        if use_node_kernels is None:
            use_node_kernels = backend == "jax"
        self.use_node_kernels = bool(use_node_kernels) and backend == "jax"
        # device-resident grouping only where the device sort wins (it
        # loses to host np.unique on the XLA CPU backend); tests flip this
        # attribute to exercise the device path anywhere.
        self.device_grouping = (
            self.use_node_kernels and kernel_ops.fast_device_grouping()
        )
        self.group_by = list(group_by)
        # delta mode: relations replaced by their append delta — the engine
        # evaluates the join with ``name`` swapped for ``overrides[name]``
        # against the live store (shared dictionaries, shared view cache).
        self.overrides = dict(overrides or {})
        unknown = set(self.overrides) - set(vorder.relations())
        if unknown:
            raise ValueError(
                f"overrides {sorted(unknown)} not in the variable order"
            )
        self.passes = 0
        self.node_visits = 0
        self.vc_hits = 0
        self.vc_misses = 0
        self._check_group_attrs(self.group_by)
        self._index_nodes()
        self._encode_attributes()
        missing = set(self.group_by) - set(self.domains)
        if missing:
            raise ValueError(
                f"group-by attributes {sorted(missing)} occur in no relation "
                "of the variable order"
            )
        # persistent cross-batch view cache (store-owned).  Scaled engines
        # opt out: their views bake engine-specific affine transforms in.
        vc = getattr(store, "view_cache", None)
        if use_view_cache is None:
            use_view_cache = vc is not None and vc.enabled
        self._vc = vc if (use_view_cache and vc is not None) else None
        if scale is not None:
            self._vc = None
        self._vc_skip = frozenset(self.overrides)
        # encoded columns are a SNAPSHOT of the catalog at construction
        # time: if the store mutates afterwards, this engine's views are
        # stale-by-design and must neither probe nor publish the shared
        # cache (a stale publish would poison every later query).  The
        # comparison is frozen-vs-live: ``live_version`` reaches through a
        # StoreSnapshot to the parent store's current version.
        self._vc_version = getattr(self.data, "version", 0)
        if self._vc is not None and hasattr(store, "_register_vorder"):
            # append maintenance needs the order to rebuild delta engines
            store._register_vorder(self.sig, vorder)
        self._leaf_memo: Dict[Tuple[str, int], _View] = {}
        # shared delta-fold memo; degree safety comes from _execute's
        # degree-aware acceptance (a low-degree view never serves a
        # higher-degree fold), so folds at every degree share descents
        self._maint_memo: Dict[Tuple[int, FrozenSet[str]], _View] = {}

    def _index_nodes(self) -> None:
        """Assign stable preorder indices and static subtree summaries —
        the store-agnostic node identity the persistent cache keys on."""
        self.sig = self.vorder.signature()
        self._nodes: List[VariableOrder] = []
        self._node_index: Dict[int, int] = {}
        self._subtree_vars: Dict[int, FrozenSet[str]] = {}
        self._subtree_rels: Dict[int, FrozenSet[str]] = {}

        def walk(node: VariableOrder) -> Tuple[set, set]:
            self._node_index[id(node)] = len(self._nodes)
            self._nodes.append(node)
            vs: set = set()
            rs: set = set()
            if node.is_relation:
                rs.add(node.relation)
            elif node.name != INTERCEPT:
                vs.add(node.name)
            for ch in node.children:
                cv, cr = walk(ch)
                vs |= cv
                rs |= cr
            self._subtree_vars[id(node)] = frozenset(vs)
            self._subtree_rels[id(node)] = frozenset(rs)
            return vs, rs

        walk(self.vorder)
        feat_set = set(self.features)
        self._node_feats: Dict[int, Tuple[str, ...]] = {
            id(n): tuple(sorted(feat_set & self._subtree_vars[id(n)]))
            for n in self._nodes
        }

    def _get_rel(self, name: str) -> Relation:
        return self.overrides.get(name) or self.data.get(name)

    def _live_version(self) -> int:
        """The live store's current version (reaches through a snapshot)."""
        v = getattr(self.store, "live_version", None)
        return v if v is not None else getattr(self.store, "version", 0)

    def _check_group_attrs(self, group_by: Sequence[str]) -> None:
        overlap = set(group_by) & set(self.features)
        if overlap:
            raise ValueError(
                f"attributes {sorted(overlap)} cannot be both a feature and "
                "a group-by key — declare them one or the other"
            )

    # -- dictionary encoding (global, per attribute) --------------------------
    def _encode_attributes(self) -> None:
        """Dictionary-encode every (relation, attribute) column.

        When the store owns append-only attribute dictionaries
        (``Store.attr_encoding``) they are the source of truth: ids are
        stable across catalog mutations (an append can only *extend* a
        dictionary), which is what lets persistent per-node views — whose
        key columns are these ids — survive ``append`` without
        renumbering, and lets two engine instances share cached views.
        Encoded columns of unchanged relations are cached store-side, so
        warm engine construction never re-scans historical data.  The
        legacy in-engine ``np.unique`` path remains for store-likes
        without dictionaries (and is what plain correctness tests of the
        block algebra exercise)."""
        self._dtype_tag = str(np.dtype(self.dtype))
        rel_names = list(dict.fromkeys(self.vorder.relations()))
        self.domains: Dict[str, int] = {}
        self.attr_values: Dict[str, np.ndarray] = {}  # id -> float value
        self.encoded: Dict[Tuple[str, str], np.ndarray] = {}  # (rel, attr) -> ids
        if hasattr(self.data, "attr_encoding"):
            attrs: set = set()
            for rn in rel_names:
                rel = self._get_rel(rn)
                for attr in rel.attributes:
                    self.encoded[(rn, attr)] = self.data.attr_encoding(
                        rn, attr, override=self.overrides.get(rn)
                    )
                    attrs.add(attr)
            # capture dictionaries AFTER all columns are encoded, so ids
            # introduced by this engine's relations are covered; the store
            # replaces (never mutates) the arrays, so these stay valid.
            for attr in attrs:
                vals = self.data.attr_values_array(attr)
                self.attr_values[attr] = vals
                self.domains[attr] = len(vals)
            return
        cols: Dict[str, List[Tuple[str, np.ndarray]]] = {}
        for rn in rel_names:
            rel = self._get_rel(rn)
            for attr in rel.attributes:
                cols.setdefault(attr, []).append((rn, rel.column(attr)))
        for attr, entries in cols.items():
            allv = np.concatenate([c.astype(np.float64) for _, c in entries])
            uniq, inv = np.unique(allv, return_inverse=True)
            self.domains[attr] = len(uniq)
            self.attr_values[attr] = uniq
            off = 0
            for rn, c in entries:
                self.encoded[(rn, attr)] = inv[off : off + len(c)].astype(np.int32)
                off += len(c)

    # -- public API ------------------------------------------------------------
    def cofactors(self) -> Cofactors:
        if self.group_by:
            raise ValueError("use grouped_cofactors() when group_by is set")
        blk = self.run_batch([AggregateQuery("__cof__", (), 2)])["__cof__"]
        if blk.num_groups != 1:
            raise AssertionError(
                f"root view must have exactly one row, got {blk.num_groups} "
                "— invalid variable order"
            )
        perm = [blk.features.index(f) for f in self.features]
        return Cofactors(
            count=float(blk.count[0]),
            lin=blk.lin[0][perm],
            quad=blk.quad[0][np.ix_(perm, perm)],
            features=list(self.features),
        )

    def grouped_cofactors(self) -> GroupedView:
        """Per-group cofactors, grouped by the ``group_by`` attributes —
        the SQL ``GROUP BY`` pushed through the factorization.

        Group attributes are carried as view keys all the way to the root
        instead of being aggregated out at their variable-order node, so the
        cost stays O(factorization size) and the flat join never
        materializes.  Keys are translated from engine-internal ids back to
        the store's dictionary ids, making the result stable under appends
        (new rows never renumber existing categories)."""
        if not self.group_by:
            raise ValueError("group_by is empty — use cofactors()")
        blk = self.run_batch(
            [AggregateQuery("__grp__", tuple(self.group_by), 2)]
        )["__grp__"]
        perm = [blk.features.index(f) for f in self.features]
        return GroupedView(
            keys=blk.keys,
            count=blk.count,
            lin=blk.lin[:, perm],
            quad=blk.quad[:, perm][:, :, perm],
            features=list(self.features),
        )

    def run_batch(
        self, queries: Sequence[AggregateQuery]
    ) -> Dict[str, AggregateBlock]:
        """Evaluate a batch of aggregate queries in ONE shared traversal.

        Plan phase: per node, collect the distinct live query subsets and
        the max degree each must be evaluated at.  Execute phase: memoized
        bottom-up evaluation — queries whose live subsets coincide at a
        node share that node's view, so subtrees below all referenced group
        attributes are computed exactly once for the whole batch.
        """
        queries = list(queries)
        plan = self._plan(queries)
        self.passes += 1
        store_passes = getattr(self.store, "passes", None)
        if store_passes is not None:
            self.store.passes = store_passes + 1
        cache: Dict[Tuple[int, FrozenSet[str]], _View] = {}
        out: Dict[str, AggregateBlock] = {}
        for q in queries:
            view = self._execute(self.vorder, frozenset(q.group_by), plan, cache)
            out[q.name] = self._to_block(view, q)
        return out

    def sum_product(self, attrs: Sequence[str]) -> float:
        """Generic SUM(Π attrs) over the join (paper Fig. 2/3 aggregates):
        COUNT(*) for [], SUM(a) for [a], SUM(a·b) for [a, b]."""
        attrs = list(attrs)
        if len(attrs) > 2:
            raise ValueError("degree > 2 — use repro.core.polynomial")
        cof = self.cofactors()
        if not attrs:
            return float(cof.count)
        if len(attrs) == 1:
            return float(cof.lin[cof.features.index(attrs[0])])
        i, j = (cof.features.index(a) for a in attrs)
        return float(cof.quad[i, j])

    # -- plan layer -------------------------------------------------------------
    def _plan(self, queries: Sequence[AggregateQuery]) -> _BatchPlan:
        names = set()
        for q in queries:
            if q.name in names:
                raise ValueError(f"duplicate query name {q.name!r}")
            names.add(q.name)
            if q.degree not in (0, 1, 2):
                raise ValueError(f"query {q.name!r}: degree must be 0, 1 or 2")
            self._check_group_attrs(q.group_by)
            missing = set(q.group_by) - set(self.domains)
            if missing:
                raise ValueError(
                    f"query {q.name!r}: group-by attributes "
                    f"{sorted(missing)} occur in no relation of the "
                    "variable order"
                )

        subtree_vars = self._subtree_vars  # static: computed once in init

        need: Dict[int, Dict[FrozenSet[str], int]] = {}

        def record(node: VariableOrder) -> None:
            at_node = need.setdefault(id(node), {})
            sub = subtree_vars[id(node)]
            for q in queries:
                sig = frozenset(q.group_by) & sub
                at_node[sig] = max(at_node.get(sig, -1), q.degree)
            for ch in node.children:
                record(ch)

        record(self.vorder)
        return _BatchPlan(
            queries=list(queries), subtree_vars=subtree_vars, need=need
        )

    # -- executor: memoized bottom-up evaluation ---------------------------------
    def _execute(
        self,
        node: VariableOrder,
        keep: FrozenSet[str],
        plan: _BatchPlan,
        cache: Dict[Tuple[int, FrozenSet[str]], _View],
    ) -> _View:
        memo_key = (id(node), keep)
        degree = plan.need[id(node)][keep]
        hit = cache.get(memo_key)
        # degree-aware acceptance: within one batch the plan pins a single
        # max degree per (node, keep), so this is always an exact hit; the
        # shared delta-fold memo also serves lower-degree folds from a
        # higher-degree view (consumers slice the blocks they declared),
        # while a lower-degree memo entry never masks a degree-2 need.
        if hit is not None and hit.degree >= degree:
            return hit
        view = self._vc_get(node, keep, degree)
        if view is None:
            self.node_visits += 1
            store_visits = getattr(self.store, "node_visits", None)
            if store_visits is not None:
                self.store.node_visits = store_visits + 1
            if node.is_relation:
                view = self._leaf_view(node.relation, degree)
            else:
                child_views = [
                    self._execute(
                        ch, keep & plan.subtree_vars[id(ch)], plan, cache
                    )
                    for ch in node.children
                ]
                view = child_views[0]
                for other in child_views[1:]:
                    view = self._combine(view, other, degree)
                if node.name == INTERCEPT:
                    if set(view.keys) != keep:
                        extra = sorted(set(view.keys) - keep)
                        raise AssertionError(
                            f"attributes {extra} survive to the intercept — "
                            "variable order misses nodes for them"
                        )
                    # canonical key layout: a multi-child intercept leaves
                    # the root view in JOIN order (first-seen keys).  Every
                    # other keyed view comes out of _group_rows in sorted-
                    # key canonical order — regroup here too, so cached
                    # views keep one layout and a delta fold (_merge_views,
                    # which regroups over sorted keys) preserves it exactly.
                    if keep and len(child_views) > 1:
                        view = self._group_rows(
                            view, sorted(view.keys), degree
                        )
                else:
                    if (
                        self.use_node_kernels
                        and node.name in self.features
                        and degree >= 1
                        and view.num_rows > 0
                    ):
                        # fused node: extend + GROUP BY in one kernel pass
                        view = self._extend_and_group(
                            view, node.name, keep, degree
                        )
                    else:
                        if node.name in self.features and degree >= 1:
                            view = self._extend_with_feature(
                                view, node.name, degree
                            )
                        view = self._aggregate_out(
                            view, node.name, keep, degree
                        )
            self._vc_put(node, keep, degree, view)
        cache[memo_key] = view
        return view

    # -- persistent (cross-batch) view cache -----------------------------------
    def _vc_key(
        self, node: VariableOrder, keep: FrozenSet[str], degree: int
    ) -> ViewKey:
        return ViewKey(
            vorder_sig=self.sig,
            backend=self.backend,
            dtype=self._dtype_tag,
            node=self._node_index[id(node)],
            feats=self._node_feats[id(node)],
            keep=keep,
            degree=degree,
        )

    def _vc_eligible(self, node: VariableOrder) -> bool:
        if self._vc is None:
            return False
        # catalog moved on since this engine snapshotted its encodings:
        # its views describe the OLD catalog — stay out of the cache.  The
        # snapshot keeps the traversal itself correct; this check only
        # stops stale publishes / probes against the newer-versioned cache.
        if self._live_version() != self._vc_version:
            return False
        # Relation leaves are never persisted: a leaf view is ones/zeros
        # plus references to the (already cached) encoded key columns —
        # caching it would spend the byte budget on the largest, cheapest
        # views and force row-level folds on every append.  When a leaf's
        # ancestor view hits, the leaf is never visited anyway.
        if node.is_relation:
            return False
        # delta engines: nodes covering an overridden relation hold delta
        # views, never totals — neither served from nor published to the
        # persistent cache.  Untouched sibling subtrees remain eligible.
        return not (self._subtree_rels[id(node)] & self._vc_skip)

    def _vc_get(
        self, node: VariableOrder, keep: FrozenSet[str], degree: int
    ) -> Optional[_View]:
        if not self._vc_eligible(node):
            return None
        version = self._vc_version  # eligibility pinned live == frozen
        for d in range(degree, 3):
            view = self._vc.get(self._vc_key(node, keep, d), version)
            if view is not None:
                self.vc_hits += 1
                self._vc.note_hit()
                return self._trim_view(view, degree)
        # cross-dtype reuse: a float64 view of the same node (any backend)
        # serves a lower-precision request by casting its blocks — an O(view)
        # copy instead of a subtree re-descent.  A fully-warm fp32 batch
        # over fp64-cached subtrees therefore reports ZERO node_visits.
        # The cast is not re-published: the fp64 entry stays the single
        # canonical copy (no double byte-accounting), and the cast itself
        # is cheaper than a second cache round-trip.
        if self._dtype_tag != "float64":
            base = self._vc_key(node, keep, degree)
            for backend in dict.fromkeys((self.backend, "numpy", "jax")):
                for d in range(degree, 3):
                    key64 = base._replace(
                        backend=backend, dtype="float64", degree=d
                    )
                    view = self._vc.get(key64, version)
                    if view is not None:
                        self.vc_hits += 1
                        self._vc.note_hit()
                        return self._cast_view(self._trim_view(view, degree))
        self.vc_misses += 1
        self._vc.note_miss()
        return None

    def _cast_view(self, view: _View) -> _View:
        """Re-express a cached view in this engine's backend/dtype.  Key
        columns are shared (ids are backend-agnostic); value blocks are
        converted — the cross-dtype serving path."""
        xp, dt = self.xp, self.dtype
        return _View(
            keys=view.keys,
            c=xp.asarray(view.c, dtype=dt),
            l=xp.asarray(view.l, dtype=dt) if view.l is not None else None,
            q=xp.asarray(view.q, dtype=dt) if view.q is not None else None,
            feats=list(view.feats),
            degree=view.degree,
        )

    def _vc_put(
        self, node: VariableOrder, keep: FrozenSet[str], degree: int, view
    ) -> None:
        if not self._vc_eligible(node) or not self._vc.enabled:
            return
        self._vc.put(
            self._vc_key(node, keep, degree),
            view,
            relations=self._subtree_rels[id(node)],
            version=self._vc_version,  # eligibility pinned live == frozen
        )

    @staticmethod
    def _trim_view(view: _View, degree: int) -> _View:
        """Serve a lower-degree request from a higher-degree cached view —
        block slicing only, no recompute (degree-0 views carry no feats)."""
        if view.degree == degree:
            return view
        return _View(
            keys=view.keys,
            c=view.c,
            l=view.l if degree >= 1 else None,
            q=view.q if degree == 2 else None,
            feats=list(view.feats) if degree >= 1 else [],
            degree=degree,
        )

    def _to_block(self, view: _View, q: AggregateQuery) -> AggregateBlock:
        keys = {
            a: self.attr_values[a][np.asarray(view.keys[a])].astype(np.float64)
            for a in q.group_by
        }
        count = np.asarray(view.c, dtype=np.float64)
        lin = quad = None
        if q.degree >= 1:
            # the view may have been evaluated at a higher degree for a
            # sibling query — slice what this query declared it reads.
            lin = np.asarray(view.l, dtype=np.float64)
        if q.degree == 2:
            quad = np.asarray(view.q, dtype=np.float64)
        return AggregateBlock(
            keys=keys,
            count=count,
            lin=lin,
            quad=quad,
            features=list(view.feats),
        )

    def _leaf_view(self, rel_name: str, degree: int) -> _View:
        # hoisted per (relation, degree): repeated batches within one
        # engine share the encoded leaf block even when the persistent
        # view cache is disabled (and the cold baseline stays fair).
        memo_key = (rel_name, degree)
        hit = self._leaf_memo.get(memo_key)
        if hit is not None:
            return hit
        for d in range(degree + 1, 3):  # a higher-degree leaf trims for free
            hit = self._leaf_memo.get((rel_name, d))
            if hit is not None:
                view = self._trim_view(hit, degree)
                self._leaf_memo[memo_key] = view
                return view
        rel = self._get_rel(rel_name)
        n = rel.num_rows
        keys = {a: self.encoded[(rel_name, a)] for a in rel.attributes}
        xp, dt = self.xp, self.dtype
        view = _View(
            keys=keys,
            c=xp.ones((n,), dtype=dt),
            l=xp.zeros((n, 0), dtype=dt) if degree >= 1 else None,
            q=xp.zeros((n, 0, 0), dtype=dt) if degree == 2 else None,
            feats=[],
            degree=degree,
        )
        self._leaf_memo[memo_key] = view
        return view

    def _combine(self, v1: _View, v2: _View, degree: int) -> _View:
        xp = self.xp
        shared = sorted(set(v1.keys) & set(v2.keys))
        if shared:
            doms = [self.domains[a] for a in shared]
            # hash-join fallback past the int64 radix limit (join_keys),
            # mirroring group_key's escape hatch on the GROUP BY side.
            k1, k2 = join_keys(
                [v1.keys[a] for a in shared],
                [v2.keys[a] for a in shared],
                doms,
            )
            i1, i2 = sort_merge_join(k1, k2)
        else:  # cross product (e.g. under the intercept)
            n1, n2 = v1.num_rows, v2.num_rows
            i1 = np.repeat(np.arange(n1, dtype=np.int64), n2)
            i2 = np.tile(np.arange(n2, dtype=np.int64), n1)
        keys = {a: c[i1] for a, c in v1.keys.items()}
        for a, c in v2.keys.items():
            if a not in keys:
                keys[a] = c[i2]
        c1 = xp.take(v1.c, i1, axis=0)
        c2 = xp.take(v2.c, i2, axis=0)
        c = c1 * c2
        l = q = None
        if degree >= 1:
            l1 = xp.take(v1.l, i1, axis=0)
            l2 = xp.take(v2.l, i2, axis=0)
            l = xp.concatenate([l1 * c2[:, None], c1[:, None] * l2], axis=1)
            if degree == 2:
                q1 = xp.take(v1.q, i1, axis=0)
                q2 = xp.take(v2.q, i2, axis=0)
                cross = l1[:, :, None] * l2[:, None, :]
                top = xp.concatenate([q1 * c2[:, None, None], cross], axis=2)
                bot = xp.concatenate(
                    [xp.swapaxes(cross, 1, 2), q2 * c1[:, None, None]], axis=2
                )
                q = xp.concatenate([top, bot], axis=1)
        feats = v1.feats + v2.feats if degree >= 1 else []
        return _View(keys=keys, c=c, l=l, q=q, feats=feats, degree=degree)

    def _feature_values(self, view: _View, attr: str):
        """Per-row (scaled) feature values for ``attr``, in backend dtype."""
        if attr not in view.keys:
            raise AssertionError(f"feature {attr} not present below its node")
        vals = self.attr_values[attr].astype(np.float64)[
            np.asarray(view.keys[attr])
        ]
        if self.scale is not None:
            vals = self.scale.transform(attr, vals)
        return self.xp.asarray(vals, dtype=self.dtype)

    def _extend_with_feature(self, view: _View, attr: str, degree: int) -> _View:
        xp = self.xp
        x = self._feature_values(view, attr)
        c, l = view.c, view.l
        l_new = xp.concatenate([(x * c)[:, None], l], axis=1)
        q_new = None
        if degree == 2:
            xl = x[:, None] * l
            top = xp.concatenate(
                [(x * x * c)[:, None, None], xl[:, None, :]], axis=2
            )
            bot = xp.concatenate([xl[:, :, None], view.q], axis=2)
            q_new = xp.concatenate([top, bot], axis=1)
        return _View(
            keys=view.keys,
            c=view.c,
            l=l_new,
            q=q_new,
            feats=[attr] + view.feats,
            degree=degree,
        )

    def _aggregate_out(
        self, view: _View, attr: str, keep: FrozenSet[str], degree: int
    ) -> _View:
        if attr not in view.keys:
            raise AssertionError(
                f"variable {attr} does not occur in any relation below its "
                "node — invalid variable order"
            )
        # live group attributes are never aggregated out: they stay among the
        # grouping keys (the group-by below still compresses duplicates), so
        # every ancestor view — and ultimately the root — is keyed by them.
        drop = set() if attr in keep else {attr}
        remaining = sorted(set(view.keys) - drop)
        return self._group_rows(view, remaining, degree)

    def _extend_and_group(
        self, view: _View, attr: str, keep: FrozenSet[str], degree: int
    ) -> _View:
        """The fused node: :meth:`_extend_with_feature` +
        :meth:`_aggregate_out` in ONE ``segment_view`` kernel dispatch —
        the extended ``[N, k+1, k+1]`` tensor never materializes in HBM.
        Grouping is bit-compatible with the host path (same segment ids,
        same sorted group order), so the resulting view is interchangeable
        with the unfused one, cache entries included."""
        x = self._feature_values(view, attr)
        drop = set() if attr in keep else {attr}
        remaining = sorted(set(view.keys) - drop)
        seg, num, keys = self._group_ids(view, remaining)
        c, l, q = kernel_ops.segment_view(
            view.c,
            x,
            view.l,
            view.q if degree == 2 else None,
            seg,
            num,
            degree=degree,
        )
        return _View(
            keys=keys,
            c=c,
            l=l,
            q=q,
            feats=[attr] + view.feats,
            degree=degree,
        )

    def _group_ids(
        self, view: _View, remaining: Sequence[str]
    ) -> Tuple[np.ndarray, int, Dict[str, np.ndarray]]:
        """Segment ids + surviving key columns for GROUP BY ``remaining``.

        Group numbering is canonical — ascending packed-key order over the
        (sorted) ``remaining`` attributes — whichever path computes it: the
        host ``np.unique`` or the device sort (``kernel_ops.
        group_ids_device``), which is bit-compatible and skips the per-node
        host round-trip of the row ids."""
        n = view.num_rows
        if not remaining:
            return np.zeros((n,), dtype=np.int32), 1, {}
        doms = [self.domains[a] for a in remaining]
        # group_key, not composite_key: a view keyed by many wide
        # attributes (fact tables with ≫8 categorical keys) overflows
        # the strict mixed-radix product, and a GROUP BY only needs
        # within-call injectivity.
        key = group_key([view.keys[a] for a in remaining], doms)
        if self.device_grouping and n > 0:
            seg, num, first = kernel_ops.group_ids_device(key)
        else:
            uniq, first, inv = np.unique(
                key, return_index=True, return_inverse=True
            )
            seg = inv.astype(np.int32)
            num = len(uniq)
        keys = {a: view.keys[a][first] for a in remaining}
        return seg, num, keys

    def _group_rows(
        self, view: _View, remaining: Sequence[str], degree: int
    ) -> _View:
        """GROUP BY ``remaining`` over a view's rows (segment-sum of every
        block) — the aggregation core shared by :meth:`_aggregate_out` and
        the delta-fold :meth:`_merge_views`."""
        seg, num, keys = self._group_ids(view, remaining)
        if self.use_node_kernels and view.num_rows > 0:
            # one multi-block kernel call instead of a scatter per block
            c, l, q = kernel_ops.segment_blocks(
                view.c,
                view.l if degree >= 1 else None,
                view.q if degree == 2 else None,
                seg,
                num,
                degree=degree,
            )
        else:
            c = self._segment_sum(view.c, seg, num)
            l = self._segment_sum(view.l, seg, num) if degree >= 1 else None
            q = self._segment_sum(view.q, seg, num) if degree == 2 else None
        return _View(
            keys=keys, c=c, l=l, q=q, feats=view.feats, degree=degree
        )

    # -- delta-path maintenance (Store.append) ---------------------------------
    def fold_delta_view(self, key: ViewKey, old_view: _View) -> _View:
        """Fold this delta engine's view of ``key``'s node into an existing
        cached total view — the per-node form of Prop. 4.1's union
        commutativity that ``Store.append`` uses to keep the view cache
        warm: only the appended relation's root path is recomputed (at
        delta size), sibling subtrees stay untouched.

        The engine must have been constructed with ``overrides`` mapping
        the appended relation to its delta rows and ``features`` equal to
        ``key.feats`` (so block layouts line up)."""
        node = self._nodes[key.node]
        if tuple(self._node_feats[id(node)]) != tuple(key.feats):
            raise ValueError(
                f"delta engine features {self._node_feats[id(node)]} do not "
                f"match cached view features {key.feats}"
            )
        keep = frozenset(key.keep)
        plan = self._subtree_plan(node, keep, key.degree)
        delta = self._execute(node, keep, plan, self._maint_memo)
        # the memo may hand back a higher-degree delta (shared with an
        # earlier fold) — trim to the entry's blocks before merging
        delta = self._trim_view(delta, key.degree)
        return self._merge_views(old_view, delta, key.degree)

    def _subtree_plan(
        self, node: VariableOrder, keep: FrozenSet[str], degree: int
    ) -> _BatchPlan:
        """A plan covering just ``node``'s subtree at one (keep, degree) —
        what :meth:`fold_delta_view` hands to the executor."""
        need: Dict[int, Dict[FrozenSet[str], int]] = {}

        def rec(n: VariableOrder, k: FrozenSet[str]) -> None:
            at = need.setdefault(id(n), {})
            at[k] = max(at.get(k, -1), degree)
            for ch in n.children:
                rec(ch, k & self._subtree_vars[id(ch)])

        rec(node, keep & self._subtree_vars[id(node)])
        return _BatchPlan(
            queries=[], subtree_vars=self._subtree_vars, need=need
        )

    def _merge_views(self, a: _View, b: _View, degree: int) -> _View:
        """Union of two keyed views over disjoint row sets: concatenate
        rows, then re-group over the full key set (duplicated key combos
        sum — Prop. 4.1).  Regrouping runs over ``sorted(keys)`` — the SAME
        canonical order every keyed view is built with (``_group_rows``
        sorts; multi-child intercept views are canonicalized in
        ``_execute``) — so folding a delta into a cached view preserves its
        key layout exactly: same key-dict order, same row order."""
        if list(a.feats) != list(b.feats) or set(a.keys) != set(b.keys):
            raise AssertionError(
                f"cannot merge views: feats {a.feats} vs {b.feats}, "
                f"keys {sorted(a.keys)} vs {sorted(b.keys)}"
            )
        xp = self.xp
        keys = {
            attr: np.concatenate(
                [np.asarray(a.keys[attr]), np.asarray(b.keys[attr])]
            )
            for attr in a.keys
        }
        stacked = _View(
            keys=keys,
            c=xp.concatenate([a.c, b.c], axis=0),
            l=xp.concatenate([a.l, b.l], axis=0) if degree >= 1 else None,
            q=xp.concatenate([a.q, b.q], axis=0) if degree == 2 else None,
            feats=list(a.feats),
            degree=degree,
        )
        return self._group_rows(stacked, sorted(keys), degree)

    def _segment_sum(self, data, seg, num: int):
        if self.backend == "jax":
            # jax.ops.segment_sum over zeros().at[seg].add(data): one fewer
            # allocation + scatter dispatch per block (the non-kernel
            # fallback; use_node_kernels batches all blocks in one call).
            return jax.ops.segment_sum(
                jnp.asarray(data), jnp.asarray(seg), num_segments=num
            )
        out = np.zeros((num,) + data.shape[1:], dtype=data.dtype)
        np.add.at(out, seg, data)
        return out


def cofactors_factorized(
    store: StoreReads,
    vorder: VariableOrder,
    features: Sequence[str],
    backend: str = "jax",
    dtype=None,
    scale=None,
    use_view_cache: Optional[bool] = None,
    use_node_kernels: Optional[bool] = None,
) -> Cofactors:
    """Convenience wrapper: cofactors over the factorized join (paper §4.3)."""
    return FactorizedEngine(
        store,
        vorder,
        features,
        backend=backend,
        dtype=dtype,
        scale=scale,
        use_view_cache=use_view_cache,
        use_node_kernels=use_node_kernels,
    ).cofactors()


def grouped_cofactors_factorized(
    store: StoreReads,
    vorder: VariableOrder,
    features: Sequence[str],
    group_by: Sequence[str],
    backend: str = "jax",
    dtype=None,
    scale=None,
    use_node_kernels: Optional[bool] = None,
) -> GroupedView:
    """Convenience wrapper: GROUP BY ``group_by`` cofactors over the
    factorized join — the building block of the categorical algebra."""
    return FactorizedEngine(
        store,
        vorder,
        features,
        backend=backend,
        dtype=dtype,
        scale=scale,
        group_by=group_by,
        use_node_kernels=use_node_kernels,
    ).grouped_cofactors()
