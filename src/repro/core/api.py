"""The store layer's public READ contract, as an explicit Protocol.

``Store`` and ``StoreSnapshot`` have always duck-typed the same read
surface — every ``FactorizedEngine`` and the serve layer run against
either interchangeably.  :class:`StoreReads` writes that contract down so
the next reader of ``store.py`` doesn't have to reverse-engineer it from
call sites, and so type checkers can hold the engine/serve layers to it.

The contract is *reads only*: anything here is safe against a snapshot
frozen at an old version.  Mutations (``append`` / ``put`` / ``add_fd``)
and maintenance state (the view cache, the pending-delta log) are
``Store``-only and deliberately absent.

``flush`` sits on the read surface because draining pending deltas is a
*read-side* concern under lazy maintenance: a reader that wants warm
caches folds the log first.  On a stale ``StoreSnapshot`` it is a no-op
(the snapshot's frozen catalog needs no cache maintenance); on a current
one it forwards to the parent store.

``runtime_checkable`` keeps ``isinstance(store, StoreReads)`` usable as a
structural smoke test (method presence only, per Protocol semantics).
"""

from __future__ import annotations

from typing import (
    TYPE_CHECKING,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    runtime_checkable,
)

import numpy as np

if TYPE_CHECKING:  # typing-only: avoid import cycles at runtime
    from .factorize import Cofactors
    from .fd import FDReduction, FunctionalDependency
    from .relation import Relation
    from .variable_order import VariableOrder

__all__ = ["StoreReads"]


@runtime_checkable
class StoreReads(Protocol):
    """What a reader (engine, solver, service) may ask of a store-like.

    Implemented by :class:`repro.core.store.Store` and
    :class:`repro.core.store.StoreSnapshot`; any object satisfying it can
    back a :class:`repro.core.factorize.FactorizedEngine`.
    """

    # -- catalog ---------------------------------------------------------------
    def get(self, name: str) -> "Relation":
        """The relation stored under ``name`` (KeyError if absent)."""
        ...

    def names(self) -> List[str]:
        """Names of all cataloged relations."""
        ...

    def relations(self) -> List["Relation"]:
        """All cataloged relations."""
        ...

    def total_rows(self) -> int:
        """Sum of row counts over the catalog."""
        ...

    def attr_domain(self, attr: str) -> int:
        """Dictionary-domain size of a key attribute."""
        ...

    # -- dictionary encodings --------------------------------------------------
    def attr_encoding(
        self, rel_name: str, attr: str, override: Optional["Relation"] = None
    ) -> np.ndarray:
        """int32 ids of a relation's column under the store's append-only
        attribute dictionary (``override``: encode a replacement
        relation's column instead — the delta-engine path)."""
        ...

    def attr_values_array(self, attr: str) -> np.ndarray:
        """id → value translation array of an attribute's dictionary."""
        ...

    # -- statistics ------------------------------------------------------------
    def column_moments(self, col: str) -> Tuple[float, float, int]:
        """(sum, max|x|, count) of ``col`` over the relations holding it."""
        ...

    # -- functional dependencies -----------------------------------------------
    def fds(self) -> List["FunctionalDependency"]:
        """The FD catalog."""
        ...

    def fd_reduction(self, cat: Sequence[str]) -> "FDReduction":
        """FD reduction plan of a categorical attribute list."""
        ...

    # -- aggregates ------------------------------------------------------------
    def sufficient_stats(
        self,
        vorder: "VariableOrder",
        features: Sequence[str],
        label: Optional[str] = None,
        categorical: Sequence[str] = (),
        backend: Optional[str] = None,
        refresh: bool = False,
        reduce_fds: bool = False,
    ):
        """Sufficient statistics (cofactors) for a regression over the
        factorized join — the single read entry point; see
        ``Store.sufficient_stats``."""
        ...

    def cofactors(
        self,
        vorder: "VariableOrder",
        features: Sequence[str],
        backend: str = "jax",
        refresh: bool = False,
    ) -> "Cofactors":
        """Continuous-only sufficient statistics (thin wrapper)."""
        ...

    def cat_cofactors(
        self,
        vorder: "VariableOrder",
        cont: Sequence[str],
        cat: Sequence[str],
        backend: str = "numpy",
        refresh: bool = False,
        reduce_fds: bool = False,
    ):
        """Categorical sufficient statistics (thin wrapper)."""
        ...

    def materialize_join(
        self, names: Optional[Sequence[str]] = None
    ) -> "Relation":
        """The flat natural join — the noPre baseline path."""
        ...

    # -- consistency -----------------------------------------------------------
    def snapshot(self) -> "StoreReads":
        """An immutable read view at the current version (snapshots
        return themselves)."""
        ...

    def flush(self, names: Optional[Sequence[str]] = None) -> Dict[str, int]:
        """Fold pending appends into the caches (lazy maintenance);
        no-op and zero-stats on an already-clean or frozen view."""
        ...
