"""Variable orders (paper §2.2, §4.1).

A variable order Δ = (T, key) is a rooted forest with one node per query
attribute such that every relation's attributes lie on a single root-to-leaf
path.  The *extended* variable order (paper §4.1) additionally

  (1) attaches each relation R as a leaf below its lowest attribute, and
  (2) adds an intercept node ``T`` as the new root.

Deviation from the paper (an improvement, documented in DESIGN.md): the
``key`` function — the ancestor set each subtree depends on — is *derived*
by the engine during evaluation (the union of child view keys), instead of
being user-declared.  The user only designs the tree shape; a wrong shape is
rejected by :func:`validate`, and derived keys are minimal-correct by
construction.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set

from .store import Store

INTERCEPT = "T"

__all__ = ["VariableOrder", "validate", "variable_order_from_store", "INTERCEPT"]


@dataclasses.dataclass
class VariableOrder:
    """One node of an (extended) variable order.

    ``name``      : attribute name, or relation name for relation leaves,
                    or ``T`` for the intercept root.
    ``children``  : child nodes.
    ``relation``  : if set, this node is a relation leaf (paper §4.1 (1)).
    """

    name: str
    children: List["VariableOrder"] = dataclasses.field(default_factory=list)
    relation: Optional[str] = None

    # -- construction helpers -------------------------------------------------
    def add(self, child: "VariableOrder") -> "VariableOrder":
        self.children.append(child)
        return self

    @staticmethod
    def intercept(children: Sequence["VariableOrder"]) -> "VariableOrder":
        return VariableOrder(INTERCEPT, children=list(children))

    @staticmethod
    def leaf(relation_name: str) -> "VariableOrder":
        return VariableOrder(relation_name, relation=relation_name)

    # -- traversal -------------------------------------------------------------
    @property
    def is_relation(self) -> bool:
        return self.relation is not None

    def variables(self) -> List[str]:
        """All attribute nodes (pre-order), excluding relation leaves and T."""
        out = []
        if not self.is_relation and self.name != INTERCEPT:
            out.append(self.name)
        for ch in self.children:
            out.extend(ch.variables())
        return out

    def relations(self) -> List[str]:
        out = []
        if self.is_relation:
            out.append(self.relation)
        for ch in self.children:
            out.extend(ch.relations())
        return out

    def signature(self) -> tuple:
        """Hashable structural identity of this (sub)tree — the cache key
        component used by ``Store``'s cofactor cache.  Two orders with the
        same shape, names and relation leaves share a signature."""
        return (
            self.name,
            self.relation,
            tuple(ch.signature() for ch in self.children),
        )

    def find_leaves(self) -> List["VariableOrder"]:
        """Paper's ``findLeaves``: all relation-leaf nodes."""
        if self.is_relation:
            return [self]
        out: List["VariableOrder"] = []
        for ch in self.children:
            out.extend(ch.find_leaves())
        return out

    def pretty(self, indent: int = 0) -> str:
        tag = f"[{self.relation}]" if self.is_relation else self.name
        lines = ["  " * indent + tag]
        for ch in self.children:
            lines.append(ch.pretty(indent + 1))
        return "\n".join(lines)


def validate(vorder: VariableOrder, store: Store) -> None:
    """Check the defining property: every relation's attributes lie on the
    root-to-leaf path ending at the relation's leaf node."""
    if vorder.name != INTERCEPT:
        raise ValueError("extended variable order must be rooted at intercept T")

    def walk(node: VariableOrder, path: Set[str]) -> None:
        if node.is_relation:
            rel = store.get(node.relation)
            missing = set(rel.attributes) - path
            if missing:
                raise ValueError(
                    f"relation {node.relation}: attributes {sorted(missing)} "
                    f"not on its root-to-leaf path {sorted(path)}"
                )
            if node.children:
                raise ValueError("relation leaves must not have children")
            return
        new_path = path | ({node.name} if node.name != INTERCEPT else set())
        if not node.children:
            raise ValueError(
                f"variable {node.name} is a leaf but represents no relation "
                "(extended variable orders require relation leaves)"
            )
        for ch in node.children:
            walk(ch, new_path)

    walk(vorder, set())

    # every relation in the order must exist; every attribute node must occur
    # in at least one relation on its path (guaranteed by leaf check above).
    covered = set(vorder.relations())
    for name in covered:
        if name not in store:
            raise ValueError(f"variable order references unknown relation {name}")


def variable_order_from_store(
    store: Store, order: Optional[Sequence[str]] = None
) -> VariableOrder:
    """Construct a valid extended variable order automatically.

    Builds a *path* order (single root-to-leaf attribute chain): trivially
    valid for any schema since all attributes share one path.  Attributes are
    ordered by how many relations contain them (most-shared first), which
    puts join attributes near the root — the same heuristic a DB optimizer
    would use.  Hand-crafted bushy orders (as in the paper's Fig. 6/8)
    factorize better; this is the always-correct fallback.
    """
    rels = store.relations()
    attr_count: Dict[str, int] = {}
    for rel in rels:
        for a in rel.attributes:
            attr_count[a] = attr_count.get(a, 0) + 1
    if order is None:
        order = sorted(attr_count, key=lambda a: (-attr_count[a], a))
    else:
        missing = set(attr_count) - set(order)
        if missing:
            raise ValueError(f"order misses attributes {sorted(missing)}")

    # Chain the attributes; attach each relation below its lowest attribute.
    depth = {a: i for i, a in enumerate(order)}
    nodes = [VariableOrder(a) for a in order]
    for i in range(len(nodes) - 1):
        nodes[i].add(nodes[i + 1])
    for rel in rels:
        lowest = max(rel.attributes, key=lambda a: depth[a])
        nodes[depth[lowest]].add(VariableOrder.leaf(rel.name))
    root = VariableOrder.intercept([nodes[0]] if nodes else [])
    validate(root, store)
    return root
