"""Batch gradient descent on a precomputed cofactor matrix (paper §3.4, §4.4).

The data-dependent part of the least-squares gradient factors as

    S_j = Σ_k θ_k · Cofactor[k, j]

so once the cofactor matrix is known every BGD step is a single [p, p] @ [p]
matvec — **independent of the number of training rows m**.  This module
reproduces the paper's convergence procedure faithfully:

* θ has one entry per feature plus the intercept plus the label; the label's
  coefficient is *fixed to −1* (paper §3.2: "y is also considered a feature
  with its corresponding θ fixed to −1").
* update:  ε_j = α · (S_j + 0.006·θ_j)   (ridge term, paper §4.4)
* α starts at 0.003 and is divided by 3 whenever Σ_j |ε_j| grew relative to
  the previous iteration (paper version 1); stop when Σ_j |ε_j| < ε_threshold
  (1e-6; version 3 uses 1e-8), when α < 1e-15, or at the iteration cap.
* version 4's "alternative adjustment" (the paper gives no formula; our
  interpretation, documented here): on an increase the step is *reverted*
  before shrinking α, and α grows by 5% on successful steps — a classic
  bold-driver schedule.  It reproduces the paper's observation that v4 is
  slightly more accurate at equal cost.

The loop runs on-device via ``jax.lax.while_loop``.  A ``bgd_data`` variant
implements the non-factorized ("noPre") baseline: mathematically the same
update, but S is recomputed from the materialized data every iteration
(two [m, p] matmuls per step), so its cost scales with m.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GDConfig", "GDResult", "bgd_cofactor", "bgd_data", "solve_cofactor"]


@dataclasses.dataclass(frozen=True)
class GDConfig:
    alpha0: float = 0.003
    eps: float = 1e-6  # version 3 sets 1e-8
    ridge: float = 0.006  # the paper's fixed 0.006·θ_j ridge term
    max_iter: int = 200_000  # paper caps at 1e8; configurable
    alpha_min: float = 1e-15
    alpha_strategy: str = "paper"  # "paper" (v1) | "revert" (v4)
    alpha_grow: float = 1.05  # only used by the "revert" strategy
    dtype: jnp.dtype = jnp.float32


@dataclasses.dataclass
class GDResult:
    theta: np.ndarray  # full vector [p]: [intercept, features..., label=-1]
    iterations: int
    alpha: float
    last_update: float

    def trainable(self) -> np.ndarray:
        return self.theta[:-1]


def _run_loop(step_fn, theta0, cfg: GDConfig):
    """Shared while_loop driver.  Carry: (θ, α, prev_sum, it, converged)."""

    def cond(carry):
        _, alpha, _, it, converged = carry
        return (~converged) & (it < cfg.max_iter) & (alpha > cfg.alpha_min)

    def body(carry):
        theta, alpha, prev_sum, it, _ = carry
        eps_vec = step_fn(theta, alpha)
        cur_sum = jnp.sum(jnp.abs(eps_vec))
        increase = cur_sum > prev_sum
        if cfg.alpha_strategy == "paper":
            theta_new = theta - eps_vec
            alpha_new = jnp.where(increase, alpha / 3.0, alpha)
            prev_new = cur_sum
        elif cfg.alpha_strategy == "revert":
            theta_new = jnp.where(increase, theta, theta - eps_vec)
            alpha_new = jnp.where(increase, alpha / 3.0, alpha * cfg.alpha_grow)
            prev_new = jnp.where(increase, prev_sum, cur_sum)
        else:
            raise ValueError(f"unknown alpha_strategy {cfg.alpha_strategy}")
        converged = cur_sum < cfg.eps
        return theta_new, alpha_new, prev_new, it + 1, converged

    alpha0 = jnp.asarray(cfg.alpha0, dtype=cfg.dtype)
    prev0 = jnp.asarray(jnp.inf, dtype=cfg.dtype)
    carry = (theta0, alpha0, prev0, jnp.asarray(0, jnp.int32), jnp.asarray(False))
    theta, alpha, last, it, _ = jax.lax.while_loop(cond, body, carry)
    return theta, alpha, last, it


@partial(jax.jit, static_argnames=("cfg",))
def _bgd_cofactor_jit(cof: jnp.ndarray, trainable: jnp.ndarray, cfg: GDConfig):
    p = cof.shape[0]
    theta0 = jnp.zeros((p,), dtype=cfg.dtype).at[-1].set(-1.0)

    def step(theta, alpha):
        s = cof @ theta  # the whole data scan, collapsed to one matvec
        return alpha * (s + cfg.ridge * theta) * trainable

    return _run_loop(step, theta0, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def _bgd_cofactor_penalty_jit(
    cof: jnp.ndarray, pen: jnp.ndarray, trainable: jnp.ndarray, cfg: GDConfig
):
    p = cof.shape[0]
    theta0 = jnp.zeros((p,), dtype=cfg.dtype).at[-1].set(-1.0)

    def step(theta, alpha):
        s = cof @ theta
        return alpha * (s + pen @ theta) * trainable

    return _run_loop(step, theta0, cfg)


def bgd_cofactor(
    cof_matrix: np.ndarray,
    cfg: Optional[GDConfig] = None,
    penalty: Optional[np.ndarray] = None,
) -> GDResult:
    """BGD on a cofactor matrix ordered [intercept, features..., label].

    ``penalty``, when given, is a full [p, p] penalty matrix replacing the
    scalar ``cfg.ridge * θ`` term with ``penalty @ θ`` — the generalized
    ridge of the FD-reduced parameter space (``repro.core.fd``).  Its label
    row/column must be zero (θ_label is pinned to −1)."""
    cfg = cfg or GDConfig()
    cof = jnp.asarray(cof_matrix, dtype=cfg.dtype)
    p = cof.shape[0]
    trainable = jnp.ones((p,), dtype=cfg.dtype).at[-1].set(0.0)
    if penalty is None:
        theta, alpha, last, it = _bgd_cofactor_jit(cof, trainable, cfg)
    else:
        theta, alpha, last, it = _bgd_cofactor_penalty_jit(
            cof, jnp.asarray(penalty, dtype=cfg.dtype), trainable, cfg
        )
    return GDResult(
        theta=np.asarray(theta, dtype=np.float64),
        iterations=int(it),
        alpha=float(alpha),
        last_update=float(last),
    )


@partial(jax.jit, static_argnames=("cfg",))
def _bgd_data_jit(z: jnp.ndarray, trainable: jnp.ndarray, cfg: GDConfig):
    p = z.shape[1]
    theta0 = jnp.zeros((p,), dtype=cfg.dtype).at[-1].set(-1.0)

    def step(theta, alpha):
        s = z.T @ (z @ theta)  # full data scan, every iteration (noPre)
        return alpha * (s + cfg.ridge * theta) * trainable

    return _run_loop(step, theta0, cfg)


def bgd_data(z: np.ndarray, cfg: Optional[GDConfig] = None) -> GDResult:
    """Non-factorized BGD over the materialized design matrix
    z = [1, x_1..x_n, y] per row — the paper's ``noPre`` baseline."""
    cfg = cfg or GDConfig()
    zj = jnp.asarray(z, dtype=cfg.dtype)
    p = zj.shape[1]
    trainable = jnp.ones((p,), dtype=cfg.dtype).at[-1].set(0.0)
    theta, alpha, last, it = _bgd_data_jit(zj, trainable, cfg)
    return GDResult(
        theta=np.asarray(theta, dtype=np.float64),
        iterations=int(it),
        alpha=float(alpha),
        last_update=float(last),
    )


def solve_cofactor(
    cof_matrix: np.ndarray,
    ridge: float = 0.0,
    penalty: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Beyond-paper: closed-form ridge solve of the normal equations.

    With ordering [intercept, features..., label] and θ_label = −1, the
    stationarity condition  C_tt·θ_t + ridge·θ_t = C_t,label  is a (p−1)
    linear system — solved directly in float64.  Returns the full θ vector.

    ``penalty`` replaces ``ridge·I`` with an arbitrary [p−1, p−1] penalty
    matrix over the trainable coordinates — the generalized ridge the
    FD-reduced solve needs (``repro.core.fd.penalty_blocks``).
    """
    cof = np.asarray(cof_matrix, dtype=np.float64)
    p = cof.shape[0]
    pen = penalty if penalty is not None else ridge * np.eye(p - 1)
    ctt = cof[: p - 1, : p - 1] + pen
    rhs = cof[: p - 1, p - 1]
    theta_t = np.linalg.solve(ctt, rhs)
    return np.concatenate([theta_t, [-1.0]])
