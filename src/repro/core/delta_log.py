"""Per-relation pending-delta log — the write side of lazy maintenance.

``Store.append`` (in its default ``maintenance="lazy"`` mode) does no
view-cache or cofactor folding on the write path: it validates FDs,
concatenates the relation, and records the append here — O(delta) metadata
work, independent of how many cached entries cover the relation.  The log
is **metadata only**: ``Relation.concat`` appends rows in order, so the
stacked pending delta of a relation is exactly the row range
``merged[base_rows:]`` of the merged relation already in the catalog, and
the frozen pre-append prefix is ``merged[:base_rows]``.  No delta rows are
copied or retained by the log itself.

Reads drain the log (``Store.flush`` / ``Store._drain_all``): every cached
entry covering a pending relation is folded once with the relation's
*stacked* delta — however many appends piled up, one fold pays for all of
them (union commutativity, Prop. 4.1: the deltas' cofactors sum, so their
concatenation folds in one engine pass).  Compaction is the escape hatch
for the crossover point where folding a huge stacked delta costs more
than recomputing from the merged base: past a size threshold the store
invalidates the covered entries and clears the log instead.

Counters (``drains`` / ``drained_rows`` / ``compactions``) feed
``Store.cache_info`` so benchmarks and tests can audit the write path:
a lazy append must leave ``pending_rows`` > 0 and every engine counter
untouched.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

__all__ = ["DeltaLog", "RelationLog"]


@dataclasses.dataclass
class RelationLog:
    """Pending-append record of ONE relation (metadata only).

    ``base_rows``     row count of the relation when its FIRST pending
                      append landed — the catalog rows ``[:base_rows]``
                      are the frozen pre-append prefix, ``[base_rows:]``
                      the stacked delta.
    ``first_version`` store version just before the first pending append
                      (every surviving cache entry covering the relation
                      is stamped at most here — the fold precondition).
    ``appends``       number of stacked appends.
    ``rows``          total pending delta rows (merged rows − base_rows).
    """

    base_rows: int
    first_version: int
    appends: int = 0
    rows: int = 0


class DeltaLog:
    """The store's pending-append bookkeeping, one record per relation
    with unfolded deltas.  Insertion order is preserved (dict semantics):
    ``Store._drain_all`` folds relations in first-pending order, freezing
    later pending relations to their pre-append prefixes so the
    multi-relation telescoping sum is exact."""

    def __init__(self) -> None:
        self._logs: Dict[str, RelationLog] = {}
        # cumulative audit counters (surfaced via Store.cache_info)
        self.drains = 0  # completed _drain_all passes
        self.drained_rows = 0  # delta rows folded by drains
        self.compactions = 0  # logs cleared by the size threshold

    def __bool__(self) -> bool:
        return bool(self._logs)

    def __len__(self) -> int:
        return len(self._logs)

    def __contains__(self, name: str) -> bool:
        return name in self._logs

    def record(
        self, name: str, base_rows: int, delta_rows: int, version: int
    ) -> RelationLog:
        """Record one append of ``delta_rows`` rows onto ``name`` whose
        pre-append row count was ``base_rows`` at store ``version``.
        Stacks onto an existing record (base_rows/first_version keep their
        first-append values — the fold boundary never moves)."""
        log = self._logs.get(name)
        if log is None:
            log = self._logs[name] = RelationLog(
                base_rows=base_rows, first_version=version
            )
        log.appends += 1
        log.rows += delta_rows
        return log

    def get(self, name: str) -> RelationLog:
        return self._logs[name]

    def pending(self, name: str) -> int:
        """Pending delta rows of ``name`` (0 when fully folded)."""
        log = self._logs.get(name)
        return log.rows if log is not None else 0

    def names(self) -> List[str]:
        """Relations with pending deltas, in first-pending order."""
        return list(self._logs)

    def items(self) -> List[Tuple[str, RelationLog]]:
        """Snapshot of (name, record) pairs in first-pending order — safe
        to clear entries while iterating."""
        return list(self._logs.items())

    def clear(self, name: str, drained: bool = False) -> None:
        """Drop ``name``'s record — after a successful fold
        (``drained=True``, counted) or because the entries it would have
        maintained were invalidated instead (compaction / put / error)."""
        log = self._logs.pop(name, None)
        if log is not None and drained:
            self.drained_rows += log.rows

    def total_rows(self) -> int:
        return sum(log.rows for log in self._logs.values())

    def debt(self) -> Tuple[int, int]:
        """(pending relations, pending rows) — the cheap should-I-run
        probe the background fold thread polls between idle windows."""
        return len(self._logs), self.total_rows()

    def total_appends(self) -> int:
        return sum(log.appends for log in self._logs.values())

    def info(self) -> Dict[str, int]:
        return {
            "pending_relations": len(self._logs),
            "pending_rows": self.total_rows(),
            "pending_appends": self.total_appends(),
            "drains": self.drains,
            "drained_rows": self.drained_rows,
            "compactions": self.compactions,
        }
