"""End-to-end in-database linear regression (paper §4.5, Table 2).

``linear_regression`` mirrors the paper's ``linearRegression(...)``:
scale features → compute cofactors (factorized or materialized) → batch
gradient descent on the cofactor matrix → rescale θ.  The six benchmark
versions of Table 2 are provided as named configurations.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cofactor import (
    cofactors_factorized,
    cofactors_materialized,
    design_matrix,
)
from .gd import GDConfig, GDResult, bgd_cofactor, bgd_data, solve_cofactor
from .scaling import (
    ScaleFactors,
    compute_scale_factors,
    predict,
    rescale_theta,
)
from .store import Store
from .variable_order import VariableOrder

__all__ = ["RegressionConfig", "RegressionResult", "VERSIONS", "linear_regression"]


@dataclasses.dataclass(frozen=True)
class RegressionConfig:
    """One row of the paper's Table 2 'version' column, plus the pipeline
    routing knobs that used to sprawl across ``linear_regression`` kwargs
    (``backend`` / ``use_kernel`` / ``use_cache`` / ``categorical`` /
    ``use_fds`` — the old kwargs still work as deprecation shims that
    forward onto a copy of the config)."""

    name: str = "v1"
    factorized: bool = True  # fact vs noPre
    eps: float = 1e-6  # version 3: 1e-8
    alpha_strategy: str = "paper"  # version 4/5: "revert"
    theta0_mode: str = "avg_label"  # versions 5/6: "theta0_conv"
    ridge: float = 0.006
    max_iter: int = 200_000
    solver: str = "bgd"  # "bgd" | "closed_form" (beyond-paper)
    # -- pipeline routing (formerly linear_regression kwargs) ---------------
    backend: str = "jax"  # engine value math: "jax" | "numpy"
    use_kernel: bool = False  # in-store SUM/MAX kernels for scaling
    use_cache: bool = False  # warm-retrain path via sufficient_stats
    categorical: Tuple[str, ...] = ()  # subset of features, sparse blocks
    use_fds: bool = True  # FD-reduced categorical solve
    # fused per-node traversal kernels (repro.kernels.segment_view);
    # None = engine default (on for the jax backend, off for numpy)
    use_node_kernels: Optional[bool] = None

    def gd(self) -> GDConfig:
        return GDConfig(
            eps=self.eps,
            ridge=self.ridge,
            max_iter=self.max_iter,
            alpha_strategy=self.alpha_strategy,
        )


#: The paper's Table 2 versions, reproduced as configurations.
VERSIONS: Dict[str, RegressionConfig] = {
    "v1": RegressionConfig(name="v1 fact"),
    "v2": RegressionConfig(name="v2 noPre", factorized=False),
    "v3": RegressionConfig(name="v3 fact,eps", eps=1e-8),
    "v4": RegressionConfig(name="v4 fact,alpha", alpha_strategy="revert"),
    "v5": RegressionConfig(
        name="v5 fact,alpha,theta0",
        alpha_strategy="revert",
        theta0_mode="theta0_conv",
    ),
    "v6": RegressionConfig(
        name="v6 noPre,theta0", factorized=False, theta0_mode="theta0_conv"
    ),
    # beyond-paper: exact closed-form solve on the factorized cofactors
    "closed": RegressionConfig(
        name="closed-form fact", solver="closed_form", theta0_mode="exact"
    ),
}


@dataclasses.dataclass
class RegressionResult:
    theta: np.ndarray  # in ORIGINAL units: [intercept, features..., label=-1]
    theta_conv: np.ndarray  # in scaled units
    factors: Optional[ScaleFactors]  # None on the categorical path
    iterations: int
    seconds_scale: float
    seconds_cofactor: float
    seconds_gd: float
    config: RegressionConfig
    names: Optional[List[str]] = None  # categorical path: assembled θ layout

    @property
    def seconds_total(self) -> float:
        return self.seconds_scale + self.seconds_cofactor + self.seconds_gd

    def evaluate(
        self,
        store: Store,
        features: Sequence[str],
        label: str,
        categorical: Sequence[str] = (),
    ) -> Dict[str, float]:
        """Average absolute / relative error over the joined data (paper §5)."""
        joined = store.materialize_join()
        if categorical:
            from .categorical import onehot_design_matrix

            x, _ = onehot_design_matrix(
                joined,
                [f for f in features if f not in categorical],
                list(categorical),
                {c: store.attr_domain(c) for c in categorical},
            )
        else:
            x = design_matrix(joined, features)
        y = joined.column(label).astype(np.float64)
        pred = predict(x, self.theta)
        abs_err = np.abs(y - pred)
        denom = np.where(np.abs(y) < 1e-9, np.nan, np.abs(y))
        rel = abs_err / denom
        return {
            "avg_abs_err": float(abs_err.mean()),
            "avg_rel_err": float(np.nanmean(rel)),
            "rmse": float(np.sqrt((abs_err**2).mean())),
        }


#: legacy linear_regression kwargs that already warned this process —
#: each shim kwarg warns once, not once per call site invocation
_LEGACY_WARNED: set = set()


def _legacy_kwargs(cfg: RegressionConfig, given: Dict[str, object]):
    """Fold non-None legacy kwargs onto a copy of ``cfg``, warning once
    per kwarg name.  The shims keep every established call site working
    while the config fields are the documented surface."""
    overrides = {k: v for k, v in given.items() if v is not None}
    if not overrides:
        return cfg
    for k in overrides:
        if k not in _LEGACY_WARNED:
            _LEGACY_WARNED.add(k)
            warnings.warn(
                f"linear_regression(..., {k}=...) is deprecated; set "
                f"RegressionConfig.{k} instead (e.g. dataclasses.replace"
                f"(config, {k}=...))",
                DeprecationWarning,
                stacklevel=3,
            )
    if "categorical" in overrides:
        overrides["categorical"] = tuple(overrides["categorical"])
    return dataclasses.replace(cfg, **overrides)


def linear_regression(
    store: Store,
    vorder: Optional[VariableOrder],
    features: Sequence[str],
    label: str,
    config: Optional[RegressionConfig] = None,
    backend: Optional[str] = None,
    use_kernel: Optional[bool] = None,
    use_cache: Optional[bool] = None,
    categorical: Optional[Sequence[str]] = None,
    use_fds: Optional[bool] = None,
) -> RegressionResult:
    """The paper's ``linearRegression(...)`` pipeline.

    All routing lives on :class:`RegressionConfig` — ``factorized`` /
    ``solver`` as before, plus ``backend`` / ``use_kernel`` / ``use_cache``
    / ``categorical`` / ``use_fds``.  The same-named keyword arguments are
    **deprecated shims**: passing one warns (once per kwarg per process)
    and forwards onto a copy of the config, producing results identical to
    the config-field spelling.

    ``use_cache=True`` (factorized mode only) is the **warm-retrain** path:
    unscaled cofactors come from the store's incrementally-maintained cache
    (``Store.sufficient_stats``), so after ``Store.append`` a retrain costs
    only the delta maintenance plus an O(k²) ``Cofactors.rescale`` with the
    fresh scale factors — no rescan of the historical data.  Under lazy
    maintenance the read itself drains pending deltas first.  The cached
    aggregates are always maintained with the fp64 numpy engine (regardless
    of ``backend``): unscaled quad entries grow with data magnitude and
    ``rescale`` is a cancelling difference, so a long-lived fp32
    accumulator would leak rounding error into the leading digits.

    ``categorical`` declares a subset of ``features`` as categorical: their
    cofactor blocks become group-by aggregates (sparse, one-hot-free — see
    ``repro.core.categorical``) and θ gains one coefficient per category in
    ``RegressionResult.names`` order.  Routed through the closed-form or
    BGD solver on the assembled matrix; features are used unscaled (one-hot
    columns are already in [0, 1]; pair with ``solver='closed_form'`` —
    the default ``VERSIONS['closed']`` — unless the continuous columns are
    pre-scaled).
    """
    cfg = _legacy_kwargs(
        config or VERSIONS["v1"],
        {
            "backend": backend,
            "use_kernel": use_kernel,
            "use_cache": use_cache,
            "categorical": categorical,
            "use_fds": use_fds,
        },
    )
    features = list(features)
    if cfg.factorized and vorder is None:
        raise ValueError("factorized mode requires a variable order")
    if cfg.categorical:
        return _linear_regression_categorical(
            store, vorder, features, label, cfg
        )

    t0 = time.perf_counter()
    factors = compute_scale_factors(
        store, features, label, use_kernel=cfg.use_kernel
    )
    t1 = time.perf_counter()

    cols = features + [label]  # cofactor ordering: [intercept] + cols
    if cfg.factorized:
        if cfg.use_cache:
            cof = store.sufficient_stats(
                vorder, features, label, backend="numpy"
            ).rescale(factors)
        else:
            cof = cofactors_factorized(
                store,
                vorder,
                cols,
                backend=cfg.backend,
                scale=factors,
                use_node_kernels=cfg.use_node_kernels,
            )
        cof_matrix = cof.matrix()
        t2 = time.perf_counter()
        if cfg.solver == "closed_form":
            theta_conv = solve_cofactor(cof_matrix, ridge=cfg.ridge)
            iters = 0
        else:
            res: GDResult = bgd_cofactor(cof_matrix, cfg.gd())
            theta_conv, iters = res.theta, res.iterations
    else:
        # noPre: materialize the join, rescan the data every GD iteration.
        joined = store.materialize_join()
        x = design_matrix(joined, cols, scale=factors)
        z = np.concatenate([np.ones((x.shape[0], 1)), x], axis=1)
        t2 = time.perf_counter()
        if cfg.solver == "closed_form":
            theta_conv = solve_cofactor(z.T @ z, ridge=cfg.ridge)
            iters = 0
        else:
            res = bgd_data(z, cfg.gd())
            theta_conv, iters = res.theta, res.iterations
    t3 = time.perf_counter()

    theta = rescale_theta(theta_conv, factors, mode=cfg.theta0_mode)
    return RegressionResult(
        theta=theta,
        theta_conv=theta_conv,
        factors=factors,
        iterations=iters,
        seconds_scale=t1 - t0,
        seconds_cofactor=t2 - t1,
        seconds_gd=t3 - t2,
        config=cfg,
    )


def _linear_regression_categorical(
    store: Store,
    vorder: Optional[VariableOrder],
    features: List[str],
    label: str,
    cfg: RegressionConfig,
) -> RegressionResult:
    """Least squares with categorical features over the sparse cofactor
    algebra: assemble the one-hot cofactor matrix from grouped aggregates
    (never the one-hot data) and hand it to the same solvers.

    With ``use_fds=True`` (a no-op unless the store has FDs covering
    ``categorical``), the solve runs over the FD-reduced parameter space:
    determined attributes are dropped before the engine traversal (fewer
    GROUP BY queries, smaller assembled Gram), the ridge becomes the
    generalized per-root penalty of ``repro.core.fd``, and the dropped
    coefficients are recovered in closed form — θ and ``names`` come back
    in the full layout, bit-for-bit the same convention as the unreduced
    path and equal to it to numerical precision."""
    from .categorical import cat_cofactors_factorized, cat_cofactors_materialized
    from .fd import apply_penalty_blocks, recover_theta_blocks

    categorical = list(cfg.categorical)
    missing = set(categorical) - set(features)
    if missing:
        raise ValueError(
            f"categorical attributes {sorted(missing)} not in features"
        )
    cont = [f for f in features if f not in categorical] + [label]

    red = store.fd_reduction(categorical) if cfg.use_fds else None
    if red is not None and red.is_trivial:
        red = None
    run_cat = list(red.kept) if red is not None else categorical

    t0 = time.perf_counter()
    if cfg.factorized:
        if cfg.use_cache:
            cof = store.sufficient_stats(
                vorder,
                features,
                label,
                categorical=categorical,
                backend="numpy",
                reduce_fds=red is not None,
            )
        else:
            cof = cat_cofactors_factorized(
                store,
                vorder,
                cont,
                run_cat,
                backend=cfg.backend,
                use_node_kernels=cfg.use_node_kernels,
            )
    else:
        cof = cat_cofactors_materialized(
            store, cont, run_cat, use_kernel=cfg.use_kernel
        )
    mat, names = cof.regression_matrix(label)
    t1 = time.perf_counter()

    penalty = None
    layout = None
    if red is not None:
        # kept-block layout inside [intercept, cont\label, kept blocks,
        # label] — shared by the penalty assembly and the recovery below
        layout = []
        off = 1 + (len(cont) - 1)  # intercept + continuous (label removed)
        for c in cof.cat:
            layout.append((c, off, cof.domains[c]))
            off += cof.domains[c]
        # generalized ridge: the paper's flat 0.006·θ on everything except
        # the per-root blocks, which carry ridge·(I + Σ RᵀR)^{-1} so the
        # reduced optimum maps exactly onto the full one (repro.core.fd).
        p = mat.shape[0]
        penalty = apply_penalty_blocks(
            cfg.ridge * np.eye(p - 1), red, layout, cfg.ridge
        )

    if cfg.solver == "closed_form":
        theta = solve_cofactor(mat, ridge=cfg.ridge, penalty=penalty)
        iters = 0
    else:
        bgd_pen = None
        if penalty is not None:
            bgd_pen = np.zeros((mat.shape[0], mat.shape[0]))
            bgd_pen[: -1, : -1] = penalty
        res: GDResult = bgd_cofactor(mat, cfg.gd(), penalty=bgd_pen)
        theta, iters = res.theta, res.iterations

    if red is not None:
        # closed-form recovery of the dropped blocks, then reassembly in
        # the FULL layout [intercept, cont\label, all cats in caller
        # order, label] — indistinguishable from the unreduced solve.
        full_domains = {c: store.attr_domain(c) for c in red.order}
        parts = [theta[: 1 + (len(cont) - 1)]]
        names = ["intercept"] + [f for f in cont if f != label]
        for c, blk in recover_theta_blocks(theta, red, layout, full_domains):
            parts.append(blk)
            names.extend(f"{c}={g}" for g in range(len(blk)))
        parts.append(theta[-1:])  # θ_label = −1
        names.append(label)
        theta = np.concatenate(parts)
    t2 = time.perf_counter()
    return RegressionResult(
        theta=theta,
        theta_conv=theta,  # unscaled path: converged θ IS the final θ
        factors=None,
        iterations=iters,
        seconds_scale=0.0,
        seconds_cofactor=t1 - t0,
        seconds_gd=t2 - t1,
        config=cfg,
        names=names,
    )
