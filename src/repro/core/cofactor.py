"""Cofactor matrices: factorized vs. materialized ("noPre") paths (paper §3.4).

Three engines, mirroring the paper's evaluation matrix:

* ``cofactors_factorized`` (re-exported)  — one pass over the factorized join
  (the paper's ``fact`` versions), O(factorization size).
* ``cofactors_materialized``              — flat join then Gram matrix
  X^T X (the ``noPre`` baseline), O(|D|^rho*); accelerated by the Pallas
  ``gram`` kernel when ``use_kernel=True``.
* ``cofactors_row_engine``                — row-at-a-time interpreted loop,
  the *disk-row-engine proxy* standing in for PostgreSQL in the
  engine-comparison benchmark (Fig. 9 analogue).  Never used for training.

Streaming / incremental paths (union commutativity, Prop. 4.1):

* ``cofactors_streaming``  — accumulates X^T X chunk-by-chunk through the
  Pallas ``gram`` kernel and folds the per-chunk ``Cofactors`` with
  ``__add__``, so arbitrarily large design matrices never materialize on
  device at once.  ``cofactors_materialized(..., chunk_rows=N)`` routes the
  noPre path through it.  Accepts any iterable of [m_i, k] row chunks, so
  it also serves out-of-core / append-stream sources directly.
* ``cofactors_grouped``    — per-group cofactors of a partition labeling in
  ONE fused pass via the Pallas ``segment_gram`` kernel (u = [1, x] makes
  u·u^T carry count/lin/quad together); the groups sum back to the global
  cofactors with ``__add__`` — the same algebra ``Store.append`` and the
  distributed reduction use.

Categorical features (AC/DC-style sparse group-by blocks instead of
one-hot columns) live in ``repro.core.categorical``; GLMs over the
compressed join in ``repro.core.glm``.  Both build on the same grouped
aggregates — ``FactorizedEngine(group_by=...)`` on the factorized side,
``segment_gram`` on the materialized side.
"""

from __future__ import annotations

from typing import Iterable, Iterator, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .factorize import Cofactors, cofactors_factorized
from .relation import Relation
from .store import Store

__all__ = [
    "Cofactors",
    "cofactors_factorized",
    "cofactors_materialized",
    "cofactors_from_matrix",
    "cofactors_grouped",
    "cofactors_row_engine",
    "cofactors_streaming",
    "design_matrix",
    "iter_design_chunks",
]


def design_matrix(
    joined: Relation, features: Sequence[str], scale=None
) -> np.ndarray:
    """Extract the [m, k] feature matrix from a materialized join, applying
    lazy view rescaling (paper §4.2) when ``scale`` is given.  The one-chunk
    case of ``iter_design_chunks`` — single source of truth for column
    extraction/transform semantics."""
    m = joined.num_rows
    if m == 0:
        return np.zeros((0, len(features)))
    return next(iter_design_chunks(joined, features, m, scale=scale))


@jax.jit
def _gram_jnp(x):
    ones = jnp.ones((x.shape[0],), dtype=x.dtype)
    return x.T @ x, x.T @ ones


def cofactors_from_matrix(
    x: np.ndarray, features: Sequence[str], use_kernel: bool = False
) -> Cofactors:
    """Gram-matrix cofactors of an already-materialized design matrix."""
    m = x.shape[0]
    if use_kernel:
        from repro.kernels import ops as kops

        quad = np.asarray(kops.gram(jnp.asarray(x, dtype=jnp.float32)))
        lin = np.asarray(jnp.asarray(x, dtype=jnp.float32).sum(axis=0))
    else:
        quad, lin = _gram_jnp(jnp.asarray(x, dtype=jnp.float32))
        quad, lin = np.asarray(quad), np.asarray(lin)
    return Cofactors(
        count=float(m),
        lin=lin.astype(np.float64),
        quad=quad.astype(np.float64),
        features=list(features),
    )


def iter_design_chunks(
    joined: Relation,
    features: Sequence[str],
    chunk_rows: int,
    scale=None,
) -> Iterator[np.ndarray]:
    """Yield the design matrix of ``joined`` in [≤chunk_rows, k] slices
    without ever stacking the full [m, k] matrix."""
    if chunk_rows <= 0:
        raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")
    m = joined.num_rows
    cols = [joined.column(f) for f in features]
    for lo in range(0, m, chunk_rows):
        hi = min(lo + chunk_rows, m)
        chunk = []
        for f, c in zip(features, cols):
            part = c[lo:hi].astype(np.float64)
            if scale is not None:
                part = scale.transform(f, part)
            chunk.append(part)
        if chunk:
            yield np.stack(chunk, axis=1)
        else:
            yield np.zeros((hi - lo, 0))


def cofactors_streaming(
    chunks: Union[np.ndarray, Iterable[np.ndarray]],
    features: Sequence[str],
    chunk_rows: Optional[int] = None,
    use_kernel: bool = True,
) -> Cofactors:
    """Fold an arbitrarily long stream of design-matrix row chunks into one
    ``Cofactors`` — each chunk's Gram runs through the Pallas ``gram``
    kernel (``use_kernel=False``: plain jnp) and the per-chunk aggregates
    sum via ``Cofactors.__add__``.  Peak device memory is one chunk plus
    the k×k accumulator, independent of the total row count.

    ``chunks`` is either an iterable of [m_i, k] arrays or a single [m, k]
    matrix together with ``chunk_rows`` (split on the host, streamed to the
    device chunk-by-chunk).
    """
    features = list(features)
    if isinstance(chunks, np.ndarray):
        if chunk_rows is None:
            raise ValueError("chunk_rows required when passing one matrix")
        if chunk_rows <= 0:
            raise ValueError(f"chunk_rows must be positive, got {chunk_rows}")
        m = chunks.shape[0]
        x = chunks
        chunks = (
            x[lo : min(lo + chunk_rows, m)] for lo in range(0, m, chunk_rows)
        )
    k = len(features)
    total = Cofactors(
        count=0.0,
        lin=np.zeros((k,), dtype=np.float64),
        quad=np.zeros((k, k), dtype=np.float64),
        features=features,
    )
    for chunk in chunks:
        if chunk.shape[1] != k:
            raise ValueError(
                f"chunk has {chunk.shape[1]} columns, expected {k} features"
            )
        if chunk.shape[0] == 0:
            continue
        total = total + cofactors_from_matrix(
            chunk, features, use_kernel=use_kernel
        )
    return total


def cofactors_grouped(
    x: np.ndarray,
    seg: np.ndarray,
    num_groups: int,
    features: Sequence[str],
    use_kernel: bool = True,
) -> List[Cofactors]:
    """Per-group cofactors of a partition labeling in one fused pass.

    Appends the intercept column (u = [1, x]) and runs the Pallas
    ``segment_gram`` kernel, whose [G, k+1, k+1] output carries every
    group's count / lin / quad at once.  Summing the returned list with
    ``Cofactors.__add__`` reproduces the global cofactors — the per-shard
    building block of the distributed delta path.  Out-of-range segment
    ids contribute to no group (matching the kernel's zero-one-hot-row
    semantics) on both paths.
    """
    m, k = x.shape
    u = np.concatenate([np.ones((m, 1), dtype=np.float64), x], axis=1)
    if use_kernel:
        from repro.kernels import ops as kops

        blocks = np.asarray(
            kops.segment_gram(
                jnp.asarray(u, dtype=jnp.float32),
                jnp.asarray(seg, dtype=jnp.int32),
                num_groups,
            ),
            dtype=np.float64,
        )
    else:
        seg = np.asarray(seg)
        keep = (seg >= 0) & (seg < num_groups)
        blocks = np.zeros((num_groups, k + 1, k + 1), dtype=np.float64)
        uk = u[keep]
        np.add.at(blocks, seg[keep], uk[:, :, None] * uk[:, None, :])
    return [
        Cofactors(
            count=float(b[0, 0]),
            lin=b[0, 1:].copy(),
            quad=b[1:, 1:].copy(),
            features=list(features),
        )
        for b in blocks
    ]


def cofactors_materialized(
    store: Store,
    features: Sequence[str],
    relations: Optional[Sequence[str]] = None,
    use_kernel: bool = False,
    scale=None,
    chunk_rows: Optional[int] = None,
) -> Cofactors:
    """The non-factorized ("noPre") path: flat join, then X^T X.  With
    ``chunk_rows`` the Gram accumulates through ``cofactors_streaming`` so
    only one chunk of the design matrix is resident at a time."""
    joined = store.materialize_join(relations)
    if chunk_rows is not None:
        return cofactors_streaming(
            iter_design_chunks(joined, features, chunk_rows, scale=scale),
            features,
            use_kernel=use_kernel,
        )
    x = design_matrix(joined, features, scale=scale)
    return cofactors_from_matrix(x, features, use_kernel=use_kernel)


def cofactors_row_engine(
    store: Store,
    features: Sequence[str],
    relations: Optional[Sequence[str]] = None,
    scale=None,
) -> Cofactors:
    """Row-at-a-time interpreted engine (disk-row-engine proxy for Fig. 9).

    Deliberately tuple-oriented: iterates Python-level rows and accumulates
    scalar products, the way a Volcano-style executor touches data.
    """
    joined = store.materialize_join(relations)
    x = design_matrix(joined, features, scale=scale)
    k = len(features)
    quad = [[0.0] * k for _ in range(k)]
    lin = [0.0] * k
    m = 0
    for row in x:  # noqa: B007 — intentionally interpreted
        m += 1
        for i in range(k):
            xi = float(row[i])
            lin[i] += xi
            for j in range(i, k):
                quad[i][j] += xi * float(row[j])
    quad_np = np.asarray(quad)
    quad_np = quad_np + np.triu(quad_np, 1).T  # symmetry (paper: half computed)
    return Cofactors(
        count=float(m),
        lin=np.asarray(lin),
        quad=quad_np,
        features=list(features),
    )
