"""Cofactor matrices: factorized vs. materialized ("noPre") paths (paper §3.4).

Three engines, mirroring the paper's evaluation matrix:

* ``cofactors_factorized`` (re-exported)  — one pass over the factorized join
  (the paper's ``fact`` versions), O(factorization size).
* ``cofactors_materialized``              — flat join then Gram matrix
  X^T X (the ``noPre`` baseline), O(|D|^rho*); accelerated by the Pallas
  ``gram`` kernel when ``use_kernel=True``.
* ``cofactors_row_engine``                — row-at-a-time interpreted loop,
  the *disk-row-engine proxy* standing in for PostgreSQL in the
  engine-comparison benchmark (Fig. 9 analogue).  Never used for training.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .factorize import Cofactors, cofactors_factorized
from .relation import Relation
from .store import Store

__all__ = [
    "Cofactors",
    "cofactors_factorized",
    "cofactors_materialized",
    "cofactors_from_matrix",
    "cofactors_row_engine",
    "design_matrix",
]


def design_matrix(
    joined: Relation, features: Sequence[str], scale=None
) -> np.ndarray:
    """Extract the [m, k] feature matrix from a materialized join, applying
    lazy view rescaling (paper §4.2) when ``scale`` is given."""
    cols = []
    for f in features:
        c = joined.column(f).astype(np.float64)
        if scale is not None:
            c = scale.transform(f, c)
        cols.append(c)
    if not cols:
        return np.zeros((joined.num_rows, 0))
    return np.stack(cols, axis=1)


@jax.jit
def _gram_jnp(x):
    ones = jnp.ones((x.shape[0],), dtype=x.dtype)
    return x.T @ x, x.T @ ones


def cofactors_from_matrix(
    x: np.ndarray, features: Sequence[str], use_kernel: bool = False
) -> Cofactors:
    """Gram-matrix cofactors of an already-materialized design matrix."""
    m = x.shape[0]
    if use_kernel:
        from repro.kernels import ops as kops

        quad = np.asarray(kops.gram(jnp.asarray(x, dtype=jnp.float32)))
        lin = np.asarray(jnp.asarray(x, dtype=jnp.float32).sum(axis=0))
    else:
        quad, lin = _gram_jnp(jnp.asarray(x, dtype=jnp.float32))
        quad, lin = np.asarray(quad), np.asarray(lin)
    return Cofactors(
        count=float(m),
        lin=lin.astype(np.float64),
        quad=quad.astype(np.float64),
        features=list(features),
    )


def cofactors_materialized(
    store: Store,
    features: Sequence[str],
    relations: Optional[Sequence[str]] = None,
    use_kernel: bool = False,
    scale=None,
) -> Cofactors:
    """The non-factorized ("noPre") path: flat join, then X^T X."""
    joined = store.materialize_join(relations)
    x = design_matrix(joined, features, scale=scale)
    return cofactors_from_matrix(x, features, use_kernel=use_kernel)


def cofactors_row_engine(
    store: Store,
    features: Sequence[str],
    relations: Optional[Sequence[str]] = None,
    scale=None,
) -> Cofactors:
    """Row-at-a-time interpreted engine (disk-row-engine proxy for Fig. 9).

    Deliberately tuple-oriented: iterates Python-level rows and accumulates
    scalar products, the way a Volcano-style executor touches data.
    """
    joined = store.materialize_join(relations)
    x = design_matrix(joined, features, scale=scale)
    k = len(features)
    quad = [[0.0] * k for _ in range(k)]
    lin = [0.0] * k
    m = 0
    for row in x:  # noqa: B007 — intentionally interpreted
        m += 1
        for i in range(k):
            xi = float(row[i])
            lin[i] += xi
            for j in range(i, k):
                quad[i][j] += xi * float(row[j])
    quad_np = np.asarray(quad)
    quad_np = quad_np + np.triu(quad_np, 1).T  # symmetry (paper: half computed)
    return Cofactors(
        count=float(m),
        lin=np.asarray(lin),
        quad=quad_np,
        features=list(features),
    )
