"""The in-memory database: a catalog of relations plus a natural-join planner.

Plays the role HyPer plays in the paper — it *holds* the training data and
executes the factorized aggregate plan close to the data.  ``materialize_join``
is the non-factorized ("noPre") path: it computes the flat natural join whose
size is O(|D|^rho*) and against which factorization is benchmarked.

Incremental cofactor maintenance (AC/DC-style, Abo Khamis et al. 2018):
the store keeps a **cofactor cache** keyed by
``(relations, features, variable-order signature, backend)``.

* ``cofactors(vorder, features)`` — compute-on-miss cached *unscaled*
  cofactors over the factorized join (scaled variants derive lazily via
  ``Cofactors.rescale``, the paper's §4.2 view algebra, so one cache entry
  serves every scaling).
* ``append(name, delta)``  — batch row update, **O(delta)** on the write
  path.  The default ``maintenance="lazy"`` mode validates FDs, concats
  the relation, pushes a metadata-only record onto the per-relation
  :class:`repro.core.delta_log.DeltaLog` and returns — no view-cache or
  cofactor folds happen on the write path, so append latency is
  independent of how many cached entries cover the relation.
  ``maintenance="eager"`` restores the fold-on-write behaviour (useful
  when reads vastly outnumber writes, or when append's all-or-nothing
  exception contract matters).
* **lazy drain** — any read entry point that touches a relation with
  pending deltas (``sufficient_stats`` / ``cofactors`` /
  ``cat_cofactors``, and every ``FactorizedEngine`` construction) first
  calls :meth:`Store.flush`, which folds the *stacked* delta of every
  pending relation into the covering entries in one pass per relation
  (joins distribute over union — ``(R ∪ ΔR) ⋈ S = (R ⋈ S) ∪ (ΔR ⋈ S)``,
  Prop. 4.1 — so however many appends piled up, one fold pays for all).
  With several relations pending, relation i's fold freezes every
  later-pending relation to its pre-append prefix, so the per-relation
  fold terms telescope to exactly the merged-join total.  Past a size
  threshold (``compact_ratio`` / ``compact_rows``) folding a huge stacked
  delta would cost more than recomputing from base, so ``append``
  *compacts* instead: covered entries are invalidated and the log
  cleared.
* ``put(rel)``             — catalog mutation: overwriting a relation
  **invalidates** every cache entry that references it (deltas are unions;
  arbitrary replacement is not).  Entries over unrelated relations survive.
* ``column_moments(col)``  — cached per-column (sum, max|x|, count) over the
  union of relations containing the column, maintained under ``append``
  (sum/count accumulate, max folds — always eager: O(delta) columnar work)
  so feature scaling never rescans the historical data either.

Cache versioning: ``version`` increments on every catalog mutation, and
``_rel_versions[name]`` records the version of the last mutation affecting
relation ``name`` (its *watermark*).  An entry is valid iff its stamp is
``>=`` the watermark of every relation its join covers — so an append
makes exactly the covering entries stale ("stale but foldable": the drain
folds them and restamps at the current version) while entries over
untouched relations stay valid with **no** restamping loop on the write
path.

Below the result-level caches sits the **persistent view cache**
(``repro.core.view_cache``): per-node engine views keyed by
``(vorder signature, node, live subset, degree, backend)``, shared by every
``FactorizedEngine`` constructed over this store.  Where the cofactor
caches answer "have I seen this exact query", the view cache answers "have
I already descended this subtree" — so *different* queries over
overlapping attribute sets (FD on/off, GLM designs, per-attribute sweeps,
warm retrains) skip finished descents.  ``append`` maintains it with
delta-path folds: only views on the appended relation's root path are
touched (each folded with a delta view computed by an engine that itself
reuses the cached sibling views); entries over untouched relations stay
valid under the same watermark rule (``ViewCache.watermarks`` aliases
``_rel_versions``).  ``put`` invalidates exactly the entries covering the
replaced relation.

Two pieces of store-owned state make those views reusable at all:

* **append-only attribute dictionaries** — every attribute's value↔id
  mapping is global to the store and only ever *extended* (new values get
  fresh ids at the end), so an append never renumbers ids baked into
  cached views;
* an **encoded-column cache** — the int32 id columns of unchanged
  relations, so warm engine construction is O(1) instead of a full
  ``np.unique`` rescan of the catalog.

Counters: ``passes`` / ``node_visits`` accumulate over EVERY engine
traversal against this store (cold computes, delta folds, GLM designs —
all paths, uniformly); ``cat_passes`` / ``cat_node_visits`` remain the
categorical-path subset for continuity.  ``reset_counters()`` zeroes all
of them plus the view-cache hit/miss/eviction counters, so benchmarks and
tests no longer depend on call order.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
)

import numpy as np

from .delta_log import DeltaLog
from .fd import (
    FDReduction,
    FunctionalDependency,
    extend_mapping,
    reduction_plan,
    witnessed_mapping,
)
from .relation import Relation, join_keys, sort_merge_join
from .view_cache import DEFAULT_MAX_BYTES, ViewCache

if TYPE_CHECKING:  # avoid a circular import at runtime (factorize -> store)
    from .factorize import Cofactors
    from .variable_order import VariableOrder

__all__ = ["Store", "StoreSnapshot"]

#: the zero-work return value of :meth:`Store.flush`
_NO_DRAIN = {"relations": 0, "rows": 0, "appends": 0}


def _locked(method: Callable) -> Callable:
    """Serialize a catalog-mutating method under ``self._mutate_lock``.

    The lock is re-entrant because mutators nest (``cofactors`` →
    ``flush`` → ``_fold_relation``; ``append`` in eager mode folds
    inline).  Readers off the snapshot path stay lock-free: catalog maps
    are replaced copy-on-write, so a concurrent reader sees either the
    old or the new map, never a half-mutated one."""

    @functools.wraps(method)
    def wrapper(self, *args, **kwargs):
        with self._mutate_lock:
            return method(self, *args, **kwargs)

    return wrapper


@dataclasses.dataclass
class _CacheEntry:
    cofactors: object  # Cofactors | CatCofactors — unscaled; treat as immutable
    relations: frozenset  # relation names the entry's join covers
    version: int  # stamp: valid iff >= every covered relation's watermark


class _AttrDict:
    """Append-only global dictionary of one attribute's values.

    ``values[i]`` is the i-th distinct value ever seen (first-seen order —
    NOT sorted: sorting would renumber existing ids when a later value
    lands in the middle, invalidating every cached view keyed by them).
    ``extend_encode`` folds a column in, assigning fresh trailing ids to
    unseen values, and returns the column's int32 ids.  Lookup is fully
    vectorized against a sorted snapshot (``searchsorted``) — continuous
    columns with ~n distinct values cost O(n log n) array work, never a
    Python-level loop.  ``values`` is replaced (never mutated) on growth,
    so captured references stay valid.
    """

    __slots__ = ("values", "_sorted_vals", "_sorted_ids", "_mu")

    def __init__(self) -> None:
        self.values = np.zeros(0, dtype=np.float64)
        self._sorted_vals = np.zeros(0, dtype=np.float64)  # values, sorted
        self._sorted_ids = np.zeros(0, dtype=np.int64)  # ids aligned above
        # a drain-thread snapshot encoding an override column races an
        # appender extending the same attribute's dictionary — growth must
        # be atomic so issued ids never alias two values
        self._mu = threading.Lock()

    def extend_encode(self, col: np.ndarray) -> np.ndarray:
        col = np.asarray(col, dtype=np.float64)
        if not len(col):
            return np.zeros(0, dtype=np.int32)
        with self._mu:
            uniq, inv = np.unique(col, return_inverse=True)
            if len(self._sorted_vals):
                pos = np.searchsorted(self._sorted_vals, uniq)
                pos_c = np.minimum(pos, len(self._sorted_vals) - 1)
                known = self._sorted_vals[pos_c] == uniq
                uid = np.where(known, self._sorted_ids[pos_c], -1)
            else:
                uid = np.full(len(uniq), -1, dtype=np.int64)
            fresh_mask = uid < 0
            if fresh_mask.any():
                fresh = uniq[fresh_mask]  # sorted (unique), first-seen here
                uid[fresh_mask] = len(self.values) + np.arange(len(fresh))
                self.values = np.concatenate([self.values, fresh])
                merged_vals = np.concatenate([self._sorted_vals, fresh])
                order = np.argsort(merged_vals, kind="stable")
                self._sorted_vals = merged_vals[order]
                self._sorted_ids = np.concatenate(
                    [self._sorted_ids, uid[fresh_mask]]
                )[order]
            return uid[inv].astype(np.int32)


class Store:
    """Catalog of named relations with natural-join materialization and an
    incrementally-maintained cofactor cache."""

    def __init__(
        self,
        relations: Optional[Sequence[Relation]] = None,
        view_cache_bytes: int = DEFAULT_MAX_BYTES,
        maintenance: str = "lazy",
        compact_ratio: Optional[float] = 0.5,
        compact_rows: Optional[int] = None,
    ) -> None:
        if maintenance not in ("lazy", "eager"):
            raise ValueError(
                f"maintenance must be 'lazy' or 'eager', got {maintenance!r}"
            )
        #: "lazy" (default): append is O(delta), folds deferred to reads;
        #: "eager": append folds every covering entry before returning.
        self.maintenance = maintenance
        #: compact (invalidate + clear log) when a relation's pending rows
        #: exceed ``compact_ratio`` × its pre-append row count …
        self.compact_ratio = compact_ratio
        #: … or this absolute row cap (either None disables that trigger).
        self.compact_rows = compact_rows
        self._relations: Dict[str, Relation] = {}
        self._cofactor_cache: Dict[tuple, _CacheEntry] = {}
        # categorical entries live in their own cache: the key includes the
        # categorical signature (cont tuple, cat tuple) and the delta
        # maintenance runs the grouped engine instead of the plain one.
        self._cat_cache: Dict[tuple, _CacheEntry] = {}
        # per-relation watermarks: version of the last mutation affecting
        # the relation.  Entry validity = stamp >= every covered watermark;
        # shared with the view cache so both levels use one rule.
        self._rel_versions: Dict[str, int] = {}
        # per-relation pending-append log (lazy maintenance write path)
        self._delta_log = DeltaLog()
        self._draining = False  # re-entrancy guard for flush()
        # serializes catalog mutation (put/append/fold/FD-catalog changes)
        # across threads — see the ``_locked`` decorator.  Snapshot readers
        # never take it.
        self._mutate_lock = threading.RLock()
        # fault-injection seam: when set, called as hook("fold", name) at
        # the top of every delta fold so tests can poison maintenance
        # deterministically (repro.serve.faults.FaultInjector).  None in
        # production.
        self.fault_hook: Optional[Callable[[str, str], None]] = None
        # observability seam twin to fault_hook: when set, called as
        # hook("Store._relations", "write") at guarded-state touch points so
        # the lockset sanitizer (repro.analysis.sanitizer) can audit which
        # locks actually protect each access.  None in production.
        self.access_hook: Optional[Callable[[str, str], None]] = None
        # persistent cross-batch per-node view cache (see module docstring);
        # view_cache_bytes=0 disables it (the cold-baseline escape hatch).
        self.view_cache = ViewCache(max_bytes=view_cache_bytes)
        self.view_cache.watermarks = self._rel_versions
        # attr -> append-only global dictionary; (rel, attr) -> cached ids
        self._dicts: Dict[str, _AttrDict] = {}
        self._enc_cols: Dict[Tuple[str, str], np.ndarray] = {}
        # per-fold memo of active override relations' encoded columns (see
        # attr_encoding): {id(override relation): {attr: ids}} while a fold
        # or drain is running — one relation may spawn several delta
        # engines, and a drain overrides several relations at once.
        self._override_enc: Optional[Dict[int, Dict[str, np.ndarray]]] = None
        # functional-dependency catalog: (lhs, rhs) -> FD with its witnessed
        # id mapping.  Declared FDs are contracts; inferred ones are dropped
        # when an append falsifies them (see append / _plan_fd_updates).
        self._fds: Dict[Tuple[str, str], FunctionalDependency] = {}
        # FD-catalog generation + reduction-plan memo: reduction_plan is
        # pure in (cat list, FD catalog), so invalidation is just a bump.
        self._fd_version = 0
        self._red_cache: Dict[tuple, FDReduction] = {}
        # signature -> VariableOrder, kept so maintenance can re-run the engine
        self._vorders: Dict[tuple, "VariableOrder"] = {}
        # col -> (sum, max|x|, count) over the union of relations with col
        self._moments: Dict[str, Tuple[float, float, int]] = {}
        # unified cumulative counters: EVERY engine traversal / view
        # evaluation against this store (cold computes, delta folds, GLM
        # designs, ...) — the engine increments them directly.
        self.passes = 0
        self.node_visits = 0
        # categorical-path subset (cold computes AND delta folds), kept for
        # continuity with the PR 3 audit trail — with the fused multi-output
        # plan this grows by 1 pass per compute/fold, however many
        # categorical attributes ride along.
        self.cat_passes = 0
        self.cat_node_visits = 0
        self.version = 0
        for rel in relations or ():
            self.put(rel)

    def _access(self, field: str, kind: str) -> None:
        """Fire the ``access_hook`` seam (no-op when uninstalled): reports a
        ``read``/``write`` of guarded shared state under whatever locks the
        calling thread currently holds, for the lockset sanitizer."""
        hook = self.access_hook
        if hook is not None:
            hook(field, kind)

    # -- attribute dictionaries (append-only, store-global) --------------------
    def _dict_for(self, attr: str) -> _AttrDict:
        d = self._dicts.get(attr)
        if d is None:
            with self._mutate_lock:  # two threads must not race the create
                d = self._dicts.get(attr)
                if d is None:
                    d = self._dicts[attr] = _AttrDict()
        return d

    def attr_encoding(
        self, rel_name: str, attr: str, override: Optional[Relation] = None
    ) -> np.ndarray:
        """int32 ids of ``rel_name``'s column ``attr`` under the store's
        append-only dictionary.  Catalog columns are cached (and extended
        in place by ``append``); ``override`` encodes a replacement
        relation's column instead — used by delta engines — without
        touching the cache."""
        if override is not None:
            # one fold spawns several delta engines (view-cache folds per
            # feature group + the result-cache folds), and a drain folds
            # several override relations; encode each override column once
            # per fold, not once per engine.
            memo = self._override_enc
            if memo is not None:
                by_attr = memo.setdefault(id(override), {})
                ids = by_attr.get(attr)
                if ids is None:
                    ids = by_attr[attr] = self._dict_for(attr).extend_encode(
                        override.column(attr)
                    )
                return ids
            return self._dict_for(attr).extend_encode(override.column(attr))
        key = (rel_name, attr)
        ids = self._enc_cols.get(key)
        if ids is None:
            col = self._relations[rel_name].column(attr)
            ids = self._dict_for(attr).extend_encode(col)
            self._access("Store._enc_cols", "write")
            # Deliberate lock-free memo fill: racing threads compute the
            # same ids (append-only dictionaries) and a dict put is atomic
            # under the GIL, so last-writer-wins is correct.
            # lockcheck: idempotent GIL-atomic memo fill
            self._enc_cols[key] = ids
        return ids

    def attr_values_array(self, attr: str) -> np.ndarray:
        """id -> value translation array of ``attr``'s global dictionary."""
        return self._dict_for(attr).values

    def _register_vorder(self, sig: tuple, vorder: "VariableOrder") -> None:
        """Remember a variable order by signature so ``append`` can rebuild
        delta engines for view-cache entries created outside
        :meth:`cofactors` / :meth:`cat_cofactors`.  Engines call this from
        snapshot reads too, so the registry insert takes the mutate lock."""
        with self._mutate_lock:
            self._access("Store._vorders", "write")
            self._vorders.setdefault(sig, vorder)

    def reset_counters(self) -> None:
        """Zero every cumulative counter (unified + categorical + view
        cache) — benches and tests measure deltas from a known origin
        instead of depending on call order.  Taken under the mutate lock so
        a reset never lands mid-fold and splits one maintenance pass's
        counters across epochs."""
        with self._mutate_lock:
            self.passes = 0
            self.node_visits = 0
            self.cat_passes = 0
            self.cat_node_visits = 0
            self.view_cache.reset_counters()

    # -- catalog -------------------------------------------------------------
    @_locked
    def put(self, rel: Relation) -> None:
        """Insert or replace a relation.  Replacement is an arbitrary
        mutation, so cache entries covering the name are invalidated, and
        every FD touching the relation's attributes is re-verified from
        scratch (a declared FD that no longer holds raises; an inferred one
        is silently dropped).

        Copy-on-write: the catalog / FD / moments / encoded-column maps are
        *replaced*, never mutated — a :class:`StoreSnapshot` taken before
        the call keeps reading the old maps, unblocked and uncorrupted.
        """
        self._access("Store._relations", "write")
        self._access("Store._fds", "write")
        old = self._relations.get(rel.name)
        old_relations = self._relations
        touched = set(rel.keys) | set(old.keys if old else ())
        stale_fds = [
            key for key in self._fds if key[0] in touched or key[1] in touched
        ]
        # install the new catalog map up front so FD re-verification sees
        # the post-put data; a declared-FD violation restores the untouched
        # old map (rollback is a single pointer swap under COW).
        self._relations = {**old_relations, rel.name: rel}
        reverified: Dict[Tuple[str, str], np.ndarray] = {}
        dropped_fds = []
        for key in stale_fds:
            fd = self._fds[key]
            try:
                dom = self.attr_domain(key[0])
            except ValueError:  # lhs attribute vanished from the catalog
                dom = 0
            mapping = (
                witnessed_mapping(self.relations(), key[0], key[1], dom)
                if dom
                else None
            )
            if mapping is None:
                if fd.source == "declared":
                    self._relations = old_relations
                    raise ValueError(
                        f"put({rel.name!r}) violates declared FD "
                        f"{key[0]} → {key[1]}"
                    )
                dropped_fds.append(key)
            else:
                reverified[key] = mapping
        if dropped_fds or reverified:
            new_fds = dict(self._fds)
            for key in dropped_fds:
                del new_fds[key]
            for key, mapping in reverified.items():
                new_fds[key] = dataclasses.replace(
                    new_fds[key], mapping=mapping
                )
            self._fds = new_fds
        if stale_fds:
            self._bump_fds()
        self.version += 1
        # watermark bump: entries covering the name fail validity from now
        # on (they are dropped below anyway); survivors stay valid with no
        # restamping loop.
        self._rel_versions[rel.name] = self.version
        self._invalidate(rel.name)
        self._invalidate_fd_entries()
        # pending deltas of the replaced relation describe rows that no
        # longer exist, and the entries they would have maintained are gone
        self._delta_log.clear(rel.name)
        stale_attrs = set(rel.attributes) | set(
            old.attributes if old else ()
        )
        self._moments = {
            k: v for k, v in self._moments.items() if k not in stale_attrs
        }
        # encoded columns of the replaced relation are stale; the global
        # dictionaries are NOT rebuilt (append-only forever — unused old
        # values keep their ids so sibling views never renumber).
        self._enc_cols = {
            k: v for k, v in self._enc_cols.items() if k[0] != rel.name
        }

    def get(self, name: str) -> Relation:
        return self._relations[name]

    def __contains__(self, name: str) -> bool:
        return name in self._relations

    def names(self) -> List[str]:
        return list(self._relations)

    def relations(self) -> List[Relation]:
        return list(self._relations.values())

    def total_rows(self) -> int:
        return sum(r.num_rows for r in self._relations.values())

    def attr_domain(self, attr: str) -> int:
        """Dictionary-domain size of a key attribute: the max declared
        domain over all relations carrying it (``concat`` merges domains
        with max, so this is stable under append)."""
        doms = [
            rel.domains[attr]
            for rel in self._relations.values()
            if attr in rel.domains
        ]
        if not doms:
            raise ValueError(
                f"attribute {attr!r} is not a dictionary-encoded key in any "
                "relation"
            )
        return max(doms)

    # -- functional dependencies ----------------------------------------------
    @_locked
    def add_fd(self, lhs: str, rhs: str) -> FunctionalDependency:
        """Declare the functional dependency ``lhs → rhs`` between two
        dictionary-encoded key attributes.  Verified against the data now
        (raises if no relation witnesses the pair or any witness violates
        functionality) and re-checked on every ``append``/``put`` — a
        mutation that breaks a declared FD is rejected."""
        mapping = witnessed_mapping(
            self.relations(), lhs, rhs, self.attr_domain(lhs)
        )
        if mapping is None:
            raise ValueError(
                f"functional dependency {lhs} → {rhs} does not hold (or no "
                "relation contains both attributes as keys)"
            )
        fd = FunctionalDependency(lhs, rhs, mapping, "declared")
        self._access("Store._fds", "write")
        self._fds = {**self._fds, (lhs, rhs): fd}
        self._bump_fds()
        self._invalidate_fd_entries()
        return fd

    @_locked
    def infer_fds(
        self, attrs: Optional[Sequence[str]] = None
    ) -> List[Tuple[str, str]]:
        """Scan the catalog for candidate FDs ``f → g`` and register every
        verified one as *inferred* (falsifiable by later appends).

        Candidates are ordered pairs of key attributes co-located in at
        least one relation — the only pairs whose FD status is decidable
        without computing the join (and, by the projection argument in
        ``repro.core.fd``, exactly the witnesses that make the FD sound on
        the join result).  ``attrs`` restricts the candidate universe.
        Returns the newly registered (lhs, rhs) pairs.
        """
        universe = set(attrs) if attrs is not None else None
        pairs: Dict[Tuple[str, str], None] = {}
        for rel in self._relations.values():
            keys = [
                a
                for a in rel.keys
                if universe is None or a in universe
            ]
            for lhs in keys:
                for rhs in keys:
                    if lhs != rhs:
                        pairs.setdefault((lhs, rhs))
        found: List[Tuple[str, str]] = []
        new_fds = dict(self._fds)
        for lhs, rhs in pairs:
            if (lhs, rhs) in new_fds:
                continue
            mapping = witnessed_mapping(
                self.relations(), lhs, rhs, self.attr_domain(lhs)
            )
            if mapping is not None:
                new_fds[(lhs, rhs)] = FunctionalDependency(
                    lhs, rhs, mapping, "inferred"
                )
                found.append((lhs, rhs))
        if found:
            self._fds = new_fds
            self._bump_fds()
            self._invalidate_fd_entries()
        return found

    def fds(self) -> List[FunctionalDependency]:
        return list(self._fds.values())

    @_locked
    def drop_fd(self, lhs: str, rhs: str) -> None:
        self._access("Store._fds", "write")
        if (lhs, rhs) in self._fds:
            self._fds = {
                k: v for k, v in self._fds.items() if k != (lhs, rhs)
            }
            self._bump_fds()
        self._invalidate_fd_entries()

    def _bump_fds(self) -> None:
        """The FD catalog changed (set membership or a mapping's contents):
        memoized reduction plans are stale."""
        self._fd_version += 1
        self._red_cache.clear()

    def fd_reduction(self, cat: Sequence[str]) -> FDReduction:
        """The FD reduction of a categorical attribute list under the
        current catalog: which attributes a solver can drop (they are
        functionally determined by an earlier one) and the id maps needed
        to recover their coefficients in closed form.  Memoized per
        (cat list, domains) until the FD catalog changes — warm
        ``cat_cofactors(reduce_fds=True)`` calls and cache-invalidation
        scans stop re-running the BFS planner."""
        domains = {a: self.attr_domain(a) for a in cat}
        key = (tuple(cat), tuple(sorted(domains.items())))
        plan = self._red_cache.get(key)
        if plan is None:
            plan = reduction_plan(self._fds, list(cat), domains)
            with self._mutate_lock:
                self._access("Store._red_cache", "write")
                self._red_cache[key] = plan
        return plan

    def _plan_fd_updates(
        self, delta: Relation
    ) -> Tuple[List[Tuple[str, str]], Dict[Tuple[str, str], np.ndarray]]:
        """Pure check of ``delta`` against the FD catalog: returns the
        inferred FDs it falsifies and the mapping extensions (new lhs ids)
        it implies; raises on a declared-FD violation — before the caller
        has mutated anything."""
        falsified: List[Tuple[str, str]] = []
        extensions: Dict[Tuple[str, str], np.ndarray] = {}
        for key, fd in self._fds.items():
            lhs, rhs = key
            if lhs not in delta.keys or rhs not in delta.keys:
                continue
            l = delta.keys[lhs].astype(np.int64)
            r = delta.keys[rhs].astype(np.int64)
            size = max(
                len(fd.mapping), int(l.max()) + 1 if len(l) else 0
            )
            mapping = np.full(size, -1, dtype=np.int64)
            mapping[: len(fd.mapping)] = fd.mapping
            if extend_mapping(mapping, l, r):
                extensions[key] = mapping
            elif fd.source == "declared":
                raise ValueError(
                    f"append violates declared FD {lhs} → {rhs}"
                )
            else:
                falsified.append(key)
        return falsified, extensions

    def _invalidate_fd_entries(self) -> None:
        """Drop categorical cache entries whose FD-reduced shape no longer
        matches the catalog (an FD was added, dropped, or falsified).
        Entries keyed with a trivial/no reduction are untouched."""
        stale = []
        for key in self._cat_cache:
            fdsig = key[4]
            if fdsig is None:
                continue
            if self.fd_reduction(list(key[2])).signature() != fdsig:
                stale.append(key)
        for key in stale:
            del self._cat_cache[key]

    # -- incremental updates ---------------------------------------------------
    @_locked
    def append(self, name: str, delta: Relation) -> Relation:
        """Append the rows of ``delta`` to relation ``name`` (batch update).

        ``delta`` must carry the same key/value attribute sets as the stored
        relation (its own ``name`` is ignored).  Returns the merged relation
        now in the catalog.

        Under the default ``maintenance="lazy"`` the write path is
        **O(delta)**: FD validation, the concat, the moments / encoded-
        column extension, and a metadata push onto the pending-delta log —
        no view-cache or cofactor folds, whatever the cache population.
        Cached entries covering ``name`` become stale-but-foldable; the
        next read that touches them drains the log (:meth:`flush`), folding
        the *stacked* delta in one pass (Prop. 4.1 union commutativity).
        If the pending rows cross the compaction threshold
        (``compact_ratio`` / ``compact_rows``), covering entries are
        invalidated instead — recomputing from the merged base is cheaper
        than folding a delta comparable to it.

        ``maintenance="eager"`` folds every covering entry before the
        catalog is touched (the pre-lazy behaviour): the delta cofactors
        are computed against the pre-merge catalog and summed in, and a
        fold that raises leaves the catalog, moments and FD catalog
        exactly as before the call (covering entries invalidated).

        FD maintenance (both modes): the delta is checked against the FD
        catalog first — a violated *declared* FD rejects the append
        outright (nothing mutated); a falsified *inferred* FD is dropped
        and every FD-reduced cache entry built under it is invalidated;
        new lhs ids with consistent rhs values extend the FD mappings.
        """
        self._access("Store._relations", "write")
        self._access("Store._delta_log", "write")
        if name not in self._relations:
            raise KeyError(f"append target {name!r} not in catalog")
        base = self._relations[name]
        merged = base.concat(delta)  # validates attribute sets first

        if not delta.num_rows:
            # empty delta: publish the (identical) merged relation and bump
            # the version WITHOUT moving the watermark — nothing about the
            # data changed, so every cached entry stays valid.
            self._relations = {**self._relations, name: merged}
            self.version += 1
            return merged

        delta_named = dataclasses.replace(
            delta,
            name=name,
            keys=dict(delta.keys),
            values=dict(delta.values),
            domains=dict(delta.domains),
        )
        # FD check is a pure plan: raises on a declared-FD violation
        # before anything below has mutated.
        falsified, extensions = self._plan_fd_updates(delta_named)
        if self.maintenance == "eager":
            # fold-on-write, against the pre-merge catalog; stamped at the
            # post-publish version so the entries are valid the moment the
            # catalog lands.  A poisoned delta raises out of here with the
            # store untouched (covering entries invalidated).
            self._override_enc = {}
            try:
                self._fold_relation(name, delta_named, {}, self.version + 1)
            except Exception:
                self._invalidate(name)
                raise
            finally:
                self._override_enc = None
        # per-column moments: accumulate under union.  Eager in BOTH modes
        # — the O(delta) column scan costs no more than the log push and
        # keeps feature scaling off the drain path.  Built as a fresh map
        # and published below with the catalog — a snapshot holding the
        # old map never sees a partial update.
        new_moments = dict(self._moments)
        for attr, (s, mx, cnt) in list(self._moments.items()):
            if attr not in delta_named.attributes:
                continue
            col = delta_named.column(attr).astype(np.float64)
            new_moments[attr] = (
                s + float(col.sum()),
                max(mx, float(np.abs(col).max())),
                cnt + len(col),
            )
        if falsified or extensions:
            new_fds = dict(self._fds)
            for key in falsified:
                del new_fds[key]
            for key, mapping in extensions.items():
                new_fds[key] = dataclasses.replace(
                    new_fds[key], mapping=mapping
                )
            self._fds = new_fds
            self._bump_fds()
        if falsified:
            self._invalidate_fd_entries()
        # encoded-column cache: the merged relation is base ++ delta,
        # so cached id columns extend with the delta's ids (global
        # dictionaries grow append-only — existing ids never move).
        new_enc = dict(self._enc_cols)
        for attr in delta_named.attributes:
            enc_key = (name, attr)
            ids = new_enc.get(enc_key)
            if ids is not None:
                delta_ids = self._dict_for(attr).extend_encode(
                    delta_named.column(attr)
                )
                new_enc[enc_key] = np.concatenate([ids, delta_ids])
        self._enc_cols = new_enc
        self._moments = new_moments
        # COW publish: snapshot readers holding the old maps are untouched.
        self._relations = {**self._relations, name: merged}
        log = None
        if self.maintenance == "lazy":
            # metadata only: the stacked delta IS merged[base_rows:], so
            # the log records row counts, never rows.
            log = self._delta_log.record(
                name, base.num_rows, delta.num_rows, self.version
            )
        self.version += 1
        self._rel_versions[name] = self.version
        if log is not None and self._should_compact(log):
            self._compact(name)
        return merged

    # -- lazy maintenance: pending-delta log + drain ---------------------------
    @_locked
    def flush(self, names: Optional[Sequence[str]] = None) -> Dict[str, int]:
        """Fold every pending append into the caches NOW (the lazy-
        maintenance read barrier, also callable as an explicit idle-window
        pass).  ``names`` is an optional scope hint: when given and no
        pending relation is among them, the call is a no-op — but a drain,
        once started, always folds ALL pending relations (partial drains
        would leave entries covering several pending relations half
        folded).

        Returns ``{"relations", "rows", "appends"}`` actually drained
        (zeros when there was nothing to do).  Never bumps ``version`` —
        folding changes no data, so snapshots taken before a flush remain
        current through it."""
        if self._draining or not self._delta_log:
            return dict(_NO_DRAIN)
        if names is not None and not (
            set(names) & set(self._delta_log.names())
        ):
            return dict(_NO_DRAIN)
        return self._drain_all()

    def _drain_all(self) -> Dict[str, int]:
        """Fold the stacked delta of every pending relation into the
        covering view-cache / cofactor entries, in first-pending order.

        Multi-relation exactness (the telescoping sum): when relations
        A, B, … are pending, relation i's fold runs with relation i
        overridden to its stacked delta and every LATER pending relation
        frozen to its pre-append prefix.  Summing the per-relation fold
        terms then telescopes to exactly the merged-join total — the
        ΔA ⋈ ΔB cross terms are picked up exactly once (by the earlier
        relation's fold, whose catalog view of the later one is still the
        prefix), independent of drain order.

        Exception safety: a fold that raises invalidates every entry
        covering a still-pending relation (the failed one may be half
        folded), clears those logs, and re-raises to the reader — the
        catalog itself was published at append time and stays correct.
        """
        log = self._delta_log
        pend = log.items()
        stats = {
            "relations": len(pend),
            "rows": log.total_rows(),
            "appends": log.total_appends(),
        }
        self._draining = True
        try:
            for i, (name, rlog) in enumerate(pend):
                # fresh memo per relation: the override slices below are
                # keyed by object id, which a freed slice could recycle
                self._override_enc = {}
                delta = self._slice_rows(name, rlog.base_rows, None)
                frozen = {
                    later: self._slice_rows(later, 0, later_log.base_rows)
                    for later, later_log in pend[i + 1 :]
                }
                self._fold_relation(name, delta, frozen, self.version)
                log.clear(name, drained=True)
        except Exception:
            for name, _ in pend:
                if name in log:
                    self._invalidate(name)
                    log.clear(name)
            raise
        finally:
            self._draining = False
            self._override_enc = None
        log.drains += 1
        return stats

    def _slice_rows(
        self, name: str, start: int, stop: Optional[int]
    ) -> Relation:
        """A row-range view of cataloged relation ``name`` — the stacked
        pending delta (``[base_rows:]``) or the frozen pre-append prefix
        (``[:base_rows]``) used as a drain override.  Its encoded columns
        are pre-seeded into the override memo by slicing the cached merged
        encodings, so delta engines never re-encode drained rows."""
        merged = self._relations[name]
        sl = slice(start, stop)
        rel = Relation(
            name=name,
            keys={a: c[sl] for a, c in merged.keys.items()},
            values={a: c[sl] for a, c in merged.values.items()},
            domains=dict(merged.domains),
        )
        memo = self._override_enc
        if memo is not None:
            # overwrite (never setdefault): a dead slice's recycled id must
            # not leak its encodings to this fresh one
            by_attr = memo[id(rel)] = {}
            for attr in rel.attributes:
                by_attr[attr] = self.attr_encoding(name, attr)[sl]
        return rel

    def _should_compact(self, log) -> bool:
        if self.compact_rows is not None and log.rows > self.compact_rows:
            return True
        return (
            self.compact_ratio is not None
            and log.rows > self.compact_ratio * max(log.base_rows, 1)
        )

    def _compact(self, name: str) -> None:
        """Pending rows crossed the fold-vs-recompute crossover: folding a
        stacked delta comparable to the base costs as much as a fresh
        descent, so drop the covering entries and the log — the next read
        recomputes from the merged base and re-seeds the caches."""
        self._invalidate(name)
        self._delta_log.clear(name)
        self._delta_log.compactions += 1

    def _fold_relation(
        self,
        name: str,
        delta: Relation,
        frozen: Dict[str, Relation],
        stamp: int,
    ) -> None:
        """Fold ``delta`` (relation ``name``'s update rows) into every
        cache entry covering ``name``, stamping survivors at ``stamp``.
        ``frozen`` overrides other relations to their pre-append prefixes
        (the drain's telescoping guard; empty for eager single-relation
        folds).  Callers own exception handling and the override memo."""
        hook = self.fault_hook
        if hook is not None:
            hook("fold", name)
        overrides = {name: delta, **frozen}
        # persistent view cache first: entries on the appended relation's
        # root path are folded with delta views (their sibling subtrees'
        # entries stay valid untouched), so the result-cache delta engines
        # below — and every later warm batch — start from an already-
        # maintained view layer.
        self._maintain_view_cache(name, overrides, stamp)
        # one delta factorization per (vorder, backend) over the union of
        # cached feature sets; entries derive via project — entries
        # differing only in features don't pay the join again.
        groups: Dict[tuple, List[tuple]] = {}
        for key, entry in self._cofactor_cache.items():
            if name in entry.relations:
                sig, feats, backend = key
                groups.setdefault((sig, backend), []).append(key)
        for (sig, backend), keys in groups.items():
            feats_union = list(dict.fromkeys(f for k in keys for f in k[1]))
            delta_cof = self._delta_cofactors(
                sig, feats_union, backend, overrides
            )
            for key in keys:
                entry = self._cofactor_cache[key]
                entry.cofactors = entry.cofactors + delta_cof.project(
                    list(key[1])
                )
                entry.version = stamp
        # categorical entries: same union algebra, grouped engine, and the
        # same delta-sharing scheme as above — one delta pass per (vorder,
        # backend) over the union feature sets, entries derive via
        # ``CatCofactors.project``.  FD-reduced entries only carry their
        # KEPT attributes (entry.cofactors.cat), so the union delta is
        # computed over kept attributes too — the reduced blocks are plain
        # cofactors over the kept set and fold with the same algebra.  The
        # delta carries the delta's (possibly larger) domains; ``__add__``
        # zero-pads, so unseen category ids appended here grow the cached
        # blocks in place.
        cat_groups: Dict[tuple, List[tuple]] = {}
        for key, entry in self._cat_cache.items():
            if name in entry.relations:
                sig, cont, cat, backend, fdsig = key
                cat_groups.setdefault((sig, backend), []).append(key)
        for (sig, backend), keys in cat_groups.items():
            cont_union = list(dict.fromkeys(f for k in keys for f in k[1]))
            cat_union = list(
                dict.fromkeys(
                    c
                    for k in keys
                    for c in self._cat_cache[k].cofactors.cat
                )
            )
            delta_cof = self._delta_cat_cofactors(
                sig, cont_union, cat_union, backend, overrides
            )
            for key in keys:
                entry = self._cat_cache[key]
                entry.cofactors = entry.cofactors + delta_cof.project(
                    list(key[1]), list(entry.cofactors.cat)
                )
                entry.version = stamp

    def _maintain_view_cache(
        self, name: str, overrides: Dict[str, Relation], stamp: int
    ) -> None:
        """Delta-path maintenance of the persistent view cache for one
        relation's fold.

        Joins distribute over union, per node: the view of a subtree
        containing ``name`` over the post-append catalog equals its
        pre-append view ⊎ the view with ``name`` replaced by the delta
        rows (Prop. 4.1 at view granularity).  So instead of blanket
        invalidation, every affected entry — they all sit on the appended
        relation leaf's root path — is folded in place with a delta view;
        the delta engines reuse the cached views of untouched sibling
        subtrees, keeping the cost O(delta root path), never O(tree).
        Entries whose variable order was never registered fall back to
        invalidation (cannot rebuild an engine for them)."""
        vc = self.view_cache
        affected = [(k, e) for k, e in vc.items() if name in e.relations]
        if not affected:
            return
        from .factorize import FactorizedEngine

        # highest degree first: the degree-2 folds populate the shared
        # delta memo, and every lower-degree fold trims from it instead
        # of re-descending
        affected.sort(key=lambda ke: -ke[0].degree)
        engines: Dict[tuple, FactorizedEngine] = {}
        for key, entry in affected:
            ekey = (key.vorder_sig, key.backend, key.dtype, key.feats)
            eng = engines.get(ekey)
            if eng is None:
                vorder = self._vorders.get(key.vorder_sig)
                if vorder is None:
                    vc.discard(key)
                    continue
                eng = FactorizedEngine(
                    self,
                    vorder,
                    list(key.feats),
                    backend=key.backend,
                    dtype=np.dtype(key.dtype),
                    overrides=overrides,
                    use_view_cache=True,
                )
                engines[ekey] = eng
            vc.replace(
                key, eng.fold_delta_view(key, entry.view), version=stamp
            )

    def column_moments(self, col: str) -> Tuple[float, float, int]:
        """(sum, max|x|, count) of ``col`` over the union of relations that
        contain it — computed once, then maintained under ``append`` and
        invalidated by ``put``.  The feature-scaling building block
        (``compute_scale_factors`` reads avg = sum/count and max|x| from
        here, so warm retrains never rescan the historical data)."""
        if col in self._moments:
            return self._moments[col]
        chunks = [
            rel.column(col).astype(np.float64)
            for rel in self._relations.values()
            if col in rel.values or col in rel.keys
        ]
        if not chunks:
            raise ValueError(f"column {col} not found in any relation")
        allv = np.concatenate(chunks)
        out = (float(allv.sum()), float(np.abs(allv).max()), len(allv))
        with self._mutate_lock:
            self._access("Store._moments", "write")
            self._moments[col] = out
        return out

    def _delta_cofactors(
        self,
        vorder_sig: tuple,
        features: List[str],
        backend: str,
        overrides: Dict[str, Relation],
    ) -> "Cofactors":
        """Cofactors of the join with the folding relation replaced by its
        delta rows (and, during a multi-relation drain, later pending
        relations frozen to their prefixes) — the additive update term for
        one cache entry.  Runs as a delta engine against THIS store
        (``overrides``), so the descent reuses cached sibling-subtree views
        and the shared dictionaries instead of re-encoding the whole
        pre-merge catalog into a throwaway store."""
        from .factorize import FactorizedEngine

        vorder = self._vorders[vorder_sig]
        return FactorizedEngine(
            self,
            vorder,
            features,
            backend=backend,
            overrides=overrides,
        ).cofactors()

    def _delta_cat_cofactors(
        self,
        vorder_sig: tuple,
        cont: List[str],
        cat: List[str],
        backend: str,
        overrides: Dict[str, Relation],
    ):
        """Categorical delta term: the full fused cofactor batch of the join
        under ``overrides`` — ONE multi-output engine traversal per fold,
        not one per attribute/pair, reusing cached sibling-subtree views
        through ``overrides``."""
        from .categorical import cat_cofactors_factorized

        vorder = self._vorders[vorder_sig]
        stats: Dict[str, int] = {}
        out = cat_cofactors_factorized(
            self,
            vorder,
            cont,
            cat,
            backend=backend,
            stats=stats,
            overrides=overrides,
        )
        self.cat_passes += stats["passes"]
        self.cat_node_visits += stats["node_visits"]
        return out

    # -- cofactor cache --------------------------------------------------------
    def sufficient_stats(
        self,
        vorder: "VariableOrder",
        features: Sequence[str],
        label: Optional[str] = None,
        categorical: Sequence[str] = (),
        backend: Optional[str] = None,
        refresh: bool = False,
        reduce_fds: bool = False,
    ):
        """Sufficient statistics of a regression over the factorized join —
        THE public read entry point for model training (and the single
        choke point the lazy-maintenance drain instruments).

        ``features`` are the model inputs; ``label`` (if given) is appended
        to the continuous block.  With ``categorical=()`` this returns the
        continuous :class:`~repro.core.factorize.Cofactors` over
        ``features + [label]`` (default backend ``"jax"``); with
        categorical attributes it returns the
        :class:`~repro.core.categorical.CatCofactors` whose continuous
        block covers the non-categorical features + label (default backend
        ``"numpy"``; ``reduce_fds`` applies the FD reduction — see
        :meth:`cat_cofactors`).  Results are cached and maintained under
        append exactly as before; ``refresh=True`` forces a from-scratch
        recompute.  Do not mutate returned objects.

        Under lazy maintenance this is a read barrier: pending deltas are
        drained (:meth:`flush`) before the cache is consulted, so entries
        are folded up to date or recomputed — never served stale.

        :meth:`cofactors` and :meth:`cat_cofactors` are thin wrappers kept
        for the established call sites.
        """
        cont = [f for f in features if f not in set(categorical)]
        if label is not None:
            cont.append(label)
        cat = list(categorical)
        if cat:
            return self.cat_cofactors(
                vorder,
                cont,
                cat,
                backend=backend if backend is not None else "numpy",
                refresh=refresh,
                reduce_fds=reduce_fds,
            )
        return self.cofactors(
            vorder,
            cont,
            backend=backend if backend is not None else "jax",
            refresh=refresh,
        )

    def _entry_current(self, entry: _CacheEntry) -> bool:
        """Entry validity under per-relation watermarks: valid iff stamped
        at or after the last mutation of every relation it covers.  A lazy
        append moves the covered relations' watermarks without touching
        the entry; the pre-read drain folds the entry and restamps it —
        this check is the backstop against drain/invalidation bugs."""
        rv = self._rel_versions
        return all(entry.version >= rv.get(r, 0) for r in entry.relations)

    @_locked
    def cofactors(
        self,
        vorder: "VariableOrder",
        features: Sequence[str],
        backend: str = "jax",
        refresh: bool = False,
    ) -> "Cofactors":
        """Cached *unscaled* cofactors over the factorized join of
        ``vorder`` for ``features`` (continuous wrapper around
        :meth:`sufficient_stats` — the features here already include any
        label column).  Computes on miss; appends maintain the entry
        incrementally (eagerly or via the pending-delta drain);
        ``refresh=True`` forces a from-scratch recompute (and re-seeds the
        cache).  Do not mutate the result — derive scaled views with
        ``Cofactors.rescale``."""
        from .factorize import FactorizedEngine

        self._access("Store._cofactor_cache", "write")
        self.flush(vorder.relations())
        sig = vorder.signature()
        key = (sig, tuple(features), backend)
        entry = self._cofactor_cache.get(key)
        if entry is not None and not refresh and self._entry_current(entry):
            return entry.cofactors
        cof = FactorizedEngine(
            self, vorder, list(features), backend=backend
        ).cofactors()
        self._vorders[sig] = vorder
        self._cofactor_cache[key] = _CacheEntry(
            cofactors=cof,
            relations=frozenset(vorder.relations()),
            version=self.version,
        )
        return cof

    @_locked
    def cat_cofactors(
        self,
        vorder: "VariableOrder",
        cont: Sequence[str],
        cat: Sequence[str],
        backend: str = "numpy",
        refresh: bool = False,
        reduce_fds: bool = False,
    ):
        """Cached categorical cofactors over the factorized join — the
        categorical twin of :meth:`cofactors` (wrapper around
        :meth:`sufficient_stats`; ``cont`` already includes the label).
        The cache key includes the categorical signature (which attributes
        are declared categorical, in order), so continuous and categorical
        entries over the same join never alias, and ``append`` maintains
        both kinds incrementally.  Cold computes and delta folds both run
        the fused multi-output plan — exactly one engine traversal each,
        audited by ``cat_passes`` / ``cat_node_visits`` in
        :meth:`cache_info`.

        ``reduce_fds=True`` applies the FD reduction of ``cat`` under the
        store's catalog: functionally-determined attributes are dropped
        before the traversal (fewer GROUP BY queries, smaller COO blocks)
        and the returned ``CatCofactors`` covers only the KEPT attributes
        (``store.fd_reduction(cat)`` describes the mapping; expansion /
        coefficient recovery live in ``repro.core.fd``).  The cache key
        carries the reduction *signature*, so entries built under an FD
        that is later falsified are invalidated rather than re-served.
        Returns a ``repro.core.categorical.CatCofactors``; do not mutate."""
        from .categorical import cat_cofactors_factorized

        self._access("Store._cat_cache", "write")
        self.flush(vorder.relations())
        sig = vorder.signature()
        red = self.fd_reduction(cat) if reduce_fds else None
        fdsig = red.signature() if red is not None else None
        key = (sig, tuple(cont), tuple(cat), backend, fdsig)
        entry = self._cat_cache.get(key)
        if entry is not None and not refresh and self._entry_current(entry):
            return entry.cofactors
        run_cat = list(red.kept) if red is not None else list(cat)
        stats: Dict[str, int] = {}
        cof = cat_cofactors_factorized(
            self, vorder, list(cont), run_cat, backend=backend, stats=stats
        )
        self.cat_passes += stats["passes"]
        self.cat_node_visits += stats["node_visits"]
        self._vorders[sig] = vorder
        self._cat_cache[key] = _CacheEntry(
            cofactors=cof,
            relations=frozenset(vorder.relations()),
            version=self.version,
        )
        return cof

    @_locked
    def cache_info(self) -> Dict[str, int]:
        # Under the mutate lock so the report is one consistent cut: entry
        # counts, counters and delta-log debt all from the same instant,
        # never straddling a fold.
        vc = self.view_cache
        self._access("Store._cofactor_cache", "read")
        self._access("Store._cat_cache", "read")
        info = {
            "entries": len(self._cofactor_cache),
            "cat_entries": len(self._cat_cache),
            "fds": len(self._fds),
            "version": self.version,
            "maintenance": self.maintenance,
            "passes": self.passes,
            "node_visits": self.node_visits,
            "cat_passes": self.cat_passes,
            "cat_node_visits": self.cat_node_visits,
            "view_cache_entries": len(vc),
            "view_cache_bytes": vc.bytes,
            "view_cache_hits": vc.hits,
            "view_cache_misses": vc.misses,
            "view_cache_evictions": vc.evictions,
        }
        info.update(self._delta_log.info())
        return info

    def _invalidate(self, name: str) -> None:
        for cache in (self._cofactor_cache, self._cat_cache):
            stale = [k for k, e in cache.items() if name in e.relations]
            for k in stale:
                del cache[k]
        self.view_cache.invalidate_relation(name)

    # -- snapshots -------------------------------------------------------------
    @property
    def live_version(self) -> int:
        """The store's current catalog version.  On a :class:`StoreSnapshot`
        the same property forwards to the parent store, so engines can ask
        "is the catalog I froze still the live one" uniformly."""
        return self.version

    def snapshot(self) -> "StoreSnapshot":
        """An immutable read view of the catalog at the current version.

        O(1): captures references to the copy-on-write maps (`_relations`,
        encoded columns, moments, FD catalog) — every later ``put`` /
        ``append`` / FD mutation *replaces* those maps on the store, so the
        snapshot keeps serving the frozen state without blocking writers
        and without writers corrupting it (MVCC by structural sharing).
        """
        return StoreSnapshot(self)

    # -- natural join (the noPre path) ----------------------------------------
    def materialize_join(
        self, names: Optional[Sequence[str]] = None
    ) -> Relation:
        """Materialize the natural join of ``names`` (default: all relations).

        Joins pairwise on shared key attributes, greedily preferring joins
        with at least one shared attribute (avoids accidental cross products
        when a connected join order exists).
        """
        return _materialize(self._relations, names)


class StoreSnapshot:
    """Read-only view of a :class:`Store` frozen at one catalog version.

    Duck-types the Store read surface (`get` / `attr_encoding` /
    `column_moments` / `fd_reduction` / `cofactors` / ... ), so a
    ``FactorizedEngine`` — or any reader — runs against it unchanged.
    Concurrent ``append`` / ``put`` / FD mutations on the parent replace
    the parent's maps copy-on-write; this object keeps the frozen
    references, so an in-flight reader observes bit-identical data whether
    or not a mutation lands mid-request.

    Shared with the parent (safe by construction):

    * the append-only attribute dictionaries — values are only ever
      *extended*, ids never renumber, so post-snapshot growth is invisible
      to ids the snapshot can produce;
    * the version-stamped ``ViewCache`` — entries carry the version they
      are valid at, and engines stand down from the cache the moment the
      live version moves past their frozen one;
    * the cumulative ``passes`` / ``node_visits`` counters — snapshot
      traversals forward into the parent's totals so store-level counter
      audits keep summing up.

    Result-level caches (`cofactors` / `cat_cofactors`) delegate to the
    parent only while the snapshot is still current; once the parent moves
    on, the snapshot computes fresh, uncached, against its frozen maps.
    """

    def __init__(self, store: Store) -> None:
        self._store = store
        self.version = store.version
        self._relations = store._relations
        self._enc_cols = store._enc_cols
        self._moments = store._moments
        self._fds_map = store._fds
        self._fd_version = store._fd_version
        self._red_cache: Dict[tuple, FDReduction] = {}
        self.view_cache = store.view_cache

    # -- freshness -------------------------------------------------------------
    @property
    def live_version(self) -> int:
        return self._store.version

    @property
    def is_current(self) -> bool:
        """True while no catalog or FD mutation has landed on the parent
        since this snapshot was taken."""
        return (
            self.version == self._store.version
            and self._fd_version == self._store._fd_version
        )

    def snapshot(self) -> "StoreSnapshot":
        return self  # already frozen; engines may call this blindly

    # -- counters (forwarded: store totals stay the audit source of truth) -----
    @property
    def passes(self) -> int:
        return self._store.passes

    @passes.setter
    def passes(self, v: int) -> None:
        self._store.passes = v

    @property
    def node_visits(self) -> int:
        return self._store.node_visits

    @node_visits.setter
    def node_visits(self, v: int) -> None:
        self._store.node_visits = v

    @property
    def cat_passes(self) -> int:
        return self._store.cat_passes

    @cat_passes.setter
    def cat_passes(self, v: int) -> None:
        self._store.cat_passes = v

    @property
    def cat_node_visits(self) -> int:
        return self._store.cat_node_visits

    @cat_node_visits.setter
    def cat_node_visits(self, v: int) -> None:
        self._store.cat_node_visits = v

    def _register_vorder(self, sig: tuple, vorder: "VariableOrder") -> None:
        # registration targets append-time maintenance on the live store
        self._store._register_vorder(sig, vorder)

    # -- catalog reads (frozen) ------------------------------------------------
    def get(self, name: str) -> Relation:
        return self._relations[name]

    def __contains__(self, name: str) -> bool:
        return name in self._relations

    def names(self) -> List[str]:
        return list(self._relations)

    def relations(self) -> List[Relation]:
        return list(self._relations.values())

    def total_rows(self) -> int:
        return sum(r.num_rows for r in self._relations.values())

    def attr_domain(self, attr: str) -> int:
        doms = [
            rel.domains[attr]
            for rel in self._relations.values()
            if attr in rel.domains
        ]
        if not doms:
            raise ValueError(
                f"attribute {attr!r} is not a dictionary-encoded key in any "
                "relation"
            )
        return max(doms)

    def attr_values_array(self, attr: str) -> np.ndarray:
        # append-only global dictionary: a longer array than at snapshot
        # time is fine — every id this snapshot can produce predates the
        # growth, and existing slots never change.
        return self._store.attr_values_array(attr)

    def attr_encoding(
        self, rel_name: str, attr: str, override: Optional[Relation] = None
    ) -> np.ndarray:
        if override is not None:
            return self._store.attr_encoding(rel_name, attr, override=override)
        key = (rel_name, attr)
        ids = self._enc_cols.get(key)
        if ids is None:
            # miss against the frozen column; fills the frozen map, which
            # the parent still shares while no mutation has landed (same
            # version ⇒ same data) and owns exclusively afterwards.
            col = self._relations[rel_name].column(attr)
            ids = self._store._dict_for(attr).extend_encode(col)
            # lockcheck: idempotent memo fill on the aliased encodings map
            self._enc_cols[key] = ids
        return ids

    def column_moments(self, col: str) -> Tuple[float, float, int]:
        if col in self._moments:
            return self._moments[col]
        chunks = [
            rel.column(col).astype(np.float64)
            for rel in self._relations.values()
            if col in rel.values or col in rel.keys
        ]
        if not chunks:
            raise ValueError(f"column {col} not found in any relation")
        allv = np.concatenate(chunks)
        out = (float(allv.sum()), float(np.abs(allv).max()), len(allv))
        # Lock-free fill of the map shared with the parent: a concurrent
        # parent append either swaps the map (this write lands in the
        # orphaned copy, lost) or folds this value forward with the delta
        # rows (correct) — lost-or-correct, never wrong.
        # lockcheck: idempotent memo fill on the aliased moments map
        self._moments[col] = out
        return out

    # -- FD catalog (frozen) ---------------------------------------------------
    def fds(self) -> List[FunctionalDependency]:
        return list(self._fds_map.values())

    def fd_reduction(self, cat: Sequence[str]) -> FDReduction:
        domains = {a: self.attr_domain(a) for a in cat}
        key = (tuple(cat), tuple(sorted(domains.items())))
        plan = self._red_cache.get(key)
        if plan is None:
            plan = reduction_plan(self._fds_map, list(cat), domains)
            self._red_cache[key] = plan
        return plan

    # -- aggregate entry points ------------------------------------------------
    def flush(self, names: Optional[Sequence[str]] = None) -> Dict[str, int]:
        """Lazy-maintenance read barrier, snapshot flavour: forwards to the
        parent while current (a drain folds caches without changing any
        data, so currency survives it); a no-op with zero stats on a stale
        snapshot, whose frozen catalog needs no cache maintenance."""
        if self.is_current:
            return self._store.flush(names)
        return dict(_NO_DRAIN)

    def sufficient_stats(
        self,
        vorder: "VariableOrder",
        features: Sequence[str],
        label: Optional[str] = None,
        categorical: Sequence[str] = (),
        backend: Optional[str] = None,
        refresh: bool = False,
        reduce_fds: bool = False,
    ):
        """See :meth:`Store.sufficient_stats` — the same routing against
        this frozen view (cached via the parent while current, computed
        over the frozen catalog once stale)."""
        cont = [f for f in features if f not in set(categorical)]
        if label is not None:
            cont.append(label)
        cat = list(categorical)
        if cat:
            return self.cat_cofactors(
                vorder,
                cont,
                cat,
                backend=backend if backend is not None else "numpy",
                refresh=refresh,
                reduce_fds=reduce_fds,
            )
        return self.cofactors(
            vorder,
            cont,
            backend=backend if backend is not None else "jax",
            refresh=refresh,
        )

    def cofactors(
        self,
        vorder: "VariableOrder",
        features: Sequence[str],
        backend: str = "jax",
        refresh: bool = False,
    ) -> "Cofactors":
        """Unscaled cofactors at this snapshot's version.  While the
        snapshot is current this is exactly the parent's cached entry;
        once the parent has moved on it is a fresh uncached compute over
        the frozen catalog (the parent's result cache holds newer data)."""
        if self.is_current:
            return self._store.cofactors(
                vorder, features, backend=backend, refresh=refresh
            )
        from .factorize import FactorizedEngine

        self._register_vorder(vorder.signature(), vorder)
        return FactorizedEngine(
            self, vorder, list(features), backend=backend
        ).cofactors()

    def cat_cofactors(
        self,
        vorder: "VariableOrder",
        cont: Sequence[str],
        cat: Sequence[str],
        backend: str = "numpy",
        refresh: bool = False,
        reduce_fds: bool = False,
    ):
        if self.is_current:
            return self._store.cat_cofactors(
                vorder,
                cont,
                cat,
                backend=backend,
                refresh=refresh,
                reduce_fds=reduce_fds,
            )
        from .categorical import cat_cofactors_factorized

        red = self.fd_reduction(cat) if reduce_fds else None
        run_cat = list(red.kept) if red is not None else list(cat)
        stats: Dict[str, int] = {}
        out = cat_cofactors_factorized(
            self, vorder, list(cont), run_cat, backend=backend, stats=stats
        )
        self._store.cat_passes += stats["passes"]
        self._store.cat_node_visits += stats["node_visits"]
        return out

    def materialize_join(
        self, names: Optional[Sequence[str]] = None
    ) -> Relation:
        return _materialize(self._relations, names)

    def cache_info(self) -> Dict[str, int]:
        return self._store.cache_info()


def _materialize(
    relations: Dict[str, Relation], names: Optional[Sequence[str]]
) -> Relation:
    todo = [relations[n] for n in (names or list(relations))]
    if not todo:
        raise ValueError("no relations to join")
    acc = todo.pop(0)
    while todo:
        pick = None
        for i, rel in enumerate(todo):
            if set(acc.keys) & set(rel.keys):
                pick = i
                break
        if pick is None:  # genuine cross product required
            pick = 0
        acc = _join_pair(acc, todo.pop(pick))
    return acc


def _join_pair(left: Relation, right: Relation) -> Relation:
    shared = sorted(set(left.keys) & set(right.keys))
    if shared:
        doms = [max(left.domains[a], right.domains[a]) for a in shared]
        # join_keys falls back to the dictionary-encoded hash join when the
        # mixed-radix product of the shared domains overflows int64 (many /
        # wide shared attributes), keeping strict composite keys otherwise.
        lk, rk = join_keys(
            [left.keys[a] for a in shared],
            [right.keys[a] for a in shared],
            doms,
        )
        il, ir = sort_merge_join(lk, rk)
    else:  # cross product
        nl, nr = left.num_rows, right.num_rows
        il = np.repeat(np.arange(nl, dtype=np.int64), nr)
        ir = np.tile(np.arange(nr, dtype=np.int64), nl)

    keys = {a: c[il] for a, c in left.keys.items()}
    for a, c in right.keys.items():
        if a not in keys:
            keys[a] = c[ir]
    values = {a: c[il] for a, c in left.values.items()}
    for a, c in right.values.items():
        if a not in values:
            values[a] = c[ir]
    # merge domains per attribute with max: the join key above was built with
    # max(left, right), so keeping a smaller domain here would desynchronize
    # later composite_key calls on the joined relation.
    domains = dict(right.domains)
    for a, d in left.domains.items():
        domains[a] = max(d, domains.get(a, 0))
    return Relation(
        name=f"({left.name}⋈{right.name})",
        keys=keys,
        values=values,
        domains=domains,
    )
