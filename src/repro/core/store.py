"""The in-memory database: a catalog of relations plus a natural-join planner.

Plays the role HyPer plays in the paper — it *holds* the training data and
executes the factorized aggregate plan close to the data.  ``materialize_join``
is the non-factorized ("noPre") path: it computes the flat natural join whose
size is O(|D|^rho*) and against which factorization is benchmarked.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .relation import Relation, composite_key, sort_merge_join

__all__ = ["Store"]


class Store:
    """Catalog of named relations with natural-join materialization."""

    def __init__(self, relations: Optional[Sequence[Relation]] = None) -> None:
        self._relations: Dict[str, Relation] = {}
        for rel in relations or ():
            self.put(rel)

    # -- catalog -------------------------------------------------------------
    def put(self, rel: Relation) -> None:
        self._relations[rel.name] = rel

    def get(self, name: str) -> Relation:
        return self._relations[name]

    def __contains__(self, name: str) -> bool:
        return name in self._relations

    def names(self) -> List[str]:
        return list(self._relations)

    def relations(self) -> List[Relation]:
        return list(self._relations.values())

    def total_rows(self) -> int:
        return sum(r.num_rows for r in self._relations.values())

    # -- natural join (the noPre path) ----------------------------------------
    def materialize_join(
        self, names: Optional[Sequence[str]] = None
    ) -> Relation:
        """Materialize the natural join of ``names`` (default: all relations).

        Joins pairwise on shared key attributes, greedily preferring joins
        with at least one shared attribute (avoids accidental cross products
        when a connected join order exists).
        """
        todo = [self._relations[n] for n in (names or self.names())]
        if not todo:
            raise ValueError("no relations to join")
        acc = todo.pop(0)
        while todo:
            pick = None
            for i, rel in enumerate(todo):
                if set(acc.keys) & set(rel.keys):
                    pick = i
                    break
            if pick is None:  # genuine cross product required
                pick = 0
            acc = _join_pair(acc, todo.pop(pick))
        return acc


def _join_pair(left: Relation, right: Relation) -> Relation:
    shared = sorted(set(left.keys) & set(right.keys))
    if shared:
        doms = [max(left.domains[a], right.domains[a]) for a in shared]
        lk = composite_key([left.keys[a] for a in shared], doms)
        rk = composite_key([right.keys[a] for a in shared], doms)
        il, ir = sort_merge_join(lk, rk)
    else:  # cross product
        nl, nr = left.num_rows, right.num_rows
        il = np.repeat(np.arange(nl, dtype=np.int64), nr)
        ir = np.tile(np.arange(nr, dtype=np.int64), nl)

    keys = {a: c[il] for a, c in left.keys.items()}
    for a, c in right.keys.items():
        if a not in keys:
            keys[a] = c[ir]
    values = {a: c[il] for a, c in left.values.items()}
    for a, c in right.values.items():
        if a not in values:
            values[a] = c[ir]
    domains = dict(right.domains)
    domains.update(left.domains)
    return Relation(
        name=f"({left.name}⋈{right.name})",
        keys=keys,
        values=values,
        domains=domains,
    )
