"""Generalized linear models over the compressed factorized join.

Least squares factors through fixed degree-≤2 cofactors; a GLM's
log-likelihood does not — the nonlinearity (σ for logistic, exp for
Poisson) must be evaluated at each distinct linear predictor value.  The
factorized counterpart (AC/DC's GLM setting) is **row compression**: group
the join result by its distinct feature combination and keep per-group
sufficient statistics

    counts[g] = SUM(1)        GROUP BY features      (group multiplicity)
    ysum[g]   = SUM(y)        GROUP BY features      (label sufficient stat)

which are exactly the aggregates the factorized engine already pushes
through the join — ``FactorizedEngine(group_by=features)`` computes them in
one pass without materializing the flat join.  Every training iteration
then costs O(G·p) for G distinct rows instead of O(m·p); over joins with
categorical keys, G ≪ m (the benchmark's regime).

Categorical features never one-hot expand: the linear predictor gathers
per-category coefficients (``theta[offset_c + id]``) and the gradient
scatter-adds back — a [G, Σ D_c] one-hot matrix exists on neither path.

Two solvers, mirroring ``gd.py``:

* ``irls``  — host fp64 Newton/IRLS with the Hessian assembled block-wise
  from the same grouped statistics (scatter-added, never via a one-hot
  matrix); quadratically convergent, the accuracy reference.
* ``gd``    — on-device ``lax.while_loop`` mirroring ``gd.py``'s driver
  with a bold-driver α gated on the NLL, for large p where an O(p³) solve
  per step is the bottleneck.

``fit_glm_onehot`` is the dense one-hot baseline (tests oracle + the slow
side of ``bench_categorical``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .factorize import FactorizedEngine
from .store import Store
from .variable_order import VariableOrder

__all__ = [
    "CompressedDesign",
    "GLMConfig",
    "GLMResult",
    "compressed_design_factorized",
    "compressed_design_materialized",
    "fit_glm",
    "fit_glm_onehot",
    "glm_predict_raw",
    "glm_regression",
]


@dataclasses.dataclass(frozen=True)
class GLMConfig:
    family: str = "logistic"  # "logistic" | "poisson"
    ridge: float = 1e-6  # L2 on all coefficients except the intercept
    solver: str = "irls"  # "irls" | "gd"
    max_iter: int = 100  # Newton iterations (irls)
    tol: float = 1e-12  # convergence: mean |grad| per row (irls)
    gd_alpha0: float = 0.5  # α on the per-row-normalized gradient (gd)
    gd_eps: float = 1e-7  # mean-|gradient| stopping threshold (gd)
    gd_max_iter: int = 100_000
    # "fp32": plain fp32 reductions.  "pairs": fp32 compute with the NLL
    # and gradient reductions accumulated in two-float (hi, lo) pairs —
    # ~fp64-precision sums without native fp64 (TPUs have none), closing
    # the gap to IRLS on large compressed designs where the fp32 NLL floor
    # stalls the bold-driver accept test.
    gd_accum: str = "fp32"


@dataclasses.dataclass
class CompressedDesign:
    """The factorized join compressed to distinct feature rows.

    ``cont``     : [G, k] continuous feature values per distinct row
    ``cat_ids``  : [G, n_cat] dictionary ids per distinct row
    ``counts``   : [G] multiplicity of the row in the join result
    ``ysum``     : [G] sum of the label over the row's group
    """

    cont: np.ndarray
    cat_ids: np.ndarray
    counts: np.ndarray
    ysum: np.ndarray
    cont_names: List[str]
    cat_names: List[str]
    domains: Dict[str, int]
    label: str

    @property
    def num_rows(self) -> int:
        return int(self.counts.shape[0])

    @property
    def total_rows(self) -> float:
        return float(self.counts.sum())

    @property
    def num_params(self) -> int:
        return (
            1
            + len(self.cont_names)
            + sum(self.domains[c] for c in self.cat_names)
        )

    def param_names(self) -> List[str]:
        names = ["intercept"] + list(self.cont_names)
        for c in self.cat_names:
            names.extend(f"{c}={g}" for g in range(self.domains[c]))
        return names

    def cat_offsets(self) -> np.ndarray:
        """Start index of each categorical block inside θ."""
        off = 1 + len(self.cont_names)
        out = []
        for c in self.cat_names:
            out.append(off)
            off += self.domains[c]
        return np.asarray(out, dtype=np.int64)

    def offset_ids(self) -> np.ndarray:
        """[G, n_cat] ids pre-shifted into θ coordinates — one gather of
        ``theta[offset_ids]`` evaluates every categorical contribution."""
        if not self.cat_names:
            return np.zeros((self.num_rows, 0), dtype=np.int64)
        return self.cat_ids.astype(np.int64) + self.cat_offsets()[None, :]

    def linpred(self, theta: np.ndarray) -> np.ndarray:
        """η_g = θ₀ + x_g·θ_cont + Σ_c θ_c[id_{g,c}] — no one-hot."""
        eta = theta[0] + self.cont @ theta[1 : 1 + len(self.cont_names)]
        if self.cat_names:
            eta = eta + theta[self.offset_ids()].sum(axis=1)
        return eta


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

def compressed_design_factorized(
    store: Store,
    vorder: VariableOrder,
    cont: Sequence[str],
    cat: Sequence[str],
    label: str,
    backend: str = "numpy",
    use_view_cache: Optional[bool] = None,
) -> CompressedDesign:
    """One factorized GROUP BY over *all* feature attributes: the engine
    carries count and Σy per distinct feature combination to the root —
    O(factorization size), flat join never materialized.  The descent
    shares the store's persistent view cache with the cofactor paths, so
    an IRLS re-solve (or a design over a feature subset already swept)
    starts from cached subtree views; ``use_view_cache=False`` opts out."""
    cont, cat = list(cont), list(cat)
    g = FactorizedEngine(
        store,
        vorder,
        [label],
        backend=backend,
        group_by=cont + cat,
        use_view_cache=use_view_cache,
    ).grouped_cofactors()
    x = (
        np.stack([g.keys[f] for f in cont], axis=1)
        if cont
        else np.zeros((g.num_groups, 0))
    )
    ids = (
        np.stack([g.ids(c) for c in cat], axis=1)
        if cat
        else np.zeros((g.num_groups, 0), dtype=np.int64)
    )
    return CompressedDesign(
        cont=x,
        cat_ids=ids,
        counts=g.count,
        ysum=g.lin[:, 0],
        cont_names=cont,
        cat_names=cat,
        domains={c: store.attr_domain(c) for c in cat},
        label=label,
    )


def compressed_design_materialized(
    store: Store,
    cont: Sequence[str],
    cat: Sequence[str],
    label: str,
    relations: Optional[Sequence[str]] = None,
) -> CompressedDesign:
    """Oracle path: materialize the join, then np.unique the feature rows."""
    cont, cat = list(cont), list(cat)
    joined = store.materialize_join(relations)
    m = joined.num_rows
    feats = np.column_stack(
        [joined.column(f).astype(np.float64) for f in cont + cat]
    ) if (cont or cat) else np.zeros((m, 0))
    y = joined.column(label).astype(np.float64)
    uniq, inv = np.unique(feats, axis=0, return_inverse=True)
    counts = np.bincount(inv, minlength=len(uniq)).astype(np.float64)
    ysum = np.bincount(inv, weights=y, minlength=len(uniq))
    return CompressedDesign(
        cont=uniq[:, : len(cont)],
        cat_ids=uniq[:, len(cont) :].astype(np.int64),
        counts=counts,
        ysum=ysum,
        cont_names=cont,
        cat_names=cat,
        domains={c: store.attr_domain(c) for c in cat},
        label=label,
    )


# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------

def _sigmoid(eta: np.ndarray) -> np.ndarray:
    out = np.empty_like(eta)
    pos = eta >= 0
    out[pos] = 1.0 / (1.0 + np.exp(-eta[pos]))
    e = np.exp(eta[~pos])
    out[~pos] = e / (1.0 + e)
    return out


def _family_stats(
    family: str, eta: np.ndarray, counts: np.ndarray, ysum: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, float]:
    """(dL/dη per group, IRLS weights per group, negative log-likelihood)."""
    if family == "logistic":
        p = _sigmoid(eta)
        grad = counts * p - ysum
        w = np.maximum(counts * p * (1.0 - p), 1e-12)
        # log(1+e^η) evaluated stably
        softplus = np.where(eta > 30, eta, np.log1p(np.exp(np.minimum(eta, 30))))
        nll = float((counts * softplus - ysum * eta).sum())
    elif family == "poisson":
        mu = np.exp(np.minimum(eta, 30))
        grad = counts * mu - ysum
        w = np.maximum(counts * mu, 1e-12)
        nll = float((counts * mu - ysum * eta).sum())
    else:
        raise ValueError(f"unknown GLM family {family!r}")
    return grad, w, nll


# ---------------------------------------------------------------------------
# Solvers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GLMResult:
    theta: np.ndarray  # [p] in param_names() order
    iterations: int
    converged: bool
    nll: float  # penalized negative log-likelihood at θ
    config: GLMConfig
    names: List[str]
    seconds_compress: float = 0.0
    seconds_fit: float = 0.0

    def coef(self, name: str) -> float:
        return float(self.theta[self.names.index(name)])


def _grad_theta(
    design: CompressedDesign, grad_eta: np.ndarray, oid: np.ndarray
) -> np.ndarray:
    """Scatter dL/dη back through the (never-materialized) design."""
    p = design.num_params
    k = len(design.cont_names)
    g = np.zeros(p, dtype=np.float64)
    g[0] = grad_eta.sum()
    g[1 : 1 + k] = design.cont.T @ grad_eta
    if design.cat_names:
        np.add.at(g, oid, grad_eta[:, None])
    return g


def _hessian(
    design: CompressedDesign, w: np.ndarray, oid: np.ndarray
) -> np.ndarray:
    """X^T W X assembled block-wise from grouped statistics — the weighted
    version of ``CatCofactors.matrix``, rebuilt each IRLS step because W
    depends on θ.  Still no one-hot matrix: every categorical block is a
    scatter-add over the G compressed rows."""
    p = design.num_params
    k = len(design.cont_names)
    x = design.cont
    wx = w[:, None] * x
    h = np.zeros((p, p), dtype=np.float64)
    h[0, 0] = w.sum()
    h[0, 1 : 1 + k] = wx.sum(axis=0)
    h[1 : 1 + k, 1 : 1 + k] = x.T @ wx
    ncat = len(design.cat_names)
    for i in range(ncat):
        col = oid[:, i]
        np.add.at(h[0], col, w)  # intercept × cat
        np.add.at(h, (col, col), w)  # diagonal block
        for j in range(k):  # cont × cat
            np.add.at(h[1 + j], col, wx[:, j])
        for j in range(i + 1, ncat):  # cat × cat (upper)
            np.add.at(h, (col, oid[:, j]), w)
    iu = np.triu_indices(p, 1)
    h[(iu[1], iu[0])] = h[iu]  # mirror the upper triangle
    return h


def fit_glm(
    design: CompressedDesign,
    config: Optional[GLMConfig] = None,
    penalty: Optional[np.ndarray] = None,
) -> GLMResult:
    """Train a GLM on the compressed representation.

    ``penalty``, when given, is a full [p, p] penalty matrix replacing the
    default ``diag(0, ridge, …, ridge)`` — the generalized ridge of the
    FD-reduced parameter space (see ``repro.core.fd``): the penalized NLL
    gains ``0.5·θᵀ·penalty·θ``, its gradient ``penalty·θ``, the Hessian
    ``penalty``.  The intercept row/column should be zero to keep it
    unpenalized."""
    cfg = config or GLMConfig()
    t0 = time.perf_counter()
    if cfg.solver == "irls":
        res = _fit_irls(design, cfg, penalty=penalty)
    elif cfg.solver == "gd":
        res = _fit_gd(design, cfg, penalty=penalty)
    else:
        raise ValueError(f"unknown solver {cfg.solver!r}")
    res.seconds_fit = time.perf_counter() - t0
    return res


def _penalty(cfg: GLMConfig, theta: np.ndarray) -> float:
    """Plain ridge penalty value (intercept-free) — the scalar twin of
    ``_default_penalty``, kept as the reference formula for tests."""
    return 0.5 * cfg.ridge * float(theta[1:] @ theta[1:])


def _default_penalty(cfg: GLMConfig, p: int) -> np.ndarray:
    pen = np.full(p, cfg.ridge)
    pen[0] = 0.0  # intercept unpenalized
    return np.diag(pen)


def _fit_irls(
    design: CompressedDesign,
    cfg: GLMConfig,
    penalty: Optional[np.ndarray] = None,
) -> GLMResult:
    p = design.num_params
    oid = design.offset_ids()
    theta = np.zeros(p, dtype=np.float64)
    pen = penalty if penalty is not None else _default_penalty(cfg, p)
    m = max(design.total_rows, 1.0)

    def pen_val(t: np.ndarray) -> float:
        return 0.5 * float(t @ (pen @ t))

    eta = design.linpred(theta)
    grad_eta, w, nll = _family_stats(
        cfg.family, eta, design.counts, design.ysum
    )
    nll += pen_val(theta)
    # the gradient is carried through the loop: an accepted full Newton
    # step hands its candidate gradient to the next iteration, so the
    # common path costs ONE _grad_theta + pen matvec per iteration.
    grad = _grad_theta(design, grad_eta, oid) + pen @ theta
    converged = False
    it = 0
    for it in range(1, cfg.max_iter + 1):  # noqa: B007 — `it` is read after the loop (iterations=it)
        if np.abs(grad).max() / m < cfg.tol:
            converged = True
            break
        h = _hessian(design, w, oid) + pen
        # tiny jitter keeps the solve well-posed when a category is empty
        h[np.diag_indices(p)] += 1e-10
        step = np.linalg.solve(h, grad)
        # full Newton step first: accept on NLL decrease OR on gradient
        # contraction.  Near the optimum the per-step NLL decrease is far
        # below fp64 resolution of the total, so an NLL-only gate starts
        # rejecting (or accepting ~zero-length backtracked variants of)
        # genuinely contracting steps on rounding noise — two formulations
        # of the same problem (e.g. the FD-reduced and the full solve)
        # would then stop ~1e-8 apart; gating on ∇ runs both to the
        # numerical floor, where they agree to ~1e-12.
        cand = theta - step
        g2, w2, nll2 = _family_stats(
            cfg.family, design.linpred(cand), design.counts, design.ysum
        )
        nll2 += pen_val(cand)
        grad_cand = _grad_theta(design, g2, oid) + pen @ cand
        if nll2 <= nll + 1e-15 or (
            np.abs(grad_cand).max() < np.abs(grad).max()
        ):
            theta, grad_eta, w, nll, grad = cand, g2, w2, nll2, grad_cand
            continue
        # overshoot: backtracking line search on the penalized NLL
        scale = 0.5
        for _ in range(29):
            cand = theta - scale * step
            g2, w2, nll2 = _family_stats(
                cfg.family, design.linpred(cand), design.counts, design.ysum
            )
            nll2 += pen_val(cand)
            if nll2 <= nll + 1e-15:
                theta, grad_eta, w, nll = cand, g2, w2, nll2
                grad = _grad_theta(design, g2, oid) + pen @ cand
                break
            scale *= 0.5
        else:  # no improving step in either gate — at numerical precision
            converged = True
            break
    return GLMResult(
        theta=theta,
        iterations=it,
        converged=converged,
        nll=nll,
        config=cfg,
        names=design.param_names(),
    )


def _two_sum(a, b):
    """Knuth's error-free transformation: s + err == a + b exactly."""
    s = a + b
    bb = s - a
    err = (a - (s - bb)) + (b - bb)
    return s, err


def _pairwise_sum2(v):
    """Compensated pairwise reduction of ``v`` along axis 0.

    Returns an (hi, lo) two-float pair whose exact sum carries ~2× the
    significand of one float — the mixed-precision accumulator for the GD
    solver (fp32 per-element compute, fp64-grade sums).  The tree has
    ⌈log₂ G⌉ statically-unrolled levels; each level's exact two-sum errors
    accumulate in ``lo`` (they are ~eps·|terms|, so their own fp32 sum is
    harmless)."""
    import jax.numpy as jnp

    hi = v
    lo = jnp.zeros_like(v)
    while hi.shape[0] > 1:
        if hi.shape[0] % 2:
            hi = jnp.concatenate([hi, jnp.zeros_like(hi[:1])], axis=0)
            lo = jnp.concatenate([lo, jnp.zeros_like(lo[:1])], axis=0)
        s, e = _two_sum(hi[0::2], hi[1::2])
        lo = lo[0::2] + lo[1::2] + e
        hi = s
    return hi[0], lo[0]


def _fit_gd(
    design: CompressedDesign,
    cfg: GLMConfig,
    penalty: Optional[np.ndarray] = None,
) -> GLMResult:
    """On-device GD via ``lax.while_loop``, mirroring ``gd.py``'s driver
    but adapted to a non-quadratic objective: the bold-driver α decision
    gates on the penalized NLL (accept if it decreased, else revert and
    shrink α) and convergence is the per-row mean |gradient| — gating on
    Σ|α·grad| as in least squares lets α collapse masquerade as
    convergence once the objective is not quadratic.

    Continuous columns are scaled to (x − avg)/max|·| internally — the
    paper's §3.3 convergence prerequisite, weighted by group counts since
    compressed rows carry multiplicity — and θ is rescaled back exactly
    before returning (one-hot coordinates need no scaling).  The ridge
    penalty applies to the *scaled* coefficients here, so with ridge > 0
    the GD optimum differs from IRLS's by O(ridge); IRLS is the accuracy
    reference, GD the large-p path.

    With ``cfg.gd_accum == "pairs"`` the NLL and the dense gradient
    reductions accumulate in two-float (hi, lo) pairs and the accept test
    compares NLL *pairs*: near the optimum the true per-step decrease is
    far below fp32 resolution of the total NLL, so the plain-fp32 gate
    rejects genuinely improving steps and α collapses at the fp32 floor —
    the pair comparison keeps resolving descent ~2³⁰× finer at the same
    fp32 element compute."""
    import jax
    import jax.numpy as jnp

    p = design.num_params
    k = len(design.cont_names)
    m = max(design.total_rows, 1.0)
    avg = (design.counts @ design.cont) / m if k else np.zeros(0)
    mx = (
        np.maximum(np.abs(design.cont - avg).max(axis=0), 1e-12)
        if k
        else np.zeros(0)
    )
    cont = jnp.asarray((design.cont - avg) / mx, dtype=jnp.float32)
    counts = jnp.asarray(design.counts, dtype=jnp.float32)
    ysum = jnp.asarray(design.ysum, dtype=jnp.float32)
    oid = jnp.asarray(design.offset_ids(), dtype=jnp.int32)
    if penalty is None:
        # plain ridge stays a vector: a dense [p, p] matvec per iteration
        # (and the matrix itself) would be O(p²) for nothing on the large-p
        # workloads this solver exists for
        ridge_vec = (
            jnp.full((p,), cfg.ridge, dtype=jnp.float32).at[0].set(0.0)
        )

        def pen_grad(theta):
            return ridge_vec * theta

        def pen_quad(theta):
            return 0.5 * cfg.ridge * jnp.sum(theta[1:] ** 2)

    else:
        pen_mat = jnp.asarray(penalty, dtype=jnp.float32)

        def pen_grad(theta):
            return pen_mat @ theta

        def pen_quad(theta):
            return 0.5 * theta @ (pen_mat @ theta)

    family = cfg.family
    has_cat = bool(design.cat_names)
    if cfg.gd_accum not in ("fp32", "pairs"):
        raise ValueError(f"unknown gd_accum {cfg.gd_accum!r}")
    pairs = cfg.gd_accum == "pairs"

    def nll_grad(theta):
        """Returns (nll_hi, nll_lo, g): the penalized NLL as a two-float
        pair (lo ≡ 0 on the plain fp32 path) plus the gradient."""
        eta = theta[0] + cont @ theta[1 : 1 + k]
        if has_cat:
            eta = eta + jnp.take(theta, oid).sum(axis=1)
        if family == "logistic":
            grad_eta = counts * jax.nn.sigmoid(eta) - ysum
            terms = counts * jax.nn.softplus(eta) - ysum * eta
        else:
            mu = jnp.exp(jnp.minimum(eta, 30.0))
            grad_eta = counts * mu - ysum
            terms = counts * mu - ysum * eta
        g = jnp.zeros((p,), dtype=theta.dtype)
        if pairs:
            nll_hi, nll_lo = _pairwise_sum2(terms)
            g0_hi, g0_lo = _pairwise_sum2(grad_eta)
            g = g.at[0].set(g0_hi + g0_lo)
            if k:
                gc_hi, gc_lo = _pairwise_sum2(cont * grad_eta[:, None])
                g = g.at[1 : 1 + k].set(gc_hi + gc_lo)
        else:
            nll_hi, nll_lo = jnp.sum(terms), jnp.zeros((), terms.dtype)
            g = g.at[0].set(grad_eta.sum())
            g = g.at[1 : 1 + k].set(cont.T @ grad_eta)
        if has_cat:
            g = g.at[oid].add(grad_eta[:, None])
        g = g + pen_grad(theta)
        pen = pen_quad(theta)
        nll_hi, err = _two_sum(nll_hi, pen)
        return nll_hi, nll_lo + err, g

    def cond(carry):
        _, _, _, _, alpha, it, converged = carry
        return (~converged) & (it < cfg.gd_max_iter) & (alpha > 1e-15)

    def body(carry):
        # carry holds (nll pair, g) AT theta, so each step costs ONE
        # nll_grad: the candidate's evaluation becomes the next step's
        # current one.
        theta, nll_hi, nll_lo, g, alpha, it, _ = carry
        cand = theta - alpha * g / m
        nh_c, nl_c, g_c = nll_grad(cand)
        # pair comparison: (nh_c + nl_c) < (nh + nl) evaluated on the
        # residuals so the lo parts are not absorbed by the hi rounding
        ok = (nh_c - nll_hi) + (nl_c - nll_lo) < 0.0
        theta_new = jnp.where(ok, cand, theta)
        nh_new = jnp.where(ok, nh_c, nll_hi)
        nl_new = jnp.where(ok, nl_c, nll_lo)
        g_new = jnp.where(ok, g_c, g)
        alpha_new = jnp.where(ok, alpha * 1.05, alpha / 3.0)
        converged = jnp.sum(jnp.abs(g_new)) / m < cfg.gd_eps
        return theta_new, nh_new, nl_new, g_new, alpha_new, it + 1, converged

    theta0 = jnp.zeros((p,), dtype=jnp.float32)
    nh0, nl0, g0 = nll_grad(theta0)
    carry = (
        theta0,
        nh0,
        nl0,
        g0,
        jnp.asarray(cfg.gd_alpha0, jnp.float32),
        jnp.asarray(0, jnp.int32),
        jnp.asarray(False),
    )
    theta, _, _, _, alpha, it, converged = jax.lax.while_loop(
        cond, body, carry
    )
    theta_np = np.asarray(theta, dtype=np.float64)
    if k:  # invert the internal scaling: η is identical by construction
        theta_np[0] -= float((theta_np[1 : 1 + k] / mx) @ avg)
        theta_np[1 : 1 + k] /= mx
    _, _, nll = _family_stats(
        family, design.linpred(theta_np), design.counts, design.ysum
    )
    if penalty is None:
        pen_final = _penalty(cfg, theta_np)
    else:
        pen_final = 0.5 * float(theta_np @ (penalty @ theta_np))
    return GLMResult(
        theta=theta_np,
        iterations=int(it),
        converged=bool(converged),
        nll=nll + pen_final,
        config=cfg,
        names=design.param_names(),
    )


def fit_glm_onehot(
    x: np.ndarray, y: np.ndarray, config: Optional[GLMConfig] = None
) -> GLMResult:
    """Dense one-hot baseline: Newton over the materialized [m, p-1] design
    (intercept added internally).  The oracle the compressed path must match
    — and the memory/runtime wall it avoids.

    Implemented as the degenerate compression: one group per ROW (counts
    all ones, any one-hot columns treated as plain continuous features), so
    both sides of every oracle comparison run the SAME ``_fit_irls`` loop
    and the comparison isolates exactly what the compressed path adds —
    grouping and the sparse categorical gather/scatter."""
    cfg = config or GLMConfig()
    m, k = x.shape
    design = CompressedDesign(
        cont=x.astype(np.float64),
        cat_ids=np.zeros((m, 0), dtype=np.int64),
        counts=np.ones(m, dtype=np.float64),
        ysum=np.asarray(y, dtype=np.float64),
        cont_names=[f"x{i}" for i in range(k)],
        cat_names=[],
        domains={},
        label="y",
    )
    return _fit_irls(design, cfg)


# ---------------------------------------------------------------------------
# Pipeline + prediction
# ---------------------------------------------------------------------------

def glm_predict_raw(
    theta: np.ndarray,
    cont: np.ndarray,
    cat_ids: np.ndarray,
    design: CompressedDesign,
    family: str,
) -> np.ndarray:
    """Mean response for raw feature columns (cont [n, k], cat_ids [n, c])
    under the layout of ``design``.  ``family`` is required — pass the one
    the model was trained with (``GLMResult.config.family``); a silent
    default would make a Poisson model predict through a sigmoid."""
    k = len(design.cont_names)
    eta = theta[0] + cont @ theta[1 : 1 + k]
    if design.cat_names:
        oid = cat_ids.astype(np.int64) + design.cat_offsets()[None, :]
        eta = eta + theta[oid].sum(axis=1)
    if family == "logistic":
        return _sigmoid(eta)
    if family == "poisson":
        return np.exp(eta)
    raise ValueError(f"unknown GLM family {family!r}")


def _fd_layout(design: CompressedDesign):
    """(attr, offset, width) of each kept categorical block inside θ —
    the layout handle ``repro.core.fd``'s shared penalty/recovery helpers
    consume."""
    offs = design.cat_offsets()
    return [
        (c, int(offs[i]), design.domains[c])
        for i, c in enumerate(design.cat_names)
    ]


def _fd_penalty_matrix(design: CompressedDesign, red, ridge: float) -> np.ndarray:
    """Generalized ridge over the reduced design's θ layout: plain ridge on
    continuous coordinates and on kept blocks without dependents, the
    per-root ``(I + Σ RᵀR)^{-1}`` block (scaled by ridge) on roots that
    absorbed dropped attributes, zero on the intercept."""
    from .fd import apply_penalty_blocks

    p = design.num_params
    pen = np.full(p, ridge)
    pen[0] = 0.0
    return apply_penalty_blocks(np.diag(pen), red, _fd_layout(design), ridge)


def _fd_expand_result(
    res: GLMResult, design: CompressedDesign, red, full_domains: Dict[str, int]
) -> GLMResult:
    """Recover the dropped attributes' coefficients in closed form and
    re-assemble θ/names in the FULL categorical layout — indistinguishable
    from an unreduced fit."""
    from .fd import recover_theta_blocks

    k = len(design.cont_names)
    parts = [res.theta[: 1 + k]]
    names = ["intercept"] + list(design.cont_names)
    for c, blk in recover_theta_blocks(
        res.theta, red, _fd_layout(design), full_domains
    ):
        parts.append(blk)
        names.extend(f"{c}={g}" for g in range(len(blk)))
    res.theta = np.concatenate(parts)
    res.names = names
    return res


def glm_regression(
    store: Store,
    vorder: Optional[VariableOrder],
    cont: Sequence[str],
    cat: Sequence[str],
    label: str,
    config: Optional[GLMConfig] = None,
    factorized: bool = True,
    backend: str = "numpy",
    use_fds: bool = True,
) -> GLMResult:
    """End-to-end GLM training: compress the join (factorized GROUP BY or
    materialized oracle), then fit — the ``linear_regression`` analogue for
    the categorical/GLM workload.

    ``use_fds=True`` (the default; a no-op unless FDs are registered on the
    store) trains over the FD-reduced parameter space: functionally
    determined categorical attributes are dropped from the GROUP BY and
    from θ (the compression yields the same groups — the dropped ids are a
    function of the kept ones — but IRLS factors a strictly smaller
    Hessian), the ridge becomes the generalized per-root penalty, and the
    dropped coefficients are recovered in closed form afterwards, so the
    returned θ/names match the full fit exactly."""
    cfg = config or GLMConfig()
    cont, cat = list(cont), list(cat)
    red = store.fd_reduction(cat) if use_fds else None
    if red is not None and red.is_trivial:
        red = None
    fit_cat = list(red.kept) if red is not None else cat
    t0 = time.perf_counter()
    if factorized:
        if vorder is None:
            raise ValueError("factorized mode requires a variable order")
        design = compressed_design_factorized(
            store, vorder, cont, fit_cat, label, backend=backend
        )
    else:
        design = compressed_design_materialized(store, cont, fit_cat, label)
    t1 = time.perf_counter()
    penalty = (
        _fd_penalty_matrix(design, red, cfg.ridge) if red is not None else None
    )
    res = fit_glm(design, cfg, penalty=penalty)
    if red is not None:
        full_domains = {c: store.attr_domain(c) for c in red.order}
        res = _fd_expand_result(res, design, red, full_domains)
    res.seconds_compress = t1 - t0
    return res
