"""Functional dependencies: catalog, reduction, and closed-form recovery.

Abo Khamis et al. ("Learning Models over Relational Data using Sparse
Tensors and Functional Dependencies") and AC/DC observe that a functional
dependency ``f → g`` between dictionary-encoded attributes makes the whole
one-hot block of ``g`` *redundant*: on every join row the one-hot vector of
``g`` is a fixed linear image of the one-hot vector of ``f``,

    x_g = R x_f          R[j, i] = 1  iff  map[i] = j,

so the model can be reparametrized onto the strictly smaller space

    gamma_f = theta_f + R^T theta_g        (theta_g dropped entirely)

without changing any prediction.  The fit term of least squares and of
every GLM depends on theta only through the linear predictor, hence only
through gamma — training can run over the reduced parameters, with the
engine issuing **fewer GROUP BY queries** (no per-``g`` vector, no pair
involving ``g``) and the solver factoring a **smaller Gram/Hessian**.

The ridge penalty does see the split.  Minimizing
``||theta_f||^2 + ||theta_g||^2`` subject to the reparametrization, for a
fixed gamma, is a tiny quadratic program with the closed-form solution

    theta_g = (I + R R^T)^{-1} R gamma
    theta_f = gamma - R^T theta_g

and residual penalty ``gamma^T (I + R^T R)^{-1} gamma``.  Training over
gamma with the *generalized* ridge ``lambda * (I + R^T R)^{-1}`` on the
reduced block and recovering the dropped coefficients with the formulas
above is therefore **exactly** equivalent to the full solve — the
coefficients match to numerical precision, not approximately.

This module is deliberately free of engine imports (the ``Store`` owns the
catalog; ``categorical``/``regression``/``glm`` consume reductions), so it
sits below everything else in the dependency order:

* verification     — :func:`witnessed_mapping` / :func:`extend_mapping`
                     build ``map`` arrays from relations that contain both
                     attributes (every natural-join row projects into such
                     a relation, so a per-relation check is join-sound).
* reduction        — :func:`reduction_plan` picks, per categorical list,
                     which attributes are functionally determined by an
                     earlier one (FD chains compose) and carries the maps.
* penalty/recovery — :func:`penalty_blocks` (the generalized ridge blocks)
                     and :func:`recover_blocks` (the closed form above,
                     with all dependents of one root solved jointly).
* expansion        — :func:`expand_cat_cofactors` reconstructs the *full*
                     categorical cofactor blocks from the reduced ones,
                     purely through the FD maps (used by tests and by
                     callers that need the assembled full matrix).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from .categorical import CatCofactors
    from .relation import Relation

__all__ = [
    "FDReduction",
    "FunctionalDependency",
    "apply_penalty_blocks",
    "compose_maps",
    "expand_cat_cofactors",
    "extend_mapping",
    "penalty_blocks",
    "recover_blocks",
    "recover_theta_blocks",
    "reduction_plan",
    "witnessed_mapping",
]


@dataclasses.dataclass
class FunctionalDependency:
    """``lhs → rhs`` with its witnessed id mapping.

    ``mapping[i]`` is the rhs dictionary id determined by lhs id ``i``, or
    −1 when id ``i`` never co-occurs with rhs in any witnessing relation
    (such ids cannot survive the natural join, so −1 entries never carry
    data).  ``source`` records how the FD entered the catalog: declared
    FDs are contracts (violating them is an error), inferred FDs are
    data-derived and silently dropped when an append falsifies them.
    """

    lhs: str
    rhs: str
    mapping: np.ndarray  # int64 [D_lhs]
    source: str  # "declared" | "inferred"


@dataclasses.dataclass
class FDReduction:
    """The reduction of one categorical attribute list under an FD catalog.

    ``order``   : the caller's full categorical list (solution layout).
    ``kept``    : the subsequence actually aggregated/solved over.
    ``dropped`` : attr -> (kept root, map root-id -> attr-id); chains are
                  pre-composed onto a kept root.
    ``domains`` : full dictionary domain of every attribute in ``order``.
    """

    order: List[str]
    kept: List[str]
    dropped: Dict[str, Tuple[str, np.ndarray]]
    domains: Dict[str, int]

    @property
    def is_trivial(self) -> bool:
        return not self.dropped

    def signature(self) -> tuple:
        """Hashable structural identity — which attributes are dropped via
        which roots.  Deliberately excludes the map *contents*: appends may
        extend a mapping with new ids without changing the reduction, and
        cached reduced aggregates stay valid under such extensions (the
        reduced blocks never depend on the maps; only expansion/recovery
        do, and they read the then-current maps)."""
        return (
            tuple(self.kept),
            tuple((g, self.dropped[g][0]) for g in self.order if g in self.dropped),
        )

    def root_deps(self) -> Dict[str, List[str]]:
        """kept root -> its dropped dependents (in ``order`` order)."""
        out: Dict[str, List[str]] = {}
        for g in self.order:
            if g in self.dropped:
                out.setdefault(self.dropped[g][0], []).append(g)
        return out


# ---------------------------------------------------------------------------
# Verification
# ---------------------------------------------------------------------------

def extend_mapping(mapping: np.ndarray, l: np.ndarray, r: np.ndarray) -> bool:
    """Fold observed ``(l, r)`` id pairs into ``mapping`` in place.

    Returns False (mapping only partially extended — callers must work on
    a copy) when the pairs conflict with each other or with existing
    entries; True when ``l → r`` remains a function.
    """
    if len(l) == 0:
        return True
    order = np.lexsort((r, l))
    ls, rs = l[order], r[order]
    same_l = ls[1:] == ls[:-1]
    if np.any(same_l & (rs[1:] != rs[:-1])):
        return False
    uniq_l, first = np.unique(ls, return_index=True)
    uniq_r = rs[first]
    cur = mapping[uniq_l]
    if np.any((cur >= 0) & (cur != uniq_r)):
        return False
    mapping[uniq_l] = np.where(cur >= 0, cur, uniq_r)
    return True


def witnessed_mapping(
    relations: Iterable["Relation"],
    lhs: str,
    rhs: str,
    domain: int,
) -> Optional[np.ndarray]:
    """Verify ``lhs → rhs`` against every relation containing both as key
    attributes; return the mapping, or None when no relation witnesses the
    pair or any witness violates functionality.

    Soundness for the join: every natural-join row, projected onto a
    witnessing relation's attributes, IS a tuple of that relation — so an
    FD that holds in each witness holds on the full join result.
    """
    mapping = np.full(max(int(domain), 1), -1, dtype=np.int64)
    witnessed = False
    for rel in relations:
        if lhs not in rel.keys or rhs not in rel.keys:
            continue
        witnessed = True
        l = rel.keys[lhs].astype(np.int64)
        r = rel.keys[rhs].astype(np.int64)
        if len(l) and int(l.max()) >= len(mapping):
            grown = np.full(int(l.max()) + 1, -1, dtype=np.int64)
            grown[: len(mapping)] = mapping
            mapping = grown
        if not extend_mapping(mapping, l, r):
            return None
    return mapping if witnessed else None


def compose_maps(m1: np.ndarray, m2: np.ndarray) -> np.ndarray:
    """``f → g`` composed with ``g → h``: out[i] = m2[m1[i]], −1-propagating."""
    out = np.full(len(m1), -1, dtype=np.int64)
    valid = (m1 >= 0) & (m1 < len(m2))
    out[valid] = m2[m1[valid]]
    return out


# ---------------------------------------------------------------------------
# Reduction planning
# ---------------------------------------------------------------------------

def _fd_adjacency(
    fds: Dict[Tuple[str, str], FunctionalDependency]
) -> Dict[str, List[Tuple[str, np.ndarray]]]:
    """lhs -> [(rhs, mapping)] — built once per plan, shared by every
    BFS (``reduction_plan`` probes |kept|·|order| pairs; rebuilding the
    adjacency inside each probe made planning quadratic in catalog size)."""
    adj: Dict[str, List[Tuple[str, np.ndarray]]] = {}
    for (l, r), fd in fds.items():
        adj.setdefault(l, []).append((r, fd.mapping))
    return adj


def _path_map(
    adj: Dict[str, List[Tuple[str, np.ndarray]]], src: str, dst: str
) -> Optional[np.ndarray]:
    """Composed map along any FD path src → … → dst (BFS, shortest first)."""
    frontier: List[Tuple[str, Optional[np.ndarray]]] = [(src, None)]
    seen = {src}
    while frontier:
        nxt: List[Tuple[str, Optional[np.ndarray]]] = []
        for node, acc in frontier:
            for r, m in adj.get(node, ()):
                composed = m if acc is None else compose_maps(acc, m)
                if r == dst:
                    return composed
                if r not in seen:
                    seen.add(r)
                    nxt.append((r, composed))
        frontier = nxt
    return None


def reduction_plan(
    fds: Dict[Tuple[str, str], FunctionalDependency],
    order: Sequence[str],
    domains: Dict[str, int],
) -> FDReduction:
    """Plan the reduction of ``order`` under the catalog: scan in order,
    keeping an attribute unless an already-kept one determines it (possibly
    through an FD chain whose intermediates need not be in ``order``).
    Scanning in order makes earlier attributes the canonical roots, so two
    attributes that determine each other (a bijection) keep the first and
    drop the second."""
    order = list(order)
    adj = _fd_adjacency(fds)
    kept: List[str] = []
    dropped: Dict[str, Tuple[str, np.ndarray]] = {}
    for attr in order:
        root: Optional[Tuple[str, np.ndarray]] = None
        for k in kept:
            m = _path_map(adj, k, attr)
            if m is not None:
                d_k = int(domains[k])
                if len(m) < d_k:
                    m = np.concatenate(
                        [m, np.full(d_k - len(m), -1, dtype=np.int64)]
                    )
                root = (k, m[:d_k])
                break
        if root is not None:
            dropped[attr] = root
        else:
            kept.append(attr)
    return FDReduction(
        order=order,
        kept=kept,
        dropped=dropped,
        domains={a: int(domains[a]) for a in order},
    )


# ---------------------------------------------------------------------------
# Generalized ridge + closed-form recovery
# ---------------------------------------------------------------------------

def _onehot_map(m: np.ndarray, d_dep: int) -> np.ndarray:
    """V [D_root, D_dep] with V[i, m[i]] = 1 on valid entries (V = R^T)."""
    v = np.zeros((len(m), d_dep), dtype=np.float64)
    valid = np.nonzero(m >= 0)[0]
    v[valid, m[valid]] = 1.0
    return v


def penalty_blocks(red: FDReduction) -> Dict[str, np.ndarray]:
    """Per-root generalized ridge blocks: root f -> (I + Σ_g R_g^T R_g)^{-1}.

    Solving over gamma with ``ridge * P_f`` on the root block (plain ridge
    elsewhere) makes the reduced problem *exactly* the full ridge problem
    after the inner minimization over the dropped coefficients — see the
    module docstring.  Roots without dependents are absent (plain ridge).
    """
    out: Dict[str, np.ndarray] = {}
    for root, deps in red.root_deps().items():
        d_f = red.domains[root]
        m_sum = np.zeros((d_f, d_f), dtype=np.float64)
        for g in deps:
            v = _onehot_map(red.dropped[g][1], red.domains[g])
            m_sum += v @ v.T
        out[root] = np.linalg.inv(np.eye(d_f) + m_sum)
    return out


def recover_blocks(
    gamma: Dict[str, np.ndarray], red: FDReduction
) -> Dict[str, np.ndarray]:
    """Closed-form recovery of every attribute's coefficients from the
    reduced solution.

    ``gamma`` maps each kept attribute to its reduced coefficient block;
    the result maps every attribute in ``red.order`` to its full-model
    block: dropped attributes via theta_g = (I + R R^T)^{-1} R gamma (all
    dependents of one root solved jointly — their cross-terms R_g R_h^T
    are not diagonal), kept roots via theta_f = gamma - R^T theta_g.
    """
    def _norm(f: str) -> np.ndarray:
        g = np.asarray(gamma[f], dtype=np.float64)
        d_f = red.domains[f]
        if len(g) < d_f:  # solver saw a smaller (pre-append) domain
            g = np.concatenate([g, np.zeros(d_f - len(g))])
        return g.copy()

    out: Dict[str, np.ndarray] = {f: _norm(f) for f in red.kept}
    for root, deps in red.root_deps().items():
        g_f = out[root]
        vs = [_onehot_map(red.dropped[g][1], red.domains[g]) for g in deps]
        r_stack = np.concatenate([v.T for v in vs], axis=0)  # [ΣD_g, D_f]
        a = np.eye(r_stack.shape[0]) + r_stack @ r_stack.T
        theta_deps = np.linalg.solve(a, r_stack @ g_f)
        out[root] = g_f - r_stack.T @ theta_deps
        off = 0
        for g in deps:
            d_g = red.domains[g]
            out[g] = theta_deps[off : off + d_g]
            off += d_g
    return out


def apply_penalty_blocks(
    pen: np.ndarray,
    red: FDReduction,
    layout: Sequence[Tuple[str, int, int]],
    ridge: float,
) -> np.ndarray:
    """Overwrite the kept-root diagonal blocks of a base penalty matrix
    with the generalized ridge.

    ``pen`` is the caller's plain-ridge base (any square slice of the θ
    layout); ``layout`` gives ``(attr, offset, width)`` for each KEPT
    categorical block inside it.  Roots without dependents keep the base
    penalty.  A width that drifted from the reduction-time domain (an
    append grew it) embeds the block into an identity — uncovered ids
    have no dependents, so plain ridge is exact for them.  Shared by the
    linear-regression and GLM solvers so the subtle part lives once.
    """
    blocks = penalty_blocks(red)
    for attr, off, width in layout:
        blk = blocks.get(attr)
        if blk is None:
            continue
        if blk.shape[0] != width:
            emb = np.eye(width)
            k = min(width, blk.shape[0])
            emb[:k, :k] = blk[:k, :k]
            blk = emb
        pen[off : off + width, off : off + width] = ridge * blk
    return pen


def recover_theta_blocks(
    theta: np.ndarray,
    red: FDReduction,
    layout: Sequence[Tuple[str, int, int]],
    full_domains: Dict[str, int],
) -> List[Tuple[str, np.ndarray]]:
    """Closed-form recovery from a solved reduced θ vector.

    ``layout`` locates each kept block inside ``theta`` (same triples as
    :func:`apply_penalty_blocks`); the result lists ``(attr, block)`` for
    EVERY attribute in ``red.order``, each block padded to
    ``full_domains[attr]`` (a solver may have seen a smaller pre-append
    domain).  The caller splices them into its own full layout.
    """
    gamma = {attr: theta[off : off + width] for attr, off, width in layout}
    blocks = recover_blocks(gamma, red)
    out: List[Tuple[str, np.ndarray]] = []
    for attr in red.order:
        blk = blocks[attr]
        d = int(full_domains[attr])
        if len(blk) < d:
            blk = np.concatenate([blk, np.zeros(d - len(blk))])
        out.append((attr, blk))
    return out


# ---------------------------------------------------------------------------
# Aggregate-level expansion
# ---------------------------------------------------------------------------

def expand_cat_cofactors(cof: "CatCofactors", red: FDReduction) -> "CatCofactors":
    """Reconstruct the FULL categorical cofactors from reduced ones.

    Every block of a dropped attribute ``g`` (root ``f``) is a deterministic
    image of a kept block under the FD map — per-category counts/sums
    aggregate along the map, pair blocks re-coordinate through it — so the
    expansion touches no data, only the already-computed reduced aggregates:
    O(D_f + nnz) per block.
    """
    from .categorical import CatCofactors, SparseCounts, coalesce_counts

    if red.is_trivial:
        return cof
    if list(cof.cat) != list(red.kept):
        raise ValueError(
            f"reduced cofactors cover {cof.cat}, reduction kept {red.kept}"
        )
    domains = {}
    for a in red.order:
        domains[a] = (
            max(red.domains[a], cof.domains[a])
            if a in cof.domains
            else red.domains[a]
        )

    def checked_map(attr: str) -> Tuple[str, np.ndarray]:
        if attr in red.dropped:
            root, m = red.dropped[attr]
        else:  # kept: identity over the (possibly append-grown) domain
            root, m = attr, np.arange(domains[attr], dtype=np.int64)
        d_root = domains[root]
        if len(m) < d_root:  # append grew the root domain past the map
            m = np.concatenate([m, np.full(d_root - len(m), -1, np.int64)])
        counts = cof.cat_count[root]
        bad = (m[: len(counts)] < 0) & (counts != 0)
        if np.any(bad):
            raise ValueError(
                f"FD map {root}→{attr} lacks entries for observed "
                f"categories {np.nonzero(bad)[0].tolist()[:5]}"
            )
        return root, m

    cat_count: Dict[str, np.ndarray] = {}
    cat_cont: Dict[str, np.ndarray] = {}
    for a in red.order:
        if a in red.kept:
            cat_count[a] = cof.cat_count[a]
            cat_cont[a] = cof.cat_cont[a]
            continue
        root, m = checked_map(a)
        counts = cof.cat_count[root]
        sums = cof.cat_cont[root]
        valid = np.nonzero(m[: len(counts)] >= 0)[0]
        tgt = m[valid]
        cc = np.zeros(domains[a], dtype=np.float64)
        np.add.at(cc, tgt, counts[valid])
        cs = np.zeros((domains[a], sums.shape[1]), dtype=np.float64)
        np.add.at(cs, tgt, sums[valid])
        cat_count[a] = cc
        cat_cont[a] = cs

    def root_pair_coo(ra: str, rb: str) -> SparseCounts:
        """COO of the (ra, rb) kept pair, oriented rows=ra, cols=rb."""
        if (ra, rb) in cof.cat_cat:
            return cof.cat_cat[(ra, rb)]
        coo = cof.cat_cat[(rb, ra)]
        return SparseCounts(
            coo.cols, coo.rows, coo.vals, (coo.shape[1], coo.shape[0])
        )

    cat_cat: Dict[Tuple[str, str], SparseCounts] = {}
    for i in range(len(red.order)):
        for j in range(i + 1, len(red.order)):
            a, b = red.order[i], red.order[j]
            if a not in red.dropped and b not in red.dropped:
                # kept-kept: the stored COO is already canonical — no
                # identity-map re-coalesce needed (kept preserves the
                # relative order of red.order, so orientation matches)
                cat_cat[(a, b)] = cof.cat_cat[(a, b)]
                continue
            root_a, m_a = checked_map(a)
            root_b, m_b = checked_map(b)
            shape = (domains[a], domains[b])
            if root_a == root_b:
                # joint distribution of (a, b) is carried entirely by the
                # shared root's per-category counts
                counts = cof.cat_count[root_a]
                n = len(counts)
                valid = np.nonzero((m_a[:n] >= 0) & (m_b[:n] >= 0))[0]
                cat_cat[(a, b)] = coalesce_counts(
                    m_a[valid], m_b[valid], counts[valid], shape
                )
            else:
                coo = root_pair_coo(root_a, root_b)
                rows = m_a[coo.rows]
                cols = m_b[coo.cols]
                keep = (rows >= 0) & (cols >= 0)
                if np.any(~keep & (coo.vals != 0)):
                    raise ValueError(
                        f"FD maps for ({a}, {b}) lack entries for observed "
                        "co-occurrences"
                    )
                cat_cat[(a, b)] = coalesce_counts(
                    rows[keep], cols[keep], coo.vals[keep], shape
                )
    return CatCofactors(
        count=cof.count,
        lin=cof.lin,
        quad=cof.quad,
        cont=list(cof.cont),
        cat=list(red.order),
        domains=domains,
        cat_count=cat_count,
        cat_cont=cat_cont,
        cat_cat=cat_cat,
    )
