"""Distributed cofactor computation — the paper's algebra as the mesh plan.

Proposition 4.1's *commutativity with union* — partition the data, compute
per-partition cofactors, sum — **is** data parallelism.  This module maps it
onto a JAX device mesh:

* each ``data``-axis shard holds a horizontal partition of the (largest)
  fact relation plus replicas of the small dimension relations — the layout
  a distributed in-memory DBMS would choose;
* every shard runs the same Gram/cofactor computation on its rows;
* one ``psum`` over the ``data`` axis (and ``pod`` axis when present)
  produces the global cofactor matrix.  The matrix is tiny (p×p, p = #feats
  + 2), so the collective is latency- not bandwidth-bound.

``sharded_gram`` is the shard_map building block; ``sharded_cofactors``
applies it to a partitioned design matrix.  ``partitioned_cofactors_host``
demonstrates the same algebra without a mesh (host-side partition + sum) and
is used by tests as the oracle.

Incremental maintenance composes with the same algebra: an *append* of new
rows Δ is a union, so ``incremental_sharded_cofactors`` computes the delta
cofactors of Δ per shard (one psum) and folds them into the previous global
cofactors with ``Cofactors.__add__`` — no rescan of the historical data.

View-cache independence: the sharded paths consume already-extracted
arrays, so they are agnostic to the store's persistent per-node view cache
— results are bit-identical with the cache on or off (tested in
``tests/test_sharding.py``).  The two maintenance schemes agree by
Prop. 4.1: a store whose caches were delta-maintained under ``append`` and
a sharded fold of the same delta arrays land on the same cofactors, which
is what lets a mesh fold the deltas while the store keeps the factorized
views warm for the next retrain.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from .categorical import CatCofactors, SparseCounts, cat_cofactors_from_arrays
from .factorize import Cofactors

__all__ = [
    "sharded_gram",
    "sharded_cofactors",
    "sharded_cat_cofactors",
    "partitioned_cofactors_host",
    "incremental_sharded_cofactors",
    "incremental_sharded_cat_cofactors",
]


def _gram_local(z: jnp.ndarray) -> jnp.ndarray:
    """Local Gram of one shard; fp32 accumulation."""
    return z.T @ z


def sharded_gram(z: jnp.ndarray, mesh: Mesh, data_axes: Sequence[str]):
    """Global Gram Z^T Z with rows sharded over ``data_axes`` of ``mesh``.

    The per-shard Gram is followed by a single psum — the paper's
    union-commutativity, executed as a collective.
    """
    axes = tuple(data_axes)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=P(axes, None),
        out_specs=P(),  # replicated result
    )
    def _fn(z_local):
        return jax.lax.psum(_gram_local(z_local), axes)

    return _fn(z)


def sharded_cofactors(
    z: np.ndarray,
    features: Sequence[str],
    mesh: Mesh,
    data_axes: Sequence[str] = ("data",),
) -> Cofactors:
    """Cofactors of a design matrix ``z`` (WITHOUT intercept column) sharded
    over the mesh's data axes.  Pads rows with zeros to a shard multiple —
    zero rows contribute nothing to any cofactor (union with empty data)."""
    nshards = 1
    for a in data_axes:
        nshards *= mesh.shape[a]
    m, k = z.shape
    pad = (-m) % nshards
    if pad:
        z = np.concatenate([z, np.zeros((pad, k), dtype=z.dtype)], axis=0)
    # prepend the intercept column: zeros on padded rows would corrupt the
    # count, so build it explicitly with the true-row indicator.
    ones = np.concatenate([np.ones((m,)), np.zeros((pad,))])[:, None]
    zz = np.concatenate([ones, z], axis=1).astype(np.float32)
    sharding = NamedSharding(mesh, P(tuple(data_axes), None))
    zz_dev = jax.device_put(jnp.asarray(zz), sharding)
    gram = np.asarray(sharded_gram(zz_dev, mesh, data_axes), dtype=np.float64)
    return Cofactors(
        count=float(gram[0, 0]),
        lin=gram[0, 1:],
        quad=gram[1:, 1:],
        features=list(features),
    )


def incremental_sharded_cofactors(
    base: Cofactors,
    z_delta: np.ndarray,
    mesh: Optional[Mesh] = None,
    data_axes: Sequence[str] = ("data",),
) -> Cofactors:
    """Fold an appended row batch into existing global cofactors.

    ``base`` holds the cofactors of all rows seen so far; ``z_delta`` is the
    design matrix (WITHOUT intercept column) of the newly appended rows only.
    The delta cofactors are computed over the mesh when one is given (each
    shard sees a horizontal slice of Δ, one psum reduces them) and on the
    host otherwise; union commutativity makes ``base + delta`` exact.

    Precision: the mesh path accumulates each delta in fp32 on-device
    (~1e-7 relative per delta), so its rounding flows into the long-lived
    base — the host path (``mesh=None``) is fp64 and matches the fp64
    maintenance policy of ``Store.append``.  Prefer the host path for
    accumulators that must survive many appends; use the mesh path when
    delta volume, not accumulation lifetime, is the bottleneck.
    """
    if z_delta.shape[0] == 0:
        return base
    if mesh is None:
        delta = partitioned_cofactors_host(z_delta, base.features, 1)
    else:
        delta = sharded_cofactors(z_delta, base.features, mesh, data_axes)
    return base + delta


def sharded_cat_cofactors(
    x_cont: np.ndarray,
    cat_ids: np.ndarray,
    cont: Sequence[str],
    cat: Sequence[str],
    domains: dict,
    mesh: Mesh,
    data_axes: Sequence[str] = ("data",),
    fd=None,  # Optional[repro.core.fd.FDReduction]
) -> CatCofactors:
    """Categorical cofactors with rows sharded over the mesh's data axes.

    ``fd`` (an ``FDReduction`` over ``cat``) drops functionally-determined
    attributes *before* the multi-hot block is built: the concatenated
    one-hot width shrinks from Σ D_all to Σ D_kept, shrinking both fused
    matmuls and all three psums.  The result then covers only the kept
    attributes — expand with ``repro.core.fd.expand_cat_cofactors`` when
    the full blocks are needed.

    Same union-commutativity as ``sharded_cofactors``, extended to the
    grouped blocks: every shard builds ONE concatenated multi-hot block
    H = [onehot(c₁) | … | onehot(c_n)] over its local rows (a [rows, ΣD]
    *shard* slice, never the global design matrix) and evaluates the whole
    categorical batch with two fused matmuls — H^T·[1|x] carries every
    per-category count/Σx block and H^T·H every cat×cat co-occurrence
    block — mirroring the engine's single-pass multi-output plan.  Three
    psums total (Gram, H^T·u, H^T·H) reduce the shards, independent of
    |cat|, where the pre-fusion formulation paid one matmul + psum per
    attribute plus one per pair.  Rows are padded to a shard multiple with
    id −1 — an all-zero one-hot row — so padding contributes nothing,
    mirroring the kernel's out-of-range trick.
    """
    cont, cat = list(cont), list(cat)
    if fd is not None and fd.dropped:
        kept_idx = [cat.index(c) for c in fd.kept]
        return sharded_cat_cofactors(
            x_cont,
            cat_ids[:, kept_idx],
            cont,
            list(fd.kept),
            {c: domains[c] for c in fd.kept},
            mesh,
            data_axes,
        )
    axes = tuple(data_axes)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    m, k = x_cont.shape
    pad = (-m) % nshards
    ind = np.concatenate([np.ones(m), np.zeros(pad)])[:, None]
    xz = np.concatenate([x_cont, np.zeros((pad, k))], axis=0)
    u = np.concatenate([ind, xz], axis=1).astype(np.float32)
    for i, c in enumerate(cat):
        if len(cat_ids) == 0:
            continue
        lo, hi = int(cat_ids[:, i].min()), int(cat_ids[:, i].max())
        if lo < 0 or hi >= int(domains[c]):
            raise ValueError(
                f"category ids of {c!r} span [{lo}, {hi}], outside domain "
                f"[0, {int(domains[c])}) — out-of-range one-hot rows are "
                "all zeros and would be silently dropped (negative ids are "
                "reserved for internal shard padding)"
            )
    ids = np.concatenate(
        [cat_ids, np.full((pad, len(cat)), -1)], axis=0
    ).astype(np.int32)
    doms = [int(domains[c]) for c in cat]
    offs = np.concatenate([[0], np.cumsum(doms)]).astype(int)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes, None)),
        out_specs=(P(), P(), P()),
    )
    def _fn(u_local, ids_local):
        rows = u_local.shape[0]
        hot = jnp.concatenate(
            [
                (
                    ids_local[:, i, None]
                    == jax.lax.broadcasted_iota(jnp.int32, (rows, d), 1)
                ).astype(jnp.float32)
                for i, d in enumerate(doms)
            ],
            axis=1,
        )  # [rows, ΣD] — n_cat ones per (unpadded) row
        gram = u_local.T @ u_local
        hu = hot.T @ u_local  # every [D_c, 1+k] block, one matmul
        hh = hot.T @ hot  # every cat×cat block, one matmul
        return (
            jax.lax.psum(gram, axes),
            jax.lax.psum(hu, axes),
            jax.lax.psum(hh, axes),
        )

    sharding = NamedSharding(mesh, P(axes, None))
    gram, hu, hh = _fn(
        jax.device_put(jnp.asarray(u), sharding),
        jax.device_put(jnp.asarray(ids), sharding),
    )
    gram = np.asarray(gram, dtype=np.float64)
    hu = np.asarray(hu, dtype=np.float64)
    hh = np.asarray(hh, dtype=np.float64)
    cat_count = {c: hu[offs[i] : offs[i + 1], 0] for i, c in enumerate(cat)}
    cat_cont = {c: hu[offs[i] : offs[i + 1], 1:] for i, c in enumerate(cat)}
    cat_cat = {}
    for i in range(len(cat)):
        for j in range(i + 1, len(cat)):
            cat_cat[(cat[i], cat[j])] = SparseCounts.from_dense(
                hh[offs[i] : offs[i + 1], offs[j] : offs[j + 1]]
            )
    return CatCofactors(
        count=float(gram[0, 0]),
        lin=gram[0, 1:],
        quad=gram[1:, 1:],
        cont=cont,
        cat=cat,
        domains={c: int(domains[c]) for c in cat},
        cat_count=cat_count,
        cat_cont=cat_cont,
        cat_cat=cat_cat,
    )


def incremental_sharded_cat_cofactors(
    base: CatCofactors,
    x_delta: np.ndarray,
    ids_delta: np.ndarray,
    mesh: Optional[Mesh] = None,
    data_axes: Sequence[str] = ("data",),
) -> CatCofactors:
    """Fold appended rows into existing categorical cofactors — the
    categorical twin of ``incremental_sharded_cofactors`` (same precision
    trade-off: mesh path accumulates fp32, host path fp64).  Unseen
    category ids in the delta grow the domains: the delta blocks are built
    at the grown size and ``__add__`` zero-pads ``base`` up to match."""
    if x_delta.shape[0] == 0:
        return base
    domains = {
        c: max(base.domains[c], int(ids_delta[:, i].max()) + 1)
        for i, c in enumerate(base.cat)
    }
    if mesh is None:
        delta = cat_cofactors_from_arrays(
            x_delta, ids_delta, base.cont, base.cat, domains
        )
    else:
        delta = sharded_cat_cofactors(
            x_delta, ids_delta, base.cont, base.cat, domains,
            mesh, data_axes,
        )
    return base + delta


def partitioned_cofactors_host(
    z: np.ndarray, features: Sequence[str], num_parts: int
) -> Cofactors:
    """Host-side demonstration of union commutativity (test oracle)."""
    parts = np.array_split(z, num_parts, axis=0)
    out: Optional[Cofactors] = None
    for part in parts:
        ones = np.ones((part.shape[0],))
        cof = Cofactors(
            count=float(part.shape[0]),
            lin=part.T @ ones,
            quad=part.T @ part,
            features=list(features),
        )
        out = cof if out is None else out + cof
    assert out is not None
    return out
