"""Beyond-paper: degree-d factorized **polynomial** regression.

The paper's conclusion names polynomial regression as future work: "The added
complexity increases the gain from factorized representations even more."
This module generalizes the degree-≤2 block algebra of ``factorize.py`` to
arbitrary degree d by representing each view's aggregates as a dictionary

    monomial (sorted tuple of feature names, len ≤ d)  →  [N] array

Combining children is monomial convolution (Σ over splits with total degree
≤ d), and aggregating out feature A multiplies in powers x_A^e.  The host
loops over monomial *pairs* (tiny — the data math stays vectorized), so this
path is intended for the moderate feature counts where polynomial models are
used; the dense degree-2 engine remains the fast path.

Training: a degree-d polynomial model is a *linear* model over the expanded
monomial features, so the cofactor trick applies verbatim — the cofactor
matrix over monomials-of-degree-≤d requires aggregates up to degree 2d.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .factorize import Cofactors
from .relation import group_key, join_keys, sort_merge_join
from .store import Store
from .variable_order import INTERCEPT, VariableOrder, validate

Monomial = Tuple[str, ...]  # sorted tuple of feature names, with repetition

__all__ = ["polynomial_aggregates", "polynomial_cofactors", "expand_monomials"]


@dataclasses.dataclass
class _PolyView:
    keys: Dict[str, np.ndarray]
    aggs: Dict[Monomial, np.ndarray]  # () -> count; ('x',) -> Σx; ('x','x') ...

    @property
    def num_rows(self) -> int:
        return len(next(iter(self.aggs.values())))


class _PolyEngine:
    def __init__(
        self,
        store: Store,
        vorder: VariableOrder,
        features: Sequence[str],
        degree: int,
    ) -> None:
        validate(vorder, store)
        if degree < 1:
            raise ValueError("degree must be >= 1")
        self.store = store
        self.vorder = vorder
        self.features = list(features)
        self.degree = degree
        self._encode()

    def _encode(self) -> None:
        cols: Dict[str, List[Tuple[str, np.ndarray]]] = {}
        for rn in self.vorder.relations():
            rel = self.store.get(rn)
            for attr in rel.attributes:
                cols.setdefault(attr, []).append((rn, rel.column(attr)))
        self.domains: Dict[str, int] = {}
        self.attr_values: Dict[str, np.ndarray] = {}
        self.encoded: Dict[Tuple[str, str], np.ndarray] = {}
        for attr, entries in cols.items():
            allv = np.concatenate([c.astype(np.float64) for _, c in entries])
            uniq, inv = np.unique(allv, return_inverse=True)
            self.domains[attr] = len(uniq)
            self.attr_values[attr] = uniq
            off = 0
            for rn, c in entries:
                self.encoded[(rn, attr)] = inv[off : off + len(c)].astype(np.int32)
                off += len(c)

    def run(self) -> Dict[Monomial, float]:
        view = self._process(self.vorder)
        if view.num_rows != 1:
            raise AssertionError("root view must have one row")
        return {m: float(a[0]) for m, a in view.aggs.items()}

    def _process(self, node: VariableOrder) -> _PolyView:
        if node.is_relation:
            rel = self.store.get(node.relation)
            keys = {a: self.encoded[(node.relation, a)] for a in rel.attributes}
            return _PolyView(
                keys=keys, aggs={(): np.ones((rel.num_rows,), dtype=np.float64)}
            )
        views = [self._process(ch) for ch in node.children]
        view = views[0]
        for other in views[1:]:
            view = self._combine(view, other)
        if node.name == INTERCEPT:
            return view
        if node.name in self.features:
            view = self._extend(view, node.name)
        return self._aggregate_out(view, node.name)

    def _combine(self, v1: _PolyView, v2: _PolyView) -> _PolyView:
        shared = sorted(set(v1.keys) & set(v2.keys))
        if shared:
            doms = [self.domains[a] for a in shared]
            # hash-join fallback past the int64 radix limit, same as the
            # quadratic engine's _combine and Store._join_pair
            k1, k2 = join_keys(
                [v1.keys[a] for a in shared],
                [v2.keys[a] for a in shared],
                doms,
            )
            i1, i2 = sort_merge_join(k1, k2)
        else:
            n1, n2 = v1.num_rows, v2.num_rows
            i1 = np.repeat(np.arange(n1, dtype=np.int64), n2)
            i2 = np.tile(np.arange(n2, dtype=np.int64), n1)
        keys = {a: c[i1] for a, c in v1.keys.items()}
        for a, c in v2.keys.items():
            keys.setdefault(a, c[i2])
        aggs: Dict[Monomial, np.ndarray] = {}
        for m1, a1 in v1.aggs.items():
            a1i = a1[i1]
            for m2, a2 in v2.aggs.items():
                if len(m1) + len(m2) > self.degree:
                    continue
                m = tuple(sorted(m1 + m2))
                prod = a1i * a2[i2]
                aggs[m] = aggs[m] + prod if m in aggs else prod
        return _PolyView(keys=keys, aggs=aggs)

    def _extend(self, view: _PolyView, attr: str) -> _PolyView:
        x = self.attr_values[attr][np.asarray(view.keys[attr])]
        aggs: Dict[Monomial, np.ndarray] = {}
        for m, a in view.aggs.items():
            xe = np.ones_like(x)
            for e in range(self.degree - len(m) + 1):
                mm = tuple(sorted(m + (attr,) * e))
                contrib = a * xe
                aggs[mm] = aggs[mm] + contrib if mm in aggs else contrib
                xe = xe * x
        return _PolyView(keys=view.keys, aggs=aggs)

    def _aggregate_out(self, view: _PolyView, attr: str) -> _PolyView:
        remaining = sorted(set(view.keys) - {attr})
        n = view.num_rows
        if remaining:
            doms = [self.domains[a] for a in remaining]
            # group_key: a GROUP BY only needs within-call injectivity, so
            # wide key sets densify instead of overflowing (as in factorize)
            key = group_key([view.keys[a] for a in remaining], doms)
            uniq, first, inv = np.unique(
                key, return_index=True, return_inverse=True
            )
            num = len(uniq)
            keys = {a: view.keys[a][first] for a in remaining}
            seg = inv
        else:
            seg = np.zeros((n,), dtype=np.int64)
            num, keys = 1, {}
        aggs = {}
        for m, a in view.aggs.items():
            out = np.zeros((num,), dtype=np.float64)
            np.add.at(out, seg, a)
            aggs[m] = out
        return _PolyView(keys=keys, aggs=aggs)


def polynomial_aggregates(
    store: Store,
    vorder: VariableOrder,
    features: Sequence[str],
    degree: int,
) -> Dict[Monomial, float]:
    """All SUM(Π monomial) aggregates of degree ≤ ``degree`` over the join."""
    return _PolyEngine(store, vorder, features, degree).run()


def expand_monomials(features: Sequence[str], degree: int) -> List[Monomial]:
    """All monomials of degree 1..degree over ``features`` (with repetition)."""
    out: List[Monomial] = []
    for d in range(1, degree + 1):
        out.extend(itertools.combinations_with_replacement(sorted(features), d))
    return out


def polynomial_cofactors(
    store: Store,
    vorder: VariableOrder,
    features: Sequence[str],
    label: str,
    degree: int,
) -> Cofactors:
    """Cofactor matrix for degree-d polynomial regression over the join.

    The expanded feature list is all monomials of degree ≤ d plus the label;
    entries require join aggregates up to degree 2d — computed factorized.
    """
    monos = expand_monomials(features, degree)
    aggs = polynomial_aggregates(
        store, vorder, list(features) + [label], 2 * degree
    )
    cols: List[str] = ["*".join(m) for m in monos] + [label]
    terms: List[Monomial] = monos + [(label,)]
    k = len(terms)
    lin = np.zeros((k,))
    quad = np.zeros((k, k))
    for i, mi in enumerate(terms):
        lin[i] = aggs[tuple(sorted(mi))]
        for j, mj in enumerate(terms):
            quad[i, j] = aggs[tuple(sorted(mi + mj))]
    return Cofactors(count=aggs[()], lin=lin, quad=quad, features=cols)
