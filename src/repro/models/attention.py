"""Grouped-query attention with KV caching (full, sliding-window, cross).

One attention implementation serves all assigned architectures:

* GQA / MQA: queries are reshaped to [B, S, KH, G, D] so keys/values are
  never materialized per query head (G = n_heads / n_kv_heads).
* Sliding-window attention (mixtral): banded mask in prefill; a **ring-buffer
  KV cache of size window** in decode, so `long_500k` decode holds a 4096-slot
  cache instead of a 524288-slot one.  Absolute positions are stored next to
  the ring so masking needs no modular arithmetic at lookup time.
* Cross attention (whisper decoder): keys/values from encoder states, no
  causal mask, KV computed once and cached at prefill.
* **Chunked online-softmax path** (flash-attention recurrence in pure jnp,
  ``lax.map`` over query chunks × ``lax.scan`` over KV chunks): O(S·chunk)
  memory instead of O(S²) — selected automatically above
  ``CHUNKED_THRESHOLD`` so 32k-token prefill and 4k-token training fit HBM.
  The Pallas flash kernel in ``repro.kernels.flash`` implements the same
  recurrence as a fused VMEM-tiled kernel for the TPU target.

Softmax runs in fp32 regardless of activation dtype.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rotary, dense_init, rotary_embedding

__all__ = ["attention_init", "attention_apply", "attention_decode", "init_kv_cache"]

NEG_INF = -1e30

#: Above this many score entries per (q, kv) pair the chunked path kicks in.
CHUNKED_THRESHOLD = 2048
DEFAULT_Q_CHUNK = 512
DEFAULT_K_CHUNK = 1024


def attention_init(key, cfg, cross: bool = False):
    """Projection params.  Shapes keep head axes explicit for sharding rules:
    wq [d, H, hd], wk/wv [d, KH, hd], wo [H, hd, d]."""
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, (h, hd), cfg.param_dtype),
        "wk": dense_init(ks[1], d, (kh, hd), cfg.param_dtype),
        "wv": dense_init(ks[2], d, (kh, hd), cfg.param_dtype),
        "wo": dense_init(ks[3], h * hd, d, cfg.param_dtype).reshape(h, hd, d),
    }


def _gqa_scores(q, k, scale):
    """q [B,Sq,H,D], k [B,Sk,KH,D] -> fp32 scores [B,KH,G,Sq,Sk]."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, d)
    return jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
    ) * scale


def _gqa_out(probs, v, out_dtype):
    """probs [B,KH,G,Sq,Sk], v [B,Sk,KH,D] -> [B,Sq,H,D]."""
    b, kh, g, sq, _ = probs.shape
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, sq, kh * g, v.shape[-1]).astype(out_dtype)


def _masked_softmax(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no visible key (fully masked) produce uniform garbage; zero them
    any_visible = jnp.any(mask, axis=-1, keepdims=True)
    return jnp.where(any_visible, probs, 0.0)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (flash recurrence in jnp)
# ---------------------------------------------------------------------------

def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is ≤ target (shapes here are powers of
    two, so this is just min(s, target) in practice — guarded anyway)."""
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def chunked_attention(
    q,
    k,
    v,
    qpos,
    kpos,
    *,
    causal: bool,
    window: Optional[int],
    out_dtype,
    q_chunk: int = DEFAULT_Q_CHUNK,
    k_chunk: int = DEFAULT_K_CHUNK,
    q_unroll: int = 1,
    kv_unroll: int = 1,
):
    """Online-softmax attention: q [B,Sq,H,D], k/v [B,Sk,KH,D],
    qpos [B,Sq], kpos [B,Sk] absolute positions (−1 = empty slot).

    Memory O(Sq·k_chunk) instead of O(Sq·Sk).  Returns [B,Sq,H,D].
    """
    b, sq, h, d = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    qc = _pick_chunk(sq, q_chunk)
    kc = _pick_chunk(sk, k_chunk)
    nq, nk = sq // qc, sk // kc
    scale = d**-0.5

    # [NQ, B, qc, KH, G, D] query-major so lax.map sweeps the leading axis
    qg = (
        q.reshape(b, nq, qc, kh, g, d).transpose(1, 0, 2, 3, 4, 5)
    )
    qp = qpos.reshape(b, nq, qc).transpose(1, 0, 2)  # [NQ, B, qc]
    kb = k.reshape(b, nk, kc, kh, d).transpose(1, 0, 2, 3, 4)  # [NK,B,kc,KH,D]
    vb = v.reshape(b, nk, kc, kh, d).transpose(1, 0, 2, 3, 4)
    kp = kpos.reshape(b, nk, kc).transpose(1, 0, 2)  # [NK, B, kc]

    def q_block(args):
        q_blk, qp_blk = args  # [B,qc,KH,G,D], [B,qc]
        m0 = jnp.full((b, kh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kh, g, qc, d), jnp.float32)

        # checkpointed: without this the backward pass would stash the
        # [B,KH,G,qc,kc] probabilities for EVERY (q,kv) chunk pair — the
        # exact O(S²) materialization the online-softmax recurrence exists
        # to avoid.  Recomputing one kv block per backward step keeps the
        # residual set at O(qc·kc) transients.
        @jax.checkpoint
        def kv_step(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, kp_blk = inp  # [B,kc,KH,D], [B,kc]
            s = (
                jnp.einsum(
                    "bqkgd,bskd->bkgqs",
                    q_blk,
                    k_blk,
                    preferred_element_type=jnp.float32,
                )
                * scale
            )  # [B,KH,G,qc,kc]
            qpx = qp_blk[:, None, None, :, None]
            kpx = kp_blk[:, None, None, None, :]
            mask = kpx >= 0  # skip empty slots
            if causal:
                mask = mask & (kpx <= qpx)
            if window is not None:
                mask = mask & (kpx > qpx - window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # exp(NEG_INF - NEG_INF) = 1 would corrupt fully-masked rows;
            # re-apply the mask to the probabilities instead of clamping m.
            p = jnp.exp(s - m_new[..., None]) * mask
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd",
                p.astype(v_blk.dtype),
                v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kb, vb, kp), unroll=min(kv_unroll, nk)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,KH,G,qc,D]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, d)

    def q_step(carry, args):
        return carry, jax.checkpoint(q_block)(args)

    _, out = jax.lax.scan(
        q_step, (), (qg, qp), unroll=min(q_unroll, nq)
    )  # [NQ, B, qc, H, D]
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d).astype(out_dtype)


def attention_apply(
    params,
    x,
    cfg,
    *,
    positions=None,
    causal: bool = True,
    window: Optional[int] = None,
    kv_states=None,
) -> jnp.ndarray:
    """Self (or cross, via ``kv_states``) attention over full sequences.

    x [B, S, d]; positions [B, S] absolute positions for RoPE/masking
    (defaults to arange).  Returns [B, S, d].
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    kv_src = x if kv_states is None else kv_states
    k = jnp.einsum("bsd,dke->bske", kv_src, params["wk"])
    v = jnp.einsum("bsd,dke->bske", kv_src, params["wv"])

    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.pos == "rope" and kv_states is None:
        cos, sin = rotary_embedding(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)

    sk = k.shape[1]
    chunked = max(s, sk) > CHUNKED_THRESHOLD and not cfg.dense_attention
    if chunked:  # O(S·chunk) memory path
        kpos = (
            positions
            if kv_states is None
            else jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
        )
        out = chunked_attention(
            q,
            k,
            v,
            positions,
            kpos,
            causal=causal and kv_states is None,
            window=window,
            out_dtype=x.dtype,
            # cross attention keeps the whole (short) KV in one chunk: the
            # kv scan then has length 1, which keeps the dry-run's
            # delta-correction algebra exact (see launch/dryrun.py)
            k_chunk=sk if kv_states is not None else DEFAULT_K_CHUNK,
            q_unroll=max(cfg.attn_q_unroll, 1),
            kv_unroll=max(cfg.attn_kv_unroll, 1),
        )
        return jnp.einsum("bshe,hed->bsd", out, params["wo"])

    scores = _gqa_scores(q, k, cfg.head_dim**-0.5)
    if kv_states is None:
        qpos = positions[:, None, None, :, None]
        kpos = positions[:, None, None, None, :]
        mask = kpos <= qpos if causal else jnp.ones_like(scores, dtype=bool)
        if window is not None:
            mask = mask & (kpos > qpos - window)
    else:  # cross attention: everything visible
        mask = jnp.ones((b, 1, 1, s, sk), dtype=bool)
    probs = _masked_softmax(scores, mask)
    out = _gqa_out(probs, v, x.dtype)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])


def attention_prefill(
    params,
    x,
    cfg,
    max_len: int,
    *,
    positions=None,
    window: Optional[int] = None,
):
    """Full causal self-attention that also emits the decode cache.

    Full attention: K/V land in slots [0, S) of a ``max_len`` cache.
    Sliding window: only the last ``window`` positions are retained, rolled
    so that slot p%W holds position p — exactly the decode ring layout.
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dke->bske", x, params["wk"])
    v = jnp.einsum("bsd,dke->bske", x, params["wv"])
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.pos == "rope":
        cos, sin = rotary_embedding(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)

    if s > CHUNKED_THRESHOLD and not cfg.dense_attention:
        out = chunked_attention(
            q, k, v, positions, positions,
            causal=True, window=window, out_dtype=x.dtype,
            q_unroll=max(cfg.attn_q_unroll, 1),
            kv_unroll=max(cfg.attn_kv_unroll, 1),
        )
    else:
        scores = _gqa_scores(q, k, cfg.head_dim**-0.5)
        qpos = positions[:, None, None, :, None]
        kpos = positions[:, None, None, None, :]
        mask = kpos <= qpos
        if window is not None:
            mask = mask & (kpos > qpos - window)
        probs = _masked_softmax(scores, mask)
        out = _gqa_out(probs, v, x.dtype)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])

    slots = max_len if window is None else min(window, max_len)
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    if slots >= s:  # write positions [0, s) directly
        ck = jnp.zeros((b, slots, kh, hd), cfg.dtype).at[:, :s].set(
            k.astype(cfg.dtype)
        )
        cv = jnp.zeros((b, slots, kh, hd), cfg.dtype).at[:, :s].set(
            v.astype(cfg.dtype)
        )
        cpos = jnp.full((b, slots), -1, jnp.int32).at[:, :s].set(positions)
    else:  # keep the last ``slots`` positions, ring-rolled to slot p%slots
        shift = (s - slots) % slots
        ck = jnp.roll(k[:, s - slots :].astype(cfg.dtype), shift, axis=1)
        cv = jnp.roll(v[:, s - slots :].astype(cfg.dtype), shift, axis=1)
        cpos = jnp.roll(positions[:, s - slots :], shift, axis=1)
    return out, {"k": ck, "v": cv, "pos": cpos}


# ---------------------------------------------------------------------------
# KV cache (decode path)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, batch: int, max_len: int, window: Optional[int] = None):
    """Cache pytree for one attention layer.

    Full attention: slots = max_len.  Sliding window: ring of ``window``
    slots.  ``pos`` stores each slot's absolute position (-1 = empty).
    """
    slots = max_len if window is None else min(window, max_len)
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, slots, kh, hd), cfg.dtype),
        "v": jnp.zeros((batch, slots, kh, hd), cfg.dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def attention_decode(
    params,
    x,
    cache,
    cur_pos,
    cfg,
    *,
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, dict]:
    """One decode step: x [B, 1, d], cur_pos scalar int32 (same for all rows).

    Writes the new KV at slot ``cur_pos % slots`` and attends over every
    non-empty slot whose absolute position is visible.  Returns (out, cache).
    """
    b = x.shape[0]
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k_new = jnp.einsum("bsd,dke->bske", x, params["wk"])
    v_new = jnp.einsum("bsd,dke->bske", x, params["wv"])

    pos_b = jnp.broadcast_to(cur_pos[None, None], (b, 1)).astype(jnp.int32)
    if cfg.pos == "rope":
        cos, sin = rotary_embedding(pos_b, cfg.head_dim, cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k_new = apply_rotary(k_new, cos, sin)

    slots = cache["k"].shape[1]
    slot = (cur_pos % slots).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache["pos"], pos_b, (0, slot))

    scores = _gqa_scores(q, k, cfg.head_dim**-0.5)  # [B,KH,G,1,slots]
    kpos = pos[:, None, None, None, :]
    mask = (kpos >= 0) & (kpos <= cur_pos)
    if window is not None:
        mask = mask & (kpos > cur_pos - window)
    probs = _masked_softmax(scores, mask)
    out = _gqa_out(probs, v, x.dtype)
    out = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    return out, {"k": k, "v": v, "pos": pos}


# ---------------------------------------------------------------------------
# Cross-attention decode against a precomputed (cached) encoder KV
# ---------------------------------------------------------------------------

def cross_kv(params, enc_states):
    """Precompute encoder K/V once (whisper prefill)."""
    k = jnp.einsum("bsd,dke->bske", enc_states, params["wk"])
    v = jnp.einsum("bsd,dke->bske", enc_states, params["wv"])
    return {"k": k, "v": v}


def cross_attention_decode(params, x, ckv, cfg):
    """x [B, 1, d] attends over cached encoder KV (no mask)."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    scores = _gqa_scores(q, ckv["k"], cfg.head_dim**-0.5)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, ckv["v"], x.dtype)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"])
