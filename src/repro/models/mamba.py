"""Mamba (S6) selective-state-space block — jamba's sequence mixer.

Faithful Mamba-1 structure (in_proj -> causal depthwise conv(4) -> selective
SSM -> gated out_proj) with the recurrence

    h_t = exp(dt_t · A) ⊙ h_{t-1} + (dt_t · B_t) x_t        h ∈ [d_inner, N]
    y_t = h_t · C_t + D ⊙ x_t

Training evaluates the recurrence with ``jax.lax.associative_scan`` over the
sequence (the parallel-scan formulation: elements (a, b) compose as
(a2·a1, a2·b1 + b2)) — O(log S) depth, TPU-friendly.  Decode is the O(1)
single-step recurrence carrying (conv window, h) as state.

Simplification vs the CUDA reference (documented in DESIGN.md): the fused
selective-scan kernel is replaced by the XLA associative scan; numerics are
identical in exact arithmetic.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "init_mamba_cache"]


def mamba_init(key, cfg):
    d = cfg.d_model
    di = cfg.mamba_d_inner
    n = cfg.mamba_d_state
    r = cfg.mamba_dt_rank
    kk = cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias set for softplus(dt)≈[1e-3, 0.1]
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    dt = jnp.exp(
        jax.random.uniform(ks[0], (di,), jnp.float32)
        * (jnp.log(0.1) - jnp.log(1e-3))
        + jnp.log(1e-3)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[1], d, 2 * di, cfg.param_dtype),
        "conv_w": dense_init(ks[2], kk, di, jnp.float32).T,  # [di, K]
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[3], di, r + 2 * n, cfg.param_dtype),
        "dt_proj": dense_init(ks[4], r, di, cfg.param_dtype),
        "dt_bias": dt_bias,
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, cfg.param_dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x [B,S,di], w [di,K] -> [B,S,di]."""
    k = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for i in range(k):  # K=4: unrolled shifts beat a grouped conv on TPU
        out = out + pad[:, i : i + s].astype(jnp.float32) * w[:, i]
    return (out + b).astype(x.dtype)


def _ssm_inputs(params, xc, cfg):
    """Shared between train and decode: per-step (dA, dBx, C) tensors."""
    n, r = cfg.mamba_d_state, cfg.mamba_dt_rank
    x_dbl = jnp.einsum("...si,ij->...sj", xc, params["x_proj"])
    dt_r, b_ssm, c_ssm = jnp.split(x_dbl, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...sr,ri->...si", dt_r, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"]
    )  # [B,S,di]
    a = -jnp.exp(params["A_log"])  # [di, N]
    da = jnp.exp(dt[..., None] * a)  # [B,S,di,N]
    dbx = (
        dt[..., None]
        * b_ssm[..., None, :].astype(jnp.float32)
        * xc[..., None].astype(jnp.float32)
    )  # [B,S,di,N]
    return da, dbx, c_ssm


def _combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def mamba_apply(params, x, cfg, return_state: bool = False):
    """Full-sequence forward: x [B,S,d] -> [B,S,d] (+ decode cache).

    **Chunked** evaluation: an outer ``lax.scan`` over sequence chunks
    carries (h, conv tail) while an inner ``associative_scan`` parallelizes
    within the chunk.  The naive formulation materializes the [B,S,di,N]
    decay/input tensors — 1.1 PB for jamba's train_4k cell — the chunking
    bounds the working set to [B,C,di,N] (the CUDA kernel's strategy,
    re-blocked for XLA/TPU).  Chunk size ``cfg.mamba_chunk``; falls back to
    single-chunk when S ≤ C.
    """
    b, s, _ = x.shape
    di = cfg.mamba_d_inner
    kk = cfg.mamba_d_conv
    n = cfg.mamba_d_state
    c = min(cfg.mamba_chunk, s)
    if s % c:  # shapes here are powers of two; guard anyway
        c = s
    nc = s // c

    xch = x.reshape(b, nc, c, x.shape[-1]).swapaxes(0, 1)  # [NC,B,C,d]
    h0 = jnp.zeros((b, di, n), jnp.float32)
    tail0 = jnp.zeros((b, kk - 1, di), x.dtype)

    @jax.checkpoint
    def chunk_step(carry, x_c):
        h_in, tail = carry
        xz = jnp.einsum("bsd,de->bse", x_c, params["in_proj"])
        xi, z = xz[..., :di], xz[..., di:]
        halo = jnp.concatenate([tail, xi], axis=1)  # [B, C+K-1, di]
        conv = _causal_conv(halo, params["conv_w"], params["conv_b"])
        xc_ = jax.nn.silu(conv[:, kk - 1 :])
        da, dbx, c_ssm = _ssm_inputs(params, xc_, cfg)
        a_cum, h_intra = jax.lax.associative_scan(_combine, (da, dbx), axis=1)
        # fold the carried-in state: h_t = (Π a)·h_in + h_intra
        h = h_intra + a_cum * h_in[:, None]
        y = jnp.einsum("bsin,bsn->bsi", h, c_ssm.astype(jnp.float32))
        y = y + params["D"] * xc_.astype(jnp.float32)
        y = y.astype(x_c.dtype) * jax.nn.silu(z)
        out_c = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
        new_tail = halo[:, -(kk - 1) :] if kk > 1 else tail
        return (h[:, -1], new_tail), out_c

    (h_f, tail_f), outs = jax.lax.scan(
        chunk_step, (h0, tail0), xch, unroll=min(max(cfg.mamba_unroll, 1), nc)
    )
    out = outs.swapaxes(0, 1).reshape(b, s, -1)
    if not return_state:
        return out
    cache = {"conv": tail_f.astype(cfg.dtype), "h": h_f}
    return out, cache


# ---------------------------------------------------------------------------
# Decode path: O(1) per token
# ---------------------------------------------------------------------------

def init_mamba_cache(cfg, batch: int):
    di, n, kk = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": jnp.zeros((batch, kk - 1, di), cfg.dtype),
        "h": jnp.zeros((batch, di, n), jnp.float32),
    }


def mamba_decode(params, x, cache, cfg) -> Tuple[jnp.ndarray, dict]:
    """One step: x [B,1,d] -> ([B,1,d], cache)."""
    di = cfg.mamba_d_inner
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    xi, z = xz[..., :di], xz[..., di:]

    window = jnp.concatenate([cache["conv"], xi.astype(cfg.dtype)], axis=1)
    w = params["conv_w"]  # [di, K]
    conv = jnp.einsum("bki,ik->bi", window.astype(jnp.float32), w)
    xc = jax.nn.silu(conv + params["conv_b"]).astype(x.dtype)[:, None, :]

    da, dbx, c_ssm = _ssm_inputs(params, xc, cfg)
    h = da[:, 0] * cache["h"] + dbx[:, 0]  # [B,di,N]
    y = jnp.einsum("bin,bn->bi", h, c_ssm[:, 0].astype(jnp.float32))
    y = y + params["D"] * xc[:, 0].astype(jnp.float32)
    y = y[:, None, :].astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, params["out_proj"])
    return out, {"conv": window[:, 1:], "h": h}
