"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, sequential) — arch ``xlstm-1.3b`` interleaves them 7:1.

mLSTM is linear attention with per-step scalar gates:

    C_t = f_t·C_{t-1} + i_t·(k_t v_tᵀ)      C ∈ [hd, hd]   (matrix memory)
    n_t = f_t·n_{t-1} + i_t·k_t
    h_t = (q_tᵀ C_t) / max(|q_tᵀ n_t|, 1)

Training uses the **chunkwise form** (GLA-style): intra-chunk quadratic
attention with log-space decay ratios + an inter-chunk recurrent state carried
by ``lax.scan`` — O(S·C) work, O(S/C) sequential depth, never materializing a
per-step [hd, hd] memory.  Decode is the O(1) recurrence.

sLSTM keeps exponential gating but a scalar memory per unit; its recurrence
(block-diagonal per head) is inherently sequential -> ``lax.scan`` over time.

Simplification vs the paper (documented in DESIGN.md): the max-tracking
stabilizer m_t is replaced by capping the input gate at exp(min(ĩ, 0)) and
sigmoid forget gates — stable in bf16 and identical in structure.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rms_norm

__all__ = [
    "mlstm_init",
    "mlstm_apply",
    "mlstm_decode",
    "init_mlstm_cache",
    "slstm_init",
    "slstm_apply",
    "slstm_decode",
    "init_slstm_cache",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg):
    d = cfg.d_model
    di = cfg.xlstm_d_inner
    h, hd = cfg.n_heads, cfg.xlstm_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, di, cfg.param_dtype),
        "w_z": dense_init(ks[1], d, di, cfg.param_dtype),
        "conv_w": dense_init(ks[2], 4, di, jnp.float32).T,  # [di, 4]
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": dense_init(ks[3], di, (h, hd), cfg.param_dtype),
        "wk": dense_init(ks[4], di, (h, hd), cfg.param_dtype),
        "wv": dense_init(ks[5], di, (h, hd), cfg.param_dtype),
        "w_gates": dense_init(ks[6], di, 2 * h, jnp.float32),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((h,), jnp.float32), 3.0 * jnp.ones((h,), jnp.float32)]
        ),  # forget gates biased open, the usual LSTM trick
        "h_scale": jnp.ones((h, hd), jnp.float32),
        "w_down": dense_init(ks[7], di, d, cfg.param_dtype),
    }


def _causal_conv(x, w, b):
    k = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for i in range(k):
        out = out + pad[:, i : i + s].astype(jnp.float32) * w[:, i]
    return (out + b).astype(x.dtype)


def _mlstm_qkvg(params, xn, cfg):
    xu = jnp.einsum("bsd,de->bse", xn, params["w_up"])
    z = jnp.einsum("bsd,de->bse", xn, params["w_z"])
    xc = jax.nn.silu(_causal_conv(xu, params["conv_w"], params["conv_b"]))
    q = jnp.einsum("bse,ehd->bshd", xc, params["wq"])
    k = jnp.einsum("bse,ehd->bshd", xc, params["wk"]) * cfg.xlstm_head_dim**-0.5
    v = jnp.einsum("bse,ehd->bshd", xu, params["wv"])
    gates = (
        jnp.einsum("bse,eg->bsg", xc.astype(jnp.float32), params["w_gates"])
        + params["gate_bias"]
    )
    h = cfg.n_heads
    i_gate = jnp.exp(jnp.minimum(gates[..., :h], 0.0))  # (0, 1]
    log_f = jax.nn.log_sigmoid(gates[..., h:])  # log decay, < 0
    return xu, z, q, k, v, i_gate, log_f


def mlstm_apply(params, x, cfg, return_state: bool = False):
    """Chunkwise-parallel forward: x [B,S,d] -> [B,S,d] (x pre-normed)."""
    b, s, _ = x.shape
    hn, hd = cfg.n_heads, cfg.xlstm_head_dim
    c = min(cfg.xlstm_chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c

    xu, z, q, k, v, i_gate, log_f = _mlstm_qkvg(params, x, cfg)

    def chunked(t):  # [B,S,...] -> [NC,B,C,...]
        return t.reshape(b, nc, c, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = chunked(q), chunked(k), chunked(v)
    ic, lfc = chunked(i_gate), chunked(log_f)

    s0 = jnp.zeros((b, hn, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, hn, hd), jnp.float32)

    def step(carry, inp):
        s_state, n_state = carry
        qq, kk, vv, ii, lf = inp  # [B,C,H,*]
        cum = jnp.cumsum(lf, axis=1)  # [B,C,H] inclusive log-decay
        # intra-chunk: scores(t,τ) = q_t·k_τ · exp(cum_t − cum_τ) · i_τ, τ ≤ t
        qk = jnp.einsum(
            "bthd,bshd->bhts", qq, kk, preferred_element_type=jnp.float32
        )
        ratio = cum.transpose(0, 2, 1)[:, :, :, None] - cum.transpose(0, 2, 1)[
            :, :, None, :
        ]
        causal = jnp.tril(jnp.ones((c, c), bool))
        decay = jnp.where(causal, jnp.exp(ratio), 0.0)
        scores = qk * decay * ii.transpose(0, 2, 1)[:, :, None, :]
        num_intra = jnp.einsum("bhts,bshd->bthd", scores, vv.astype(jnp.float32))
        den_intra = jnp.sum(scores, axis=-1).transpose(0, 2, 1)  # [B,C,H]
        # inter-chunk: carry-in state scaled by exp(cum_t)
        et = jnp.exp(cum)  # [B,C,H]
        num_inter = (
            jnp.einsum("bthd,bhde->bthe", qq.astype(jnp.float32), s_state)
            * et[..., None]
        )
        den_inter = (
            jnp.einsum("bthd,bhd->bth", qq.astype(jnp.float32), n_state) * et
        )
        den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
        hh = (num_intra + num_inter) / den[..., None]
        # state update: S' = exp(tot)·S + Σ_τ exp(tot − cum_τ)·i_τ·k_τ v_τᵀ
        tot = cum[:, -1]  # [B,H]
        w_tau = jnp.exp(tot[:, None] - cum) * ii  # [B,C,H]
        kv = jnp.einsum(
            "bshd,bshe->bhde",
            kk.astype(jnp.float32) * w_tau[..., None],
            vv.astype(jnp.float32),
        )
        s_new = jnp.exp(tot)[..., None, None] * s_state + kv
        n_new = jnp.exp(tot)[..., None] * n_state + jnp.einsum(
            "bshd,bsh->bhd", kk.astype(jnp.float32), w_tau
        )
        return (s_new, n_new), hh

    (s_f, n_f), hs = jax.lax.scan(
        step, (s0, n0), (qc, kc, vc, ic, lfc),
        unroll=min(max(cfg.mlstm_unroll, 1), nc),
    )
    h = hs.swapaxes(0, 1).reshape(b, s, hn, hd)  # [B,S,H,hd]
    h = rms_norm(h, params["h_scale"]).reshape(b, s, hn * hd)
    out = h.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", out, params["w_down"])
    if not return_state:
        return out
    cache = {"conv": xu[:, -3:].astype(cfg.dtype), "S": s_f, "n": n_f}
    return out, cache


def init_mlstm_cache(cfg, batch: int):
    hn, hd = cfg.n_heads, cfg.xlstm_head_dim
    return {
        "conv": jnp.zeros((batch, 3, cfg.xlstm_d_inner), cfg.dtype),
        "S": jnp.zeros((batch, hn, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, hn, hd), jnp.float32),
    }


def mlstm_decode(params, x, cache, cfg) -> Tuple[jnp.ndarray, dict]:
    b = x.shape[0]
    hn, hd = cfg.n_heads, cfg.xlstm_head_dim
    xu = jnp.einsum("bsd,de->bse", x, params["w_up"])
    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    window = jnp.concatenate([cache["conv"], xu.astype(cfg.dtype)], axis=1)
    conv = jnp.einsum(
        "bki,ik->bi", window.astype(jnp.float32), params["conv_w"]
    )
    xc = jax.nn.silu(conv + params["conv_b"]).astype(x.dtype)[:, None, :]
    q = jnp.einsum("bse,ehd->bshd", xc, params["wq"])[:, 0]
    k = (
        jnp.einsum("bse,ehd->bshd", xc, params["wk"])[:, 0]
        * cfg.xlstm_head_dim**-0.5
    )
    v = jnp.einsum("bse,ehd->bshd", xu, params["wv"])[:, 0]
    gates = (
        jnp.einsum("be,eg->bg", xc[:, 0].astype(jnp.float32), params["w_gates"])
        + params["gate_bias"]
    )
    i_g = jnp.exp(jnp.minimum(gates[:, :hn], 0.0))[..., None]
    f_g = jax.nn.sigmoid(gates[:, hn:])[..., None]
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    s_new = f_g[..., None] * cache["S"] + i_g[..., None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n_new = f_g * cache["n"] + i_g * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, s_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)), 1.0)
    h = (num / den[..., None]).reshape(b, 1, hn, hd)
    h = rms_norm(h, params["h_scale"]).reshape(b, 1, hn * hd)
    out = h.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", out, params["w_down"])
    return out, {"conv": window[:, 1:], "S": s_new, "n": n_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg):
    d = cfg.d_model
    hn = cfg.n_heads
    hd = d // hn
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, cfg.param_dtype),  # z,i,f,o
        "r": dense_init(ks[1], hd, (hn, 4 * hd), jnp.float32).transpose(1, 0, 2),
        "bias": jnp.concatenate(
            [
                jnp.zeros((2 * d,), jnp.float32),
                3.0 * jnp.ones((d,), jnp.float32),  # forget bias
                jnp.zeros((d,), jnp.float32),
            ]
        ),
        "h_scale": jnp.ones((hn, hd), jnp.float32),
        "w_out": dense_init(ks[2], d, d, cfg.param_dtype),
    }


def _slstm_cell(params, wx_t, state, cfg):
    """One recurrence step.  wx_t [B, 4d] precomputed input projection."""
    d = cfg.d_model
    hn = cfg.n_heads
    hd = d // hn
    h_prev, c_prev, n_prev = state  # [B,hn,hd] each
    rec = jnp.einsum("bhd,hde->bhe", h_prev, params["r"]).reshape(-1, 4 * d)
    pre = wx_t.astype(jnp.float32) + rec + params["bias"]
    zg, ig, fg, og = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zg).reshape(-1, hn, hd)
    i = jnp.exp(jnp.minimum(ig, 0.0)).reshape(-1, hn, hd)
    f = jax.nn.sigmoid(fg).reshape(-1, hn, hd)
    o = jax.nn.sigmoid(og).reshape(-1, hn, hd)
    c = f * c_prev + i * z
    n = f * n_prev + i
    h = o * c / jnp.maximum(n, 1.0)
    return (h, c, n)


def slstm_apply(params, x, cfg, return_state: bool = False):
    """Sequential forward: x [B,S,d] -> [B,S,d] (x pre-normed)."""
    b, s, d = x.shape
    hn = cfg.n_heads
    hd = d // hn
    wx = jnp.einsum("bsd,de->bse", x, params["w_in"])  # [B,S,4d]

    def step(state, wx_t):
        new = _slstm_cell(params, wx_t, state, cfg)
        return new, new[0]

    init = tuple(jnp.zeros((b, hn, hd), jnp.float32) for _ in range(3))
    (h_f, c_f, n_f), hs = jax.lax.scan(
        step, init, wx.swapaxes(0, 1), unroll=cfg.slstm_unroll
    )
    h = hs.swapaxes(0, 1)  # [B,S,hn,hd]
    h = rms_norm(h, params["h_scale"]).reshape(b, s, d)
    out = jnp.einsum("bsd,de->bse", h.astype(x.dtype), params["w_out"])
    if not return_state:
        return out
    return out, {"h": h_f, "c": c_f, "n": n_f}


def init_slstm_cache(cfg, batch: int):
    hn = cfg.n_heads
    hd = cfg.d_model // hn
    return {
        "h": jnp.zeros((batch, hn, hd), jnp.float32),
        "c": jnp.zeros((batch, hn, hd), jnp.float32),
        "n": jnp.zeros((batch, hn, hd), jnp.float32),
    }


def slstm_decode(params, x, cache, cfg) -> Tuple[jnp.ndarray, dict]:
    b, _, d = x.shape
    hn = cfg.n_heads
    hd = d // hn
    wx = jnp.einsum("bsd,de->bse", x, params["w_in"])[:, 0]
    h, c, n = _slstm_cell(params, wx, (cache["h"], cache["c"], cache["n"]), cfg)
    hh = rms_norm(h, params["h_scale"]).reshape(b, 1, d)
    out = jnp.einsum("bsd,de->bse", hh.astype(x.dtype), params["w_out"])
    return out, {"h": h, "c": c, "n": n}
