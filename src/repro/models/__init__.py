"""Model definitions for the assigned architecture pool.

``model`` assembles the blocks below according to a declarative
``ModelConfig`` (see ``repro.configs``):

* ``attention`` — GQA / MQA / sliding-window / cross attention + KV caches
* ``mamba``     — selective state space (jamba's mixer)
* ``xlstm``     — mLSTM / sLSTM blocks
* ``moe``       — top-k capacity-dispatch mixture of experts
* ``layers``    — norms, MLPs, positions, initializers
"""

from . import attention, layers, mamba, model, moe, xlstm
from .model import (
    abstract_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    padded_vocab,
    prefill,
)

__all__ = [
    "attention",
    "layers",
    "mamba",
    "model",
    "moe",
    "xlstm",
    "abstract_params",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "padded_vocab",
    "prefill",
]
