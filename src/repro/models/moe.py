"""Mixture-of-Experts layer (mixtral / qwen2-moe / jamba).

TPU-native, GShard-style **capacity dispatch without sort**: per-slot one-hot
cumsum assigns each (token, slot) a position inside its expert; tokens are
*gathered* into a dense [E, capacity, d] buffer (gathers cost bytes, not
FLOPs — unlike one-hot dispatch matmuls, HLO FLOPs stay proportional to
*active* compute, which keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio
honest).  Expert FFNs run as one batched einsum over the expert axis; combine
is a weighted scatter-add.

Router runs in fp32.  Over-capacity tokens are dropped (their combine weight
is zero) — the classic capacity-factor trade-off; cf=1.25 by default.
Optional shared experts (qwen2-moe) run densely alongside.

Load-balance auxiliary loss (Switch-style): E · Σ_e fraction_e · prob_e.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import constrain
from .layers import dense_init

__all__ = ["moe_init", "moe_apply", "moe_apply_row_local"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def moe_init(key, cfg):
    d, e, ff = cfg.d_model, cfg.moe_experts, cfg.moe_ff
    ks = jax.random.split(key, 7)
    params = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "we_gate": dense_init(ks[1], d, (e, ff), cfg.param_dtype).transpose(1, 0, 2),
        "we_up": dense_init(ks[2], d, (e, ff), cfg.param_dtype).transpose(1, 0, 2),
        "we_down": dense_init(ks[3], ff, (e, d), cfg.param_dtype).transpose(1, 0, 2),
    }
    if cfg.moe_shared_ff:
        params["shared"] = {
            "w_gate": dense_init(ks[4], d, cfg.moe_shared_ff, cfg.param_dtype),
            "w_up": dense_init(ks[5], d, cfg.moe_shared_ff, cfg.param_dtype),
            "w_down": dense_init(ks[6], cfg.moe_shared_ff, d, cfg.param_dtype),
        }
    return params


def moe_apply(
    params, x, cfg, capacity_factor: Optional[float] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar fp32)."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum(
        "td,de->te", xt.astype(jnp.float32), params["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)  # [t, e]
    gate_w, sel = jax.lax.top_k(probs, k)  # [t, k]
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # 128-aligned: MXU lanes + keeps the capacity dim divisible by the data
    # axis so the dispatch buffers shard (see the constrain below)
    capacity = _round_up(max(int(t * k / e * cf), 1), 128)
    capacity = min(capacity, _round_up(t, 128))

    # GShard position assignment: slot-by-slot one-hot cumsum (k is tiny).
    onehots = jax.nn.one_hot(sel, e, dtype=jnp.int32)  # [t, k, e]
    prev = jnp.zeros((e,), jnp.int32)
    pos_list = []
    for slot in range(k):
        oh = onehots[:, slot, :]
        within = jnp.cumsum(oh, axis=0) - oh  # tokens before me, this slot
        pos_list.append(jnp.sum((within + prev[None]) * oh, axis=-1))
        prev = prev + jnp.sum(oh, axis=0)
    pos = jnp.stack(pos_list, axis=1)  # [t, k] position inside expert

    keep = pos < capacity
    e_flat = sel.reshape(-1)
    pos_flat = pos.reshape(-1)
    keep_flat = keep.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    dst = jnp.where(keep_flat, e_flat * capacity + pos_flat, e * capacity)

    # slot -> source token, slot -> combine weight (scatter; drops collide to
    # the overflow slot e*capacity which is sliced away)
    slot_tok = jnp.zeros((e * capacity + 1,), jnp.int32).at[dst].set(tok_flat)
    slot_w = (
        jnp.zeros((e * capacity + 1,), jnp.float32)
        .at[dst]
        .set(gate_w.reshape(-1) * keep_flat)
    )
    slot_tok, slot_w = slot_tok[:-1], slot_w[:-1]
    slot_valid = (slot_w > 0).astype(cfg.dtype)

    xe = jnp.take(xt, slot_tok, axis=0).reshape(e, capacity, d)
    xe = xe * slot_valid.reshape(e, capacity, 1)
    # EP dispatch layout: expert dim over "model" when divisible, capacity
    # dim over "data" — without this the [E, capacity, d] buffers replicate
    # per device (prefill_32k MoE cells blow HBM otherwise).  The gather
    # from token-sharded xt to this layout is the EP all-to-all.
    xe = constrain(xe, ("expert", "moe_cap", "embed"))

    gate = jnp.einsum("ecd,edf->ecf", xe, params["we_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, params["we_up"])
    ye = jnp.einsum(
        "ecf,efd->ecd", jax.nn.silu(gate) * up, params["we_down"]
    )
    ye = constrain(ye, ("expert", "moe_cap", "embed"))

    combine = ye.reshape(e * capacity, d) * slot_w[:, None].astype(ye.dtype)
    out = (
        jnp.zeros((t, d), ye.dtype).at[slot_tok].add(combine)
    )

    if "shared" in params:
        sh = params["shared"]
        g = jnp.einsum("td,df->tf", xt, sh["w_gate"])
        u = jnp.einsum("td,df->tf", xt, sh["w_up"])
        out = out + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u, sh["w_down"])

    # Switch-style load-balance loss
    frac = jnp.mean(
        jax.nn.one_hot(sel[:, 0], e, dtype=jnp.float32), axis=0
    )
    imp = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * imp)
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_apply_row_local(
    params, x, cfg, capacity_factor: Optional[float] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row-local dispatch: capacity groups are per BATCH ROW, so every
    gather/scatter index stays inside the row and the batch axis shards
    cleanly — the global formulation's cross-shard gather/scatter (an
    all-gather + all-reduce of the full [t, d] token buffer per MoE layer
    under GSPMD) disappears; only the EP expert compute crosses shards.

    Trade-off (standard data-parallel-routing-group design): capacity and
    load-balance are enforced per row instead of globally — in the
    dropless regime both formulations are exactly equal (tested).
    """
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)  # [b, s, e]
    gate_w, sel = jax.lax.top_k(probs, k)  # [b, s, k]
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    capacity = _round_up(max(int(s * k / e * cf), 1), 128)
    capacity = min(capacity, _round_up(s, 128))

    # per-row position of each (token, slot) inside its expert
    onehots = jax.nn.one_hot(sel, e, dtype=jnp.int32)  # [b, s, k, e]
    prev = jnp.zeros((b, e), jnp.int32)
    pos_list = []
    for slot in range(k):
        oh = onehots[:, :, slot, :]  # [b, s, e]
        within = jnp.cumsum(oh, axis=1) - oh
        pos_list.append(jnp.sum((within + prev[:, None]) * oh, axis=-1))
        prev = prev + jnp.sum(oh, axis=1)
    pos = jnp.stack(pos_list, axis=2)  # [b, s, k]

    keep = pos < capacity
    dst = jnp.where(
        keep, sel * capacity + pos, e * capacity
    ).reshape(b, s * k)
    tok_idx = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[:, None], (s, k)
    ).reshape(1, s * k)
    tok_idx = jnp.broadcast_to(tok_idx, (b, s * k))
    w_flat = (gate_w * keep).reshape(b, s * k)

    rows = jnp.arange(b)[:, None]
    slot_tok = jnp.zeros((b, e * capacity + 1), jnp.int32).at[rows, dst].set(
        tok_idx
    )[:, :-1]
    slot_w = jnp.zeros((b, e * capacity + 1), jnp.float32).at[rows, dst].set(
        w_flat
    )[:, :-1]
    slot_valid = (slot_w > 0).astype(cfg.dtype)

    xe = jnp.take_along_axis(x, slot_tok[..., None], axis=1)  # [b, e*C, d]
    xe = (xe * slot_valid[..., None]).reshape(b, e, capacity, d)
    xe = constrain(xe, ("batch", "expert", "moe_cap", "embed"))

    gate = jnp.einsum("becd,edf->becf", xe, params["we_gate"])
    up = jnp.einsum("becd,edf->becf", xe, params["we_up"])
    ye = jnp.einsum(
        "becf,efd->becd", jax.nn.silu(gate) * up, params["we_down"]
    )
    ye = constrain(ye, ("batch", "expert", "moe_cap", "embed"))

    combine = ye.reshape(b, e * capacity, d) * slot_w[..., None].astype(
        ye.dtype
    )
    out = jnp.zeros((b, s, d), ye.dtype).at[rows, slot_tok].add(combine)

    if "shared" in params:
        sh = params["shared"]
        g = jnp.einsum("bsd,df->bsf", x, sh["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, sh["w_up"])
        out = out + jnp.einsum(
            "bsf,fd->bsd", jax.nn.silu(g) * u, sh["w_down"]
        )

    frac = jnp.mean(
        jax.nn.one_hot(sel[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    imp = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * imp)
    return out.astype(x.dtype), aux
