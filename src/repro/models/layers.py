"""Shared neural layers for the assigned LM architectures.

Everything is a pure function over explicit parameter pytrees (no flax/haiku
dependency): ``init_*`` builds params, the forward functions consume them.
Parameter leaves carry no metadata — sharding is derived from the leaf *path*
by ``repro.launch.policy`` (logical-axis rules, MaxText-style), so model code
stays sharding-agnostic and the same definition serves CPU smoke tests and
the 512-chip dry-run.

Dtype policy: parameters are created in ``cfg.param_dtype`` (bf16 at
production scale, fp32 for CPU smoke), matmuls run in ``cfg.dtype`` with
fp32 accumulation where it matters (norms, softmax, losses, gates).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init",
    "embed_init",
    "norm_init",
    "rms_norm",
    "layer_norm",
    "apply_norm",
    "mlp_init",
    "mlp_apply",
    "rotary_embedding",
    "apply_rotary",
    "sinusoidal_positions",
]


def truncated_normal(key, shape, dtype, stddev: float):
    # 2-sigma truncation like flax's default initializers.
    unscaled = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (unscaled * stddev).astype(dtype)


def dense_init(key, in_dim: int, out_shape, dtype, scale: Optional[float] = None):
    """Weight [in_dim, *out_shape]; fan-in scaled init."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    stddev = scale if scale is not None else in_dim**-0.5
    return truncated_normal(key, (in_dim, *out_shape), dtype, stddev)


def embed_init(key, vocab: int, dim: int, dtype):
    return truncated_normal(key, (vocab, dim), dtype, 0.02)


def norm_init(dim: int, kind: str):
    """``rms`` / ``ln`` carry scale (+bias); ``np_ln`` (OLMo) is parameter-free."""
    if kind == "rms":
        return {"scale": jnp.ones((dim,), jnp.float32)}
    if kind == "ln":
        return {
            "scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32),
        }
    if kind == "np_ln":
        return {}
    raise ValueError(f"unknown norm kind {kind}")


def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale).astype(x.dtype)


def layer_norm(x, scale=None, bias=None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        out = out * scale
    if bias is not None:
        out = out + bias
    return out.astype(x.dtype)


def apply_norm(x, params, kind: str):
    if kind == "rms":
        return rms_norm(x, params["scale"])
    if kind == "ln":
        return layer_norm(x, params["scale"], params["bias"])
    if kind == "np_ln":
        return layer_norm(x)  # OLMo's non-parametric LayerNorm
    raise ValueError(f"unknown norm kind {kind}")


# ---------------------------------------------------------------------------
# MLP: SwiGLU (llama family) or GELU (whisper / gpt-bigcode family)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    if kind == "gelu":
        return {
            "w_up": dense_init(ks[0], d_model, d_ff, dtype),
            "w_down": dense_init(ks[1], d_ff, d_model, dtype),
        }
    raise ValueError(f"unknown mlp kind {kind}")


def mlp_apply(params, x, kind: str):
    if kind == "swiglu":
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
        h = jax.nn.silu(gate) * up
    elif kind == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["w_up"]))
    else:
        raise ValueError(f"unknown mlp kind {kind}")
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"])


# ---------------------------------------------------------------------------
# Positions: RoPE and sinusoidal
# ---------------------------------------------------------------------------

def rotary_embedding(positions, head_dim: int, theta: float = 10000.0):
    """cos/sin tables [*, head_dim/2] for integer ``positions``."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rotary(x, cos, sin):
    """x [B, S, H, D]; cos/sin [B, S, D/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


def sinusoidal_positions(num: int, dim: int) -> np.ndarray:
    """Classic transformer sinusoids [num, dim] (whisper-style stub)."""
    pos = np.arange(num)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(0, dim, 2) / dim)
    table = np.zeros((num, dim), dtype=np.float32)
    table[:, 0::2] = np.sin(pos * div)
    table[:, 1::2] = np.cos(pos * div)
    return table
