"""Architecture assembly: ``ModelConfig`` -> params / forward / prefill / decode.

One assembly interprets every assigned architecture declaratively:

* depth is a **block pattern** (one period of (mixer, ffn) pairs) scanned
  ``n_periods`` times — compiled HLO stays O(pattern), not O(depth), which is
  what lets the 95-layer deepseek-67b lower in seconds;
* mixers: GQA attention (full / sliding-window / cross), Mamba, mLSTM, sLSTM;
* ffns: dense MLP (SwiGLU / GELU), MoE (top-k capacity dispatch), or none;
* modality frontends are stubs per the assignment: whisper consumes
  precomputed frame embeddings (``frames``), llava precomputed patch
  embeddings (``patches``) — the backbone is the deliverable;
* remat: each period is ``jax.checkpoint``-ed under ``cfg.remat`` so training
  activations scale with O(periods · layer-input), not O(depth · hidden).

Params are a plain pytree; sharding comes from ``repro.sharding`` leaf-path
rules, so this file contains no mesh-axis names.

Public entry points::

    init_params(key, cfg)                     -> params
    forward(params, batch, cfg)               -> (logits, aux_loss)
    loss_fn(params, batch, cfg)               -> (scalar, metrics)
    prefill(params, batch, cfg, max_len)      -> (last_logits, cache)
    init_cache(cfg, batch, max_len)           -> cache pytree (decode state)
    decode_step(params, token, cache, pos, cfg) -> (logits, cache)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding import constrain
from . import attention as attn
from . import mamba as mb
from . import moe as moe_mod
from . import xlstm as xl
from .layers import (
    apply_norm,
    dense_init,
    embed_init,
    mlp_apply,
    mlp_init,
    norm_init,
    sinusoidal_positions,
    truncated_normal,
)

__all__ = [
    "init_params",
    "abstract_params",
    "forward",
    "loss_fn",
    "prefill",
    "init_cache",
    "decode_step",
    "padded_vocab",
    "num_moe_layers",
]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def padded_vocab(cfg) -> int:
    """Vocab padded to a 256 multiple: keeps the vocab-sharded lm-head and
    embedding MXU/lane aligned (51865 -> 52096 etc.)."""
    return _round_up(cfg.vocab, 256)


def num_moe_layers(cfg) -> int:
    return cfg.n_periods * sum(1 for b in cfg.pattern if b.ffn == "moe")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg, blk) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"mixer_norm": norm_init(cfg.d_model, cfg.norm)}
    if blk.mixer == "attn":
        p["mixer"] = attn.attention_init(ks[0], cfg)
    elif blk.mixer == "mamba":
        p["mixer"] = mb.mamba_init(ks[0], cfg)
    elif blk.mixer == "mlstm":
        p["mixer"] = xl.mlstm_init(ks[0], cfg)
    elif blk.mixer == "slstm":
        p["mixer"] = xl.slstm_init(ks[0], cfg)
    else:
        raise ValueError(f"unknown mixer {blk.mixer}")
    if cfg.is_encoder_decoder:
        p["cross_norm"] = norm_init(cfg.d_model, cfg.norm)
        p["cross"] = attn.attention_init(ks[1], cfg, cross=True)
    if blk.ffn == "mlp":
        p["ffn_norm"] = norm_init(cfg.d_model, cfg.norm)
        p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp, cfg.param_dtype)
    elif blk.ffn == "moe":
        p["ffn_norm"] = norm_init(cfg.d_model, cfg.norm)
        p["ffn"] = moe_mod.moe_init(ks[2], cfg)
    elif blk.ffn != "none":
        raise ValueError(f"unknown ffn {blk.ffn}")
    return p


def _init_period(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, len(cfg.pattern))
    return {
        f"b{bi}": _init_block(ks[bi], cfg, blk)
        for bi, blk in enumerate(cfg.pattern)
    }


def _init_enc_layer(key, cfg) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": norm_init(cfg.d_model, "ln"),
        "attn": attn.attention_init(k1, cfg),
        "mlp_norm": norm_init(cfg.d_model, "ln"),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, "gelu", cfg.param_dtype),
    }


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_periods + max(cfg.enc_layers, 1) + 4)
    periods = [_init_period(ks[i], cfg) for i in range(cfg.n_periods)]
    k_extra = ks[cfg.n_periods :]
    pv = padded_vocab(cfg)
    params: Dict[str, Any] = {
        "embed": embed_init(k_extra[0], pv, cfg.d_model, cfg.param_dtype),
        "periods": _stack(periods),
        "final_norm": norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            k_extra[1], cfg.d_model, pv, cfg.param_dtype
        )
    if cfg.pos == "learned":
        params["pos_embed"] = truncated_normal(
            k_extra[2], (cfg.max_pos, cfg.d_model), cfg.param_dtype, 0.02
        )
    if cfg.is_encoder_decoder:
        enc_ks = k_extra[4 : 4 + cfg.enc_layers]
        params["encoder"] = {
            "layers": _stack([_init_enc_layer(k, cfg) for k in enc_ks]),
            "final_norm": norm_init(cfg.d_model, "ln"),
        }
    if cfg.n_patches:
        params["mm_proj"] = dense_init(
            k_extra[3], cfg.d_model, cfg.d_model, cfg.param_dtype
        )
    return params


def abstract_params(cfg, seed: int = 0):
    """ShapeDtypeStruct pytree of the params — never allocates (dry-run)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(seed), cfg))


# ---------------------------------------------------------------------------
# Encoder (whisper backbone; conv frontend stubbed to frame embeddings)
# ---------------------------------------------------------------------------

def encode(params, frames, cfg):
    """frames [B, n_frames, d_model] (precomputed stub embeddings)."""
    x = frames.astype(cfg.dtype)
    pos = jnp.asarray(
        sinusoidal_positions(frames.shape[1], cfg.d_model), cfg.dtype
    )
    x = x + pos[None]
    x = constrain(x, ("batch", "seq", "embed"))

    def enc_layer(h, lp):
        y = apply_norm(h, lp["attn_norm"], "ln")
        y = attn.attention_apply(lp["attn"], y, cfg, causal=False)
        h = h + y
        y = apply_norm(h, lp["mlp_norm"], "ln")
        h = h + mlp_apply(lp["mlp"], y, "gelu")
        h = constrain(h, ("batch", "seq", "embed"))
        return h, None

    fn = jax.checkpoint(enc_layer) if cfg.remat else enc_layer
    x, _ = jax.lax.scan(
        fn, x, params["encoder"]["layers"],
        unroll=cfg.enc_layers if cfg.scan_unroll else 1,
    )
    return apply_norm(x, params["encoder"]["final_norm"], "ln")


# ---------------------------------------------------------------------------
# Decoder-side full-sequence pass
# ---------------------------------------------------------------------------

def _apply_block(p, blk, x, cfg, positions, enc_states, aux):
    h = apply_norm(x, p["mixer_norm"], cfg.norm)
    if blk.mixer == "attn":
        h = attn.attention_apply(
            p["mixer"], h, cfg, positions=positions, causal=True,
            window=cfg.window,
        )
    elif blk.mixer == "mamba":
        h = mb.mamba_apply(p["mixer"], h, cfg)
    elif blk.mixer == "mlstm":
        h = xl.mlstm_apply(p["mixer"], h, cfg)
    elif blk.mixer == "slstm":
        h = xl.slstm_apply(p["mixer"], h, cfg)
    x = x + h
    if cfg.is_encoder_decoder:
        h = apply_norm(x, p["cross_norm"], cfg.norm)
        h = attn.attention_apply(
            p["cross"], h, cfg, causal=False, kv_states=enc_states
        )
        x = x + h
    if blk.ffn != "none":
        h = apply_norm(x, p["ffn_norm"], cfg.norm)
        if blk.ffn == "mlp":
            x = x + mlp_apply(p["ffn"], h, cfg.mlp)
        else:
            moe_fn = (moe_mod.moe_apply_row_local if cfg.moe_row_local
                      else moe_mod.moe_apply)
            mo, a = moe_fn(p["ffn"], h, cfg)
            x = x + mo
            aux = aux + a
    # act_seq: the block-boundary tensor is what the remat'd period scan
    # SAVES — sharding its sequence dim (SP) divides stored-activation HBM
    # by the model-axis size at the price of boundary all-gathers.
    x = constrain(x, ("batch", "act_seq", "embed"))
    return x, aux


def _embed_inputs(params, batch, cfg):
    """Token (+ modality prefix) embedding.  Returns (x, positions)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.n_patches:
        patches = batch["patches"].astype(cfg.dtype)
        patches = jnp.einsum("bpd,de->bpe", patches, params["mm_proj"])
        x = jnp.concatenate([patches, x], axis=1)
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.pos == "learned":
        x = x + jnp.take(params["pos_embed"], positions[0], axis=0)[None]
    return constrain(x.astype(cfg.dtype), ("batch", "seq", "embed")), positions


def _head(params, x, cfg):
    """Final logits in fp32 (never materializes an fp32 weight copy)."""
    if cfg.tie_embeddings:
        return jnp.einsum(
            "bsd,vd->bsv", x, params["embed"],
            preferred_element_type=jnp.float32,
        )
    return jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"],
        preferred_element_type=jnp.float32,
    )


def forward(params, batch, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence logits [B, S_total, padded_vocab] + MoE aux loss."""
    x, positions = _embed_inputs(params, batch, cfg)
    enc_states = (
        encode(params, batch["frames"], cfg) if cfg.is_encoder_decoder else None
    )
    aux0 = jnp.zeros((), jnp.float32)

    def period_fn(carry, pp):
        h, aux = carry
        for bi, blk in enumerate(cfg.pattern):
            h, aux = _apply_block(
                pp[f"b{bi}"], blk, h, cfg, positions, enc_states, aux
            )
        return (h, aux), None

    fn = jax.checkpoint(period_fn) if cfg.remat else period_fn
    (x, aux), _ = jax.lax.scan(
        fn, (x, aux0), params["periods"],
        unroll=cfg.n_periods if cfg.scan_unroll else 1,
    )
    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = _head(params, x, cfg)
    return constrain(logits, ("batch", "seq", "vocab")), aux


def loss_fn(params, batch, cfg):
    """Mean next-token cross entropy (+ router aux).  ``labels`` are already
    aligned to predict-next; positions with label < 0 are masked out."""
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.n_patches:  # image-prefix positions carry no labels
        logits = logits[:, cfg.n_patches :]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - tgt) * mask
    ntok = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll) / ntok
    nm = num_moe_layers(cfg)
    total = ce + (cfg.router_aux * aux / nm if nm else 0.0)
    metrics = {"loss": total, "ce": ce, "aux": aux, "ntok": ntok}
    return total, metrics


# ---------------------------------------------------------------------------
# Prefill: full-sequence pass that also emits the decode cache
# ---------------------------------------------------------------------------

def _prefill_block(p, blk, x, cfg, positions, enc_states, max_len):
    h = apply_norm(x, p["mixer_norm"], cfg.norm)
    if blk.mixer == "attn":
        h, c = attn.attention_prefill(
            p["mixer"], h, cfg, max_len, positions=positions, window=cfg.window
        )
    elif blk.mixer == "mamba":
        h, c = mb.mamba_apply(p["mixer"], h, cfg, return_state=True)
    elif blk.mixer == "mlstm":
        h, c = xl.mlstm_apply(p["mixer"], h, cfg, return_state=True)
    elif blk.mixer == "slstm":
        h, c = xl.slstm_apply(p["mixer"], h, cfg, return_state=True)
    x = x + h
    cache = {"mixer": c}
    if cfg.is_encoder_decoder:
        ckv = attn.cross_kv(p["cross"], enc_states)
        h = apply_norm(x, p["cross_norm"], cfg.norm)
        h = attn.attention_apply(
            p["cross"], h, cfg, causal=False, kv_states=enc_states
        )
        x = x + h
        cache["cross"] = ckv
    if blk.ffn != "none":
        h = apply_norm(x, p["ffn_norm"], cfg.norm)
        if blk.ffn == "mlp":
            x = x + mlp_apply(p["ffn"], h, cfg.mlp)
        else:
            moe_fn = (moe_mod.moe_apply_row_local if cfg.moe_row_local
                      else moe_mod.moe_apply)
            mo, _ = moe_fn(
                p["ffn"], h, cfg, capacity_factor=cfg.moe_capacity_serve
            )
            x = x + mo
    x = constrain(x, ("batch", "seq", "embed"))
    return x, cache


def prefill(params, batch, cfg, max_len: int):
    """Returns (last-position logits [B, pv], decode cache)."""
    x, positions = _embed_inputs(params, batch, cfg)
    enc_states = (
        encode(params, batch["frames"], cfg) if cfg.is_encoder_decoder else None
    )

    def period_fn(h, pp):
        cache = {}
        for bi, blk in enumerate(cfg.pattern):
            h, c = _prefill_block(
                pp[f"b{bi}"], blk, h, cfg, positions, enc_states, max_len
            )
            cache[f"b{bi}"] = c
        return h, cache

    x, caches = jax.lax.scan(
        period_fn, x, params["periods"],
        unroll=cfg.n_periods if cfg.scan_unroll else 1,
    )
    x = apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
    logits = _head(params, x, cfg)[:, 0]
    return logits, {"periods": caches}


# ---------------------------------------------------------------------------
# Decode: one token against the cache
# ---------------------------------------------------------------------------

def _init_block_cache(cfg, blk, batch: int, max_len: int):
    if blk.mixer == "attn":
        c = attn.init_kv_cache(cfg, batch, max_len, window=cfg.window)
    elif blk.mixer == "mamba":
        c = mb.init_mamba_cache(cfg, batch)
    elif blk.mixer == "mlstm":
        c = xl.init_mlstm_cache(cfg, batch)
    elif blk.mixer == "slstm":
        c = xl.init_slstm_cache(cfg, batch)
    out = {"mixer": c}
    if cfg.is_encoder_decoder:
        out["cross"] = {
            "k": jnp.zeros(
                (batch, cfg.n_frames, cfg.n_kv_heads, cfg.head_dim), cfg.dtype
            ),
            "v": jnp.zeros(
                (batch, cfg.n_frames, cfg.n_kv_heads, cfg.head_dim), cfg.dtype
            ),
        }
    return out


def init_cache(cfg, batch: int, max_len: int):
    """Fresh (empty) decode cache — the dry-run's serve-state stand-in."""
    period = {
        f"b{bi}": _init_block_cache(cfg, blk, batch, max_len)
        for bi, blk in enumerate(cfg.pattern)
    }
    periods = jax.tree.map(
        lambda x: jnp.tile(x[None], (cfg.n_periods,) + (1,) * x.ndim), period
    )
    return {"periods": periods}


def decode_step(params, token, cache, cur_pos, cfg):
    """token [B, 1] int32, cur_pos scalar int32 -> (logits [B, pv], cache)."""
    x = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], cur_pos, 1, axis=0
        )[None]
    x = constrain(x, ("batch", None, "embed"))

    def period_fn(h, inp):
        pp, pc = inp
        new_pc = {}
        for bi, blk in enumerate(cfg.pattern):
            p, c = pp[f"b{bi}"], pc[f"b{bi}"]
            y = apply_norm(h, p["mixer_norm"], cfg.norm)
            if blk.mixer == "attn":
                y, nc = attn.attention_decode(
                    p["mixer"], y, c["mixer"], cur_pos, cfg, window=cfg.window
                )
            elif blk.mixer == "mamba":
                y, nc = mb.mamba_decode(p["mixer"], y, c["mixer"], cfg)
            elif blk.mixer == "mlstm":
                y, nc = xl.mlstm_decode(p["mixer"], y, c["mixer"], cfg)
            elif blk.mixer == "slstm":
                y, nc = xl.slstm_decode(p["mixer"], y, c["mixer"], cfg)
            h = h + y
            ncache = {"mixer": nc}
            if cfg.is_encoder_decoder:
                y = apply_norm(h, p["cross_norm"], cfg.norm)
                y = attn.cross_attention_decode(p["cross"], y, c["cross"], cfg)
                h = h + y
                ncache["cross"] = c["cross"]
            if blk.ffn != "none":
                y = apply_norm(h, p["ffn_norm"], cfg.norm)
                if blk.ffn == "mlp":
                    h = h + mlp_apply(p["ffn"], y, cfg.mlp)
                else:
                    moe_fn = (moe_mod.moe_apply_row_local
                              if cfg.moe_row_local else moe_mod.moe_apply)
                    mo, _ = moe_fn(
                        p["ffn"], y, cfg,
                        capacity_factor=cfg.moe_capacity_serve,
                    )
                    h = h + mo
            new_pc[f"b{bi}"] = ncache
        h = constrain(h, ("batch", None, "embed"))
        return h, new_pc

    x, new_periods = jax.lax.scan(
        period_fn, x, (params["periods"], cache["periods"]),
        unroll=cfg.n_periods if cfg.scan_unroll else 1,
    )
    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = _head(params, x, cfg)[:, 0]
    return logits, {"periods": new_periods}
