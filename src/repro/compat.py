"""Version compatibility shims for the pinned JAX.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace in newer releases; the pinned version only ships the
experimental spelling.  Import it from here so every caller (library code,
tests, benchmarks) tracks whichever location exists.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]

try:  # JAX >= 0.4.34 style
    shard_map = jax.shard_map
except AttributeError:  # pinned JAX: experimental namespace only
    from jax.experimental.shard_map import shard_map
