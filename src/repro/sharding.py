"""Logical-axis sharding policy (MaxText-style).

Model code never names mesh axes.  It annotates tensors with *logical* axes
(``batch``, ``seq``, ``heads``, ``ffn``, ...) via :func:`constrain`, and
parameter leaves get logical axes from their *path* (``wq`` -> (fsdp, heads,
head_dim)).  A :class:`ShardingPolicy` maps logical axes onto mesh axes and
is installed as a context; with no active policy every annotation is a no-op,
so the same model definition serves single-device CPU smoke tests and the
512-chip dry-run unchanged.

Resolution rules (applied per tensor):

* a logical axis maps to one mesh axis or a tuple of mesh axes;
* mesh axes missing from the active mesh are dropped (single-pod vs
  multi-pod reuse one rule set);
* a mesh axis may appear **once** per PartitionSpec — later logical axes
  that want an already-used mesh axis fall back to replication.  This is
  what lets one rule set serve MoE (expert wins ``model``, ffn falls back)
  and dense (ffn takes ``model``) weights alike;
* a dimension not divisible by its mesh-axis product falls back to
  replication (e.g. MQA's kv_heads=1, qwen2-moe's 60 experts on a 16-way
  axis) instead of forcing GSPMD padding.

Two built-in rule sets: ``TRAIN_RULES`` (batch-DP + FSDP over ``data``, TP
over ``model``) and ``SERVE_RULES`` (weights replicated over ``data``, TP
over ``model``, KV-cache sequence sharded over ``model`` — SP decode).
Hillclimbing (EXPERIMENTS.md §Perf) swaps individual rules.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "AxisRules",
    "ShardingPolicy",
    "TRAIN_RULES",
    "SERVE_RULES",
    "active_policy",
    "constrain",
    "use_policy",
    "logical_spec",
]

MeshAxes = Union[None, str, Tuple[str, ...]]

#: logical axis -> mesh axes.  ``fsdp`` is the *parameter* embed/width dim
#: (sharded over data for ZeRO-3); activation ``embed`` stays replicated.
TRAIN_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    # the *saved* (remat carry) activations' sequence dim: mapping this to
    # "model" is Megatron-style sequence parallelism — 16x less HBM for
    # stored layer inputs, paid for with per-period all-gathers.  Off in the
    # baseline; production policy for the largest train cells (see
    # launch/dryrun.PROD_OVERRIDES) and a §Perf hillclimb knob.
    "act_seq": None,
    "embed": None,
    "fsdp": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "expert": "model",
    "vocab": "model",
    "kv_seq": None,
    "state": None,
    "moe_cap": "data",  # MoE dispatch-buffer capacity dim (EP layout)
}

SERVE_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": None,
    "embed": None,
    "fsdp": None,  # serving keeps full weight replicas per data shard
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "expert": "model",
    "vocab": "model",
    "kv_seq": "model",  # SP: decode cache sequence dim over model
    "state": "model",  # SSM / mLSTM state inner dim
    "moe_cap": "data",
}


class AxisRules:
    """Immutable logical->mesh axis mapping with override support."""

    def __init__(self, rules: Dict[str, MeshAxes]) -> None:
        self._rules = dict(rules)

    def get(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        axes = self._rules.get(logical, None)
        if axes is None:
            return ()
        if isinstance(axes, str):
            return (axes,)
        return tuple(axes)

    def override(self, **updates: MeshAxes) -> "AxisRules":
        merged = dict(self._rules)
        merged.update(updates)
        return AxisRules(merged)

    def items(self):
        return self._rules.items()


class ShardingPolicy:
    """Binds an :class:`AxisRules` to a concrete mesh."""

    def __init__(self, mesh: Mesh, rules: Union[AxisRules, Dict[str, MeshAxes]]):
        self.mesh = mesh
        self.rules = rules if isinstance(rules, AxisRules) else AxisRules(rules)

    def spec(
        self, logical: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None
    ) -> PartitionSpec:
        """Resolve logical axes to a PartitionSpec (see module doc rules)."""
        used: set = set()
        out = []
        for i, name in enumerate(logical):
            axes = [
                a
                for a in self.rules.get(name)
                if a in self.mesh.shape and a not in used
            ]
            if shape is not None and axes:
                nshards = 1
                for a in axes:
                    nshards *= self.mesh.shape[a]
                if shape[i] % nshards != 0:
                    axes = []
            if not axes:
                out.append(None)
            else:
                used.update(axes)
                out.append(tuple(axes) if len(axes) > 1 else axes[0])
        while out and out[-1] is None:
            out.pop()
        return PartitionSpec(*out)

    def sharding(
        self, logical: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None
    ) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def constrain(self, x, logical: Sequence[Optional[str]]):
        return jax.lax.with_sharding_constraint(
            x, self.sharding(logical, x.shape)
        )


_STATE = threading.local()


def active_policy() -> Optional[ShardingPolicy]:
    return getattr(_STATE, "policy", None)


@contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    prev = active_policy()
    _STATE.policy = policy
    try:
        yield policy
    finally:
        _STATE.policy = prev


def constrain(x, logical: Sequence[Optional[str]]):
    """Annotate ``x`` with logical axes; no-op without an active policy."""
    pol = active_policy()
    if pol is None:
        return x
    return pol.constrain(x, logical)


def logical_spec(logical: Sequence[Optional[str]], shape=None) -> PartitionSpec:
    """Resolve under the active policy (PartitionSpec() when none active)."""
    pol = active_policy()
    if pol is None:
        return PartitionSpec()
    return pol.spec(logical, shape)


# ---------------------------------------------------------------------------
# Leaf-path -> logical axes (parameters, optimizer state, caches, batches)
# ---------------------------------------------------------------------------

#: parameter leaf name -> logical axes of its (unstacked) shape.
PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / heads
    "embed": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    "pos_embed": (None, "fsdp"),
    "mm_proj": ("fsdp", None),
    # attention
    "wq": ("fsdp", "heads", "head_dim"),
    "wk": ("fsdp", "kv_heads", "head_dim"),
    "wv": ("fsdp", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "fsdp"),
    # dense MLP (also MoE shared experts)
    "w_gate": ("fsdp", "ffn"),
    "w_up": ("fsdp", "ffn"),
    "w_down": ("ffn", "fsdp"),
    # MoE
    "router": ("fsdp", None),
    "we_gate": ("expert", "fsdp", "ffn"),
    "we_up": ("expert", "fsdp", "ffn"),
    "we_down": ("expert", "ffn", "fsdp"),
    # mamba (di = expanded inner dim -> "ffn" logical axis)
    "in_proj": ("fsdp", "ffn"),
    "conv_w": ("ffn", None),
    "conv_b": ("ffn",),
    "x_proj": ("ffn", None),
    "dt_proj": (None, "ffn"),
    "dt_bias": ("ffn",),
    "A_log": ("ffn", "state"),
    "D": ("ffn",),
    "out_proj": ("ffn", "fsdp"),
    # xLSTM
    "w_z": ("fsdp", "ffn"),
    "w_gates": ("ffn", None),
    "w_in": ("fsdp", "ffn"),
    "w_out": ("fsdp", None),
    "r": ("heads", "head_dim", None),
    # norms / small vectors: replicated
    "scale": (),
    "bias": (),
    "gate_bias": (),
    "h_scale": (),
}

#: decode-cache leaf name -> logical axes.
CACHE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("batch", "kv_seq", "kv_heads", "head_dim"),
    "pos": ("batch", "kv_seq"),
    "conv": ("batch", None, "ffn"),
    "h": ("batch", "ffn", "state"),
    "S": ("batch", "heads", None, "state"),
    "n": ("batch", "heads", "state"),
    "c": ("batch", "heads", "state"),
}

#: batch-input leaf name -> logical axes.
BATCH_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", "embed"),
    "patches": ("batch", "seq", "embed"),
    "token": ("batch", None),
    "cur_pos": (),
}

_FACTORED_SUFFIX = {"vr": -1, "vc": -2}  # adafactor factored stats


def _path_names(path) -> list:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return names


def _leaf_logical(path, ndim: int, table: Dict) -> Tuple[Optional[str], ...]:
    """Resolve a leaf's logical axes from its path.

    Handles: scan-stacked leading axes (periods/layers -> extra None dims),
    optimizer-state wrappers (mu/nu/v mirror the param), and adafactor's
    factored vr/vc (parent's axes minus the reduced dim).
    """
    names = _path_names(path)
    if not names:
        return (None,) * ndim
    last = names[-1]
    drop = None
    if last in _FACTORED_SUFFIX and len(names) >= 2 and names[-2] in table:
        drop = _FACTORED_SUFFIX[last]
        last = names[-2]
    elif last == "v" and len(names) >= 2 and names[-2] in table:
        # adafactor unfactored stat wraps the param name
        last = names[-2]
    logical = table.get(last)
    if logical is None:
        return (None,) * ndim
    logical = tuple(logical)
    if drop is not None:
        idx = len(logical) + drop
        logical = logical[:idx] + logical[idx + 1 :]
    # scan-stacked (periods / encoder layers / microbatch) leading dims
    while len(logical) < ndim:
        logical = (None,) + logical
    if len(logical) > ndim:  # defensive: over-specified -> replicate
        return (None,) * ndim
    return logical


def tree_logical_specs(tree, policy: ShardingPolicy, table: Dict):
    """NamedSharding pytree for ``tree`` under ``policy`` via path rules."""

    def leaf_spec(path, leaf):
        shape = getattr(leaf, "shape", ())
        return policy.sharding(_leaf_logical(path, len(shape), table), shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


def param_specs(params, policy: ShardingPolicy):
    return tree_logical_specs(params, policy, PARAM_AXES)


def state_specs(state, policy: ShardingPolicy):
    """Specs for a TrainState (params + optimizer state + step + err)."""
    return tree_logical_specs(state, policy, PARAM_AXES)


def cache_specs(cache, policy: ShardingPolicy):
    return tree_logical_specs(cache, policy, CACHE_AXES)


def batch_specs(batch, policy: ShardingPolicy):
    return tree_logical_specs(batch, policy, BATCH_AXES)
