"""Training loop with the fault-tolerance envelope.

Production behaviours implemented (all testable on CPU):

* **checkpoint/restart** — resumes from the latest checkpoint if one exists
  (elastic: restore reshards to the current mesh via the active policy);
* **step watchdog / straggler detection** — an EMA of step wall-time; a step
  slower than ``watchdog_factor``× the EMA is counted and logged.  On real
  multi-pod hardware the same signal triggers pre-emptive re-scheduling; in
  this repo it feeds metrics so the behaviour is observable and tested;
* **preemption handling** — SIGTERM/SIGINT set a flag; the loop finishes the
  current step, writes an emergency checkpoint and exits cleanly (the
  standard TPU-maintenance contract);
* **async checkpointing** — saves overlap subsequent steps;
* **NaN guard** — a non-finite loss aborts after saving a post-mortem
  checkpoint (restartable at the pre-NaN state).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import numpy as np

from .checkpoint import Checkpointer
from .train_step import TrainState

__all__ = ["LoopConfig", "LoopResult", "run_loop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    keep: int = 3
    log_every: int = 10
    watchdog_factor: float = 3.0
    watchdog_warmup: int = 5  # steps before the EMA is trusted
    handle_signals: bool = False  # opt-in (tests drive the flag directly)


@dataclasses.dataclass
class LoopResult:
    state: Any
    history: List[Dict[str, float]]
    straggler_steps: int
    preempted: bool
    resumed_from: Optional[int]


def run_loop(
    state: TrainState,
    train_step: Callable,
    batches: Iterable[Dict],
    cfg: LoopConfig,
    log: Callable[[str], None] = print,
) -> LoopResult:
    ckpt = Checkpointer(cfg.checkpoint_dir, cfg.keep) if cfg.checkpoint_dir else None
    resumed_from = None
    if ckpt is not None:
        try:
            state, resumed_from = ckpt.restore_latest(state)
            log(f"[loop] resumed from step {resumed_from}")
        except FileNotFoundError:
            pass

    preempt = {"flag": False}
    old_handlers = {}
    if cfg.handle_signals:
        def _handler(signum, frame):
            preempt["flag"] = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            old_handlers[sig] = signal.signal(sig, _handler)

    history: List[Dict[str, float]] = []
    stragglers = 0
    ema: Optional[float] = None
    steps_done = 0
    try:
        for batch in batches:
            step_no = int(state.step)
            if step_no >= cfg.total_steps or preempt["flag"]:
                break
            t0 = time.perf_counter()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0

            if ema is None:
                ema = dt
            else:
                if steps_done >= cfg.watchdog_warmup and dt > cfg.watchdog_factor * ema:
                    stragglers += 1
                    log(
                        f"[watchdog] step {step_no}: {dt*1e3:.1f} ms vs EMA "
                        f"{ema*1e3:.1f} ms — straggler"
                    )
                ema = 0.9 * ema + 0.1 * dt
            steps_done += 1

            rec = {"step": step_no, "loss": loss, "sec": dt}
            rec.update(
                {
                    k: float(v)
                    for k, v in metrics.items()
                    if k not in ("loss",) and np.ndim(v) == 0
                }
            )
            history.append(rec)
            if step_no % cfg.log_every == 0:
                log(f"[loop] step {step_no}: loss={loss:.4f} ({dt*1e3:.1f} ms)")

            if not np.isfinite(loss):
                if ckpt is not None:
                    ckpt.save_sync(step_no + 1, state)
                raise FloatingPointError(
                    f"non-finite loss at step {step_no}; post-mortem saved"
                )

            if ckpt is not None and (step_no + 1) % cfg.checkpoint_every == 0:
                ckpt.save_async(int(state.step), state)

        if preempt["flag"]:
            log("[loop] preemption signal — emergency checkpoint")
        if ckpt is not None:
            ckpt.save_sync(int(state.step), state)
    finally:
        if ckpt is not None:
            ckpt.wait()
        for sig, h in old_handlers.items():
            signal.signal(sig, h)

    return LoopResult(
        state=state,
        history=history,
        straggler_steps=stragglers,
        preempted=preempt["flag"],
        resumed_from=resumed_from,
    )
