"""Optimizers as pure (init, update) pairs over parameter pytrees.

No optax dependency — the three optimizers the configs reference are
implemented directly:

* ``sgd``       — momentum SGD (paper-era baseline)
* ``adamw``     — decoupled weight decay Adam; fp32 moments
* ``adafactor`` — factored second moments (Shazeer & Stern 2018): for a
  [r, c] matrix the second-moment statistics are one row vector + one col
  vector instead of r·c — the only way optimizer state for the 398B jamba
  fits the mesh (DESIGN.md §Mesh).  Matrices factor over their last two
  dims; vectors fall back to full statistics.

Update rules run in fp32 regardless of param dtype; the cast back happens
once per step.  ``clip_by_global_norm`` and the warmup-cosine schedule are
provided here too so the train step has no other deps.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "sgd",
    "adamw",
    "adafactor",
    "make_optimizer",
    "clip_by_global_norm",
    "warmup_cosine",
]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (updates, opt_state)


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def warmup_cosine(
    peak_lr: float,
    total_steps: int,
    warmup_steps: int = 100,
    final_frac: float = 0.1,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return schedule


# ---------------------------------------------------------------------------
# SGD
# ---------------------------------------------------------------------------

def sgd(lr: Callable, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        del params
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
        )
        lr_t = lr(step)
        updates = jax.tree.map(lambda m: -lr_t * m, mu)
        return updates, {"mu": mu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(
    lr: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        gf = _f32(grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], gf)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * g * g, state["nu"], gf
        )
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t
        lr_t = lr(step)

        def upd(m, v, p):
            step_ = m / bc1 / (jnp.sqrt(v / bc2) + eps)
            return -lr_t * (step_ + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments)
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(
    lr: Callable,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Factored RMS-style optimizer; no first moment (memory-lean)."""

    def init(params):
        def make(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"v": jax.tree.map(make, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        # increasing-decay schedule from the paper: 1 - t^{-0.8}
        beta = 1.0 - t**-decay
        lr_t = lr(step)

        def upd(g, v, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of the second moment
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = (
                    vr[..., None] * vc[..., None, :] / denom[..., None]
                )
                new_v = {"vr": vr, "vc": vc}
            else:
                vhat = beta * v["v"] + (1 - beta) * g2
                new_v = {"v": vhat}
            u = gf * jax.lax.rsqrt(vhat + eps)
            # RMS clip (adafactor's built-in update clipping)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            du = -lr_t * (u + weight_decay * p.astype(jnp.float32))
            return du, new_v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_vs = treedef.unflatten([o[1] for o in outs])
        return updates, {"v": new_vs}

    return Optimizer(init, update)


def make_optimizer(
    name: str, lr_schedule: Callable, weight_decay: float = 0.1
) -> Optimizer:
    if name == "adamw":
        return adamw(lr_schedule, weight_decay=weight_decay)
    if name == "adafactor":
        return adafactor(lr_schedule)
    if name == "sgd":
        return sgd(lr_schedule)
    raise ValueError(f"unknown optimizer {name}")
