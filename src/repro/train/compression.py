"""Int8 error-feedback gradient compression for the DP all-reduce.

At 1000+-node scale the data-parallel gradient all-reduce is the dominant
inter-pod collective.  Quantizing gradients to int8 cuts its bytes 2x (vs
bf16) / 4x (vs fp32); **error feedback** (Seide et al. 2014) keeps SGD
convergence: the quantization residual is carried into the next step, so the
compression error telescopes instead of accumulating.

    e_t      : residual state (same pytree as grads, fp32)
    c_t      = quantize(g_t + e_t)
    e_{t+1}  = (g_t + e_t) - dequantize(c_t)
    ĝ_t      = all_reduce(c_t) -> dequantize

Quantization is per-leaf symmetric int8 (scale = max|x| / 127).  On a real
mesh the int8 payload is what crosses ICI — ``compressed_psum`` shows the
shard_map wiring (psum over int32 accumulators to avoid int8 overflow: with
≤ 2^23 / 127 ≈ 66k shards headroom, far beyond any mesh).  In the jit/GSPMD
train step the same math runs as a grad transform (quantize→dequantize with
error feedback) so convergence behaviour is testable off-mesh.
"""

from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "init_error_state",
    "compress_decompress",
    "quantize_int8",
    "dequantize_int8",
    "compressed_psum",
]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads, err):
    """Error-feedback int8 round trip.  Returns (ĝ, new_err)."""

    def one(g, e):
        tot = g.astype(jnp.float32) + e
        q, scale = quantize_int8(tot)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), tot - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )


def compressed_psum(grads, err, axis_names: Sequence[str]):
    """The on-mesh form: int8 quantize -> **all-gather(int8)** -> local
    dequant-sum, with error feedback.  Call inside ``shard_map``.

    Why all-gather and not psum: summing int8 across P shards needs ≥
    log2(127·P) bits, so a psum would carry int32 on the wire — zero
    savings.  Gathering the int8 payloads and reducing locally moves
    ~n·(P−1)/P bytes per device vs ~2·n·2·(P−1)/P for a ring bf16
    all-reduce: **4× fewer wire bytes** (+ one fp32 scale per leaf).  This
    is the standard compressed-collective formulation (1-bit Adam family);
    intended for the *cross-pod* axis where links are scarce — use P small
    (e.g. 2 pods), since the gather buffer is [P, n] int8.

    The per-shard scale is pmax'd so every shard dequantizes with a common
    factor; error feedback keeps convergence (tests/test_train.py).
    """
    axes = tuple(axis_names)
    nshards = jax.lax.psum(1, axes)

    def one(g, e):
        tot = g.astype(jnp.float32) + e
        scale = jnp.max(jnp.abs(tot)) / 127.0
        scale = jax.lax.pmax(jnp.maximum(scale, 1e-30), axes)
        q = jnp.clip(jnp.round(tot / scale), -127, 127).astype(jnp.int8)
        gathered = jax.lax.all_gather(q, axes)  # int8 on the wire
        summed = jnp.sum(gathered.astype(jnp.float32), axis=0)
        deq_local = q.astype(jnp.float32) * scale
        mean = summed * scale / nshards
        return mean.astype(g.dtype), tot - deq_local

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
