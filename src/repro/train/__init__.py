"""Training substrate: optimizers, train step, checkpointing, loop.

* ``optim``        — SGD / AdamW / Adafactor + schedules + clipping
* ``train_step``   — microbatched grad-accumulating step builder
* ``compression``  — int8 error-feedback gradient compression
* ``checkpoint``   — atomic async checkpoints, mesh-agnostic restore
* ``loop``         — watchdog / preemption / resume envelope
"""

from . import checkpoint, compression, loop, optim, train_step
from .checkpoint import Checkpointer
from .loop import LoopConfig, run_loop
from .optim import make_optimizer, warmup_cosine
from .train_step import TrainHParams, TrainState, init_state, make_train_step

__all__ = [
    "Checkpointer",
    "LoopConfig",
    "TrainHParams",
    "TrainState",
    "checkpoint",
    "compression",
    "init_state",
    "loop",
    "make_optimizer",
    "make_train_step",
    "optim",
    "run_loop",
    "train_step",
    "warmup_cosine",
]
