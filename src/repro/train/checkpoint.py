"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic.

Layout (one directory per step)::

    <dir>/step_000420/
        manifest.json      # step, leaf paths, shapes, dtypes, leaf files
        leaf_00000.npy ... # one .npy per state leaf (host numpy)
    <dir>/LATEST           # atomic pointer file -> "step_000420"

Guarantees used by the restart path:

* **atomicity** — writes land in ``.tmp-step_X`` and are ``os.rename``-d
  into place only after fsync; a crash mid-save never corrupts the previous
  checkpoint, and LATEST flips last;
* **async** — ``save_async`` snapshots device arrays to host (blocking only
  for the device->host copy) then writes on a background thread, so the
  train loop overlaps checkpoint I/O with the next steps;
* **mesh-agnostic restore** — leaves are stored as *full* (unsharded)
  host arrays keyed by pytree path.  ``restore`` rebuilds the pytree and
  ``device_put``s each leaf with the sharding derived from the *current*
  policy — so a job checkpointed on 256 chips restarts on 512 (or 8): this
  is the elastic-scaling contract;
* **retention** — ``keep`` most recent checkpoints are retained, older ones
  deleted after a successful save (never before).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["Checkpointer", "save", "restore", "latest_step"]


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        out.append((jax.tree_util.keystr(path), leaf))
    return out


def latest_step(directory: str) -> Optional[int]:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not name.startswith("step_"):
        return None
    return int(name[len("step_") :])


def save(directory: str, step: int, state) -> str:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:06d}"
    tmp = os.path.join(directory, f".tmp-{name}")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_names(state)
    manifest: Dict[str, Any] = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {
                "path": path,
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    pointer_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(pointer_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.rename(pointer_tmp, os.path.join(directory, "LATEST"))
    return final


def restore(directory: str, state_like, step: Optional[int] = None,
            shardings=None):
    """Rebuild ``state_like``'s pytree from disk.

    ``state_like`` provides structure (may be ShapeDtypeStructs).
    ``shardings`` (optional pytree of NamedSharding, same structure) reshards
    each leaf for the current mesh — mismatched meshes are fine because the
    stored leaves are unsharded host arrays.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:06d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    by_path = {e["path"]: e for e in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
        else [None] * len(flat)
    )
    leaves = []
    for (kpath, like), shd in zip(flat, shard_flat):
        key = jax.tree_util.keystr(kpath)
        if key not in by_path:
            raise KeyError(f"checkpoint misses leaf {key}")
        entry = by_path[key]
        arr = np.load(os.path.join(path, entry["file"]))
        want_shape = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != {want_shape}"
            )
        dtype = getattr(like, "dtype", arr.dtype)
        arr = arr.astype(dtype)
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(leaves), manifest["step"]


class Checkpointer:
    """Async wrapper with retention.  One in-flight save at a time — a new
    ``save_async`` waits for the previous write to finish (device->host
    snapshot is taken synchronously so the state can keep mutating)."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, state) -> None:
        self.wait()
        # snapshot to host NOW (cheap vs. step time; device buffer freed)
        host_state = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), state
        )

        def work():
            try:
                save(self.directory, step, host_state)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, state) -> str:
        self.wait()
        out = save(self.directory, step, state)
        self._gc()
        return out

    def restore_latest(self, state_like, shardings=None):
        self.wait()
        return restore(self.directory, state_like, shardings=shardings)

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n[len("step_") :])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:06d}"),
                ignore_errors=True,
            )
