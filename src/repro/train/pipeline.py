"""Pipeline parallelism over the ``pod`` axis (GPipe-style, looped SPMD).

At 2 pods the multi-pod mesh's outer axis can either replicate (outer DP —
the dry-run default) or **pipeline**: each pod holds half the depth and
microbatch activations stream pod0 -> pod1 through ``ppermute`` — turning
the cross-pod traffic from a full gradient all-reduce into boundary
activations (B_micro × S × d per tick), which is the standard reason to
pipeline across the slow inter-pod links.

Schedule: the looped/collective formulation (as in praxis/MaxText pipeline
layers).  All stages run the SAME program for ``M + stages − 1`` ticks; at
tick t, stage 0 injects microbatch t (or zeros in the drain phase), every
stage applies its half of the periods, and boundary activations rotate
forward one stage.  The last stage's head+loss contributions are collected
where valid (``t ≥ stages − 1``).  ``jax.grad`` differentiates through the
whole schedule — ``ppermute`` transposes to the reverse rotation, giving
the backward drain automatically.

Scope (documented): homogeneous decoder-only patterns (no enc-dec / vlm
prefix), depth split evenly across stages.  Used by the dry-run as the
``pp2`` §Perf alternative for the multi-pod mesh, and validated numerically
against the sequential loss in ``tests/test_pipeline.py`` (2 host devices,
subprocess).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..models import model as model_lib
from ..models.layers import apply_norm
from ..models.model import _apply_block  # same block code as the assembly

__all__ = ["pipeline_loss_fn", "make_pp_loss_for_mesh"]


def _run_periods(params_periods, x, cfg, positions, vary=()):
    """Apply this stage's stacked periods (scan, rematted like forward).

    The aux accumulator is [1]-shaped, not scalar: rank-0 floats crossing
    the shard_map linearization boundary break the pinned JAX's transpose
    (scalar residuals get all-axes names; see ``_pvary``)."""
    aux0 = _pvary(jnp.zeros((1,), jnp.float32), vary)

    def period_fn(carry, pp):
        h, aux = carry
        for bi, blk in enumerate(cfg.pattern):
            h, aux = _apply_block(
                pp[f"b{bi}"], blk, h, cfg, positions, None, aux
            )
        return (h, aux), None

    fn = jax.checkpoint(period_fn) if cfg.remat else period_fn
    (x, aux), _ = jax.lax.scan(fn, (x, aux0), params_periods)
    return x, aux


def _pvary(x, axes):
    """Mark a constant as varying over the manual axes (shard_map vma typing
    requires scan carries to have consistent varying sets).  Older JAX (the
    pinned 0.4.x) has no vma typing at all — there the marking is a no-op."""
    if not axes:
        return x
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, tuple(axes))
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, tuple(axes), to="varying")  # newer spelling
    # pre-vma JAX (pinned 0.4.x): no varying annotation exists.  Tie the
    # constant to the manual axes with a zero-valued axis_index term so it
    # enters the shard_map jaxpr as a device-dependent value rather than a
    # captured constant — the old transpose machinery mishandles rank-0
    # constant scan carries (_SpecError on the cotangent).
    bump = sum(jax.lax.axis_index(a) for a in axes) * 0
    return x + bump.astype(x.dtype)


def pipeline_loss_fn(params, batch, cfg, *, stages: int, microbatches: int,
                     axis: str = "pod", all_axes: Tuple[str, ...] = ()):
    """Per-shard pipelined loss.  MUST run inside ``shard_map`` over a mesh
    that has ``axis``; ``params['periods']`` leaves carry this stage's
    n_periods/stages slice (leading dim already divided)."""
    stage = jax.lax.axis_index(axis)
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    m = microbatches
    assert b % m == 0
    mb_tokens = tokens.reshape(m, b // m, s)
    mb_labels = labels.reshape(m, b // m, s)
    positions = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b // m, s)
    )
    d = cfg.d_model
    ticks = m + stages - 1
    perm = [(i, (i + 1) % stages) for i in range(stages)]

    def head_loss(x, labels_mb):
        x = apply_norm(x, params["final_norm"], cfg.norm)
        logits = model_lib._head(params, x, cfg)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(labels_mb, 0)[..., None], axis=-1
        )[..., 0]
        mask = (labels_mb >= 0).astype(jnp.float32)
        # [1]-shaped sums — keep every float accumulator rank ≥ 1 inside the
        # shard_map body (scalar residuals break the pinned JAX transpose)
        return (
            jnp.sum((logz - tgt) * mask).reshape(1),
            jnp.sum(mask).reshape(1),
        )

    def tick(carry, t):
        buf, loss_sum, tok_sum, aux_sum = carry
        # stage 0 injects microbatch t during the fill phase; other stages
        # consume the rotated boundary activations.
        inj_idx = jnp.clip(t, 0, m - 1)
        injected = jnp.take(params["embed"], mb_tokens[inj_idx], axis=0)
        injected = injected.astype(cfg.dtype)
        x = jnp.where(stage == 0, injected, buf)
        y, aux = _run_periods(params["periods"], x, cfg, positions, vary)
        # last stage: microbatch (t - stages + 1) finishes at tick t
        out_idx = jnp.clip(t - (stages - 1), 0, m - 1)
        lsum, ntok = head_loss(y, mb_labels[out_idx])
        valid = (
            (stage == stages - 1) & (t >= stages - 1) & (t - (stages - 1) < m)
        ).astype(jnp.float32).reshape(1)
        loss_sum = loss_sum + valid * lsum
        tok_sum = tok_sum + valid * ntok
        aux_sum = aux_sum + aux / ticks
        buf = jax.lax.ppermute(y, axis, perm)
        return (buf, loss_sum, tok_sum, aux_sum), None

    vary = tuple(all_axes) or (axis,)
    buf0 = _pvary(jnp.zeros((b // m, s, d), cfg.dtype), vary)
    zero = _pvary(jnp.zeros((1,), jnp.float32), vary)
    (buf, loss_sum, tok_sum, aux_sum), _ = jax.lax.scan(
        tick, (buf0, zero, zero, zero), jnp.arange(ticks)
    )
    # total over stages (only the last stage contributed); mean per token
    loss_sum = jax.lax.psum(loss_sum, axis)
    tok_sum = jax.lax.psum(tok_sum, axis)
    aux_sum = jax.lax.psum(aux_sum, axis) / stages
    nm = model_lib.num_moe_layers(cfg)
    ce = loss_sum / jnp.maximum(tok_sum, 1.0)
    total = ce + (cfg.router_aux * aux_sum / nm if nm else 0.0)
    return total[0]  # rank-1 accumulators squeeze only at the very end


def _stage_slice_specs(params_abs, mesh: Mesh, policy, axis: str = "pod"):
    """Shardings for PP: periods' leading (depth) dim over ``axis``; other
    leaves follow the normal policy rules."""
    from .. import sharding as shd

    base = shd.param_specs(params_abs, policy)

    def fix(path, spec_leaf, abs_leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "periods" in names:
            old = spec_leaf.spec
            rest = tuple(old)[1:] if len(tuple(old)) >= 1 else ()
            # drop any use of `axis` elsewhere in the spec (depth owns it)
            rest = tuple(
                None if (a == axis or (isinstance(a, tuple) and axis in a))
                else a
                for a in rest
            )
            return NamedSharding(mesh, P(axis, *rest))
        return spec_leaf

    return jax.tree_util.tree_map_with_path(fix, base, params_abs)


def make_pp_loss_for_mesh(cfg, mesh: Mesh, policy, batch_abs,
                          *, microbatches: int, axis: str = "pod"):
    """shard_map-wrapped pipelined loss + its in_shardings.

    Returns (fn(params, batch) -> scalar, (param_shardings, batch_shardings))
    where the params pytree is the FULL model (depth dim sharded over
    ``axis`` = each stage stores only its slice).
    """
    from .. import sharding as shd

    stages = mesh.shape[axis]
    assert cfg.n_periods % stages == 0, (cfg.n_periods, stages)
    # the pipeline owns ``axis``: batch parallelism must not use it
    policy = shd.ShardingPolicy(
        mesh, policy.rules.override(batch="data")
    )
    params_abs = model_lib.abstract_params(cfg)
    param_sh = _stage_slice_specs(params_abs, mesh, policy, axis)
    batch_sh = shd.batch_specs(batch_abs, policy)

    param_specs = jax.tree.map(lambda s: s.spec, param_sh)
    batch_specs_ = jax.tree.map(lambda s: s.spec, batch_sh)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(param_specs, batch_specs_),
        out_specs=P(),
    )
    def fn(params, batch):
        # constrain() must be inert per-shard: shard_map already fixes layout
        with shd.use_policy(None):
            loss = pipeline_loss_fn(
                params, batch, cfg, stages=stages,
                microbatches=microbatches, axis=axis,
                all_axes=tuple(mesh.axis_names),
            )
            # mean over the data-parallel shards too
            other = tuple(a for a in mesh.axis_names if a != axis)
            return jax.lax.pmean(loss, other) if other else loss

    return fn, (param_sh, batch_sh)
