"""Train-step builder: loss → grads → (clip, compress) → optimizer update.

``make_train_step(cfg, ...)`` returns a pure ``(state, batch) -> (state,
metrics)`` function plus an ``init_state``.  Features:

* **microbatching** — ``cfg.microbatches`` splits the global batch and
  accumulates grads with ``lax.scan`` (remat-friendly; activations for one
  microbatch at a time);
* **global-norm clipping** (fp32);
* **int8 error-feedback gradient compression** (optional) — the residual
  state lives in ``TrainState.err`` so the transform is a pure function;
* sharding-agnostic: under an active ``repro.sharding`` policy the state
  specs derive from parameter leaf paths (see ``state_logical_axes``).

The TrainState is a registered pytree, so ``jax.jit`` / ``.lower()`` accept
it directly, and checkpointing flattens it with named paths.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import sharding as shd
from ..models import model as model_lib
from . import compression as comp
from .optim import Optimizer, clip_by_global_norm, make_optimizer, warmup_cosine

__all__ = ["TrainState", "make_train_step", "init_state", "TrainHParams"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray
    err: Optional[Any] = None  # compression residual (None = off)


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    total_steps: int = 10_000
    warmup_steps: int = 100
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False


def init_state(key, cfg, hp: TrainHParams = TrainHParams()) -> TrainState:
    params = model_lib.init_params(key, cfg)
    opt = _optimizer(cfg, hp)
    err = comp.init_error_state(params) if hp.compress_grads else None
    return TrainState(
        params=params,
        opt_state=opt.init(params),
        step=jnp.zeros((), jnp.int32),
        err=err,
    )


def _optimizer(cfg, hp: TrainHParams) -> Optimizer:
    sched = warmup_cosine(hp.peak_lr, hp.total_steps, hp.warmup_steps)
    return make_optimizer(cfg.optimizer, sched, weight_decay=hp.weight_decay)


def _constrain_like_params(grads):
    """Pin each (micro)batch gradient to its parameter's sharding.

    Under GSPMD with grad accumulation, an unconstrained per-microbatch
    gradient is ALL-REDUCED over the data axis before being added to the
    accumulator — M all-reduces of the full gradient per step.  Declaring
    the param sharding here turns each into a reduce-scatter onto the
    FSDP-sharded accumulator (ZeRO-2 pattern): ~2× less wire and the
    accumulator stays sharded.  No-op without an active policy (CPU tests).
    """
    pol = shd.active_policy()
    if pol is None:
        return grads
    return jax.tree_util.tree_map_with_path(
        lambda path, g: pol.constrain(
            g, shd._leaf_logical(path, g.ndim, shd.PARAM_AXES)
        ),
        grads,
    )


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    """[B, ...] -> [n, B/n, ...] per leaf (scalar leaves broadcast)."""

    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    cfg,
    hp: TrainHParams = TrainHParams(),
    loss_fn: Optional[Callable] = None,
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict]]:
    """Returns the pure train_step; jit it (with shardings) at the call site."""
    opt = _optimizer(cfg, hp)
    loss_fn = loss_fn or (lambda p, b: model_lib.loss_fn(p, b, cfg))
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    nmicro = max(cfg.microbatches, 1)

    def compute_grads(params, batch):
        if nmicro == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics

        micro = _split_microbatches(batch, nmicro)

        def acc_step(carry, mb):
            g_acc, m_acc = carry
            (_, metrics), grads = grad_fn(params, mb)
            grads = _constrain_like_params(grads)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads
            )
            m_acc = jax.tree.map(lambda a, m: a + m, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        m0 = {
            "loss": jnp.zeros((), jnp.float32),
            "ce": jnp.zeros((), jnp.float32),
            "aux": jnp.zeros((), jnp.float32),
            "ntok": jnp.zeros((), jnp.float32),
        }
        (grads, metrics), _ = jax.lax.scan(
            acc_step, (g0, m0), micro,
            unroll=nmicro if cfg.scan_unroll else 1,
        )
        inv = 1.0 / nmicro
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m * inv, metrics)
        metrics["ntok"] = metrics["ntok"] * nmicro
        return grads, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        grads, metrics = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, hp.clip_norm)
        err = state.err
        if err is not None:
            grads, err = comp.compress_decompress(grads, err)
        updates, opt_state = opt.update(
            grads, state.opt_state, state.params, state.step
        )
        params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
            state.params,
            updates,
        )
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        new_state = TrainState(
            params=params,
            opt_state=opt_state,
            step=state.step + 1,
            err=err,
        )
        return new_state, metrics

    return train_step
