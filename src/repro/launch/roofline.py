"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (assignment formulas):

    compute    = HLO_FLOPs      / (chips × peak_FLOP/s)
    memory     = HLO_bytes      / (chips × HBM_bw)
    collective = collective_B   / (chips × link_bw)

``compiled.cost_analysis()`` provides FLOPs and bytes accessed.  Collective
bytes are NOT in cost_analysis: :func:`collective_bytes` parses the
optimized HLO (``compiled.as_text()``) and sums operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

cost_analysis on the CPU backend reports totals for the *whole program*
(all shards execute on the 512 host "devices", so FLOPs are global); the
per-chip terms divide by the chip count, matching the assignment formulas.

MODEL_FLOPS uses the classic 6·N·D (dense) / 6·N_active·D (MoE) estimate
per training step, or 2·N·D per generated token for decode — the
"useful compute" yardstick the §Roofline table compares HLO_FLOPs against.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from .mesh import HW

__all__ = [
    "RooflineTerms",
    "TraversalNodeTerms",
    "collective_bytes",
    "roofline_terms",
    "model_flops",
    "traversal_node_terms",
]

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

#: one HLO op result, e.g. ``f32[8,128]{1,0}`` or a tuple of them.
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op, by op kind.

    Parses the *optimized* HLO (post-SPMD-partitioning), where shapes are
    already per-shard; an op's result size ~= bytes moved per chip (the
    standard approximation for ring all-gather / reduce-scatter; all-reduce
    moves ~2× its payload — accounted with a factor below).
    """
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        # skip the paired ``-done`` ops (zero-size start tokens parse as 0)
        if b == 0:
            continue
        out[kind] = out.get(kind, 0) + b
    return out


def total_collective_bytes(per_kind: Dict[str, int]) -> float:
    """Weighted wire bytes: ring all-reduce = reduce-scatter + all-gather
    (2× payload); the others move ~1× their result."""
    tot = 0.0
    for kind, b in per_kind.items():
        tot += 2.0 * b if kind == "all-reduce" else float(b)
    return tot


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # as reported by cost_analysis (see flops_scope)
    hlo_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, int]
    model_flops: float
    per_device_hbm_peak: Optional[float] = None
    #: calibrated semantics of cost_analysis on this backend (dryrun
    #: --calibrate): "per_shard" = numbers are already per device.
    flops_scope: str = "per_shard"

    @property
    def _div(self) -> float:
        return float(self.chips) if self.flops_scope == "global" else 1.0

    @property
    def flops_per_device(self) -> float:
        return self.hlo_flops / self._div

    @property
    def bytes_per_device(self) -> float:
        return self.hlo_bytes / self._div

    @property
    def global_flops(self) -> float:
        return self.flops_per_device * self.chips

    @property
    def t_compute(self) -> float:
        # == HLO_FLOPs_global / (chips × peak): evaluated per device
        return self.flops_per_device / HW.peak_flops_bf16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HW.hbm_bw

    @property
    def t_collective(self) -> float:
        # coll_bytes are already per-shard (post-SPMD shapes)
        return self.coll_bytes / HW.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.global_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute-term share of the bound: T_comp / max(all terms)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t else 0.0

    def to_json(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "model_flops": self.model_flops,
            "flops_scope": self.flops_scope,
            "flops_per_device": self.flops_per_device,
            "global_flops": self.global_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "per_device_hbm_peak": self.per_device_hbm_peak,
        }


@dataclasses.dataclass
class TraversalNodeTerms:
    """Analytic bytes/FLOPs for ONE factorized-traversal feature node —
    the fused ``segment_view`` pass vs the unfused extend-then-group pair
    (``repro.core.factorize``).  Shapes: ``n_rows`` view rows with blocks
    (c [N], l [N, k], q [N, k, k]), reduced to ``num_groups`` groups at
    ``degree`` ∈ {1, 2}; ``dtype_bytes`` per element, int32 segment ids.

    The fused kernel reads each input block once and writes only the
    ``[G, k+2, k+2]`` packed output — the extended ``[N, k+1, k+1]``
    tensor never round-trips through memory.  The unfused path writes it
    (extend) and reads it back (group), which is where the predicted
    speedup (a pure byte ratio — both paths are bandwidth-bound, the
    FLOP/byte intensity is far below any machine balance point) comes
    from.  ``achieved_fraction(seconds)`` turns a measured node time into
    the fraction of the HBM bandwidth bound the §Roofline table reports.
    """

    n_rows: int
    k: int
    num_groups: int
    degree: int = 2
    dtype_bytes: int = 4

    def _block_elems(self, k: int) -> int:
        """Elements per row of (c, l[, q]) blocks with k features."""
        return 1 + k + (k * k if self.degree == 2 else 0)

    @property
    def packed_width(self) -> int:
        w = self.k + 2
        return w * w if self.degree == 2 else w

    @property
    def bytes_in(self) -> float:
        """Input blocks + feature column + int32 segment ids."""
        n, b = self.n_rows, self.dtype_bytes
        return n * (self._block_elems(self.k) + 1) * b + n * 4

    @property
    def bytes_fused(self) -> float:
        return self.bytes_in + self.num_groups * self.packed_width * self.dtype_bytes

    @property
    def bytes_unfused(self) -> float:
        """Extend writes the [N, k+1(, k+1)] blocks, group reads them back
        and writes the grouped result — two extra N-sized round-trips."""
        n, b = self.n_rows, self.dtype_bytes
        ext = self._block_elems(self.k + 1)
        return (
            self.bytes_in
            + 2.0 * n * ext * b  # write + re-read of the extended blocks
            + n * b  # re-read of c by the group stage
            + self.num_groups * ext * b
        )

    @property
    def flops_fused(self) -> float:
        """Assembly muls (x·c, x²·c, x·l) + one add per packed cell."""
        n = self.n_rows
        muls = n * (self.k + 2) if self.degree == 2 else n * 1
        return muls + n * self.packed_width

    @property
    def arith_intensity(self) -> float:
        return self.flops_fused / self.bytes_fused if self.bytes_fused else 0.0

    @property
    def t_memory_fused(self) -> float:
        return self.bytes_fused / HW.hbm_bw

    @property
    def t_memory_unfused(self) -> float:
        return self.bytes_unfused / HW.hbm_bw

    @property
    def predicted_speedup(self) -> float:
        """Bandwidth-bound fused-over-unfused node throughput ratio."""
        return self.bytes_unfused / self.bytes_fused if self.bytes_fused else 0.0

    def achieved_gbs(self, seconds: float) -> float:
        return self.bytes_fused / seconds / 1e9 if seconds > 0 else 0.0

    def achieved_fraction(self, seconds: float) -> float:
        """Measured node time → fraction of the HBM bandwidth bound."""
        return self.t_memory_fused / seconds if seconds > 0 else 0.0

    def to_json(self) -> Dict:
        return {
            "n_rows": self.n_rows,
            "k": self.k,
            "num_groups": self.num_groups,
            "degree": self.degree,
            "dtype_bytes": self.dtype_bytes,
            "bytes_fused": self.bytes_fused,
            "bytes_unfused": self.bytes_unfused,
            "flops_fused": self.flops_fused,
            "arith_intensity": self.arith_intensity,
            "t_memory_fused": self.t_memory_fused,
            "predicted_speedup": self.predicted_speedup,
        }


def traversal_node_terms(
    n_rows: int,
    k: int,
    num_groups: int,
    degree: int = 2,
    dtype_bytes: int = 4,
) -> TraversalNodeTerms:
    """Per-node traversal accounting for the §Roofline audit: bytes/FLOPs
    of one fused extend-and-group node from its view shape and degree."""
    if degree not in (1, 2):
        raise ValueError(f"degree must be 1 or 2, got {degree}")
    return TraversalNodeTerms(
        n_rows=int(n_rows),
        k=int(k),
        num_groups=int(num_groups),
        degree=int(degree),
        dtype_bytes=int(dtype_bytes),
    )


def model_flops(cfg, shape) -> float:
    """Analytic 'useful FLOPs' for one step of this cell."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    if shape.kind == "train":
        return 6.0 * n_active * tokens  # fwd + bwd
    return 2.0 * n_active * tokens  # inference fwd only


def roofline_terms(
    cfg,
    shape,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    memory_stats: Optional[Dict] = None,
) -> RooflineTerms:
    per_kind = collective_bytes(hlo_text)
    return RooflineTerms(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=total_collective_bytes(per_kind),
        coll_by_kind=per_kind,
        model_flops=model_flops(cfg, shape),
        per_device_hbm_peak=(memory_stats or {}).get("peak_bytes"),
    )
