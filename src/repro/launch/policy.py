"""Per-(arch × shape) sharding policies — thin façade over ``repro.sharding``.

The logical-axis machinery lives in ``repro.sharding`` (model code imports
it without touching the launch layer); this module re-exports it for
launcher-side use and owns the *named* policy presets that the dry-run and
the hillclimb iterate over.
"""

from __future__ import annotations

from typing import Dict, Optional

from ..sharding import (
    AxisRules,
    SERVE_RULES,
    ShardingPolicy,
    TRAIN_RULES,
    batch_specs,
    cache_specs,
    constrain,
    param_specs,
    state_specs,
    tree_logical_specs,
    use_policy,
)

__all__ = [
    "AxisRules",
    "SERVE_RULES",
    "ShardingPolicy",
    "TRAIN_RULES",
    "PRESETS",
    "batch_specs",
    "cache_specs",
    "constrain",
    "make_policy",
    "param_specs",
    "state_specs",
    "tree_logical_specs",
    "use_policy",
]

#: Named rule-set variants used by §Perf hillclimbing.  Keys are preset
#: names; values are overrides applied to the kind's base rules.
PRESETS: Dict[str, Dict] = {
    "baseline": {},
    # decode long-context: spread the KV cache over data too (batch=1 cells)
    "kv_data_model": {"kv_seq": ("data", "model")},
    # training: put sequence (context) parallel over model instead of TP
    "seq_over_model": {"seq": "model", "ffn": None, "heads": None},
    # training: pure FSDP (no TP)
    "fsdp_only": {"heads": None, "ffn": None, "vocab": None, "expert": None},
    # serving: replicate weights fully, shard batch only
    "replicated_weights": {"heads": None, "ffn": None, "vocab": None},
}


def make_policy(mesh, kind: str, preset: str = "baseline",
                extra: Optional[Dict] = None) -> ShardingPolicy:
    base = TRAIN_RULES if kind == "train" else SERVE_RULES
    rules = AxisRules(base).override(**PRESETS.get(preset, {}))
    if extra:
        rules = rules.override(**extra)
    return ShardingPolicy(mesh, rules)
