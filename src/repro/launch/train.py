"""Training CLI: ``python -m repro.launch.train --arch smollm-135m ...``.

Runs the full stack on whatever devices exist: config -> token pipeline ->
jit'd train step (sharded when ``--mesh`` is given) -> fault-tolerant loop
(checkpoints, watchdog, resume).  ``--smoke`` selects the reduced config so
the same driver exercises the real code path on a laptop.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import sharding as shd
from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.train import (
    LoopConfig,
    TrainHParams,
    init_state,
    make_train_step,
    run_loop,
)

__all__ = ["main"]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--smoke", action="store_true", help="reduced config")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=None)
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--mesh", default=None,
                   help="DxM, e.g. 1x1; shards over real devices")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.microbatches:
        cfg = type(cfg)(**{**cfg.__dict__, "microbatches": args.microbatches})
    hp = TrainHParams(
        peak_lr=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 1),
        compress_grads=args.compress_grads,
    )

    pipe = TokenPipeline(
        vocab=cfg.vocab,
        seq_len=cfg.text_len(args.seq),
        global_batch=args.batch,
        seed=args.seed,
        n_frames=cfg.n_frames,
        n_patches=cfg.n_patches,
        d_model=cfg.d_model,
    )

    state = init_state(jax.random.key(args.seed), cfg, hp)
    step_fn = make_train_step(cfg, hp)

    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh(d, m)
        policy = shd.ShardingPolicy(mesh, shd.TRAIN_RULES)
        state_sh = shd.state_specs(state, policy)
        state = jax.device_put(state, state_sh)
        with shd.use_policy(policy):
            step = jax.jit(step_fn, in_shardings=(state_sh, None))
    else:
        step = jax.jit(step_fn)

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(state.params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    lc = LoopConfig(
        total_steps=args.steps,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        log_every=max(args.steps // 20, 1),
        handle_signals=True,
    )
    result = run_loop(state, step, pipe.batches(), lc)
    first, last = result.history[0]["loss"], result.history[-1]["loss"]
    print(f"[train] loss {first:.4f} -> {last:.4f} over "
          f"{len(result.history)} steps; stragglers={result.straggler_steps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
