"""Multi-pod dry-run: lower + compile every (arch × shape) cell.

MUST be the process entry point (``python -m repro.launch.dryrun``): the
first two lines below pin 512 placeholder host devices BEFORE any other
import so ``jax.make_mesh`` can build the production meshes.  Nothing here
ever allocates a full-scale array — parameters, optimizer state, batches and
caches are ShapeDtypeStructs end to end.

Per cell it records (EXPERIMENTS.md §Dry-run / §Roofline inputs):

* ``compiled.memory_analysis()``  — proves the cell fits per-device HBM
* ``compiled.cost_analysis()``    — HLO FLOPs / bytes for the roofline
* collective bytes parsed from the optimized HLO (``compiled.as_text()``)
* lower/compile wall times

Cost-analysis semantics on this backend are *calibrated*, not assumed:
``--calibrate`` compiles a known matmul on 1 vs N devices and reports
whether FLOPs come back global or per-shard; the roofline reader consumes
the recorded ``flops_scope`` field.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (env var must precede any jax-importing module)
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import sharding as shd
from repro.configs import ARCHS, SHAPES, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import RooflineTerms, collective_bytes, model_flops, total_collective_bytes
from repro.models import model as model_lib
from repro.train.train_step import TrainHParams, init_state, make_train_step

__all__ = ["run_cell", "main"]

#: CPU-backend artifact: XLA CPU cannot run bf16 dots natively, so it hoists
#: ``convert(param: bf16 -> f32)`` out of loops, materializing fp32 copies
#: of (stacked) weights.  TPU executes bf16 natively — these buffers do not
#: exist on the target.  We measure them from the optimized HLO so the
#: memory record can report the TPU-relevant adjusted figure.
_UPCAST_RE = re.compile(
    r"=\s*f32\[([\d,]*)\]\S*\s+(?:fusion|convert|copy)\(%?param"
)


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


#: per-cell production policy choices (rule overrides applied on top of the
#: kind's base rules).  These ARE the production config — the largest train
#: cells turn on sequence-parallel activation saving (act_seq -> model) so
#: stored remat carries fit per-device HBM; every choice is re-derivable
#: from the §Perf hillclimb log.
PROD_OVERRIDES: Dict = {
    ("deepseek-67b", "train_4k"): {"act_seq": "model"},
    # jamba: §Perf hc1 showed act_seq SP loses to plain microbatching here
    # (boundary gathers outweigh the ~5 GB/dev of stored carries).
    ("granite-20b", "train_4k"): {"act_seq": "model"},
    ("mixtral-8x7b", "train_4k"): {"act_seq": "model"},
    ("llava-next-mistral-7b", "train_4k"): {"act_seq": "model"},
}


def _policy(mesh, kind: str, overrides: Optional[Dict] = None):
    rules = shd.TRAIN_RULES if kind == "train" else shd.SERVE_RULES
    ar = shd.AxisRules(rules)
    if overrides:
        ar = ar.override(**{k: tuple(v) if isinstance(v, list) else v
                            for k, v in overrides.items()})
    return shd.ShardingPolicy(mesh, ar)


def _build_cell(cfg, shape, policy):
    """Returns (fn, args_abs, in_shardings) for one cell."""
    kind = shape.kind
    if kind == "train":
        hp = TrainHParams()
        state_abs = jax.eval_shape(
            lambda: init_state(jax.random.key(0), cfg, hp)
        )
        batch_abs = input_specs(cfg, shape)
        fn = make_train_step(cfg, hp)
        in_sh = (
            shd.state_specs(state_abs, policy),
            shd.batch_specs(batch_abs, policy),
        )
        return fn, (state_abs, batch_abs), in_sh

    if kind == "prefill":
        params_abs = model_lib.abstract_params(cfg)
        batch_abs = input_specs(cfg, shape)

        def fn(params, batch):
            return model_lib.prefill(params, batch, cfg, shape.seq_len)

        in_sh = (
            shd.param_specs(params_abs, policy),
            shd.batch_specs(batch_abs, policy),
        )
        return fn, (params_abs, batch_abs), in_sh

    # decode: one new token against a seq_len cache
    params_abs = model_lib.abstract_params(cfg)
    cache_abs = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, shape.global_batch, shape.seq_len)
    )
    toks = input_specs(cfg, shape)

    def fn(params, token, cache, cur_pos):
        return model_lib.decode_step(params, token, cache, cur_pos, cfg)

    in_sh = (
        shd.param_specs(params_abs, policy),
        shd.batch_specs({"token": toks["token"]}, policy)["token"],
        shd.cache_specs(cache_abs, policy),
        shd.batch_specs({"cur_pos": toks["cur_pos"]}, policy)["cur_pos"],
    )
    args = (params_abs, toks["token"], cache_abs, toks["cur_pos"])
    return fn, args, in_sh


def _compile_cell(cfg, shape, policy):
    """Lower+compile one variant; returns (compiled, lower_s, compile_s)."""
    fn, args_abs, in_sh = _build_cell(cfg, shape, policy)
    t0 = time.perf_counter()
    lowered = jax.jit(fn, in_shardings=in_sh).lower(*args_abs)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    return compiled, t1 - t0, t2 - t1


def _extract_cost(compiled) -> Dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_by_kind": collective_bytes(hlo),
    }


def _upcast_bytes(hlo: str) -> float:
    """Bytes of fp32 copies of bf16 params hoisted by the CPU emitter.

    Only the ENTRY computation is scanned: fusion *bodies* also name their
    operands ``%param_N`` and would double-count.
    """
    idx = hlo.rfind("\nENTRY ")
    region = hlo[idx:] if idx >= 0 else hlo
    total = 0.0
    for m in _UPCAST_RE.finditer(region):
        n = 1
        for d in m.group(1).split(","):
            if d:
                n *= int(d)
        total += 4.0 * n
    return total


def _depth_variant(cfg, periods: int):
    """Same architecture at ``periods`` pattern-periods, scans unrolled —
    the cost-extrapolation point (never executed, only lowered)."""
    plen = len(cfg.pattern)
    enc = 0
    if cfg.enc_layers:
        assert cfg.enc_layers % cfg.n_periods == 0, (
            cfg.enc_layers, cfg.n_periods,
        )
        enc = cfg.enc_layers // cfg.n_periods * periods
    return dataclasses.replace(
        cfg,
        n_layers=periods * plen,
        enc_layers=enc,
        microbatches=1,
        scan_unroll=True,  # unrolls the period / encoder scans only
    )


def _combine_costs(c1: Dict, c2: Dict, periods: int) -> Dict:
    """total = c1 + (P-1)·(c2-c1): identical scan bodies extrapolate
    exactly (the whole point of the two-point protocol)."""
    out = {"flops": 0.0, "bytes": 0.0, "coll_by_kind": {}}
    for k in ("flops", "bytes"):
        body = c2[k] - c1[k]
        out[k] = c1[k] + (periods - 1) * body
    kinds = set(c1["coll_by_kind"]) | set(c2["coll_by_kind"])
    for kind in kinds:
        a = c1["coll_by_kind"].get(kind, 0)
        b = c2["coll_by_kind"].get(kind, 0)
        out["coll_by_kind"][kind] = max(a + (periods - 1) * (b - a), 0)
    return out


def _pick_chunk(s: int, target: int) -> int:
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def _add_inner_scan_corrections(
    cfg, shape, policy, c1: Dict, cost: Dict
) -> Dict:
    """Inner while loops (sLSTM per-token, mLSTM/mamba per-chunk, chunked
    attention q/kv sweeps) are counted ONCE by cost analysis.  Each knob is
    compiled at unroll=2; the delta is exactly one loop body across all
    instances in one period, so

        total += P · Σ_scans (iterations − 1) · body

    Attention nests (kv scan inside q scan):
        total_attn = (nq−1)·Δq + nq·(nk−1)·Δkv
    where Δq carries one q body (incl. one kv body) and Δkv one kv body.
    Cross attention keeps its whole KV in a single chunk (length-1 kv scan,
    see models/attention.py), so Δkv touches only self-attention bodies and
    the algebra stays exact for the enc-dec arch.
    """
    if shape.kind == "decode":
        return cost  # decode paths are O(1): no inner scans
    s = cfg.text_len(shape.seq_len)
    corrections = []  # (cfg override, multiplier)
    if any(b.mixer == "slstm" for b in cfg.pattern):
        corrections.append(({"slstm_unroll": 2}, s - 1))
    if any(b.mixer == "mlstm" for b in cfg.pattern):
        nc = max(s // min(cfg.xlstm_chunk, s), 1)
        if nc > 1:
            corrections.append(({"mlstm_unroll": 2}, nc - 1))
    if any(b.mixer == "mamba" for b in cfg.pattern):
        nc = max(s // min(cfg.mamba_chunk, s), 1)
        if nc > 1:
            corrections.append(({"mamba_unroll": 2}, nc - 1))
    from repro.models.attention import (
        CHUNKED_THRESHOLD, DEFAULT_K_CHUNK, DEFAULT_Q_CHUNK,
    )
    s_total = shape.seq_len if cfg.n_patches else s  # vlm: prefix + text
    if (
        any(b.mixer == "attn" for b in cfg.pattern)
        and s_total > CHUNKED_THRESHOLD
    ):
        nq = s_total // _pick_chunk(s_total, DEFAULT_Q_CHUNK)
        nk = s_total // _pick_chunk(s_total, DEFAULT_K_CHUNK)
        corrections.append(({"attn_q_unroll": 2}, nq - 1))
        if nk > 1:
            corrections.append(({"attn_kv_unroll": 2}, nq * (nk - 1)))
    p = cfg.n_periods
    cost.setdefault("corrections", {})
    for overrides, factor in corrections:
        v = dataclasses.replace(_depth_variant(cfg, 1), **overrides)
        compiled, _, _ = _compile_cell(v, shape, policy)
        cu2 = _extract_cost(compiled)
        knob = next(iter(overrides))
        contrib = {}
        for k in ("flops", "bytes"):
            body = max(cu2[k] - c1[k], 0.0)
            contrib[k] = p * factor * body
            cost[k] += contrib[k]
        cost["corrections"][knob] = contrib
        for kind, b2 in cu2["coll_by_kind"].items():
            body = max(b2 - c1["coll_by_kind"].get(kind, 0), 0)
            if body:
                cost["coll_by_kind"][kind] = (
                    cost["coll_by_kind"].get(kind, 0) + p * factor * body
                )
    return cost


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    overrides: Optional[Dict] = None,
    verbose: bool = True,
    cost_pass: bool = True,
    cfg_overrides: Optional[Dict] = None,
) -> Dict:
    """Lower + compile one cell; returns the JSON-able record.

    Pass A (contract): the production form — depth/microbatch scans intact —
    must lower+compile; ``memory_analysis`` proves per-device fit.
    Pass B (roofline): two small unrolled depth-variants (1 and 2 periods)
    whose cost delta is one period body; totals extrapolate exactly since
    scan bodies are identical.  (XLA cost analysis counts a while body once,
    so pass-A cost numbers undercount depth — documented in EXPERIMENTS.md.)
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    if shape_name in cfg.skip_shapes:
        return {
            "arch": arch, "shape": shape_name, "mesh": _mesh_name(multi_pod),
            "status": "skipped",
            "reason": "full-attention arch; long-context decode excluded "
                      "per assignment (DESIGN.md §Shape-applicability)",
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    merged = dict(PROD_OVERRIDES.get((arch, shape_name), {}))
    merged.update(overrides or {})
    policy = _policy(mesh, shape.kind, merged or None)

    rec: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": _mesh_name(multi_pod),
        "chips": int(chips),
        "kind": shape.kind,
        "rule_overrides": merged,
        "cfg_overrides": cfg_overrides or {},
        "status": "ok",
    }
    try:
        with shd.use_policy(policy), mesh:
            # ---- pass A: the contract compile (production form) ----------
            compiled, rec["lower_s"], rec["compile_s"] = _compile_cell(
                cfg, shape, policy
            )
            try:
                mem = compiled.memory_analysis()
                if mem is not None:
                    rec["memory"] = {
                        k: float(getattr(mem, k))
                        for k in (
                            "argument_size_in_bytes",
                            "output_size_in_bytes",
                            "temp_size_in_bytes",
                            "generated_code_size_in_bytes",
                        )
                        if hasattr(mem, k)
                    }
            except Exception as e:  # pragma: no cover
                rec["memory_error"] = repr(e)
            hlo_a = compiled.as_text()
            rec["hlo_len"] = len(hlo_a)
            if "memory" in rec:
                up = _upcast_bytes(hlo_a)
                rec["memory"]["cpu_bf16_upcast_bytes"] = up
                rec["memory"]["temp_adjusted_bytes"] = (
                    rec["memory"].get("temp_size_in_bytes", 0.0) - up
                )
            rec["cost_raw"] = _extract_cost(compiled)

            # ---- pass B: two-point depth extrapolation -------------------
            if cost_pass:
                c1c, _, t1 = _compile_cell(_depth_variant(cfg, 1), shape, policy)
                c2c, _, t2 = _compile_cell(_depth_variant(cfg, 2), shape, policy)
                rec["cost_pass_compile_s"] = t1 + t2
                c1 = _extract_cost(c1c)
                c2 = _extract_cost(c2c)
                cost = _combine_costs(c1, c2, cfg.n_periods)
                cost = _add_inner_scan_corrections(
                    cfg, shape, policy, c1, cost
                )
            else:
                cost = rec["cost_raw"]
        rec["cost"] = cost

        terms = RooflineTerms(
            arch=cfg.name,
            shape=shape.name,
            mesh=rec["mesh"],
            chips=int(chips),
            hlo_flops=cost["flops"],
            hlo_bytes=cost["bytes"],
            coll_bytes=total_collective_bytes(cost["coll_by_kind"]),
            coll_by_kind=cost["coll_by_kind"],
            model_flops=model_flops(cfg, shape),
            per_device_hbm_peak=rec.get("memory", {}).get(
                "temp_adjusted_bytes"
            ),
        )
        rec["roofline"] = terms.to_json()
        if verbose:
            mem_pd = rec.get("memory", {})
            tot_mem = (
                mem_pd.get("argument_size_in_bytes", 0.0)
                + mem_pd.get("temp_adjusted_bytes",
                             mem_pd.get("temp_size_in_bytes", 0.0))
            )
            print(
                f"[dryrun] {arch:24s} {shape_name:12s} {rec['mesh']:11s} "
                f"lower {rec['lower_s']:5.1f}s compile {rec['compile_s']:5.1f}s "
                f"flops/dev {terms.flops_per_device:.3e} "
                f"coll {terms.coll_bytes:.3e}B "
                f"mem/dev {tot_mem/1e9:.2f}GB "
                f"bottleneck={terms.bottleneck}"
            )
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()
        if verbose:
            print(f"[dryrun] {arch} {shape_name} {rec['mesh']} FAILED: {e!r}")
    return rec


def calibrate() -> Dict:
    """Determine whether cost_analysis FLOPs are global or per-shard."""
    mesh = make_production_mesh(multi_pod=False)
    n = 1024
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    flops_expected = 2.0 * n**3

    c1 = jax.jit(lambda a, b: a @ b).lower(x, x).compile()
    f1 = float(c1.cost_analysis()["flops"])

    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    with mesh:
        c2 = (
            jax.jit(lambda a, b: a @ b, in_shardings=(sh, sh))
            .lower(x, x)
            .compile()
        )
    f2 = float(c2.cost_analysis()["flops"])
    scope = "per_shard" if f2 < 0.6 * f1 else "global"
    return {
        "unsharded_flops": f1,
        "sharded_flops": f2,
        "expected": flops_expected,
        "flops_scope": scope,
    }


def all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            yield arch, shape.name


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None, help="architecture id (default: all)")
    p.add_argument("--shape", default=None, help="shape name (default: all)")
    p.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    p.add_argument("--out", default="benchmarks/results/dryrun")
    p.add_argument("--rules", default=None,
                   help="JSON dict of logical-axis rule overrides (hillclimb)")
    p.add_argument("--cfg", default=None,
                   help="JSON dict of ModelConfig field overrides (hillclimb)")
    p.add_argument("--tag", default=None, help="suffix for the output file")
    p.add_argument("--calibrate", action="store_true")
    args = p.parse_args()

    if args.calibrate:
        print(json.dumps(calibrate(), indent=2))
        return 0

    overrides = json.loads(args.rules) if args.rules else None
    cfg_overrides = json.loads(args.cfg) if args.cfg else None
    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]
    cells = [
        (a, s)
        for a, s in all_cells()
        if (args.arch is None or a == args.arch)
        and (args.shape is None or s == args.shape)
    ]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            # the §Roofline table is single-pod only (assignment): the
            # multi-pod pass proves the pod axis shards (pass A) without
            # paying for the cost-extrapolation compiles.
            rec = run_cell(arch, shape, multi_pod=mp, overrides=overrides,
                           cfg_overrides=cfg_overrides, cost_pass=not mp)
            tag = f"_{args.tag}" if args.tag else ""
            fname = f"{arch}_{shape}_{_mesh_name(mp)}{tag}.json"
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(rec, f, indent=2)
            if rec["status"] == "error":
                failures += 1
    print(f"[dryrun] done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
