"""Launch layer: meshes, sharding policies, dry-run, roofline, CLI drivers.

NOTE: ``dryrun`` must be imported/executed as the process entry point (it
pins ``XLA_FLAGS`` before jax init); this package ``__init__`` therefore
does NOT import it.
"""

from . import mesh, policy, roofline
from .mesh import HW, make_host_mesh, make_production_mesh

__all__ = [
    "HW",
    "make_host_mesh",
    "make_production_mesh",
    "mesh",
    "policy",
    "roofline",
]
