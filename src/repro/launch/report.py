"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the sweep JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir benchmarks/results/dryrun]

Reads every record the dry-run wrote and emits the two markdown tables plus
a bottleneck summary.  Keeping this separate from the dry-run means the
tables are always regenerable from the recorded artifacts.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

__all__ = ["load_records", "dryrun_table", "roofline_table", "main"]

_ARCH_ORDER = [
    "whisper-medium", "smollm-135m", "deepseek-67b", "olmo-1b",
    "granite-20b", "xlstm-1.3b", "qwen2-moe-a2.7b", "mixtral-8x7b",
    "llava-next-mistral-7b", "jamba-1.5-large-398b",
]
_SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(directory: str, tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        stem = os.path.basename(path)[: -len(".json")]
        if tag:
            if not stem.endswith(f"_{tag}"):
                continue
        elif any(
            stem.endswith(f"_{t}") for t in ("hc1", "hc2", "hc3")
        ):  # hillclimb variants excluded from baseline tables
            continue
        with open(path) as f:
            recs.append(json.load(f))
    key = lambda r: (
        _ARCH_ORDER.index(r["arch"]) if r["arch"] in _ARCH_ORDER else 99,
        _SHAPE_ORDER.index(r["shape"]) if r["shape"] in _SHAPE_ORDER else 99,
        r["mesh"],
    )
    return sorted(recs, key=key)


def _gb(x) -> str:
    return f"{x / 1e9:.2f}" if x is not None else "—"


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | lower s | compile s | "
        "args GB/dev | temp GB/dev | temp adj GB/dev | overrides |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped "
                f"(sub-quadratic rule) | — | — | — | — | — | — |"
            )
            continue
        if r["status"] == "error":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **ERROR** "
                f"| — | — | — | — | — | — |"
            )
            continue
        mem = r.get("memory", {})
        ov = ",".join(f"{k}→{v}" for k, v in
                      (r.get("rule_overrides") or {}).items()) or "baseline"
        lines.append(
            "| {arch} | {shape} | {mesh} | ok | {lo:.1f} | {co:.1f} | "
            "{a} | {t} | {ta} | {ov} |".format(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                lo=r.get("lower_s", 0), co=r.get("compile_s", 0),
                a=_gb(mem.get("argument_size_in_bytes")),
                t=_gb(mem.get("temp_size_in_bytes")),
                ta=_gb(mem.get("temp_adjusted_bytes")),
                ov=ov,
            )
        )
    return "\n".join(lines)


_HINTS = {
    "compute": "compute-bound: gains need better MXU utilization "
               "(layout, fusion) or fewer redundant FLOPs (remat policy)",
    "memory": "HBM-bound: cut bytes/step — wider fusion, bf16 carries, "
              "larger per-chip batch to amortize weight streaming",
    "collective": "ICI-bound: reshard to remove the dominant collective "
                  "or overlap it with compute (async collectives)",
}


def roofline_table(recs: List[Dict], mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | t_comp s | t_mem s | t_coll s | bottleneck | "
        "MODEL_FLOPS | useful | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r["status"] != "ok":
            continue
        t = r["roofline"]
        lines.append(
            "| {arch} | {shape} | {tc:.3e} | {tm:.3e} | {tl:.3e} | {b} | "
            "{mf:.2e} | {u:.2f} | {fr:.2f} | {hint} |".format(
                arch=r["arch"], shape=r["shape"],
                tc=t["t_compute"], tm=t["t_memory"], tl=t["t_collective"],
                b=t["bottleneck"], mf=t["model_flops"],
                u=t["useful_ratio"], fr=t["roofline_fraction"],
                hint=_HINTS[t["bottleneck"]],
            )
        )
    return "\n".join(lines)


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    out = [
        f"cells: {len(ok)} ok, {len(skipped)} skipped (per assignment), "
        f"{len(err)} errors",
    ]
    bn: Dict[str, int] = {}
    for r in ok:
        if r["mesh"] == "pod16x16":
            b = r["roofline"]["bottleneck"]
            bn[b] = bn.get(b, 0) + 1
    out.append(f"single-pod bottlenecks: {bn}")
    for r in err:
        out.append(f"ERROR {r['arch']} {r['shape']} {r['mesh']}: "
                   f"{r.get('error', '?')}")
    return "\n".join(out)


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="benchmarks/results/dryrun")
    p.add_argument("--tag", default="")
    p.add_argument("--mesh", default="pod16x16")
    args = p.parse_args()
    recs = load_records(args.dir, args.tag)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, args.mesh))
    print("\n## Summary\n")
    print(summary(recs))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
