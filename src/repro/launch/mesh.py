"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests must keep seeing 1 device.

Mesh shapes per the assignment:

* single-pod:  (16, 16)      axes ("data", "model")   — 256 chips
* multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

TPU v5e hardware constants for the roofline live in ``HW`` here so every
consumer (roofline, benchmarks, docs) quotes one source.
"""

from __future__ import annotations

import dataclasses

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "HW"]


@dataclasses.dataclass(frozen=True)
class _Hardware:
    name: str = "TPU v5e"
    peak_flops_bf16: float = 197e12  # per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16e9  # per chip


HW = _Hardware()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
