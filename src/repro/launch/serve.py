"""Serving CLI: ``python -m repro.launch.serve --arch smollm-135m --smoke``.

Boots the continuous-batching engine with random weights and drives a
synthetic request trace through it (prompt lengths and max-new-tokens drawn
from a seeded distribution), reporting throughput and per-request latency.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serve import Engine, Request, ServeConfig

__all__ = ["main"]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prefill-len", type=int, default=32)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = model_lib.init_params(jax.random.key(args.seed), cfg)
    eng = Engine(
        params,
        cfg,
        ServeConfig(
            slots=args.slots,
            prefill_len=args.prefill_len,
            max_len=args.max_len,
            temperature=args.temperature,
            seed=args.seed,
        ),
    )
    rng = np.random.RandomState(args.seed)
    total_new = 0
    for uid in range(args.requests):
        plen = int(rng.randint(4, args.prefill_len))
        toks = [int(t) for t in rng.randint(1, cfg.vocab, size=plen)]
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=args.max_new))
        total_new += args.max_new

    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    gen = sum(len(r.tokens) for r in results)
    lat = sorted(r.latency_s for r in results)
    print(
        f"[serve] {cfg.name}: {len(results)} requests, {gen} tokens in "
        f"{dt:.2f}s ({gen/dt:.1f} tok/s); "
        f"p50 latency {lat[len(lat)//2]*1e3:.0f} ms, "
        f"p100 {lat[-1]*1e3:.0f} ms"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
