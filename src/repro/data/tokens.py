"""Synthetic LM token pipeline.

Deterministic, seekable, host-side generator producing ``{tokens, labels}``
batches (plus frame/patch stubs for the audio/vlm archs).  Design points a
production input pipeline needs and this one honours:

* **deterministic resume** — ``batch_at(step)`` is a pure function of
  (seed, step): a restarted job re-reads exactly the batches it would have
  seen, with no shared iterator state to checkpoint;
* **shard-addressable** — ``batch_at(step, shard, num_shards)`` slices the
  global batch so each data-parallel host loads only its rows;
* **learnable structure** — tokens come from a Zipf-weighted order-2 Markov
  chain, so cross-entropy falls well below the uniform floor and e2e
  training examples show real learning curves (a uniform stream would pin
  loss at ln V).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_frames: int = 0  # whisper stub frontend
    n_patches: int = 0  # llava stub frontend
    d_model: int = 0  # embed dim for the stubs
    branch: int = 32  # Markov successors per state

    def __post_init__(self) -> None:
        rng = np.random.RandomState(self.seed)
        # order-2 Markov chain: state = (prev % 256) -> `branch` successors
        # with Zipf weights.  256 states keeps the table tiny but the
        # structure rich enough to be learnable.
        self._succ = rng.randint(
            0, self.vocab, size=(256, self.branch)
        ).astype(np.int64)
        w = 1.0 / np.arange(1, self.branch + 1) ** 1.1
        self._w = (w / w.sum()).astype(np.float64)

    def _rows(self, step: int, rows: np.ndarray) -> np.ndarray:
        """Token matrix [len(rows), seq_len+1] for the given global rows."""
        out = np.empty((len(rows), self.seq_len + 1), dtype=np.int64)
        for i, r in enumerate(rows):
            rng = np.random.RandomState(
                (self.seed * 1_000_003 + step * 131 + int(r)) % (2**31 - 1)
            )
            toks = np.empty(self.seq_len + 1, dtype=np.int64)
            toks[0] = rng.randint(self.vocab)
            draws = rng.choice(self.branch, size=self.seq_len, p=self._w)
            jitter = rng.rand(self.seq_len) < 0.05  # 5% noise tokens
            noise = rng.randint(0, self.vocab, size=self.seq_len)
            for t in range(self.seq_len):
                state = toks[t] % 256
                toks[t + 1] = (
                    noise[t] if jitter[t] else self._succ[state, draws[t]]
                )
            out[i] = toks
        return out

    def batch_at(
        self, step: int, shard: int = 0, num_shards: int = 1
    ) -> Dict[str, np.ndarray]:
        assert self.global_batch % num_shards == 0
        per = self.global_batch // num_shards
        rows = np.arange(shard * per, (shard + 1) * per)
        toks = self._rows(step, rows)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        rng = np.random.RandomState((self.seed + 7) * 2654435761 % (2**31 - 1) + step)
        if self.n_frames:
            batch["frames"] = rng.randn(per, self.n_frames, self.d_model).astype(
                np.float32
            )
        if self.n_patches:
            batch["patches"] = rng.randn(
                per, self.n_patches, self.d_model
            ).astype(np.float32)
        return batch

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
