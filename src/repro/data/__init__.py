"""Data substrate: synthetic relational datasets (paper's evaluation data)
and the LM token pipeline for the assigned architecture pool."""

from .synthetic import figure1_schema, favorita_like, random_acyclic_schema

__all__ = ["figure1_schema", "favorita_like", "random_acyclic_schema"]
