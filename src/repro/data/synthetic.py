"""Synthetic relational datasets mirroring the paper's evaluation.

* ``figure1_schema``       — the paper's running example (Fig. 1):
                             Sales(P, S), Inventory(L, P, I), Competition(L, C).
* ``favorita_like``        — a schema-faithful stand-in for the Kaggle
                             Favorita set (Fig. 8): a sales fact table joined
                             with items / stores / transactions / oil /
                             holiday dimensions; label ``unit_sales`` derived
                             from ``date, store_nbr, item_nbr, onpromotion``
                             plus noise.  The real data is not
                             redistributable offline; row-count *ratios* and
                             the variable order match the paper, so the
                             factorized-vs-flat runtime ratio is the
                             reproduction target (see DESIGN.md §7).
* ``random_acyclic_schema``— randomized star/snowflake schemas for property
                             tests (hypothesis drives the parameters).
* ``many_cat_schema``      — a star schema with a configurable NUMBER of
                             categorical key attributes (one dimension
                             relation each), the axis
                             ``benchmarks/bench_categorical.py`` sweeps to
                             show the fused multi-output plan is flat in
                             |cat| where the per-pass path is quadratic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.relation import Relation
from repro.core.store import Store
from repro.core.variable_order import VariableOrder, variable_order_from_store

__all__ = [
    "figure1_schema",
    "favorita_like",
    "fd_star_schema",
    "many_cat_schema",
    "random_acyclic_schema",
    "SchemaBundle",
]


@dataclasses.dataclass
class SchemaBundle:
    """A store + hand-crafted variable order + learning roles."""

    store: Store
    vorder: VariableOrder
    features: List[str]
    label: str


# ---------------------------------------------------------------------------
# Paper Figure 1: Sales(P, S), Inventory(L, P, I), Competition(L, C)
# ---------------------------------------------------------------------------

def figure1_schema(
    n_locations: int = 4,
    n_products_per_loc: int = 3,
    n_sales_per_product: int = 3,
    n_competitors_per_loc: int = 2,
    seed: int = 0,
) -> SchemaBundle:
    """The paper's running example, scaled by the given fan-outs.

    Variable order (paper Fig. 1c / Fig. 6):  T → L → {C, P → {S, I}}
    with Competition under C, Sales under S, Inventory under I.
    Features: Inventory, Competitor, Sale is the label (as in Listing 2,
    where relevantColumns = Inventory, Competitor, Sale, T).
    """
    rng = np.random.default_rng(seed)
    locs = np.arange(n_locations, dtype=np.int32)

    # Inventory(L, P, I): each location stocks its own products
    inv_l, inv_p, inv_i = [], [], []
    pid = 0
    products_at: Dict[int, List[int]] = {}
    for l in locs:
        products_at[int(l)] = []
        for _ in range(n_products_per_loc):
            inv_l.append(int(l))
            inv_p.append(pid)
            inv_i.append(float(rng.integers(1, 50)))
            products_at[int(l)].append(pid)
            pid += 1
    # Sales(P, S)
    sal_p, sal_s = [], []
    for p in range(pid):
        for _ in range(n_sales_per_product):
            sal_p.append(p)
            sal_s.append(float(rng.normal(10.0, 3.0)))
    # Competition(L, C)
    com_l, com_c = [], []
    for l in locs:
        for _ in range(n_competitors_per_loc):
            com_l.append(int(l))
            com_c.append(float(rng.integers(1, 10)))

    store = Store(
        [
            Relation.from_columns(
                "Sales", {"P": sal_p}, {"Sale": sal_s}, {"P": pid}
            ),
            Relation.from_columns(
                "Inventory",
                {"L": inv_l, "P": inv_p},
                {"Inventory": inv_i},
                {"L": n_locations, "P": pid},
            ),
            Relation.from_columns(
                "Competition",
                {"L": com_l},
                {"Competitor": com_c},
                {"L": n_locations},
            ),
        ]
    )

    s = VariableOrder("Sale", [VariableOrder.leaf("Sales")])
    i = VariableOrder("Inventory", [VariableOrder.leaf("Inventory")])
    p = VariableOrder("P", [s, i])
    c = VariableOrder("Competitor", [VariableOrder.leaf("Competition")])
    l = VariableOrder("L", [c, p])
    root = VariableOrder.intercept([l])
    return SchemaBundle(
        store=store,
        vorder=root,
        features=["Inventory", "Competitor"],
        label="Sale",
    )


# ---------------------------------------------------------------------------
# Favorita-like star schema (paper Fig. 8 / §5)
# ---------------------------------------------------------------------------

def favorita_like(
    n_dates: int = 64,
    n_stores: int = 16,
    n_items: int = 32,
    sales_fraction: float = 0.5,
    seed: int = 0,
) -> SchemaBundle:
    """Sales(date, store_nbr, item_nbr, unit_sales, onpromotion) joined with
    Transactions(date, store_nbr, transactions), Oil(date, dcoilwtico),
    Items(item_nbr, perishable), Stores(store_nbr, cluster).

    The label unit_sales is generated as a linear function of the paper's
    feature set (date, store_nbr-effects via cluster, item effects via
    perishable, onpromotion) plus noise, so a linear model is learnable and
    the error metrics are meaningful.
    """
    rng = np.random.default_rng(seed)
    dates = np.arange(n_dates, dtype=np.int32)
    stores_ids = np.arange(n_stores, dtype=np.int32)
    items_ids = np.arange(n_items, dtype=np.int32)

    # dimensions
    cluster = rng.integers(1, 6, size=n_stores).astype(np.float64)
    perishable = rng.integers(0, 2, size=n_items).astype(np.float64)
    dcoil = np.cumsum(rng.normal(0, 1, size=n_dates)) + 50.0
    transactions_rows = []
    for d in dates:
        for s in stores_ids:
            transactions_rows.append(
                (int(d), int(s), float(rng.integers(500, 3000)))
            )

    # fact table: a random subset of (date, store, item)
    total = n_dates * n_stores * n_items
    n_sales = max(1, int(total * sales_fraction))
    flat = rng.choice(total, size=n_sales, replace=False)
    f_date = (flat // (n_stores * n_items)).astype(np.int32)
    rem = flat % (n_stores * n_items)
    f_store = (rem // n_items).astype(np.int32)
    f_item = (rem % n_items).astype(np.int32)
    onpromo = rng.integers(0, 2, size=n_sales).astype(np.float64)
    unit_sales = (
        5.0
        + 0.05 * f_date
        + 2.0 * cluster[f_store]
        + 3.0 * perishable[f_item]
        + 4.0 * onpromo
        + rng.normal(0, 1.0, size=n_sales)
    )

    store = Store(
        [
            Relation.from_columns(
                "SalesF",
                {"date": f_date, "store_nbr": f_store, "item_nbr": f_item},
                {"unit_sales": unit_sales, "onpromotion": onpromo},
                {"date": n_dates, "store_nbr": n_stores, "item_nbr": n_items},
            ),
            Relation.from_columns(
                "Transactions",
                {
                    "date": [r[0] for r in transactions_rows],
                    "store_nbr": [r[1] for r in transactions_rows],
                },
                {"transactions": [r[2] for r in transactions_rows]},
                {"date": n_dates, "store_nbr": n_stores},
            ),
            Relation.from_columns(
                "Oil", {"date": dates}, {"dcoilwtico": dcoil}, {"date": n_dates}
            ),
            Relation.from_columns(
                "Items",
                {"item_nbr": items_ids},
                {"perishable": perishable},
                {"item_nbr": n_items},
            ),
            Relation.from_columns(
                "Stores",
                {"store_nbr": stores_ids},
                {"cluster": cluster},
                {"store_nbr": n_stores},
            ),
        ]
    )

    # Variable order (Fig. 8 style): date at the root; store_nbr and item_nbr
    # below; numeric attributes at the bottom of their relation's path.
    oil = VariableOrder("dcoilwtico", [VariableOrder.leaf("Oil")])
    trans = VariableOrder("transactions", [VariableOrder.leaf("Transactions")])
    clus = VariableOrder("cluster", [VariableOrder.leaf("Stores")])
    peri = VariableOrder("perishable", [VariableOrder.leaf("Items")])
    promo = VariableOrder("onpromotion", [VariableOrder.leaf("SalesF")])
    usales = VariableOrder("unit_sales", [promo])
    item = VariableOrder("item_nbr", [peri, usales])
    storev = VariableOrder("store_nbr", [clus, trans, item])
    date = VariableOrder("date", [oil, storev])
    root = VariableOrder.intercept([date])

    # Paper §5: "unit_sales ... is derived from the features date, store_nbr,
    # item_nbr and onpromotion".  date/store_nbr/item_nbr enter as numeric-
    # encoded ids (the paper uses YYYYMMDD-min for date) — raw id features
    # fit poorly, which is why the paper's relative error is ~2.5; we keep
    # the same convention so error magnitudes are comparable.
    return SchemaBundle(
        store=store,
        vorder=root,
        features=["date", "store_nbr", "item_nbr", "onpromotion"],
        label="unit_sales",
    )


# ---------------------------------------------------------------------------
# Many-categorical star schema (the |cat| sweep axis)
# ---------------------------------------------------------------------------

def many_cat_schema(
    n_cat: int = 4,
    domain: int = 16,
    n_rows: int = 2000,
    seed: int = 0,
) -> SchemaBundle:
    """Fact(c0..c{n-1}, x, y) ⋈ Dim_i(c_i, w_i) for i < n_cat.

    Every c_i is a dictionary-encoded key with ``domain`` categories and
    its own dimension relation, so a categorical cofactor batch over all
    of them issues 1 + n_cat + C(n_cat, 2) aggregate outputs — the regime
    where the fused single-pass plan's shared traversal beats the
    per-attribute/per-pair passes quadratically.  The label ``y`` depends
    on a per-category effect of every attribute plus ``x`` and noise, so
    the swept models stay learnable.
    """
    rng = np.random.default_rng(seed)
    keys = {
        f"c{i}": rng.integers(0, domain, n_rows).astype(np.int32)
        for i in range(n_cat)
    }
    effects = [rng.normal(0, 1.0, domain) for _ in range(n_cat)]
    x = rng.normal(0, 2.0, n_rows)
    y = 0.5 * x + rng.normal(0, 0.5, n_rows)
    for i in range(n_cat):
        y = y + effects[i][keys[f"c{i}"]]
    rels = [
        Relation.from_columns(
            "Fact",
            keys,
            {"x": x, "y": y},
            {f"c{i}": domain for i in range(n_cat)},
        )
    ]
    for i in range(n_cat):
        rels.append(
            Relation.from_columns(
                f"Dim{i}",
                {f"c{i}": np.arange(domain, dtype=np.int32)},
                {f"w{i}": rng.normal(0, 1.0, domain)},
                {f"c{i}": domain},
            )
        )
    store = Store(rels)
    return SchemaBundle(
        store=store,
        vorder=variable_order_from_store(store),
        features=["x"],
        label="y",
    )


# ---------------------------------------------------------------------------
# Star schema with planted functional dependencies
# ---------------------------------------------------------------------------

def fd_star_schema(
    n_cat: int = 2,
    domain: int = 16,
    dep_domain: int = 4,
    n_rows: int = 2000,
    seed: int = 0,
) -> SchemaBundle:
    """``many_cat_schema`` with planted FDs: Fact(c0..c{n-1}, x, y, promo)
    ⋈ Dim_i(c_i, d_i, w_i), where each dimension carries a *determined* key
    attribute ``d_i = map_i[c_i]`` with a strictly smaller domain — the
    ``store_nbr → cluster`` pattern of the Favorita schema, expressed as a
    dictionary-encoded key so it can enter the model as a categorical
    feature.  ``Store.infer_fds()`` discovers every ``c_i → d_i`` (each
    Dim_i witnesses it), and the FD-reduced solve over
    ``cat = [c_0..c_{n-1}, d_0..d_{n-1}]`` drops all ``d_i`` blocks.

    The label ``y`` carries a per-category effect of every c_i AND every
    d_i (so the dropped blocks genuinely matter to the model), ``promo``
    is a Bernoulli label driven by the same effects for the GLM leg.
    """
    rng = np.random.default_rng(seed)
    keys = {
        f"c{i}": rng.integers(0, domain, n_rows).astype(np.int32)
        for i in range(n_cat)
    }
    maps = [
        rng.integers(0, dep_domain, domain).astype(np.int64)
        for _ in range(n_cat)
    ]
    c_eff = [rng.normal(0, 1.0, domain) for _ in range(n_cat)]
    d_eff = [rng.normal(0, 1.0, dep_domain) for _ in range(n_cat)]
    x = rng.normal(0, 2.0, n_rows)
    eta = 0.5 * x
    for i in range(n_cat):
        ids = keys[f"c{i}"]
        eta = eta + c_eff[i][ids] + d_eff[i][maps[i][ids]]
    y = eta + rng.normal(0, 0.5, n_rows)
    promo = rng.binomial(1, 1.0 / (1.0 + np.exp(-0.5 * eta))).astype(
        np.float64
    )
    rels = [
        Relation.from_columns(
            "Fact",
            keys,
            {"x": x, "y": y, "promo": promo},
            {f"c{i}": domain for i in range(n_cat)},
        )
    ]
    for i in range(n_cat):
        rels.append(
            Relation.from_columns(
                f"Dim{i}",
                {
                    f"c{i}": np.arange(domain, dtype=np.int32),
                    f"d{i}": maps[i].astype(np.int32),
                },
                {f"w{i}": rng.normal(0, 1.0, domain)},
                {f"c{i}": domain, f"d{i}": dep_domain},
            )
        )
    store = Store(rels)
    return SchemaBundle(
        store=store,
        vorder=variable_order_from_store(store),
        features=["x"],
        label="y",
    )


# ---------------------------------------------------------------------------
# Random acyclic schemas for property testing
# ---------------------------------------------------------------------------

def random_acyclic_schema(
    seed: int,
    n_branches: int = 2,
    max_fanout: int = 4,
    max_rows: int = 12,
) -> SchemaBundle:
    """A random snowflake: root key k0; branch b has relation
    R_b(k0, k_b, x_b) and child relation C_b(k_b, y_b).  Acyclic by
    construction; the hand-built variable order nests k_b under k0."""
    rng = np.random.default_rng(seed)
    n_k0 = int(rng.integers(1, max_fanout + 1))
    rels: List[Relation] = []
    branch_nodes: List[VariableOrder] = []
    features: List[str] = []
    for b in range(n_branches):
        n_kb = int(rng.integers(1, max_fanout + 1))
        rows = int(rng.integers(1, max_rows + 1))
        r_k0 = rng.integers(0, n_k0, size=rows).astype(np.int32)
        r_kb = rng.integers(0, n_kb, size=rows).astype(np.int32)
        r_x = rng.normal(0, 2, size=rows)
        rels.append(
            Relation.from_columns(
                f"R{b}",
                {"k0": r_k0, f"k{b + 1}": r_kb},
                {f"x{b}": r_x},
                {"k0": n_k0, f"k{b + 1}": n_kb},
            )
        )
        crows = int(rng.integers(1, max_rows + 1))
        c_kb = rng.integers(0, n_kb, size=crows).astype(np.int32)
        c_y = rng.normal(0, 2, size=crows)
        rels.append(
            Relation.from_columns(
                f"C{b}",
                {f"k{b + 1}": c_kb},
                {f"y{b}": c_y},
                {f"k{b + 1}": n_kb},
            )
        )
        y_node = VariableOrder(f"y{b}", [VariableOrder.leaf(f"C{b}")])
        x_node = VariableOrder(f"x{b}", [VariableOrder.leaf(f"R{b}")])
        kb_node = VariableOrder(f"k{b + 1}", [x_node, y_node])
        branch_nodes.append(kb_node)
        features.extend([f"x{b}", f"y{b}"])
    k0_node = VariableOrder("k0", branch_nodes)
    root = VariableOrder.intercept([k0_node])
    label = features[-1]
    return SchemaBundle(
        store=Store(rels),
        vorder=root,
        features=features[:-1],
        label=label,
    )
