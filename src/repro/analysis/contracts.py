"""Declared concurrency contracts for ``src/repro``.

This module is the single source of truth shared by the static checker
(`repro.analysis.lockcheck`, `repro.analysis.cow`) and the dynamic lockset
sanitizer (`repro.analysis.sanitizer`).  It declares:

* the **lock hierarchy** — which locks exist, whether they are reentrant,
  and the partial order in which they may be nested;
* the **guarded-by map** — which attributes are protected by which lock,
  and whether the protection covers writes only (copy-on-write fields whose
  readers are deliberately lock-free) or reads *and* writes;
* the **COW discipline** — which catalog maps are strictly replace-only
  (never mutated in place) and which dataclass types are replace-only
  (fields never reassigned after construction);
* **entry contracts** — helper methods that are only ever called with a
  lock already held, so the checker can reason intraprocedurally.

Everything here is plain data (stdlib only): the static checker must run in
a bare-Python CI job with no numpy/jax installed.

Suppressions
------------
A source line (or the line directly above it) containing the tag
``lockcheck:`` suppresses all findings anchored to that line.  The text
after the tag is the human-readable justification; suppressions without a
reason are themselves reported.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Mapping, Tuple

SUPPRESS_TAG = "lockcheck:"


# --------------------------------------------------------------------------
# Locks
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LockSpec:
    """One named lock in the hierarchy.

    ``name`` is the canonical ``Class.attr`` identifier used everywhere
    (contracts, findings, sanitizer reports).  ``reentrant`` distinguishes
    ``RLock`` (self-nesting allowed) from plain ``Lock``/``Condition`` base
    locks (self-nesting is a guaranteed deadlock and is reported).
    """

    name: str
    owner: str
    attr: str
    reentrant: bool
    doc: str = ""


LOCKS: Tuple[LockSpec, ...] = (
    LockSpec(
        "FactorizedService._cycle_lock",
        "FactorizedService",
        "_cycle_lock",
        reentrant=True,
        doc="Serializes drain cycles, folds and batch-group execution.",
    ),
    LockSpec(
        "FactorizedService._lock",
        "FactorizedService",
        "_lock",
        reentrant=False,
        doc="Queue lock: admission, sequencing, backpressure condition base.",
    ),
    LockSpec(
        "FactorizedService._stats_lock",
        "FactorizedService",
        "_stats_lock",
        reentrant=True,
        doc="Per-tenant counter map; leaf lock, nothing acquired under it.",
    ),
    LockSpec(
        "Store._mutate_lock",
        "Store",
        "_mutate_lock",
        reentrant=True,
        doc="Catalog mutation lock (put/append/fold/FD churn).",
    ),
    LockSpec(
        "ViewCache._mu",
        "ViewCache",
        "_mu",
        reentrant=True,
        doc="View-cache entry map + byte/hit accounting.",
    ),
    LockSpec(
        "_AttrDict._mu",
        "_AttrDict",
        "_mu",
        reentrant=False,
        doc="Per-attribute dictionary extension lock (append-only encodings).",
    ),
)

LOCKS_BY_NAME: Dict[str, LockSpec] = {spec.name: spec for spec in LOCKS}

#: Condition variables and the lock they are built over.  Acquiring the
#: condition (``with self._not_full``) IS acquiring the base lock; waiting on
#: it releases only the base lock, so waiting while holding anything else
#: wedges every other holder of that second lock.
CONDITIONS: Dict[str, str] = {
    "FactorizedService._not_full": "FactorizedService._lock",
}

#: Direct edges of the allowed nesting partial order: ``A -> (B, ...)`` means
#: B may be acquired while A is held.  The checker works with the transitive
#: closure; anything not reachable is an ordering violation.
ORDER: Dict[str, Tuple[str, ...]] = {
    "FactorizedService._cycle_lock": (
        "FactorizedService._lock",
        "FactorizedService._stats_lock",
        "Store._mutate_lock",
    ),
    "FactorizedService._lock": ("FactorizedService._stats_lock",),
    "Store._mutate_lock": ("ViewCache._mu", "_AttrDict._mu"),
    "FactorizedService._stats_lock": (),
    "ViewCache._mu": (),
    "_AttrDict._mu": (),
}


# --------------------------------------------------------------------------
# Guarded-by map
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class GuardSpec:
    """One attribute protected by a lock.

    ``policy`` is ``"write"`` for copy-on-write / monotonic fields whose
    readers are deliberately lock-free (only unlocked *writes* are
    violations), ``"full"`` for fields where unlocked reads are races too,
    and ``"memo"`` for idempotent lock-free memo maps (snapshots alias and
    fill them concurrently by design): statically a ``memo`` write still
    needs the lock or an explicit ``# lockcheck:`` suppression, but the
    dynamic sanitizer ignores the field entirely — its empty lockset is the
    documented design, not a race.  ``owners`` lists the classes whose
    ``self.<attr>`` is covered;
    accesses through a non-``self`` receiver match by attribute name alone
    (the guarded names below are unique within ``src/repro`` by design).
    """

    attr: str
    lock: str
    policy: str  # "write" | "full"
    owners: Tuple[str, ...]
    doc: str = ""


GUARDS: Tuple[GuardSpec, ...] = (
    # --- Store catalog (COW: lock-free readers see immutable values) ---
    GuardSpec("_relations", "Store._mutate_lock", "write",
              ("Store", "StoreSnapshot"),
              "Relation catalog; replace-only (see COW_REPLACE_ONLY)."),
    GuardSpec("_fds", "Store._mutate_lock", "write",
              ("Store", "StoreSnapshot"),
              "FD catalog; replace-only (see COW_REPLACE_ONLY)."),
    GuardSpec("_moments", "Store._mutate_lock", "memo",
              ("Store", "StoreSnapshot"),
              "Column-moment memo; snapshot fills are lost-or-correct."),
    GuardSpec("_enc_cols", "Store._mutate_lock", "memo",
              ("Store", "StoreSnapshot"),
              "Per-(relation, attr) encoded-id memo; ids deterministic "
              "from append-only dictionaries."),
    GuardSpec("_rel_versions", "Store._mutate_lock", "write", ("Store",),
              "Per-relation fold watermarks (aliased as ViewCache.watermarks)."),
    GuardSpec("_cofactor_cache", "Store._mutate_lock", "full", ("Store",),
              "Keyed cofactor entries: mutated in place, reads need the lock."),
    GuardSpec("_cat_cache", "Store._mutate_lock", "full", ("Store",),
              "Keyed categorical-cofactor entries."),
    GuardSpec("_red_cache", "Store._mutate_lock", "write", ("Store",),
              "FD-reduction plan memo (snapshots keep their own copy)."),
    GuardSpec("_vorders", "Store._mutate_lock", "write", ("Store",),
              "Traversal variable-order registry."),
    GuardSpec("_dicts", "Store._mutate_lock", "write", ("Store",),
              "Append-only attribute dictionaries (created double-checked)."),
    GuardSpec("_delta_log", "Store._mutate_lock", "write", ("Store",),
              "Pending-delta log; lock-free debt() probe reads are fine."),
    GuardSpec("_fd_version", "Store._mutate_lock", "write", ("Store",),
              "FD-catalog generation counter."),
    GuardSpec("_override_enc", "Store._mutate_lock", "write", ("Store",),
              "Temporary encoding override during drains."),
    GuardSpec("_draining", "Store._mutate_lock", "full", ("Store",),
              "Reentrancy latch for _drain_all."),
    # --- FactorizedService queues / runtime state ---
    GuardSpec("_reads", "FactorizedService._lock", "full", ("FactorizedService",),
              "Pending read-request deque."),
    GuardSpec("_writes", "FactorizedService._lock", "full", ("FactorizedService",),
              "Pending write-request deque."),
    GuardSpec("_seq", "FactorizedService._lock", "full", ("FactorizedService",),
              "Admission sequence counter."),
    GuardSpec("_accepting", "FactorizedService._lock", "full", ("FactorizedService",),
              "Admission gate flag."),
    GuardSpec("_runtime", "FactorizedService._lock", "write", ("FactorizedService",),
              "Runtime handle; lock-free pointer reads are fine."),
    GuardSpec("_shed", "FactorizedService._lock", "write", ("FactorizedService",),
              "Shed-oldest counter; read in cache_info without the lock."),
    GuardSpec("_tenants", "FactorizedService._stats_lock", "full",
              ("FactorizedService",), "Per-tenant counter map."),
    GuardSpec("_snapshot", "FactorizedService._cycle_lock", "full",
              ("FactorizedService",), "Current read snapshot for the cycle."),
    GuardSpec("_writers_since_flush", "FactorizedService._cycle_lock", "full",
              ("FactorizedService",), "Tenants charged for the next fold."),
    GuardSpec("_batches", "FactorizedService._cycle_lock", "write",
              ("FactorizedService",), "Coalescing counters."),
    GuardSpec("_coalesced_requests", "FactorizedService._cycle_lock", "write",
              ("FactorizedService",), "Coalescing counters."),
    GuardSpec("_quarantined", "FactorizedService._cycle_lock", "write",
              ("FactorizedService",), "Poisoned-request log."),
    GuardSpec("_retries", "FactorizedService._cycle_lock", "write",
              ("FactorizedService",), "Retry counter."),
    GuardSpec("_fold_failures", "FactorizedService._cycle_lock", "write",
              ("FactorizedService",), "Failed-fold counter."),
    # --- ViewCache ---
    GuardSpec("_entries", "ViewCache._mu", "full", ("ViewCache",),
              "LRU entry map."),
    GuardSpec("hits", "ViewCache._mu", "write", ("ViewCache",),
              "Hit counter; lock-free reads via cache_info snapshots."),
    GuardSpec("misses", "ViewCache._mu", "write", ("ViewCache",),
              "Miss counter."),
    GuardSpec("evictions", "ViewCache._mu", "write", ("ViewCache",),
              "Eviction counter."),
    # --- _AttrDict (append-only encodings) ---
    GuardSpec("_sorted_vals", "_AttrDict._mu", "write", ("_AttrDict",),
              "Sorted value snapshot for binary search."),
    GuardSpec("_sorted_ids", "_AttrDict._mu", "write", ("_AttrDict",),
              "Ids aligned with _sorted_vals."),
)

GUARDS_BY_ATTR: Dict[str, GuardSpec] = {g.attr: g for g in GUARDS}

#: ``Class.attr`` -> GuardSpec, the canonical field names the sanitizer's
#: access probes report against.
GUARDS_BY_FIELD: Dict[str, GuardSpec] = {
    f"{owner}.{g.attr}": g for g in GUARDS for owner in g.owners
}

#: Constructors (and constructor-like scopes) where guarded attributes may be
#: freely initialised: ``self.x = ...`` before the object is shared is not a
#: race.  Matched by bare function name within any class.
CONSTRUCTOR_SCOPES: FrozenSet[str] = frozenset({"__init__", "__post_init__"})

#: Scopes (``Class.method``) whitelisted to read guarded parent state without
#: the guard: snapshot constructors capture COW maps by reference, which is
#: exactly the pattern the snapshot design blesses.
SNAPSHOT_SCOPES: FrozenSet[str] = frozenset({
    "StoreSnapshot.__init__",
    "Store.snapshot",
})


# --------------------------------------------------------------------------
# Entry contracts + call-edge hints
# --------------------------------------------------------------------------

#: ``Class.method`` -> locks held on entry.  These helpers are only ever
#: called from regions that already hold the named lock(s); the checker
#: verifies their bodies *given* the contract and verifies lexically visible
#: call sites acquire before calling.
ENTRY_HELD: Dict[str, Tuple[str, ...]] = {
    # Store helpers invoked from @_locked methods / explicit with-blocks.
    "Store._drain_all": ("Store._mutate_lock",),
    "Store._fold_relation": ("Store._mutate_lock",),
    "Store._maintain_view_cache": ("Store._mutate_lock",),
    "Store._delta_cofactors": ("Store._mutate_lock",),
    "Store._delta_cat_cofactors": ("Store._mutate_lock",),
    "Store._invalidate": ("Store._mutate_lock",),
    "Store._invalidate_fd_entries": ("Store._mutate_lock",),
    "Store._plan_fd_updates": ("Store._mutate_lock",),
    "Store._bump_fds": ("Store._mutate_lock",),
    "Store._slice_rows": ("Store._mutate_lock",),
    "Store._should_compact": ("Store._mutate_lock",),
    "Store._compact": ("Store._mutate_lock",),
    "Store._entry_current": ("Store._mutate_lock",),
    # Service helpers invoked from the drain cycle (cycle lock held) or the
    # admission path (queue lock held).
    "FactorizedService._admit": ("FactorizedService._lock",),
    "FactorizedService._next_seq": ("FactorizedService._lock",),
    "FactorizedService._drain_cycle": ("FactorizedService._cycle_lock",),
    "FactorizedService._run_batch_group": ("FactorizedService._cycle_lock",),
    "FactorizedService._fail_or_retry": ("FactorizedService._cycle_lock",),
    "FactorizedService._fail_read": ("FactorizedService._cycle_lock",),
    "FactorizedService._flush_pending": ("FactorizedService._cycle_lock",),
    "FactorizedService._charge_store_delta": ("FactorizedService._cycle_lock",),
    "FactorizedService._finish": ("FactorizedService._cycle_lock",),
    "FactorizedService._apply_write": ("FactorizedService._cycle_lock",),
}

#: Methods that *acquire* a lock internally, for call-edge inference: calling
#: one of these while holding lock H adds edge H -> acquired lock.  The
#: static pass also discovers acquisitions lexically; this map resolves
#: cross-class calls through receiver hints below.
METHOD_ACQUIRES: Dict[str, Tuple[str, ...]] = {
    "Store.put": ("Store._mutate_lock",),
    "Store.append": ("Store._mutate_lock",),
    "Store.flush": ("Store._mutate_lock",),
    "Store.add_fd": ("Store._mutate_lock",),
    "Store.infer_fds": ("Store._mutate_lock",),
    "Store.drop_fd": ("Store._mutate_lock",),
    "Store.cofactors": ("Store._mutate_lock",),
    "Store.cat_cofactors": ("Store._mutate_lock",),
    "FactorizedService._stats": ("FactorizedService._stats_lock",),
    "ViewCache.get": ("ViewCache._mu",),
    "ViewCache.put": ("ViewCache._mu",),
    "ViewCache.invalidate": ("ViewCache._mu",),
    "ViewCache.restamp": ("ViewCache._mu",),
    "ViewCache.delta_update": ("ViewCache._mu",),
    "_AttrDict.extend_encode": ("_AttrDict._mu",),
}

#: Receiver-name hints for resolving ``<recv>.method(...)`` to a class when
#: the receiver is not ``self``.  Keys are dotted receiver expressions as
#: rendered by the checker (``self.store`` or bare names).
RECEIVER_CLASS_HINTS: Dict[str, str] = {
    "self.store": "Store",
    "self._store": "Store",
    "store": "Store",
    "self.view_cache": "ViewCache",
    "view_cache": "ViewCache",
    "vc": "ViewCache",
    "self._vc": "ViewCache",
    "svc": "FactorizedService",
    "service": "FactorizedService",
    "self.service": "FactorizedService",
    "self._service": "FactorizedService",
}


# --------------------------------------------------------------------------
# COW discipline
# --------------------------------------------------------------------------

#: Attributes holding strictly replace-only catalog maps: every mutation must
#: build a new dict and swap the reference; in-place ``d[k] = ``, ``del``,
#: ``.update``/``.pop``/``.setdefault``/``.clear`` are violations anywhere,
#: locked or not (snapshots alias these maps by reference).
COW_REPLACE_ONLY: FrozenSet[str] = frozenset({"_relations", "_fds"})

#: Replace-only dataclass fields: ``obj.field = ...`` after construction must
#: go through ``dataclasses.replace`` instead.  ``FunctionalDependency`` is a
#: plain dataclass shared by reference across snapshots; the frozen config
#: types would raise at runtime but are caught statically too.
FROZEN_FIELDS: Dict[str, Tuple[str, ...]] = {
    "FunctionalDependency": ("lhs", "rhs", "mapping", "source"),
    "RetryPolicy": ("max_attempts", "backoff", "multiplier", "max_backoff",
                    "retry_on"),
    "RuntimeConfig": ("poll_interval", "fold_interval", "fold_min_rows",
                      "drain_timeout"),
}

#: Method names that mutate their receiver in place when called on a guarded
#: or replace-only container.
MUTATOR_METHODS: FrozenSet[str] = frozenset({
    "append", "appendleft", "extend", "extendleft", "insert", "remove",
    "pop", "popleft", "popitem", "clear", "update", "setdefault",
    "add", "discard", "record", "sort",
})


@dataclass(frozen=True)
class Contracts:
    """Bundle handed to the checker/sanitizer; defaults to the repo contracts.

    Tests construct alternate bundles for fixture modules.
    """

    locks: Tuple[LockSpec, ...] = LOCKS
    conditions: Mapping[str, str] = field(default_factory=lambda: CONDITIONS)
    order: Mapping[str, Tuple[str, ...]] = field(default_factory=lambda: ORDER)
    guards: Tuple[GuardSpec, ...] = GUARDS
    entry_held: Mapping[str, Tuple[str, ...]] = field(
        default_factory=lambda: ENTRY_HELD)
    method_acquires: Mapping[str, Tuple[str, ...]] = field(
        default_factory=lambda: METHOD_ACQUIRES)
    receiver_hints: Mapping[str, str] = field(
        default_factory=lambda: RECEIVER_CLASS_HINTS)
    cow_replace_only: FrozenSet[str] = COW_REPLACE_ONLY
    frozen_fields: Mapping[str, Tuple[str, ...]] = field(
        default_factory=lambda: FROZEN_FIELDS)
    constructor_scopes: FrozenSet[str] = CONSTRUCTOR_SCOPES
    snapshot_scopes: FrozenSet[str] = SNAPSHOT_SCOPES

    def lock_names(self) -> FrozenSet[str]:
        return frozenset(spec.name for spec in self.locks)

    def lock_by_attr(self) -> Dict[str, Tuple[LockSpec, ...]]:
        """Lock attribute name -> specs sharing it (usually one)."""
        out: Dict[str, list] = {}
        for spec in self.locks:
            out.setdefault(spec.attr, []).append(spec)
        return {attr: tuple(specs) for attr, specs in out.items()}

    def closure(self) -> Dict[str, FrozenSet[str]]:
        closure: Dict[str, set] = {
            name: set(nbrs) for name, nbrs in self.order.items()
        }
        for spec in self.locks:
            closure.setdefault(spec.name, set())
        changed = True
        while changed:
            changed = False
            for reach in closure.values():
                for nxt in tuple(reach):
                    extra = closure.get(nxt, set()) - reach
                    if extra:
                        reach.update(extra)
                        changed = True
        return {name: frozenset(reach) for name, reach in closure.items()}

    def guards_by_attr(self) -> Dict[str, GuardSpec]:
        return {g.attr: g for g in self.guards}

    def reentrant(self, lock_name: str) -> bool:
        spec = LOCKS_BY_NAME.get(lock_name)
        if spec is None:
            for s in self.locks:
                if s.name == lock_name:
                    spec = s
                    break
        return bool(spec and spec.reentrant)


DEFAULT_CONTRACTS = Contracts()


def guard_policy(field_name: str) -> str:
    """Policy for a canonical ``Class.attr`` field name (sanitizer helper)."""
    spec = GUARDS_BY_FIELD.get(field_name)
    return spec.policy if spec is not None else "full"


def guard_lock(field_name: str) -> str:
    spec = GUARDS_BY_FIELD.get(field_name)
    return spec.lock if spec is not None else ""
