"""Concurrency contract checking for the factorized-learning runtime.

Two prongs over one declared contract set (:mod:`repro.analysis.contracts`):

* **Static** — :mod:`repro.analysis.lockcheck` (lock-order / guarded-by /
  condition discipline) and :mod:`repro.analysis.cow` (copy-on-write lint),
  shipped as ``python -m repro.analysis`` with a committed ratchet baseline
  (``analysis_baseline.json``).  Stdlib-only: runs in CI without the
  numeric stack installed.
* **Dynamic** — :mod:`repro.analysis.sanitizer`, an Eraser-style lockset
  race detector plus runtime lock-order assertions, installed into a live
  ``Store``/``FactorizedService`` via the same seam pattern as
  ``FaultInjector`` and wired into the threaded stress tests behind the
  ``sanitize`` pytest marker.
"""

from . import contracts, cow, lockcheck
from .cli import collect, main
from .contracts import Contracts, DEFAULT_CONTRACTS
from .lockcheck import Finding
from .sanitizer import LockSanitizer

__all__ = [
    "Contracts",
    "DEFAULT_CONTRACTS",
    "Finding",
    "LockSanitizer",
    "collect",
    "contracts",
    "cow",
    "lockcheck",
    "main",
]
