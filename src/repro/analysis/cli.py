"""``python -m repro.analysis`` — run the static concurrency checks.

Usage::

    python -m repro.analysis [paths ...] [--baseline FILE]
                             [--write-baseline FILE] [--verbose]

With no paths, scans ``src/repro`` (resolved relative to the repository
root, i.e. the directory containing this package's ``src`` tree).

Baseline ratchet
----------------
``--baseline FILE`` loads a committed JSON file of finding fingerprints
(rule | file | scope | detail — no line numbers, so unrelated edits don't
churn it).  Findings whose fingerprint appears in the baseline are reported
as *ratcheted* and do not fail the run; any new fingerprint fails with exit
code 1.  ``--write-baseline FILE`` writes the current finding set and exits
0 — use it once to ratchet legacy debt, never to paper over a regression.

Exit codes: 0 clean (or all findings ratcheted), 1 new findings, 2 usage
or I/O error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Iterable, List, Sequence

from . import cow, lockcheck
from .contracts import Contracts, DEFAULT_CONTRACTS
from .lockcheck import Finding

BASELINE_VERSION = 1


def collect(paths: Iterable[Path],
            contracts: Contracts = DEFAULT_CONTRACTS) -> List[Finding]:
    """All static findings (lockcheck + cow) over the given roots."""
    findings: List[Finding] = []
    for root in paths:
        findings.extend(lockcheck.check_paths(root, contracts))
        findings.extend(cow.check_paths(root, contracts))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.detail))
    return findings


def load_baseline(path: Path) -> set:
    data = json.loads(path.read_text())
    if not isinstance(data, dict) or "fingerprints" not in data:
        raise ValueError(f"{path}: not a baseline file")
    return set(data["fingerprints"])


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    payload = {
        "version": BASELINE_VERSION,
        "fingerprints": sorted({f.fingerprint for f in findings}),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


def _default_root() -> Path:
    # .../src/repro/analysis/cli.py -> .../src/repro
    return Path(__file__).resolve().parent.parent


def main(argv: Sequence[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static concurrency-contract checker "
                    "(lock order, guarded-by, COW discipline).")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files or directories to scan "
                             "(default: the repro source tree)")
    parser.add_argument("--baseline", type=Path, default=None,
                        help="committed fingerprint baseline; "
                             "ratchets pre-existing findings")
    parser.add_argument("--write-baseline", type=Path, default=None,
                        help="write current findings as the new baseline "
                             "and exit 0")
    parser.add_argument("--verbose", action="store_true",
                        help="also list ratcheted (baselined) findings")
    args = parser.parse_args(argv)

    roots = args.paths or [_default_root()]
    for root in roots:
        if not root.exists():
            print(f"error: no such path: {root}", file=sys.stderr)
            return 2

    try:
        findings = collect(roots)
    except SyntaxError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.write_baseline is not None:
        write_baseline(args.write_baseline, findings)
        print(f"wrote {len(findings)} finding(s) "
              f"({len({f.fingerprint for f in findings})} fingerprint(s)) "
              f"to {args.write_baseline}")
        return 0

    baseline = set()
    if args.baseline is not None:
        try:
            baseline = load_baseline(args.baseline)
        except (OSError, ValueError, json.JSONDecodeError) as exc:
            print(f"error: cannot read baseline: {exc}", file=sys.stderr)
            return 2

    new = [f for f in findings if f.fingerprint not in baseline]
    old = [f for f in findings if f.fingerprint in baseline]

    if args.verbose and old:
        print(f"-- {len(old)} ratcheted finding(s) (in baseline):")
        for f in old:
            print(f"   {f.render()}")
    if new:
        print(f"-- {len(new)} NEW finding(s):")
        for f in new:
            print(f"   {f.render()}")
        print(f"\n{len(new)} new concurrency-contract violation(s); "
              f"fix them or (for deliberate patterns) annotate the line "
              f"with `# lockcheck: <reason>`.")
        return 1
    tag = f", {len(old)} ratcheted" if old else ""
    print(f"analysis clean: {len(findings)} finding(s) total{tag}.")
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via __main__
    sys.exit(main())
