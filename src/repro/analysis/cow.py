"""Copy-on-write discipline lint.

The catalog maps that snapshots capture by reference (``Store._relations``,
``Store._fds``) are **replace-only**: a mutation must build a new dict and
swap the attribute, never edit in place — an in-place edit is visible
through every live snapshot and silently breaks snapshot isolation even
when it happens under the mutate lock.  This pass flags, anywhere in the
scanned tree:

* ``obj.<cow>[k] = v`` / ``del obj.<cow>[k]`` — in-place subscript edits;
* ``obj.<cow>.update/pop/setdefault/clear/popitem(...)`` — mutator calls;
* rebinding a replace-only dataclass field after construction
  (``fd.mapping = ...`` instead of ``dataclasses.replace(fd, ...)``);
* ``object.__setattr__(...)`` — the frozen-dataclass bypass.

Constructors are exempt (``__init__``/``__post_init__`` run before the
object is shared) and ``# lockcheck: <reason>`` suppressions apply as in
:mod:`repro.analysis.lockcheck`.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence

from .contracts import Contracts, DEFAULT_CONTRACTS
from .lockcheck import Finding, _dotted, _suppressed

_DICT_MUTATORS = frozenset({
    "update", "pop", "setdefault", "clear", "popitem",
})


class _CowVisitor(ast.NodeVisitor):
    def __init__(self, path: str, source: str, contracts: Contracts) -> None:
        self.path = path
        self.lines = source.splitlines()
        self.c = contracts
        self.findings: List[Finding] = []
        self._scope: List[str] = []
        self._class: List[str] = []

    # -- scope tracking ----------------------------------------------------

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class.append(node.name)
        self.generic_visit(node)
        self._class.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._scope.append(node.name)
        self.generic_visit(node)
        self._scope.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _scope_name(self) -> str:
        parts = self._class[-1:] + self._scope[-1:]
        return ".".join(parts) if parts else "<module>"

    def _in_constructor(self) -> bool:
        return bool(self._scope) and (
            self._scope[-1] in self.c.constructor_scopes)

    # -- COW map mutations -------------------------------------------------

    def _cow_attr(self, node: ast.expr) -> Optional[str]:
        """Return the replace-only attr name if ``node`` refers to one."""
        if isinstance(node, ast.Attribute) and (
                node.attr in self.c.cow_replace_only):
            return node.attr
        return None

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._check_target(tgt)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_target(node.target)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript):
                attr = self._cow_attr(tgt.value)
                if attr is not None:
                    self._finding(
                        "cow-mutation", tgt.lineno, f"{attr}|del",
                        f"del on replace-only map {attr}; build a new dict "
                        f"and swap the reference instead")
        self.generic_visit(node)

    def _check_target(self, tgt: ast.expr) -> None:
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for elt in tgt.elts:
                self._check_target(elt)
            return
        if isinstance(tgt, ast.Subscript):
            attr = self._cow_attr(tgt.value)
            if attr is not None:
                self._finding(
                    "cow-mutation", tgt.lineno, f"{attr}|setitem",
                    f"in-place item assignment on replace-only map {attr}; "
                    f"snapshots alias it — build a new dict and swap")
            return
        if isinstance(tgt, ast.Attribute) and not self._in_constructor():
            owner_fields = self._frozen_owner(tgt.attr)
            if owner_fields is not None:
                self._finding(
                    "frozen-field", tgt.lineno,
                    f"{owner_fields}.{tgt.attr}",
                    f"rebinds replace-only field {tgt.attr} of "
                    f"{owner_fields} after construction; use "
                    f"dataclasses.replace")

    def _frozen_owner(self, attr: str) -> Optional[str]:
        for owner, fields in self.c.frozen_fields.items():
            if attr in fields:
                return owner
        return None

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            if func.attr in _DICT_MUTATORS:
                attr = self._cow_attr(func.value)
                if attr is not None:
                    self._finding(
                        "cow-mutation", node.lineno,
                        f"{attr}|{func.attr}",
                        f".{func.attr}() on replace-only map {attr}; build "
                        f"a new dict and swap the reference instead")
            # object.__setattr__ is the frozen-dataclass bypass — except in
            # a constructor, where it is how frozen __post_init__ normalizes
            # its own fields.
            if (func.attr == "__setattr__"
                    and _dotted(func.value) == "object"
                    and not self._in_constructor()):
                self._finding(
                    "frozen-field", node.lineno, "object.__setattr__",
                    "object.__setattr__ bypasses frozen/replace-only "
                    "discipline; use dataclasses.replace")
        self.generic_visit(node)

    # -- plumbing ----------------------------------------------------------

    def _finding(self, rule: str, line: int, detail: str,
                 message: str) -> None:
        if _suppressed(self.lines, line):
            return
        self.findings.append(
            Finding(rule, self.path, line, self._scope_name(), detail,
                    message))


def check_source(source: str, path: str = "<string>",
                 contracts: Contracts = DEFAULT_CONTRACTS) -> List[Finding]:
    tree = ast.parse(source, filename=path)
    visitor = _CowVisitor(path, source, contracts)
    visitor.visit(tree)
    return visitor.findings


def check_paths(root: Path,
                contracts: Contracts = DEFAULT_CONTRACTS) -> List[Finding]:
    findings: List[Finding] = []
    paths: Sequence[Path]
    if root.is_file():
        paths = [root]
        rel_to = root.parent
    else:
        paths = sorted(root.rglob("*.py"))
        rel_to = root
    for path in paths:
        findings.extend(check_source(
            path.read_text(), path.relative_to(rel_to).as_posix(),
            contracts))
    return findings
