"""Static lock-order and guarded-by checker for ``src/repro``.

A stdlib-``ast`` pass (no third-party imports — runs in a bare-Python CI
job) that, per file:

1. **Lock-acquisition graph.**  Every acquisition site — ``with self._lock``
   blocks (including ``Condition`` context managers), the ``@_locked``
   decorator, and calls to methods declared in
   ``contracts.METHOD_ACQUIRES`` — is folded into a directed graph of
   *observed* nesting edges ``held -> acquired``.  The graph is checked
   against the declared partial order (``contracts.ORDER``): edges outside
   the transitive closure are ``lock-order`` findings, cycles in the
   observed graph are ``lock-cycle`` findings, and re-acquisition of a
   non-reentrant lock is a ``self-deadlock`` finding.

2. **Condition discipline.**  ``cond.wait()`` while holding any lock other
   than the condition's own base lock is a ``condition-wait`` finding
   (waiting releases only the base lock; everything else stays wedged).
   ``notify``/``notify_all`` without the base lock held is a
   ``condition-notify`` finding.

3. **Guarded-by enforcement.**  Attribute accesses against the declared
   guard map (``contracts.GUARDS``): writes (and, under the ``"full"``
   policy, reads) of a guarded attribute outside a region holding its lock
   are ``guarded-by`` findings.  Constructors, declared snapshot scopes and
   ``# lockcheck: <reason>`` suppression comments are exempt.

The pass is intraprocedural with two contract-driven extensions: functions
in ``contracts.ENTRY_HELD`` are analyzed with their declared locks held, and
calls to known acquiring methods contribute graph edges (receiver resolved
via ``self``/class context or ``contracts.RECEIVER_CLASS_HINTS``).
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .contracts import SUPPRESS_TAG, Contracts, DEFAULT_CONTRACTS


# --------------------------------------------------------------------------
# Findings
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    """One checker finding.

    The fingerprint deliberately omits the line number so unrelated edits in
    the same file don't churn the ratchet baseline — a finding is identified
    by (rule, file, enclosing scope, detail).
    """

    rule: str
    path: str
    line: int
    scope: str
    detail: str
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.scope}|{self.detail}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.scope}: "
                f"{self.message}")


# --------------------------------------------------------------------------
# Helpers
# --------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """Render ``a.b.c`` attribute/name chains; None for anything else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        if base is None:
            return None
        return f"{base}.{node.attr}"
    return None


def _suppressed(lines: Sequence[str], lineno: int) -> bool:
    """True if the source line (or the one above) carries the suppress tag."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and SUPPRESS_TAG in lines[ln - 1]:
            return True
    return False


@dataclass
class _Access:
    attr: str
    kind: str  # "read" | "write"
    line: int


class _FileChecker:
    """Runs all checks over one parsed module."""

    def __init__(self, path: str, tree: ast.Module, source: str,
                 contracts: Contracts) -> None:
        self.path = path
        self.tree = tree
        self.lines = source.splitlines()
        self.c = contracts
        self.closure = contracts.closure()
        self.lock_attrs = contracts.lock_by_attr()
        self.guards = contracts.guards_by_attr()
        self.findings: List[Finding] = []
        #: observed nesting edges: (held, acquired) -> first line seen
        self.edges: Dict[Tuple[str, str], Tuple[int, str]] = {}
        #: acquisitions with nothing held (graph nodes)
        self.acquired: Set[str] = set()

    # -- entry point -------------------------------------------------------

    def run(self) -> List[Finding]:
        for node in self.tree.body:
            self._visit_toplevel(node, cls=None)
        self._check_graph()
        return self.findings

    def _visit_toplevel(self, node: ast.AST, cls: Optional[str]) -> None:
        if isinstance(node, ast.ClassDef):
            for child in node.body:
                self._visit_toplevel(child, cls=node.name)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._check_function(node, cls)
        # module-level statements outside functions rarely touch locks; the
        # guarded attrs are instance state, so nothing to do here.

    # -- per-function analysis --------------------------------------------

    def _qualname(self, cls: Optional[str], func: str) -> str:
        return f"{cls}.{func}" if cls else func

    def _check_function(self, fn: ast.FunctionDef, cls: Optional[str]) -> None:
        qual = self._qualname(cls, fn.name)
        held: List[str] = list(self.c.entry_held.get(qual, ()))
        for deco in fn.decorator_list:
            name = _dotted(deco)
            if name and name.split(".")[-1] == "_locked":
                # The _locked decorator wraps the body in the owner's mutate
                # lock; the decorator itself acquires with nothing held.
                self.acquired.add("Store._mutate_lock")
                held.append("Store._mutate_lock")
        self._walk_body(fn.body, held, cls, qual)
        # nested defs are visited by _walk_body with a fresh held stack

    def _walk_body(self, body: Sequence[ast.stmt], held: List[str],
                   cls: Optional[str], scope: str) -> None:
        for stmt in body:
            self._walk_stmt(stmt, held, cls, scope)

    def _walk_stmt(self, stmt: ast.stmt, held: List[str],
                   cls: Optional[str], scope: str) -> None:
        if isinstance(stmt, ast.With):
            locks_here: List[str] = []
            for item in stmt.items:
                lock = self._resolve_lock_expr(item.context_expr, cls)
                if lock is not None:
                    self._note_acquire(lock, held + locks_here,
                                       stmt.lineno, scope)
                    locks_here.append(lock)
                else:
                    self._scan_expr(item.context_expr, held, cls, scope)
            self._walk_body(stmt.body, held + locks_here, cls, scope)
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Nested function: runs later, with an unknown held set.  Analyze
            # with an empty stack unless it has its own entry contract.
            self._check_function(stmt, cls)
            return
        if isinstance(stmt, ast.ClassDef):
            self._visit_toplevel(stmt, cls=stmt.name)
            return
        # Generic statement: scan expressions, then recurse into blocks.
        for expr in ast.iter_child_nodes(stmt):
            if isinstance(expr, ast.expr):
                self._scan_expr(expr, held, cls, scope)
        self._scan_targets(stmt, held, cls, scope)
        for attr in ("body", "orelse", "finalbody"):
            block = getattr(stmt, attr, None)
            if block:
                self._walk_body(block, held, cls, scope)
        for handler in getattr(stmt, "handlers", ()) or ():
            self._walk_body(handler.body, held, cls, scope)
        for case in getattr(stmt, "cases", ()) or ():
            self._walk_body(case.body, held, cls, scope)

    # -- lock resolution ---------------------------------------------------

    def _resolve_lock_expr(self, expr: ast.expr,
                           cls: Optional[str]) -> Optional[str]:
        """Map a with-item context expression to a canonical lock name."""
        dotted = _dotted(expr)
        if dotted is None:
            return None
        attr = dotted.split(".")[-1]
        # Condition variables count as their base lock.
        for cond, base in self.c.conditions.items():
            if attr == cond.split(".")[-1]:
                return base
        specs = self.lock_attrs.get(attr)
        if not specs:
            return None
        if dotted == f"self.{attr}" and cls is not None:
            # `with self.<attr>` in a class that is not a declared owner is
            # some other class's lock of the same name — not ours to check.
            for spec in specs:
                if spec.owner == cls:
                    return spec.name
            return None
        if len(specs) == 1:
            return specs[0].name
        # Ambiguous attr on a non-self receiver: try receiver hints.
        owner = self._resolve_receiver_class(
            expr.value if isinstance(expr, ast.Attribute) else expr, cls)
        for spec in specs:
            if spec.owner == owner:
                return spec.name
        return specs[0].name

    def _note_acquire(self, lock: str, held: Sequence[str], line: int,
                      scope: str) -> None:
        self.acquired.add(lock)
        for h in reversed(held):
            if h == lock:
                if not self.c.reentrant(lock):
                    self._finding(
                        "self-deadlock", line, scope, lock,
                        f"re-acquires non-reentrant {lock} while already "
                        f"holding it (guaranteed deadlock)")
                # Reentrant self-edge carries no ordering information.
                continue
            key = (h, lock)
            if key not in self.edges:
                self.edges[key] = (line, scope)
            if lock not in self.closure.get(h, frozenset()):
                self._finding(
                    "lock-order", line, scope, f"{h}->{lock}",
                    f"acquires {lock} while holding {h}, which the declared "
                    f"hierarchy does not allow")

    # -- expression scanning ----------------------------------------------

    def _scan_targets(self, stmt: ast.stmt, held: List[str],
                      cls: Optional[str], scope: str) -> None:
        """Classify assignment/del targets as writes."""
        targets: List[ast.expr] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        elif isinstance(stmt, ast.Delete):
            targets = list(stmt.targets)
        for tgt in targets:
            self._scan_write_target(tgt, held, cls, scope)

    def _scan_write_target(self, tgt: ast.expr, held: List[str],
                           cls: Optional[str], scope: str) -> None:
        if isinstance(tgt, (ast.Tuple, ast.List)):
            for elt in tgt.elts:
                self._scan_write_target(elt, held, cls, scope)
            return
        if isinstance(tgt, ast.Attribute):
            self._check_guarded(tgt.attr, "write", tgt, held, cls, scope)
        elif isinstance(tgt, ast.Subscript):
            # d[k] = v / del d[k] on a guarded attribute is an in-place write
            if isinstance(tgt.value, ast.Attribute):
                self._check_guarded(tgt.value.attr, "write", tgt.value,
                                    held, cls, scope)

    def _scan_expr(self, expr: ast.expr, held: List[str],
                   cls: Optional[str], scope: str) -> None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call):
                self._scan_call(node, held, cls, scope)
            elif isinstance(node, ast.Attribute):
                if isinstance(node.ctx, (ast.Store, ast.Del)):
                    self._check_guarded(node.attr, "write", node, held, cls,
                                        scope)
                elif isinstance(node.ctx, ast.Load):
                    self._check_guarded(node.attr, "read", node, held, cls,
                                        scope)

    def _scan_call(self, call: ast.Call, held: List[str],
                   cls: Optional[str], scope: str) -> None:
        func = call.func
        if not isinstance(func, ast.Attribute):
            return
        meth = func.attr
        recv = func.value
        # cond.wait(...) / cond.notify_all(...)
        if isinstance(recv, ast.Attribute) or isinstance(recv, ast.Name):
            recv_dotted = _dotted(recv)
        else:
            recv_dotted = None
        if recv_dotted is not None:
            recv_attr = recv_dotted.split(".")[-1]
            for cond, base in self.c.conditions.items():
                if recv_attr != cond.split(".")[-1]:
                    continue
                if meth in ("wait", "wait_for"):
                    others = [h for h in held if h != base]
                    if others:
                        self._finding(
                            "condition-wait", call.lineno, scope,
                            f"{cond}|{','.join(sorted(set(others)))}",
                            f"waits on {cond} while holding "
                            f"{', '.join(sorted(set(others)))}; wait() "
                            f"releases only {base}")
                    if base not in held:
                        self._finding(
                            "condition-wait", call.lineno, scope,
                            f"{cond}|unheld",
                            f"waits on {cond} without holding {base}")
                elif meth in ("notify", "notify_all"):
                    if base not in held:
                        self._finding(
                            "condition-notify", call.lineno, scope,
                            f"{cond}|{meth}",
                            f"calls {meth}() on {cond} without holding "
                            f"{base}")
        # Mutator-method call on a guarded attribute: x._reads.append(...)
        if meth in _MUTATORS and isinstance(recv, ast.Attribute):
            self._check_guarded(recv.attr, "write", recv, held, cls, scope)
        # Call-edge inference: known acquiring methods.
        owner = self._resolve_receiver_class(recv, cls)
        if owner is not None:
            acquired = self.c.method_acquires.get(f"{owner}.{meth}")
            if acquired:
                for lock in acquired:
                    self._note_acquire(lock, held, call.lineno, scope)

    def _resolve_receiver_class(self, recv: ast.expr,
                                cls: Optional[str]) -> Optional[str]:
        dotted = _dotted(recv)
        if dotted is None:
            return None
        if dotted == "self":
            return cls
        hint = self.c.receiver_hints.get(dotted)
        if hint is not None:
            return hint
        # `self.<x>` with an unhinted tail: try the tail alone.
        tail = dotted.split(".")[-1]
        return self.c.receiver_hints.get(tail)

    # -- guarded-by --------------------------------------------------------

    def _check_guarded(self, attr: str, kind: str, node: ast.expr,
                       held: List[str], cls: Optional[str],
                       scope: str) -> None:
        spec = self.guards.get(attr)
        if spec is None:
            return
        # Receiver scoping: `self.<attr>` only counts when the enclosing
        # class is a declared owner; non-self receivers match by name (the
        # guarded attribute names are project-unique).
        if isinstance(node, ast.Attribute):
            recv = _dotted(node.value)
            if recv == "self" and cls is not None and cls not in spec.owners:
                return
        if spec.policy in ("write", "memo") and kind == "read":
            return
        if spec.lock in held:
            return
        func_name = scope.split(".")[-1]
        if func_name in self.c.constructor_scopes:
            return
        if scope in self.c.snapshot_scopes:
            return
        if _suppressed(self.lines, node.lineno):
            return
        need = ("write" if kind == "write" else "read")
        self._finding(
            "guarded-by", node.lineno, scope, f"{attr}|{kind}",
            f"{need} of {attr} (guarded by {spec.lock}, policy "
            f"{spec.policy}) outside the lock")

    # -- graph-level checks ------------------------------------------------

    def _check_graph(self) -> None:
        """Cycle detection over the observed acquisition graph."""
        adj: Dict[str, Set[str]] = {}
        for (a, b) in self.edges:
            if a == b:
                continue
            adj.setdefault(a, set()).add(b)
        # Iterative DFS with colors.
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in set(adj) | {b for s in adj.values()
                                              for b in s}}
        for root in sorted(color):
            if color[root] != WHITE:
                continue
            stack: List[Tuple[str, List[str]]] = [(root, [root])]
            while stack:
                node, path = stack.pop()
                if node == "__pop__":
                    color[path[-1]] = BLACK
                    continue
                if color[node] == BLACK:
                    continue
                color[node] = GRAY
                stack.append(("__pop__", [node]))
                for nxt in sorted(adj.get(node, ())):
                    if color[nxt] == GRAY and nxt in path:
                        cyc = path[path.index(nxt):] + [nxt]
                        line, scope = self.edges.get((node, nxt), (0, ""))
                        self._finding(
                            "lock-cycle", line, scope or "<module>",
                            "->".join(cyc),
                            f"observed acquisition cycle "
                            f"{' -> '.join(cyc)}")
                    elif color[nxt] == WHITE:
                        stack.append((nxt, path + [nxt]))

    # -- plumbing ----------------------------------------------------------

    def _finding(self, rule: str, line: int, scope: str, detail: str,
                 message: str) -> None:
        if _suppressed(self.lines, line):
            return
        self.findings.append(Finding(rule, self.path, line, scope, detail,
                                     message))


_MUTATORS = frozenset({
    "append", "appendleft", "extend", "extendleft", "insert", "remove",
    "pop", "popleft", "popitem", "clear", "update", "setdefault",
    "add", "discard", "record", "sort",
})


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------


def check_source(source: str, path: str = "<string>",
                 contracts: Contracts = DEFAULT_CONTRACTS) -> List[Finding]:
    tree = ast.parse(source, filename=path)
    findings = _FileChecker(path, tree, source, contracts).run()
    # The walker can classify one access through two paths (expression scan
    # + assignment-target scan); collapse exact duplicates.
    seen: Set[Tuple[str, int]] = set()
    out: List[Finding] = []
    for f in findings:
        key = (f.fingerprint, f.line)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def check_file(file_path: Path, rel_to: Optional[Path] = None,
               contracts: Contracts = DEFAULT_CONTRACTS) -> List[Finding]:
    source = file_path.read_text()
    rel = (file_path.relative_to(rel_to) if rel_to is not None
           else file_path)
    return check_source(source, rel.as_posix(), contracts)


def check_paths(root: Path,
                contracts: Contracts = DEFAULT_CONTRACTS) -> List[Finding]:
    """Check a file or every ``*.py`` under a directory (sorted, stable)."""
    findings: List[Finding] = []
    if root.is_file():
        return check_file(root, root.parent, contracts)
    for path in sorted(root.rglob("*.py")):
        findings.extend(check_file(path, root, contracts))
    return findings
