"""Eraser-style lockset race sanitizer + runtime lock-order assertions.

Dynamic counterpart of the static checker, sharing the declared contracts
(:mod:`repro.analysis.contracts`).  ``LockSanitizer.install(store=...,
service=...)`` rewires a live ``Store``/``FactorizedService`` pair the same
way ``FaultInjector`` does — by swapping seam attributes, no subclassing:

* every declared lock is replaced with a :class:`SanitizedLock` wrapper
  that keeps a per-thread stack of held locks, asserts the declared
  acquisition order (via the transitive closure of ``contracts.ORDER``) on
  every acquire, and flags re-acquisition of non-reentrant locks;
* the service's backpressure ``Condition`` is rebuilt as a
  :class:`SanitizedCondition` over the wrapped queue lock, recording any
  ``wait()`` entered while the thread holds locks other than the
  condition's own base lock;
* the ``access_hook`` seams on Store / FactorizedService / ViewCache feed a
  simplified Eraser lockset algorithm: for each shared field the sanitizer
  intersects the set of locks held across accesses; once a field has been
  touched by two threads, an empty intersection means no single lock
  consistently protects it — a candidate race.  Fields declared with the
  ``"write"`` policy (copy-on-write / monotonic) only track writes, because
  their lock-free readers are the design, not a bug.

Violations are *recorded*, not raised, so a stress run completes and the
test asserts ``report()`` is empty at the end (raising inside ``acquire``
would itself perturb the schedule under test).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Tuple

from .contracts import Contracts, DEFAULT_CONTRACTS


@dataclass
class OrderViolation:
    thread: str
    held: Tuple[str, ...]
    acquired: str

    def __str__(self) -> str:
        return (f"[{self.thread}] acquired {self.acquired} while holding "
                f"{', '.join(self.held)}")


@dataclass
class WaitViolation:
    thread: str
    condition: str
    held: Tuple[str, ...]

    def __str__(self) -> str:
        return (f"[{self.thread}] waited on {self.condition} while holding "
                f"{', '.join(self.held)}")


@dataclass
class LocksetReport:
    field: str
    kind: str
    thread: str
    held: Tuple[str, ...]

    def __str__(self) -> str:
        locks = ", ".join(self.held) if self.held else "<none>"
        return (f"{self.field}: lockset went empty on {self.kind} in "
                f"[{self.thread}] (held: {locks})")


@dataclass
class _FieldState:
    """Per-field Eraser state: Virgin -> Exclusive(first thread) -> Shared."""

    first_thread: Optional[int] = None
    shared: bool = False
    lockset: Optional[FrozenSet[str]] = None
    reported: bool = False
    reads: int = 0
    writes: int = 0


class _Held(threading.local):
    def __init__(self) -> None:
        self.stack: List[str] = []


class SanitizedLock:
    """Wraps a ``threading.Lock``/``RLock`` with order + reentrancy checks.

    Only ``acquire``/``release``/``__enter__``/``__exit__`` are defined —
    deliberately **no** ``_release_save``/``_acquire_restore``/``_is_owned``
    — so a ``threading.Condition`` built over the wrapper falls back to its
    portable default implementations, which route through ``acquire`` and
    ``release`` and keep the held-stack bookkeeping correct across
    ``wait()``.
    """

    def __init__(self, sanitizer: "LockSanitizer", name: str,
                 inner) -> None:
        self._san = sanitizer
        self._name = name
        self._inner = inner

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._san._on_acquire(self._name)
        return ok

    def release(self) -> None:
        self._san._on_release(self._name)
        self._inner.release()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<SanitizedLock {self._name} over {self._inner!r}>"


class SanitizedCondition(threading.Condition):
    """``Condition`` over a :class:`SanitizedLock` that audits ``wait()``.

    Waiting releases only the condition's base lock; entering a wait while
    holding anything else wedges every other would-be holder of that lock
    for the full wait.  The base Condition machinery itself works unmodified
    because the wrapped lock exposes only the portable subset (see
    :class:`SanitizedLock`).
    """

    def __init__(self, sanitizer: "LockSanitizer", name: str,
                 lock: SanitizedLock) -> None:
        super().__init__(lock)
        self._san = sanitizer
        self._cond_name = name
        self._base_name = lock._name

    def wait(self, timeout: Optional[float] = None) -> bool:
        held = [h for h in self._san._held.stack if h != self._base_name]
        if held:
            self._san._record_wait(self._cond_name, tuple(held))
        return super().wait(timeout)


class LockSanitizer:
    """Installable lockset race detector for Store + FactorizedService."""

    def __init__(self, contracts: Contracts = DEFAULT_CONTRACTS) -> None:
        self.c = contracts
        self.closure = contracts.closure()
        self._policies: Dict[str, str] = {
            f"{owner}.{g.attr}": g.policy
            for g in contracts.guards for owner in g.owners
        }
        self._held = _Held()
        self._meta = threading.Lock()  # guards everything below
        self._fields: Dict[str, _FieldState] = {}
        self.order_violations: List[OrderViolation] = []
        self.wait_violations: List[WaitViolation] = []
        self.empty_locksets: List[LocksetReport] = []
        self.acquisitions: Dict[str, int] = {}
        self.accesses: int = 0

    # -- installation ------------------------------------------------------

    def install(self, store=None, service=None) -> "LockSanitizer":
        """Swap sanitized wrappers into a live store/service pair.

        Must be called before any worker threads start (the swap itself is
        not atomic).  Wrapping the service also wraps its store unless a
        different one is passed explicitly.
        """
        if service is not None and store is None:
            store = service.store
        if store is not None:
            self._install_store(store)
        if service is not None:
            self._install_service(service)
        return self

    def _install_store(self, store) -> None:
        store._mutate_lock = SanitizedLock(
            self, "Store._mutate_lock", store._mutate_lock)
        store.access_hook = self._access
        vc = getattr(store, "view_cache", None)
        if vc is not None:
            vc._mu = SanitizedLock(self, "ViewCache._mu", vc._mu)
            vc.access_hook = self._access
        # Attribute dictionaries are created lazily on first categorical
        # touch; force them into existence now so their extend locks can be
        # wrapped before threads race on them.
        for rel in store.relations():
            for attr in rel.attributes:
                try:
                    store.attr_encoding(rel.name, attr)
                except (KeyError, TypeError, ValueError):
                    continue  # non-encodable column; no dict to wrap
        for d in store._dicts.values():
            d._mu = SanitizedLock(self, "_AttrDict._mu", d._mu)

    def _install_service(self, service) -> None:
        service._cycle_lock = SanitizedLock(
            self, "FactorizedService._cycle_lock", service._cycle_lock)
        service._stats_lock = SanitizedLock(
            self, "FactorizedService._stats_lock", service._stats_lock)
        service._lock = SanitizedLock(
            self, "FactorizedService._lock", service._lock)
        # Rebuild the backpressure condition over the wrapped queue lock so
        # notify/wait and admission all see one lock identity.
        service._not_full = SanitizedCondition(
            self, "FactorizedService._not_full", service._lock)
        service.access_hook = self._access

    # -- lock bookkeeping --------------------------------------------------

    def _on_acquire(self, name: str) -> None:
        stack = self._held.stack
        reentry = name in stack
        with self._meta:
            self.acquisitions[name] = self.acquisitions.get(name, 0) + 1
            if reentry and not self.c.reentrant(name):
                # A plain Lock would already have deadlocked by now (the
                # inner acquire blocks), so in practice this records the
                # wrapper-level evidence for non-blocking acquires.
                self.order_violations.append(OrderViolation(
                    threading.current_thread().name,
                    tuple(stack), name))
            elif not reentry:
                bad = [h for h in stack
                       if name not in self.closure.get(h, frozenset())]
                if bad:
                    self.order_violations.append(OrderViolation(
                        threading.current_thread().name,
                        tuple(stack), name))
        stack.append(name)

    def _on_release(self, name: str) -> None:
        stack = self._held.stack
        # Release innermost matching entry (reentrant locks stack).
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                break

    def _record_wait(self, cond_name: str, held: Tuple[str, ...]) -> None:
        with self._meta:
            self.wait_violations.append(WaitViolation(
                threading.current_thread().name, cond_name, held))

    # -- Eraser lockset ----------------------------------------------------

    def _access(self, field_name: str, kind: str) -> None:
        """Field-access probe (the ``access_hook`` seam target).

        ``field_name`` is the canonical ``Class.attr`` name; ``kind`` is
        ``"read"`` or ``"write"``.
        """
        policy = self._policy(field_name)
        if policy == "memo":
            return  # idempotent lock-free memo map: empty lockset is design
        if policy == "write" and kind == "read":
            return  # lock-free reads are the declared design for COW fields
        held = frozenset(self._held.stack)
        tid = threading.get_ident()
        with self._meta:
            self.accesses += 1
            st = self._fields.setdefault(field_name, _FieldState())
            if kind == "read":
                st.reads += 1
            else:
                st.writes += 1
            if st.first_thread is None:
                st.first_thread = tid
                st.lockset = held
                return
            if not st.shared and tid == st.first_thread:
                # Still exclusive to the first thread: refresh, don't narrow.
                st.lockset = held
                return
            st.shared = True
            assert st.lockset is not None
            st.lockset = st.lockset & held
            if not st.lockset and not st.reported:
                st.reported = True
                self.empty_locksets.append(LocksetReport(
                    field_name, kind, threading.current_thread().name,
                    tuple(sorted(held))))

    def _policy(self, field_name: str) -> str:
        return self._policies.get(field_name, "full")

    # -- reporting ---------------------------------------------------------

    def report(self) -> List[str]:
        with self._meta:
            return ([str(v) for v in self.order_violations]
                    + [str(v) for v in self.wait_violations]
                    + [str(v) for v in self.empty_locksets])

    def assert_clean(self) -> None:
        problems = self.report()
        if problems:
            raise AssertionError(
                "lock sanitizer found {} problem(s):\n  {}".format(
                    len(problems), "\n  ".join(problems)))

    def field_stats(self) -> Dict[str, Tuple[int, int]]:
        """field -> (reads, writes) seen by the probes (test sanity hook)."""
        with self._meta:
            return {name: (st.reads, st.writes)
                    for name, st in self._fields.items()}
