"""Quickstart: factorized linear regression on the paper's Fig. 1 schema.

    PYTHONPATH=src python examples/quickstart.py

Builds the running example — Sales(P, S), Inventory(L, P, I),
Competition(L, C) — computes degree-≤2 aggregates over the factorized join
(never materializing it), and fits SUM-of-squares linear regression with
the paper's batch-gradient-descent procedure, checking against the
closed-form normal-equation solve.
"""

import numpy as np

from repro.core import (
    FactorizedEngine,
    VERSIONS,
    cofactors_factorized,
    cofactors_materialized,
    linear_regression,
)
from repro.data.synthetic import figure1_schema


def main() -> None:
    bundle = figure1_schema(
        n_locations=6, n_products_per_loc=4, n_sales_per_product=5,
        n_competitors_per_loc=3,
    )
    store, vorder = bundle.store, bundle.vorder
    print("Relations:", {r.name: r.num_rows for r in store.relations()})
    print("Flat join rows:", store.materialize_join().num_rows)
    print("Variable order:\n" + vorder.pretty())

    # -- Fig. 2/3-style aggregates over the factorization ---------------------
    eng = FactorizedEngine(store, vorder, ["Sale", "Competitor"],
                           backend="numpy")
    print("\nCOUNT(*)                 =", eng.sum_product([]))
    print("SUM(Sale)                =", eng.sum_product(["Sale"]))
    print("SUM(Sale * Competitor)   =",
          eng.sum_product(["Sale", "Competitor"]))

    # -- cofactors: factorized == materialized (Prop. 4.1) --------------------
    cols = bundle.features + [bundle.label]
    fact = cofactors_factorized(store, vorder, cols, backend="numpy")
    flat = cofactors_materialized(store, cols)
    err = np.abs(fact.matrix() - flat.matrix()).max()
    print(f"\ncofactor matrix ({len(cols) + 1}x{len(cols) + 1}), "
          f"fact-vs-flat max |Δ| = {err:.2e}")

    # -- the paper's full pipeline (v1) vs closed form -------------------------
    res = linear_regression(store, vorder, bundle.features, bundle.label,
                            VERSIONS["v1"])
    closed = linear_regression(store, vorder, bundle.features, bundle.label,
                               VERSIONS["closed"])
    print(f"\nBGD      θ = {np.round(res.theta[:-1], 4)} "
          f"({res.iterations} iterations, {res.seconds_total * 1e3:.1f} ms)")
    print(f"closed   θ = {np.round(closed.theta[:-1], 4)}")
    metrics = res.evaluate(store, bundle.features, bundle.label)
    print(f"avg abs err = {metrics['avg_abs_err']:.4f}, "
          f"avg rel err = {metrics['avg_rel_err']:.4f}")


if __name__ == "__main__":
    main()
