"""Where the paper's algebra meets the LM stack: cofactor-based linear
probes over transformer hidden states.

    PYTHONPATH=src python examples/linear_probe.py

A linear probe (predict a property from frozen hidden states) is EXACTLY
the paper's setting: least-squares regression whose gradient is a function
of degree-≤2 aggregates.  So instead of storing an [N, d] activation matrix
and iterating over it, we stream activations through the **cofactor
accumulator** (the Pallas gram kernel's math) — commutativity with union
(Prop. 4.1) means batches fold into a running [d+2, d+2] matrix and the
probe is solved in closed form afterwards, independent of N.  This is also
the distributed-evaluation pattern: per-shard cofactors + one psum.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.kernels import ops
from repro.models import model


def main() -> None:
    cfg = get_config("smollm-135m", smoke=True)
    params = model.init_params(jax.random.key(0), cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=3)

    @jax.jit
    def hidden_states(tokens):
        """Mean-pooled final hidden state per sequence (frozen LM)."""
        x = jnp.take(params["embed"], tokens, axis=0)
        # reuse the model's forward, reading the pre-head representation by
        # probing the logits against the tied embedding is lossy — instead
        # run the stack by calling forward and mean-pool the logits' argmax
        # embedding; simplest faithful probe source: the embedding mean.
        logits, _ = model.forward(params, {"tokens": tokens}, cfg)
        return jnp.mean(logits[..., : cfg.vocab], axis=1)  # [B, V]

    # probe target: fraction of tokens < vocab/2 in the sequence (a property
    # linearly decodable from frequency statistics)
    def target(tokens):
        return (tokens < cfg.vocab // 2).mean(axis=1)

    d = 16  # probe on a random projection of the state (keeps demo fast)
    key = jax.random.key(1)
    proj = jax.random.normal(key, (cfg.vocab, d), jnp.float32) / np.sqrt(d)

    # stream batches through the cofactor accumulator (union commutativity)
    cof = np.zeros((d + 2, d + 2))
    n_rows = 0
    feats_all, ys_all = [], []
    for step in range(16):
        batch = pipe.batch_at(step)
        h = np.asarray(hidden_states(jnp.asarray(batch["tokens"])))
        f = h @ np.asarray(proj)  # [B, d]
        y = np.asarray(target(batch["tokens"]))
        z = np.concatenate(
            [np.ones((f.shape[0], 1)), f, y[:, None]], axis=1
        )
        cof += np.asarray(ops.gram(jnp.asarray(z, jnp.float32)), np.float64)
        n_rows += z.shape[0]
        feats_all.append(f)
        ys_all.append(y)

    # closed-form solve on the accumulated cofactors (paper §3.4)
    from repro.core import solve_cofactor

    ridge = 1e-3
    theta = solve_cofactor(cof, ridge=ridge)
    f = np.concatenate(feats_all)
    y = np.concatenate(ys_all)
    zfull = np.concatenate([np.ones((f.shape[0], 1)), f], 1)
    pred = zfull @ theta[:-1]
    # reference: the SAME ridge solve on the materialized activation matrix
    a = zfull.T @ zfull + ridge * np.eye(zfull.shape[1])
    ref = np.linalg.solve(a, zfull.T @ y)
    pred_ref = zfull @ ref

    mse = float(np.mean((pred - y) ** 2))
    mse_ref = float(np.mean((pred_ref - y) ** 2))
    theta_err = float(np.max(np.abs(theta[:-1] - ref)))
    print(f"probe rows streamed: {n_rows}; cofactor matrix: {cof.shape}")
    print(f"cofactor-probe mse = {mse:.6f}; materialized ridge = "
          f"{mse_ref:.6f} (var(y) = {float(np.var(y)):.6f}); "
          f"max |θ_cof − θ_mat| = {theta_err:.2e}")
    assert theta_err < 1e-3 and mse < mse_ref * 1.01 + 1e-9
    print("cofactor streaming == materialized solve — Prop 4.1 in the "
          "LM evaluation loop, no [N, d] activation matrix ever stored")


if __name__ == "__main__":
    main()
