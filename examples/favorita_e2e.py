"""End-to-end paper reproduction on the Favorita-like dataset (Table 2).

    PYTHONPATH=src python examples/favorita_e2e.py [--scale N]

Runs all six of the paper's benchmark versions (fact/noPre × eps × alpha ×
theta0) on the schema-faithful synthetic Favorita and prints the Table-2
matrix, checking the paper's qualitative claims:

  * factorized beats non-factorized end-to-end,
  * v4's alpha schedule is most accurate,
  * v5/v6's theta0-by-conversion notably hurts error.
"""

import argparse

from repro.core import VERSIONS, linear_regression
from repro.data.synthetic import favorita_like


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--scale", type=int, default=2,
                   help="data-size multiplier (1 = small, 4 = slow)")
    args = p.parse_args()
    s = args.scale
    bundle = favorita_like(n_dates=48 * s, n_stores=12 * s, n_items=24 * s)
    m = bundle.store.materialize_join().num_rows
    print(f"join rows: {m}; relations: "
          f"{{r.name: r.num_rows for r in bundle.store.relations()}}")

    header = f"{'version':24s} {'runtime':>9s} {'iters':>8s} " \
             f"{'abs err':>10s} {'rel err':>10s}"
    print("\n" + header + "\n" + "-" * len(header))
    rows = {}
    for key in ("v1", "v2", "v3", "v4", "v5", "v6"):
        cfg = VERSIONS[key]
        res = linear_regression(
            bundle.store, bundle.vorder, bundle.features, bundle.label, cfg
        )
        err = res.evaluate(bundle.store, bundle.features, bundle.label)
        rows[key] = (res, err)
        print(f"{cfg.name:24s} {res.seconds_total:8.2f}s "
              f"{res.iterations:8d} {err['avg_abs_err']:10.4f} "
              f"{err['avg_rel_err']:10.4f}")

    v1, v2 = rows["v1"][0], rows["v2"][0]
    print(f"\nfact vs noPre end-to-end: "
          f"{v2.seconds_total / max(v1.seconds_total, 1e-9):.2f}x "
          f"(paper, HyPer: ~3.5x)")
    print(f"cofactor stage alone:     "
          f"{v2.seconds_cofactor + v2.seconds_gd:.2f}s noPre GD vs "
          f"{v1.seconds_cofactor:.2f}s fact cofactors + "
          f"{v1.seconds_gd:.2f}s GD")
    best = min(rows, key=lambda k: rows[k][1]["avg_abs_err"])
    print(f"most accurate version:    {VERSIONS[best].name} "
          f"(paper: v4)")


if __name__ == "__main__":
    main()
