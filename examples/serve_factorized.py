"""Multi-tenant factorized training service — worked example.

Run:  PYTHONPATH=src python examples/serve_factorized.py

Two tenants share one in-memory ``Store`` through a
``FactorizedService``.  Their requests queue up, coalesce into shared
join traversals (their feature sets overlap), and are served from an
immutable catalog snapshot — so when tenant A's writer appends fresh
fact rows mid-stream, every request already admitted keeps seeing the
pre-append catalog, and the append becomes visible exactly at the next
drain cycle.  ``cache_info()`` shows the per-tenant bill at the end.
"""

import numpy as np

from repro.core.relation import Relation
from repro.core.variable_order import VariableOrder
from repro.core.store import Store
from repro.serve import FactorizedService


def build_star(n_dims=4, domain=16, fact_rows=5_000, dim_rows=800, seed=7):
    """Fact(c*, x, y) joined with one Dim_i(c_i, w_i) per dimension."""
    rng = np.random.default_rng(seed)
    keys = {
        f"c{i}": rng.integers(0, domain, fact_rows).astype(np.int32)
        for i in range(n_dims)
    }
    x = rng.normal(0, 2.0, fact_rows)
    y = 0.5 * x + rng.normal(0, 0.5, fact_rows)
    rels = [
        Relation.from_columns(
            "Fact", keys, {"x": x, "y": y},
            {f"c{i}": domain for i in range(n_dims)},
        )
    ]
    for i in range(n_dims):
        rels.append(
            Relation.from_columns(
                f"Dim{i}",
                {f"c{i}": rng.integers(0, domain, dim_rows).astype(np.int32)},
                {f"w{i}": rng.normal(0, 1.0, dim_rows)},
                {f"c{i}": domain},
            )
        )
    node = VariableOrder("x", [VariableOrder("y", [VariableOrder.leaf("Fact")])])
    for i in reversed(range(n_dims)):
        w = VariableOrder(f"w{i}", [VariableOrder.leaf(f"Dim{i}")])
        node = VariableOrder(f"c{i}", [w, node])
    return rels, VariableOrder.intercept([node])


def main() -> None:
    rels, vorder = build_star()
    store = Store(rels)
    svc = FactorizedService(store)  # coalescing on, unbounded window
    rng = np.random.default_rng(11)

    # -- cycle 1: two tenants, overlapping features, one shared traversal --
    t_alice = svc.train("alice", vorder, ["w0", "w1", "x"], "y")
    t_bob = svc.train("bob", vorder, ["w1", "w2", "x"], "y")
    # alice's writer appends fresh fact rows *while those sit queued*: the
    # admitted reads still train on the pre-append snapshot.
    delta = Relation.from_columns(
        "delta",
        {f"c{i}": rng.integers(0, 16, 400).astype(np.int32) for i in range(4)},
        {"x": rng.normal(0, 2.0, 400), "y": rng.normal(0, 1.0, 400)},
    )
    t_write = svc.append("alice", "Fact", delta)
    svc.drain()

    ra, rb = t_alice.result(), t_bob.result()
    print("cycle 1 (pre-append snapshot, coalesced):")
    print(f"  alice theta = {np.round(ra.theta, 4)}")
    print(f"  bob   theta = {np.round(rb.theta, 4)}")
    print(f"  append merged Fact -> {t_write.result().num_rows} rows")

    # -- cycle 2: the append is now visible; bob rescores, alice retrains --
    s_bob = svc.score("bob", vorder, ["w1", "w2", "x"], "y", rb.theta)
    t_alice2 = svc.train("alice", vorder, ["w0", "w1", "x"], "y")
    svc.drain()
    print("cycle 2 (post-append catalog):")
    print(f"  bob   rmse on grown store = {s_bob.result().rmse:.4f}")
    drift = float(np.abs(t_alice2.result().theta - ra.theta).max())
    print(f"  alice retrained; max |theta drift| = {drift:.4f}")

    # -- the bill: per-tenant shares sum to the store totals exactly -------
    info = svc.cache_info()
    print(f"coalesced {info['coalesced_requests']} requests "
          f"into {info['coalesced_batches']} shared traversals")
    print(f"{'tenant':<8}{'requests':>9}{'appends':>8}{'passes':>7}"
          f"{'node_visits':>12}{'vc_hits':>8}")
    for name, t in info["tenants"].items():
        print(f"{name:<8}{t['requests']:>9}{t['appends']:>8}"
              f"{t['passes']:>7}{t['node_visits']:>12}{t['vc_hits']:>8}")
    shares = info["tenants"].values()
    assert sum(t["passes"] for t in shares) == info["passes"]
    assert sum(t["node_visits"] for t in shares) == info["node_visits"]
    print(f"sum of shares == store totals "
          f"({info['passes']} passes, {info['node_visits']} node visits)")


if __name__ == "__main__":
    main()
