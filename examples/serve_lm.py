"""Batched serving example (deliverable b): continuous batching engine.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b

Boots the engine on a smoke config, drives a mixed trace of requests
through slot-based continuous batching, and verifies one request against
the full-forward greedy oracle.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.serve import Engine, Request, ServeConfig


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mixtral-8x7b")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--slots", type=int, default=3)
    args = p.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = model.init_params(jax.random.key(0), cfg)
    eng = Engine(params, cfg, ServeConfig(slots=args.slots, prefill_len=16,
                                          max_len=96))
    rng = np.random.RandomState(0)
    lens = {}
    for uid in range(args.requests):
        plen = int(rng.randint(4, 14))
        toks = [int(t) for t in rng.randint(1, cfg.vocab, plen)]
        n_new = int(rng.randint(4, 12))
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=n_new))
        lens[uid] = (plen, n_new, toks)

    results = eng.run()
    total = 0
    for r in sorted(results, key=lambda r: r.uid):
        total += len(r.tokens)
        print(f"req {r.uid}: prompt {r.prompt_len:2d} -> "
              f"{len(r.tokens):2d} new tokens in {r.latency_s * 1e3:6.1f} ms "
              f"| {r.tokens}")

    # verify one request against the full-forward greedy oracle
    uid = 0
    plen, n_new, toks = lens[uid]
    serve_cfg = dataclasses.replace(cfg, moe_capacity=cfg.moe_capacity_serve)
    ref = list(toks)
    for _ in range(n_new):
        lg, _ = model.forward(
            params, {"tokens": jnp.asarray([ref], jnp.int32)}, serve_cfg
        )
        ref.append(int(jnp.argmax(lg[0, -1, : cfg.vocab])))
    got = next(r for r in results if r.uid == uid).tokens
    assert got == ref[plen:], (got, ref[plen:])
    print(f"\n[serve_lm] {total} tokens generated; "
          f"request {uid} verified against full-forward greedy — exact match")


if __name__ == "__main__":
    main()
