"""End-to-end LM training driver (deliverable b): ~100M params, a few
hundred steps, full production stack — config -> token pipeline -> jit'd
train step -> fault-tolerant loop with checkpoints.

    # laptop-scale sanity run (~2 min on CPU):
    PYTHONPATH=src python examples/train_lm.py

    # the full ~100M / 300-step run (sized for one accelerator host):
    PYTHONPATH=src python examples/train_lm.py --preset paper

    # any assigned architecture's smoke config trains with the same driver:
    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b

Demonstrates: resume (rerun the same command — it continues from the last
checkpoint), preemption (Ctrl-C writes an emergency checkpoint), watchdog
metrics, and the paper-faithful loss curve on the Markov token stream.
"""

import argparse
import os

import jax

from repro.configs import get_config, paper_arch
from repro.data.tokens import TokenPipeline
from repro.train import (
    LoopConfig,
    TrainHParams,
    init_state,
    make_train_step,
    run_loop,
)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--preset", choices=["cpu", "paper"], default="cpu")
    p.add_argument("--arch", default=None,
                   help="train an assigned arch's smoke config instead")
    p.add_argument("--steps", type=int, default=None)
    p.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = p.parse_args()

    if args.arch:
        cfg = get_config(args.arch, smoke=True)
        steps = args.steps or 60
        batch, seq = 8, 64
    elif args.preset == "paper":
        cfg = paper_arch()  # ~100M llama-family decoder
        steps = args.steps or 300
        batch, seq = 16, 512
    else:
        cfg = get_config("smollm-135m", smoke=True)
        steps = args.steps or 120
        batch, seq = 16, 128

    hp = TrainHParams(peak_lr=3e-3, total_steps=steps,
                      warmup_steps=max(steps // 20, 1))
    state = init_state(jax.random.key(0), cfg, hp)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[train_lm] {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{steps} steps @ {batch}x{seq}")

    pipe = TokenPipeline(
        vocab=cfg.vocab, seq_len=cfg.text_len(seq), global_batch=batch,
        seed=0, n_frames=cfg.n_frames, n_patches=cfg.n_patches,
        d_model=cfg.d_model,
    )
    step = jax.jit(make_train_step(cfg, hp))
    lc = LoopConfig(
        total_steps=steps,
        checkpoint_dir=os.path.join(args.ckpt, cfg.name),
        checkpoint_every=max(steps // 4, 10),
        log_every=max(steps // 15, 1),
        handle_signals=True,
    )
    result = run_loop(state, step, pipe.batches(), lc)
    if result.history:
        first, last = result.history[0], result.history[-1]
        import math
        print(f"[train_lm] loss {first['loss']:.4f} -> {last['loss']:.4f} "
              f"(uniform floor ln V = {math.log(cfg.vocab):.2f}); "
              f"steps/s = {1.0 / max(last['sec'], 1e-9):.2f}, "
              f"stragglers = {result.straggler_steps}")
    print(f"[train_lm] checkpoints in {lc.checkpoint_dir} — rerun to resume")


if __name__ == "__main__":
    main()
