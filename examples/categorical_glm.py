"""Categorical features and GLMs over a factorized join — worked example.

Run:  PYTHONPATH=src python examples/categorical_glm.py

Walks the new workload end to end on the synthetic Favorita schema:

1. declare dictionary-encoded join keys as *categorical* features —
   cofactor blocks become group-by aggregates (sparse, one-hot-free);
2. train ridge least squares on the assembled cofactor matrix, warm-
   retrain from the store's incrementally-maintained cache after an
   append;
3. train logistic regression on the compressed representation (per-group
   sufficient statistics + IRLS) and check it against the dense one-hot
   oracle.
"""

import dataclasses

import numpy as np

from repro.core import VERSIONS, linear_regression
from repro.core.categorical import cat_cofactors_factorized, onehot_design_matrix
from repro.core.glm import GLMConfig, fit_glm_onehot, glm_regression
from repro.core.relation import Relation
from repro.data.synthetic import favorita_like


def main() -> None:
    bundle = favorita_like(n_dates=32, n_stores=8, n_items=24, seed=0)
    store, vorder = bundle.store, bundle.vorder

    # -- 1. categorical cofactors --------------------------------------------
    # store_nbr / item_nbr enter the model as one coefficient per category
    # instead of one numeric id column; the sparse algebra never builds the
    # [rows, Σ domains] one-hot matrix.
    cont = ["transactions", "unit_sales"]  # label rides along, as usual
    cat = ["store_nbr", "item_nbr"]
    # the whole batch — continuous Gram, per-category counts/sums, sparse
    # co-occurrence — rides ONE engine traversal (stats proves it): the
    # multi-output plan shares the join descent across every output.
    stats = {}
    cof = cat_cofactors_factorized(store, vorder, cont, cat, stats=stats)
    print(
        f"cofactors: p={cof.num_params} params, "
        f"{cof.nnz()} stored entries vs {cof.num_params ** 2} dense, "
        f"{stats['passes']} engine pass ({stats['node_visits']} node views)"
    )

    # -- 2. least squares with categorical features --------------------------
    feats = ["transactions", "store_nbr", "item_nbr"]
    ls_cfg = dataclasses.replace(
        VERSIONS["closed"], categorical=tuple(cat), use_cache=True
    )
    res = linear_regression(store, vorder, feats, "unit_sales", config=ls_cfg)
    err = res.evaluate(store, feats, "unit_sales", categorical=cat)
    print(f"ridge LS   rmse={err['rmse']:.3f}  (θ has {len(res.names)} coords)")

    # append new fact rows: the cached categorical cofactors fold in the
    # delta (O(delta factorization)) — the retrain below rescans nothing.
    rng = np.random.default_rng(1)
    n = 500
    store.append("SalesF", Relation.from_columns(
        "delta",
        {
            "date": rng.integers(0, 32, n).astype(np.int32),
            "store_nbr": rng.integers(0, 8, n).astype(np.int32),
            "item_nbr": rng.integers(0, 24, n).astype(np.int32),
        },
        {
            "unit_sales": rng.normal(10, 2, n),
            "onpromotion": rng.integers(0, 2, n).astype(np.float64),
        },
    ))
    res2 = linear_regression(store, vorder, feats, "unit_sales", config=ls_cfg)
    print(f"warm retrain after append: cofactor time {res2.seconds_cofactor * 1e3:.2f} ms")

    # -- 3. logistic regression over the compressed join ---------------------
    glm = glm_regression(
        store, vorder, ["transactions"], cat, "onpromotion",
        GLMConfig(family="logistic", ridge=1e-3),
    )
    print(
        f"logistic   converged={glm.converged} in {glm.iterations} IRLS steps, "
        f"compress {glm.seconds_compress * 1e3:.1f} ms + fit "
        f"{glm.seconds_fit * 1e3:.1f} ms"
    )

    # oracle check: dense one-hot Newton reaches the same optimum
    joined = store.materialize_join()
    doms = {c: store.attr_domain(c) for c in cat}
    x, _ = onehot_design_matrix(joined, ["transactions"], cat, doms)
    dense = fit_glm_onehot(
        x, joined.column("onpromotion").astype(np.float64),
        GLMConfig(family="logistic", ridge=1e-3),
    )
    gap = np.abs(glm.theta - dense.theta).max()
    print(f"max |θ_compressed − θ_onehot| = {gap:.2e}  (join rows: {joined.num_rows})")
    assert gap < 1e-5


if __name__ == "__main__":
    main()
