"""Beyond-paper example: degree-d polynomial regression over a factorized
join (the paper's §6 future-work item, implemented).

    PYTHONPATH=src python examples/polynomial_regression.py

Fits y = f(x) where f is genuinely quadratic in the joined features — a
linear model (degree 1) underfits, the factorized degree-2 model recovers
it.  All monomial aggregates (up to degree 2d) are computed in one pass
over the factorization.
"""

import numpy as np

from repro.core import solve_cofactor
from repro.core.polynomial import expand_monomials, polynomial_cofactors
from repro.core.relation import Relation
from repro.core.store import Store
from repro.core.variable_order import VariableOrder


def build_schema(n_keys: int = 40, fan: int = 6, seed: int = 0):
    """R(k, x) ⋈ S(k, y, label): label = 1 + 2x - 0.5y + 0.8x² - 1.2xy."""
    rng = np.random.default_rng(seed)
    rk = np.repeat(np.arange(n_keys, dtype=np.int32), fan)
    x = rng.normal(0, 1, size=rk.size)
    sk = np.repeat(np.arange(n_keys, dtype=np.int32), fan)
    y = rng.normal(0, 1, size=sk.size)
    r = Relation.from_columns("R", {"k": rk}, {"x": x}, {"k": n_keys})
    # the label lives in S and depends on x through the join -> generate it
    # after materializing the pairing (keeps the schema honest)
    store = Store([r, Relation.from_columns(
        "S", {"k": sk}, {"y": y}, {"k": n_keys})])
    joined = store.materialize_join()
    xj = joined.column("x")
    yj = joined.column("y")
    label = 1 + 2 * xj - 0.5 * yj + 0.8 * xj**2 - 1.2 * xj * yj \
        + rng.normal(0, 0.05, size=xj.size)
    # attach the label to S rows is impossible (it depends on x) — model the
    # realistic case: a fact table F(k, x, y, label) with dimension tables.
    f = Relation.from_columns(
        "F", {"k": joined.column("k").astype(np.int32)},
        {"x": xj, "y": yj, "label": label}, {"k": n_keys},
    )
    store2 = Store([f])
    label_n = VariableOrder("label", [VariableOrder.leaf("F")])
    y_n = VariableOrder("y", [label_n])
    x_n = VariableOrder("x", [y_n])
    k_n = VariableOrder("k", [x_n])
    vorder = VariableOrder.intercept([k_n])
    return store2, vorder


def fit(store, vorder, degree: int):
    cof = polynomial_cofactors(store, vorder, ["x", "y"], "label",
                               degree=degree)
    theta = solve_cofactor(cof.matrix(), ridge=1e-6)
    return cof, theta


def mse(store, theta, cof_features, degree):
    joined = store.materialize_join()
    x, y = joined.column("x"), joined.column("y")
    label = joined.column("label")
    monos = expand_monomials(["x", "y"], degree)
    cols = [np.ones_like(x)]
    vals = {"x": x, "y": y}
    for m in monos:
        v = np.ones_like(x)
        for name in m:
            v = v * vals[name]
        cols.append(v)
    z = np.stack(cols, axis=1)
    pred = z @ theta[:-1]
    return float(np.mean((pred - label) ** 2))


def main() -> None:
    store, vorder = build_schema()
    for degree in (1, 2, 3):
        cof, theta = fit(store, vorder, degree)
        err = mse(store, theta, cof.features, degree)
        names = ["1"] + cof.features[:-1]
        show = ", ".join(
            f"{n}={t:+.3f}" for n, t in zip(names, theta[:-1])
        )
        print(f"degree {degree}: mse = {err:.5f}   [{show}]")
    print("\nTrue model: 1 + 2x - 0.5y + 0.8x^2 - 1.2xy (σ=0.05 noise)")
    print("Degree 1 underfits; degree 2 recovers the coefficients; "
          "degree 3's extra terms vanish.")


if __name__ == "__main__":
    main()
