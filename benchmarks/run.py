"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (+ substrate benches):

  table2_factorized_versions   — Table 2 (v1–v6, fact vs noPre)
  figure9_engines              — Fig. 9 (in-memory vs row-engine proxy)
  figure23_aggregates          — Figs. 2–3 (COUNT / SUM over factorization)
  union_commutativity_scaling  — Prop. 4.1 as the distribution rule
  incremental_retrain_after_append — retrain cost after appends (AC/DC)
  polynomial_extension         — §6 outlook (beyond-paper degree-d)
  kernel_hotspots              — hot-aggregate arithmetic intensity
  lm_smoke_steps               — assigned-arch step timings (smoke, CPU)

JSON mirrors land in benchmarks/results/.
"""

from __future__ import annotations

import sys
import time


def main() -> int:
    from . import (
        bench_aggregates,
        bench_engines,
        bench_factorized,
        bench_incremental,
        bench_kernels,
        bench_lm,
        bench_polynomial,
        bench_scaling,
    )

    suites = [
        ("table2 (factorized versions)", bench_factorized.main),
        ("figure9 (engine comparison)", bench_engines.main),
        ("figures2-3 (aggregates)", bench_aggregates.main),
        ("union commutativity scaling", bench_scaling.main),
        ("incremental retrain after append", bench_incremental.main),
        ("polynomial extension", bench_polynomial.main),
        ("kernel hotspots", bench_kernels.main),
        ("lm smoke steps", bench_lm.main),
    ]
    failures = 0
    for name, fn in suites:
        t0 = time.perf_counter()
        print(f"\n#### {name}")
        try:
            fn()
            print(f"#### {name}: ok ({time.perf_counter() - t0:.1f}s)")
        except Exception as e:  # keep the suite going; report at the end
            failures += 1
            print(f"#### {name}: FAILED — {e!r}")
    print(f"\n[benchmarks] {len(suites) - failures}/{len(suites)} suites ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
