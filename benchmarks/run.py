"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run [--smoke]
[--suite NAME ...]``.

One module per paper table/figure (+ substrate benches):

  table2_factorized_versions   — Table 2 (v1–v6, fact vs noPre)
  figure9_engines              — Fig. 9 (in-memory vs row-engine proxy)
  figure23_aggregates          — Figs. 2–3 (COUNT / SUM over factorization)
  union_commutativity_scaling  — Prop. 4.1 as the distribution rule
  incremental_retrain_after_append — retrain cost after appends (AC/DC)
  streaming_ingest             — lazy vs eager append p50/p99 latency +
                                 retrain staleness under sustained writes
  categorical_vs_onehot        — sparse categorical cofactors vs one-hot
  view_cache_cold_warm_append  — persistent view cache: warm batches +
                                 retrain-after-append vs invalidate-all
  serve_coalescing             — multi-tenant service: coalesced vs
                                 private traversals under Zipfian overlap,
                                 plus degraded-mode throughput retention
                                 under injected faults (fault-rate sweep)
  polynomial_extension         — §6 outlook (beyond-paper degree-d)
  traversal_nodes / _end_to_end — fused vs unfused traversal nodes with
                                 roofline-audited bandwidth fractions
  kernel_hotspots              — hot-aggregate arithmetic intensity
  lm_smoke_steps               — assigned-arch step timings (smoke, CPU)

``--smoke`` runs every selected suite at tiny fixed-seed sizes (< 2 min
total) — the CI benchmark-smoke job's mode.  ``--suite NAME`` (repeatable)
filters to named suites; an unknown name errors listing the valid ones.
JSON mirrors land in benchmarks/results/, plus a ``summary.json`` with
per-suite status.

Exit code is non-zero when ANY suite raises (each failure prints its full
traceback); CI gates on it.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import traceback

from .common import RESULTS_DIR, stopwatch

#: slug (the --suite name) -> (display title, bench module)
SUITES = [
    ("factorized", "table2 (factorized versions)", "bench_factorized"),
    ("engines", "figure9 (engine comparison)", "bench_engines"),
    ("aggregates", "figures2-3 (aggregates)", "bench_aggregates"),
    ("scaling", "union commutativity scaling", "bench_scaling"),
    ("incremental", "incremental retrain after append", "bench_incremental"),
    ("ingest", "streaming ingest producer/consumer", "bench_ingest"),
    ("categorical", "categorical vs one-hot", "bench_categorical"),
    ("view_cache", "view cache cold/warm/append", "bench_view_cache"),
    ("serve", "multi-tenant serve coalescing", "bench_serve"),
    ("polynomial", "polynomial extension", "bench_polynomial"),
    ("traversal", "fused traversal nodes (roofline)", "bench_traversal"),
    ("kernels", "kernel hotspots", "bench_kernels"),
    ("lm", "lm smoke steps", "bench_lm"),
]


def suite_names() -> list:
    return [slug for slug, _, _ in SUITES]


def default_suites(only=None):
    """(title, fn) pairs for the selected suites (all when ``only`` is
    falsy).  Unknown names raise ValueError listing the valid slugs —
    before any bench module is imported."""
    if only:
        unknown = sorted(set(only) - set(suite_names()))
        if unknown:
            raise ValueError(
                f"unknown suite(s) {', '.join(unknown)} — valid suites: "
                f"{', '.join(suite_names())}"
            )
        selected = [s for s in SUITES if s[0] in set(only)]
    else:
        selected = SUITES
    return [
        (title, importlib.import_module(f".{mod}", __package__).main)
        for _, title, mod in selected
    ]


def run_suites(suites, smoke: bool = False) -> int:
    """Run each (name, fn) suite; fn takes ``smoke``.  Failures never stop
    the sweep but always fail the run: every exception is reported with a
    full traceback, recorded in summary.json, and turned into exit code 1."""
    summary = []
    for name, fn in suites:
        print(f"\n#### {name}")
        try:
            with stopwatch() as sw:
                fn(smoke=smoke)
            print(f"#### {name}: ok ({sw.seconds:.1f}s)")
            summary.append(
                {"suite": name, "status": "ok", "seconds": sw.seconds}
            )
        except Exception:
            traceback.print_exc()
            print(f"#### {name}: FAILED ({sw.seconds:.1f}s)")
            summary.append(
                {
                    "suite": name,
                    "status": "failed",
                    "seconds": sw.seconds,
                    "error": traceback.format_exc(limit=20),
                }
            )
    failures = sum(1 for s in summary if s["status"] != "ok")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "summary.json"), "w") as f:
        json.dump({"smoke": smoke, "suites": summary}, f, indent=2)
    print(
        f"\n[benchmarks] {len(summary) - failures}/{len(summary)} suites ok"
        + (" (smoke)" if smoke else "")
    )
    return 1 if failures else 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--smoke",
        action="store_true",
        help="tiny fixed-seed sizes for CI gating (< 2 min total)",
    )
    parser.add_argument(
        "--suite",
        action="append",
        metavar="NAME",
        help="run only the named suite (repeatable); one of: "
        + ", ".join(suite_names()),
    )
    args = parser.parse_args(argv)
    try:
        suites = default_suites(args.suite)
    except ValueError as err:
        print(f"[benchmarks] {err}", file=sys.stderr)
        return 2
    return run_suites(suites, smoke=args.smoke)


if __name__ == "__main__":
    sys.exit(main())
