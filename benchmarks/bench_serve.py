"""Multi-tenant serving: cross-request batch coalescing vs private engines.

A Zipfian client population fires cofactor requests at one shared store
(star schema, per-dimension feature subtrees).  Each client draws its
attribute set from a pool of overlapping feature subsets — Zipf-skewed,
so a few hot subsets dominate at high overlap and the tail flattens at
low overlap.  Two arms serve the identical schedule:

* **base** — ``FactorizedService(coalesce=False)``: every request gets a
  private ``FactorizedEngine`` + traversal (the persistent view cache is
  ON, as in production: repeated identical subsets still warm-hit, so the
  baseline is the strongest fair one);
* **coalesced** — requests in each drain window merge
  (``merge_batches``) into ONE union-feature traversal, results scatter
  back per request by slicing.

Coalescing trades one O((Σkᵢ)²) union traversal for N O(kᵢ²) private
ones, so it wins exactly when the subsets overlap (shared attributes →
shared subtree views + shared root descent) and loses when they are
disjoint — the sweep reports both regimes; ``coalesce_speedup`` (the
high-overlap row) is the field gated by ``benchmarks/compare.py`` in
nightly (target ≥2x).  Correctness is asserted before timing: coalesced
≡ per-request results at 1e-12 (summation-order differences only).

A second sweep (``run_fault_sweep``) measures degraded-mode throughput
under seeded node-visit faults via ``FaultInjector`` — its
``throughput_retention`` field (rps at fault rate r / rps at rate 0) is
the nightly-gated robustness metric.
"""

from __future__ import annotations

import numpy as np

from repro.core.store import Store
from repro.core.relation import Relation
from repro.core.variable_order import VariableOrder
from repro.serve import FactorizedService, FaultInjector, RetryPolicy

from .common import emit, stopwatch


def _star(n_dims: int, domain: int, fact_rows: int, dim_rows: int, seed: int):
    """Fact(c0..c_{n-1}, x, y) ⋈ Dim_i(c_i, w_i), bushy order with one
    subtree per dimension — feature subsets over {w_i} ∪ {x} touch only
    their own subtrees, so overlap structure maps onto shared descents."""
    rng = np.random.default_rng(seed)
    keys = {
        f"c{i}": rng.integers(0, domain, fact_rows).astype(np.int32)
        for i in range(n_dims)
    }
    x = rng.normal(0, 2.0, fact_rows)
    y = 0.5 * x + rng.normal(0, 0.5, fact_rows)
    rels = [
        Relation.from_columns(
            "Fact", keys, {"x": x, "y": y},
            {f"c{i}": domain for i in range(n_dims)},
        )
    ]
    for i in range(n_dims):
        rels.append(
            Relation.from_columns(
                f"Dim{i}",
                {f"c{i}": rng.integers(0, domain, dim_rows).astype(np.int32)},
                {f"w{i}": rng.normal(0, 1.0, dim_rows)},
                {f"c{i}": domain},
            )
        )
    node = VariableOrder(
        "x", [VariableOrder("y", [VariableOrder.leaf("Fact")])]
    )
    for i in reversed(range(n_dims)):
        w = VariableOrder(f"w{i}", [VariableOrder.leaf(f"Dim{i}")])
        node = VariableOrder(f"c{i}", [w, node])
    return rels, VariableOrder.intercept([node])


def _schedule(
    pool: list, width: int, n_subsets: int, n_requests: int,
    zipf_s: float, seed: int,
):
    """The request schedule: ``n_subsets`` DISTINCT feature subsets (sizes
    2–4) sampled from the first ``width`` pool attributes, then
    ``n_requests`` Zipf(s)-ranked draws over them.  ``width`` is the
    overlap knob: a narrow pool forces distinct subsets to share most
    attributes (high overlap — the coalesced union stays small), a wide
    pool makes them near-disjoint (low overlap — the union blows up)."""
    rng = np.random.default_rng(seed)
    live = pool[:width]
    subsets, seen = [], set()
    while len(subsets) < n_subsets:
        size = int(rng.integers(2, min(4, len(live)) + 1))
        s = tuple(sorted(rng.choice(live, size=size, replace=False)))
        if s not in seen:
            seen.add(s)
            subsets.append(list(s))
    ranks = np.arange(1, n_subsets + 1, dtype=np.float64)
    p = ranks ** -zipf_s if zipf_s > 0 else np.ones(n_subsets)
    p /= p.sum()
    picks = rng.choice(n_subsets, size=n_requests, p=p)
    return [subsets[i] for i in picks]


def _serve(store, vorder, schedule, label, coalesce, window, n_tenants):
    svc = FactorizedService(
        store, coalesce=coalesce, backend="numpy", window=window
    )
    tickets = []
    for i, feats in enumerate(schedule):
        tickets.append(
            svc.cofactors(
                f"tenant{i % n_tenants}", vorder, list(feats) + [label]
            )
        )
    svc.run()
    return svc, tickets


def run_overlap_sweep(
    n_dims: int = 12,
    domain: int = 32,
    fact_rows: int = 30_000,
    dim_rows: int = 20_000,
    n_requests: int = 192,
    n_subsets: int = 24,
    window: int = 16,
    n_tenants: int = 8,
    zipf_s: float = 1.1,
    seed: int = 23,
) -> list:
    rels, vorder = _star(n_dims, domain, fact_rows, dim_rows, seed)
    pool = [f"w{i}" for i in range(n_dims)] + ["x"]
    label = "y"
    levels = [
        # (tag, attribute-pool width): how much the distinct subsets share
        ("high", 5),
        ("mid", 8),
        ("low", len(pool)),
    ]

    # correctness first: coalesced ≡ per-request sequential at 1e-12
    check = _schedule(pool, 5, 8, 2 * window, zipf_s, seed + 1)
    svc_a, ta = _serve(
        Store(rels), vorder, check, label, True, window, n_tenants
    )
    svc_b, tb = _serve(
        Store(rels), vorder, check, label, False, window, n_tenants
    )
    for a, b in zip(ta, tb):
        ca, cb = a.result(), b.result()
        scale = max(1.0, float(np.abs(cb.matrix()).max()))
        np.testing.assert_allclose(
            ca.matrix(), cb.matrix(), rtol=0, atol=1e-12 * scale
        )

    rows = []
    for tag, width in levels:
        schedule = _schedule(
            pool, width, n_subsets, n_requests, zipf_s, seed
        )
        with stopwatch() as sw_base:
            svc_base, _ = _serve(
                Store(rels), vorder, schedule, label, False, window,
                n_tenants,
            )
        with stopwatch() as sw_coal:
            svc_coal, _ = _serve(
                Store(rels), vorder, schedule, label, True, window,
                n_tenants,
            )
        ratio = sw_base.seconds / max(sw_coal.seconds, 1e-9)
        row = {
            "overlap": tag,
            "zipf_s": zipf_s,
            "attr_pool_width": width,
            "distinct_subsets": n_subsets,
            "n_requests": n_requests,
            "window": window,
            "fact_rows": fact_rows,
            "base_s": sw_base.seconds,
            "coalesced_s": sw_coal.seconds,
            "base_rps": n_requests / max(sw_base.seconds, 1e-9),
            "coal_rps": n_requests / max(sw_coal.seconds, 1e-9),
            "base_node_visits": svc_base.store.node_visits,
            "coal_node_visits": svc_coal.store.node_visits,
            "coalesced_batches": svc_coal.cache_info()["coalesced_batches"],
        }
        # only the high-overlap row carries the nightly-gated field: the
        # low-overlap regime is where coalescing is *designed* to lose
        # (union quad blocks grow quadratically in disjoint features), so
        # gating it would alarm on expected behavior.
        if tag == "high":
            row["coalesce_speedup"] = ratio
        else:
            row["throughput_ratio"] = ratio
        rows.append(row)
        print(
            f"-- overlap={tag} ({n_subsets} subsets over {width} attrs): "
            f"{row['base_rps']:.0f} -> {row['coal_rps']:.0f} req/s "
            f"({ratio:.2f}x{', target >= 2' if tag == 'high' else ''})"
        )
    emit("serve_overlap", rows)
    return rows


def run_fault_sweep(
    n_dims: int = 8,
    domain: int = 24,
    fact_rows: int = 20_000,
    dim_rows: int = 12_000,
    n_requests: int = 96,
    n_subsets: int = 16,
    window: int = 8,
    n_tenants: int = 8,
    zipf_s: float = 1.1,
    rates: tuple = (0.0, 0.05, 0.2),
    seed: int = 29,
) -> list:
    """Degraded-mode throughput under a seeded per-node-visit fault
    hazard (:class:`repro.serve.faults.FaultInjector`).

    The same Zipfian schedule is served at each fault rate through a
    coalesced service with a retry policy; faults poison merged
    traversals, so the service pays bisection + retry work to keep
    serving.  Correctness is asserted before timing counts: every ticket
    resolves (no wedges), and every SUCCESSFUL result is identical (at
    1e-12) to the zero-fault run's.  The nonzero-rate rows carry
    ``throughput_retention`` = rps / zero-fault rps — the nightly-gated
    bigger-is-better field (a robustness-code regression that makes fault
    recovery dramatically more expensive drops it)."""
    rels, vorder = _star(n_dims, domain, fact_rows, dim_rows, seed)
    pool = [f"w{i}" for i in range(n_dims)] + ["x"]
    schedule = _schedule(pool, 6, n_subsets, n_requests, zipf_s, seed)
    retry = RetryPolicy(max_attempts=6, backoff=1e-4, max_backoff=1e-3)

    rows, base_rps, base_results = [], None, None
    for rate in rates:
        inj = FaultInjector(Store(rels), seed=seed)
        svc = FactorizedService(
            inj, coalesce=True, backend="numpy", window=window, retry=retry
        )
        tickets = []
        inj.arm_random_node_faults(rate, transient=True)
        with stopwatch() as sw:
            for i, feats in enumerate(schedule):
                tickets.append(
                    svc.cofactors(
                        f"tenant{i % n_tenants}", vorder, list(feats) + ["y"]
                    )
                )
            svc.run()
        results, failures = [], 0
        for t in tickets:
            assert t.done, "wedged ticket in fault sweep"
            try:
                results.append(t.result().matrix())
            except Exception:
                results.append(None)
                failures += 1
        if base_results is None:
            base_results = results
            assert failures == 0, "zero-fault arm must serve everything"
        else:
            for got, want in zip(results, base_results):
                if got is None:
                    continue
                scale = max(1.0, float(np.abs(want).max()))
                np.testing.assert_allclose(
                    got, want, rtol=0, atol=1e-12 * scale
                )
        rps = n_requests / max(sw.seconds, 1e-9)
        if base_rps is None:
            base_rps = rps
        info = svc.cache_info()
        row = {
            "fault_rate": rate,
            "n_requests": n_requests,
            "window": window,
            "fact_rows": fact_rows,
            "elapsed_s": sw.seconds,
            "rps": rps,
            "success_rate": (n_requests - failures) / n_requests,
            "retries": info["retries"],
            "quarantined": info["quarantined"],
            "faults_fired": len(inj.fired),
            "node_visits": info["node_visits"],
        }
        if rate > 0:
            row["throughput_retention"] = rps / max(base_rps, 1e-9)
        rows.append(row)
        print(
            f"-- fault_rate={rate}: {rps:.0f} req/s, "
            f"{row['success_rate'] * 100:.1f}% served, "
            f"{row['retries']} retries, {row['faults_fired']} faults"
            + (
                f", retention {row['throughput_retention']:.2f}x"
                if rate > 0
                else " (baseline)"
            )
        )
    emit("serve_faults", rows)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        # small but not toy: the coalescing win must stay measurable above
        # scheduler overhead or the smoke-gated field reports noise.
        run_overlap_sweep(
            n_dims=6, domain=12, fact_rows=6_000, dim_rows=4_000,
            n_requests=64, n_subsets=12, window=16,
        )
        run_fault_sweep(
            n_dims=6, domain=12, fact_rows=4_000, dim_rows=3_000,
            n_requests=48, n_subsets=10, window=8,
        )
    else:
        run_overlap_sweep()
        run_fault_sweep()


if __name__ == "__main__":
    main()
