"""Benchmark regression detector: diff a results directory against a baseline.

``python -m benchmarks.compare --baseline DIR --new DIR [--threshold 0.5]``

Compares two benchmark artifact directories (each as produced by
``benchmarks.run``: per-suite ``*.json`` row dumps plus ``summary.json``):

* a suite that was ``ok`` in the baseline but ``failed`` in the new run is
  always a regression;
* every numeric field ending in ``_s`` (wall seconds) in a per-suite row is
  a regression when  ``new > base * (1 + threshold) + slack``  — the
  relative threshold absorbs shared-runner noise, the absolute ``slack``
  keeps micro-timings (sub-ms rows where 2x is measurement jitter) quiet;
* fields ending in ``_speedup`` / ``speedup_vs_*`` regress when the new
  value drops below ``base / (1 + threshold)`` (they are
  bigger-is-better);
* fields ending in ``staleness`` (pending retrain staleness from
  ``bench_ingest`` — smaller-is-better, dimensionless) regress when
  ``new > base * (1 + threshold) + 0.01`` — the small absolute floor
  keeps near-zero staleness values from tripping on jitter;
* fields ending in ``_retention`` (degraded-mode throughput retention
  from the ``bench_serve`` fault sweep — bigger-is-better, a ratio in
  (0, 1]) regress when the new value drops below ``base / (1 +
  threshold)`` with an absolute guard of 0.01 against jitter on
  near-equal ratios.

Exit code 1 on any regression, 0 otherwise.  A missing/empty baseline
directory exits 0 with a notice — the first nightly run has nothing to
compare against.  The nightly workflow downloads the previous successful
run's artifact as the baseline and gates on this script.

Bootstrap robustness: the gate compares *artifacts from different code
versions*, so shape drift is normal, never fatal — a baseline missing a
suite file or summary entry (suite added since the last green run), a row
missing a time/speedup field (field added/renamed), malformed summary
entries or unparseable JSON on the baseline side are all
reported-and-skipped, not a crash.  Only problems with the NEW artifact
(missing/unreadable summary) fail the gate.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List

DEFAULT_THRESHOLD = 0.5
DEFAULT_SLACK_S = 0.05


def _is_time_field(name: str) -> bool:
    return name.endswith("_s")


def _is_speedup_field(name: str) -> bool:
    return name.endswith("_speedup") or "speedup_vs_" in name


def _is_staleness_field(name: str) -> bool:
    return name.endswith("staleness")


def _is_retention_field(name: str) -> bool:
    return name.endswith("_retention")


def _load_json(path: str):
    """Parse a JSON artifact, returning None instead of raising on corrupt
    or truncated files (a killed nightly run can leave either behind)."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _suite_entries(summary: Dict, side: str) -> List[Dict]:
    """Well-formed suite entries of a summary; malformed ones (missing
    ``suite``/``status`` — written by an older runner, or a partial write)
    are reported and skipped instead of raising KeyError."""
    out = []
    for s in summary.get("suites", []):
        if isinstance(s, dict) and "suite" in s and "status" in s:
            out.append(s)
        else:
            print(f"[compare] malformed {side} summary entry skipped: {s!r}")
    return out


def _row_key(row: Dict, idx: int) -> str:
    """Stable label for a row: its first non-float fields, else its index."""
    parts = [
        f"{k}={row[k]}"
        for k in row
        if isinstance(row[k], (int, str)) and not isinstance(row[k], bool)
    ][:3]
    return ",".join(parts) if parts else f"row{idx}"


def compare_suite_rows(
    name: str,
    base_rows: List[Dict],
    new_rows: List[Dict],
    threshold: float,
    slack: float,
) -> List[str]:
    """Regressions between two row lists (matched positionally — suites
    emit a fixed sweep order)."""
    out = []
    for idx, (b, n) in enumerate(zip(base_rows, new_rows)):
        if not isinstance(b, dict) or not isinstance(n, dict):
            print(f"[compare] {name}: row {idx} is not an object — skipped")
            continue
        label = _row_key(n, idx)
        for field, bv in b.items():
            nv = n.get(field)
            if not isinstance(bv, (int, float)) or isinstance(bv, bool):
                continue
            if not isinstance(nv, (int, float)) or isinstance(nv, bool):
                if (
                    _is_time_field(field)
                    or _is_speedup_field(field)
                    or _is_staleness_field(field)
                    or _is_retention_field(field)
                ):
                    # a gated field the suite no longer emits (renamed or
                    # removed since the baseline) — report, don't crash
                    print(
                        f"[compare] {name}[{label}].{field}: in baseline "
                        "but missing in new run — skipped"
                    )
                continue
            if _is_time_field(field):
                if nv > bv * (1.0 + threshold) + slack:
                    out.append(
                        f"{name}[{label}].{field}: {bv:.4g}s -> {nv:.4g}s "
                        f"(+{(nv / max(bv, 1e-12) - 1) * 100:.0f}%)"
                    )
            elif _is_speedup_field(field):
                if nv < bv / (1.0 + threshold) and bv - nv > 1e-9:
                    out.append(
                        f"{name}[{label}].{field}: {bv:.3g}x -> {nv:.3g}x"
                    )
            elif _is_retention_field(field):
                if nv < bv / (1.0 + threshold) and bv - nv > 0.01:
                    out.append(
                        f"{name}[{label}].{field}: {bv:.3g} -> {nv:.3g}"
                    )
            elif _is_staleness_field(field):
                if nv > bv * (1.0 + threshold) + 0.01:
                    out.append(
                        f"{name}[{label}].{field}: {bv:.3g} -> {nv:.3g}"
                    )
    return out


def compare_dirs(
    baseline: str,
    new: str,
    threshold: float = DEFAULT_THRESHOLD,
    slack: float = DEFAULT_SLACK_S,
) -> int:
    """Compare two artifact dirs; print a report; return the exit code."""
    base_summary = os.path.join(baseline, "summary.json")
    if not os.path.isfile(base_summary):
        print(
            f"[compare] no baseline summary at {base_summary} — "
            "nothing to compare (first run?)"
        )
        return 0
    new_summary = os.path.join(new, "summary.json")
    if not os.path.isfile(new_summary):
        print(f"[compare] new run has no summary at {new_summary}")
        return 1
    base = _load_json(base_summary)
    if not isinstance(base, dict):
        # a corrupt/partial baseline artifact is a bootstrap situation,
        # not a regression — same treatment as a missing baseline
        print(
            f"[compare] baseline summary at {base_summary} is unreadable "
            "— nothing to compare"
        )
        return 0
    cur = _load_json(new_summary)
    if not isinstance(cur, dict):
        print(f"[compare] new summary at {new_summary} is unreadable")
        return 1

    regressions: List[str] = []
    base_status = {}
    for s in _suite_entries(base, "baseline"):
        base_status[s["suite"]] = s["status"]
    for s in _suite_entries(cur, "new"):
        if s["suite"] not in base_status:
            print(
                f"[compare] suite {s['suite']!r}: not in baseline summary "
                "— skipped"
            )
            continue
        if base_status[s["suite"]] == "ok" and s["status"] != "ok":
            regressions.append(
                f"suite {s['suite']!r}: ok in baseline, "
                f"{s['status']} in new run"
            )

    compared = 0
    for path in sorted(glob.glob(os.path.join(new, "*.json"))):
        fname = os.path.basename(path)
        if fname == "summary.json":
            continue
        bpath = os.path.join(baseline, fname)
        if not os.path.isfile(bpath):
            print(f"[compare] {fname}: new suite, no baseline — skipped")
            continue
        base_rows = _load_json(bpath)
        new_rows = _load_json(path)
        if base_rows is None:
            print(f"[compare] {fname}: unreadable baseline JSON — skipped")
            continue
        if new_rows is None:
            print(f"[compare] {fname}: unreadable new JSON — skipped")
            continue
        if not (isinstance(base_rows, list) and isinstance(new_rows, list)):
            continue
        compared += 1
        regressions.extend(
            compare_suite_rows(
                fname[: -len(".json")], base_rows, new_rows, threshold, slack
            )
        )

    if regressions:
        print(
            f"[compare] {len(regressions)} regression(s) vs baseline "
            f"(threshold +{threshold * 100:.0f}%, slack {slack}s):"
        )
        for r in regressions:
            print(f"  REGRESSION {r}")
        return 1
    print(
        f"[compare] no regressions across {compared} suite file(s) "
        f"(threshold +{threshold * 100:.0f}%)"
    )
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--baseline", required=True,
                        help="baseline results directory (previous artifact)")
    parser.add_argument("--new", required=True,
                        help="fresh results directory to gate")
    parser.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                        help="relative slowdown tolerated before failing")
    parser.add_argument("--slack", type=float, default=DEFAULT_SLACK_S,
                        help="absolute seconds ignored on top of threshold")
    args = parser.parse_args(argv)
    return compare_dirs(args.baseline, args.new, args.threshold, args.slack)


if __name__ == "__main__":
    sys.exit(main())
