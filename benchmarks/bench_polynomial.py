"""Beyond-paper (§6 outlook): polynomial regression over factorized joins.

The paper's conclusion names degree-d polynomial regression as the natural
extension — "the added complexity increases the gain from factorized
representations even more".  This benchmark quantifies that: the number of
degree-≤d monomial aggregates grows as C(n+d, d) while the factorized pass
still touches each relation once, so the fact/flat advantage widens with d.
"""

from __future__ import annotations

import numpy as np

from repro.core.polynomial import expand_monomials, polynomial_cofactors
from repro.core import design_matrix
from repro.data.synthetic import favorita_like

from .common import emit, timeit


def run(degrees=(1, 2, 3), scale=(48, 12, 24)) -> list:
    bundle = favorita_like(*scale)
    cols = bundle.features + [bundle.label]
    joined = bundle.store.materialize_join()
    z = design_matrix(joined, cols)
    col_of = {c: i for i, c in enumerate(cols)}
    rows = []
    for d in degrees:
        monos = expand_monomials(bundle.features, d)
        t_fact = timeit(
            lambda d=d: polynomial_cofactors(
                bundle.store, bundle.vorder, bundle.features, bundle.label,
                degree=d,
            ),
            repeats=3,
        )

        def flat_pass(monos=monos):
            # flat equivalent: expand the materialized join to monomial
            # features, then one Gram over the expanded design matrix.
            cols_exp = [np.ones(z.shape[0])]
            for mono in monos:
                v = np.ones(z.shape[0])
                for name in mono:
                    v = v * z[:, col_of[name]]
                cols_exp.append(v)
            cols_exp.append(z[:, col_of[bundle.label]])
            zz = np.stack(cols_exp, axis=1)
            return zz.T @ zz

        t_flat = timeit(flat_pass, repeats=3)
        # correctness: both engines agree on the cofactor matrix
        fact = polynomial_cofactors(
            bundle.store, bundle.vorder, bundle.features, bundle.label,
            degree=d,
        ).matrix()
        np.testing.assert_allclose(fact, flat_pass(), rtol=1e-7, atol=1e-5)
        rows.append(
            {
                "degree": d,
                "monomials": len(monos),
                "fact_s": t_fact,
                "flat_s": t_flat,
                "join_rows": z.shape[0],
            }
        )
    emit("polynomial_extension", rows)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(degrees=(1, 2), scale=(16, 4, 8))
    else:
        run()


if __name__ == "__main__":
    main()
