"""Streaming ingest: bounded-latency appends under lazy delta maintenance.

Sustained producer/consumer workload (Favorita-style): a producer appends
fact batches continuously while a consumer periodically retrains a warm
model.  Two stores run the identical schedule —

  lazy   — ``Store(maintenance="lazy")`` (the default): ``append`` validates
           FDs, concats the relation and pushes metadata onto the pending-
           delta log; all cofactor/view folding is deferred to the next
           read, which drains the stacked deltas in one pass.
  eager  — ``Store(maintenance="eager")``: every ``append`` folds the delta
           into each covering cache entry before returning, so write
           latency grows with the number of cached queries.

We sweep the cache-population axis (how many distinct cofactor queries are
warm) and report per-append p50/p99 wall time for both modes.  The lazy
percentiles should stay flat as population grows while the eager ones
scale with it — ``append_p99_speedup`` is the headline gap.  ``staleness``
is the worst pending-rows fraction observed at retrain time; it is bounded
by the store's compaction ratio, which is the knob trading append cost for
read-time drain work.

Correctness is asserted inline: after every retrain the lazy and eager
models must agree (the drain folds exactly what eager folded).
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.core import VERSIONS, Store, linear_regression
from repro.core.relation import Relation
from repro.data.synthetic import favorita_like

from .common import emit


def _delta(rng, n_rows, n_dates, n_stores, n_items):
    return Relation.from_columns(
        "delta",
        {
            "date": rng.integers(0, n_dates, n_rows).astype(np.int32),
            "store_nbr": rng.integers(0, n_stores, n_rows).astype(np.int32),
            "item_nbr": rng.integers(0, n_items, n_rows).astype(np.int32),
        },
        {
            "unit_sales": rng.normal(10, 2, n_rows),
            "onpromotion": rng.integers(0, 2, n_rows).astype(np.float64),
        },
    )


def _feature_subsets(features, n_queries):
    """The first ``n_queries`` non-empty feature subsets, largest first, so
    level 1 is the full model and higher levels add projected queries."""
    subsets = [list(features)]
    for k in range(len(features) - 1, 0, -1):
        for combo in itertools.combinations(features, k):
            subsets.append(list(combo))
    return subsets[:n_queries]


def _populate(store, bundle, subsets):
    for feats in subsets:
        store.sufficient_stats(
            bundle.vorder, feats, bundle.label, backend="numpy"
        )


def _pct(samples, q):
    s = sorted(samples)
    return s[min(len(s) - 1, int(round(q * (len(s) - 1))))]


def _fresh_store(bundle, maintenance):
    return Store(
        [bundle.store.get(n) for n in bundle.store.names()],
        maintenance=maintenance,
    )


def run(
    n_dates: int = 64,
    n_stores: int = 16,
    n_items: int = 32,
    sales_fraction: float = 0.5,
    n_rounds: int = 4,
    appends_per_round: int = 20,
    delta_rows: int = 200,
    query_levels=(1, 4, 12),
) -> list:
    bundle = favorita_like(
        n_dates=n_dates, n_stores=n_stores, n_items=n_items,
        sales_fraction=sales_fraction,
    )
    warm_cfg = dataclasses.replace(
        VERSIONS["closed"], backend="numpy", use_cache=True
    )

    rows = []
    for n_queries in query_levels:
        subsets = _feature_subsets(bundle.features, n_queries)
        lat = {"lazy": [], "eager": []}
        retrain = {"lazy": [], "eager": []}
        thetas = {}
        staleness = 0.0

        for mode in ("lazy", "eager"):
            # identical producer schedule for both stores
            rng = np.random.default_rng(23)
            store = _fresh_store(bundle, mode)
            _populate(store, bundle, subsets)
            base_rows = store.get("SalesF").num_rows

            for _ in range(n_rounds):
                for _ in range(appends_per_round):
                    delta = _delta(
                        rng, delta_rows, n_dates, n_stores, n_items
                    )
                    t0 = time.perf_counter()
                    store.append("SalesF", delta)
                    lat[mode].append(time.perf_counter() - t0)
                if mode == "lazy":
                    pend = store.cache_info()["pending_rows"]
                    total = store.get("SalesF").num_rows
                    staleness = max(
                        staleness, pend / max(1, total - pend)
                    )
                t0 = time.perf_counter()
                res = linear_regression(
                    store, bundle.vorder, bundle.features, bundle.label,
                    config=warm_cfg,
                )
                retrain[mode].append(time.perf_counter() - t0)
            thetas[mode] = res.theta
            assert store.get("SalesF").num_rows == (
                base_rows + n_rounds * appends_per_round * delta_rows
            )

        # the drained lazy cofactors are exactly the eagerly folded ones
        np.testing.assert_allclose(
            thetas["lazy"], thetas["eager"], rtol=1e-9, atol=1e-9
        )

        lazy_p99 = _pct(lat["lazy"], 0.99)
        eager_p99 = _pct(lat["eager"], 0.99)
        rows.append(
            {
                "cached_queries": n_queries,
                "appends": len(lat["lazy"]),
                "lazy_p50_s": _pct(lat["lazy"], 0.50),
                "lazy_p99_s": lazy_p99,
                "eager_p50_s": _pct(lat["eager"], 0.50),
                "eager_p99_s": eager_p99,
                "append_p99_speedup": eager_p99 / max(lazy_p99, 1e-9),
                "lazy_retrain_s": _pct(retrain["lazy"], 0.50),
                "eager_retrain_s": _pct(retrain["eager"], 0.50),
                "staleness": staleness,
            }
        )

    emit("streaming_ingest", rows)
    top = rows[-1]
    print(
        f"-- append p99 lazy vs eager @ {top['cached_queries']} cached "
        f"queries: {top['append_p99_speedup']:.1f}x "
        f"(staleness <= {top['staleness']:.3f})"
    )
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(
            n_dates=16, n_stores=6, n_items=8, n_rounds=2,
            appends_per_round=5, delta_rows=50, query_levels=(1, 3),
        )
    else:
        run()


if __name__ == "__main__":
    main()
