"""Union-commutativity as the distribution rule (paper Prop. 4.1).

Measures the core scaling property the multi-pod design leans on: cofactor
computation over P partitions = P local Grams + one tiny [p, p] reduction.
On one host this shows the work-partitioning is exact and the combine cost
is O(p²) regardless of rows — the psum payload measured in the dry-run's
collective table is this same matrix.

Also benchmarks feature scaling (paper §4.2): single fused pass per
feature over the union of relations.
"""

from __future__ import annotations

import numpy as np

from repro.core import compute_scale_factors, design_matrix
from repro.core.distributed import partitioned_cofactors_host
from repro.data.synthetic import favorita_like

from .common import emit, timeit


def run(scale=(96, 24, 48), partitions=(1, 2, 4, 8, 16)) -> list:
    bundle = favorita_like(*scale)
    cols = bundle.features + [bundle.label]
    joined = bundle.store.materialize_join()
    z = design_matrix(joined, cols)
    rows = []
    base = None
    for parts in partitions:
        t = timeit(
            lambda parts=parts: partitioned_cofactors_host(z, cols, parts), repeats=3
        )
        full = partitioned_cofactors_host(z, cols, parts).matrix()
        ref = partitioned_cofactors_host(z, cols, 1).matrix()
        np.testing.assert_allclose(full, ref, rtol=1e-9)
        base = base or t
        rows.append(
            {
                "partitions": parts,
                "rows": z.shape[0],
                "sec": t,
                "combine_payload_B": full.nbytes,
            }
        )
    t_scale = timeit(
        lambda: compute_scale_factors(
            bundle.store, bundle.features, bundle.label
        ),
        repeats=3,
    )
    rows.append(
        {"partitions": "feature_scaling", "rows": bundle.store.total_rows(),
         "sec": t_scale, "combine_payload_B": 0}
    )
    emit("union_commutativity_scaling", rows)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(scale=(24, 6, 12), partitions=(1, 2, 4))
    else:
        run()


if __name__ == "__main__":
    main()
