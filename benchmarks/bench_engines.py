"""Paper Figure 9: engine comparison (in-memory columnar vs disk-row proxy).

The paper benchmarks HyPer (compiled, in-memory) against PostgreSQL
(interpreted, buffered row engine).  Neither ships in this container, so
two engine *proxies* make the same architectural comparison honestly
(DESIGN.md §7):

  * ``columnar``  — the repo's compiled JAX/XLA columnar engine (HyPer role)
  * ``row``       — a deliberately tuple-at-a-time interpreted Python
                    executor (Volcano/disk-engine role)

Both compute identical cofactors on the same data; the figure of merit is
the ratio, reported per data scale alongside the paper's (~50x factorized,
~20x non-factorized HyPer/PostgreSQL).
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    cofactors_factorized,
    cofactors_materialized,
    cofactors_row_engine,
)
from repro.data.synthetic import favorita_like

from .common import emit, timeit


def run(scales=((32, 8, 16), (64, 16, 32), (96, 24, 48))) -> list:
    rows = []
    for n_dates, n_stores, n_items in scales:
        bundle = favorita_like(n_dates, n_stores, n_items)
        cols = bundle.features + [bundle.label]
        m = bundle.store.materialize_join().num_rows

        # use_view_cache=False: the figure of merit is engine TRAVERSAL
        # cost (columnar vs row proxy); cross-batch view reuse would turn
        # the repeats into cache hits (bench_view_cache covers that axis).
        t_col_fact = timeit(
            lambda: cofactors_factorized(
                bundle.store, bundle.vorder, cols, backend="jax",
                use_view_cache=False,
            ),
            repeats=3,
        )
        t_col_flat = timeit(
            lambda: cofactors_materialized(bundle.store, cols), repeats=3
        )
        t_row = timeit(
            lambda: cofactors_row_engine(bundle.store, cols), repeats=1,
            warmup=0,
        )

        a = cofactors_factorized(
            bundle.store, bundle.vorder, cols, backend="numpy"
        ).matrix()
        b = cofactors_row_engine(bundle.store, cols).matrix()
        np.testing.assert_allclose(a, b, rtol=1e-6)  # same math, all engines

        rows.append(
            {
                "join_rows": m,
                "columnar_fact_s": t_col_fact,
                "columnar_flat_s": t_col_flat,
                "row_engine_flat_s": t_row,
                "row_over_columnar_flat": t_row / max(t_col_flat, 1e-9),
                "row_over_columnar_fact": t_row / max(t_col_fact, 1e-9),
            }
        )
    emit("figure9_engines", rows)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(scales=((16, 4, 8),))
    else:
        run()


if __name__ == "__main__":
    main()
