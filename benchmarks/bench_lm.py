"""LM substrate micro-benchmarks on CPU (smoke configs, compiled).

Wall-times here are CPU numbers for the reduced configs — they demonstrate
the step functions compile+run end to end and give per-arch relative cost;
the TPU performance story lives in the roofline table (§Roofline), which is
derived from the dry-run's compiled artifacts, not from this machine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.data.tokens import TokenPipeline
from repro.models import model
from repro.train import TrainHParams, init_state, make_train_step

from .common import emit, timeit


def run(archs=None) -> list:
    rows = []
    key = jax.random.key(0)
    B, S = 2, 64
    for name in archs if archs is not None else sorted(ARCHS):
        cfg = get_config(name, smoke=True)
        hp = TrainHParams(total_steps=10, warmup_steps=0)
        state = init_state(key, cfg, hp)
        step = jax.jit(make_train_step(cfg, hp))
        pipe = TokenPipeline(
            cfg.vocab, cfg.text_len(S), B, seed=0,
            n_frames=cfg.n_frames, n_patches=cfg.n_patches,
            d_model=cfg.d_model,
        )
        batch = pipe.batch_at(0)
        t_train = timeit(
            lambda: jax.block_until_ready(step(state, batch)[1]["loss"]),
            repeats=3,
        )
        params = state.params
        cache = model.init_cache(cfg, B, max_len=128)
        dec = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, cfg)
        )
        tok = jnp.zeros((B, 1), jnp.int32)
        t_dec = timeit(
            lambda: jax.block_until_ready(
                dec(params, tok, cache, jnp.asarray(0, jnp.int32))[0]
            ),
            repeats=5,
        )
        rows.append(
            {
                "arch": name,
                "train_step_s": t_train,
                "decode_step_s": t_dec,
                "tok_s_train": B * cfg.text_len(S) / t_train,
                "tok_s_decode": B / t_dec,
            }
        )
    emit("lm_smoke_steps", rows)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(archs=sorted(ARCHS)[:2])
    else:
        run()


if __name__ == "__main__":
    main()
