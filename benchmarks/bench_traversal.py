"""Fused vs unfused traversal-node throughput, roofline-audited.

Two legs.  ``traversal_nodes`` isolates the engine's per-node hot path —
extend-with-feature + GROUP BY over an [N]-row degree-2 view — and times
the fused ``segment_view`` dispatch (``FactorizedEngine._extend_and_group``)
against the unfused pair (``_extend_with_feature`` + ``_aggregate_out``)
on identical inputs, reporting ``node_fusion_speedup`` (compare.py-gated)
plus the roofline accounting from ``launch.roofline.traversal_node_terms``:
predicted bandwidth-bound speedup, achieved GB/s, and the achieved fraction
of the memory bound.  ``traversal_end_to_end`` times whole ``cofactors()``
traversals over the paper's Figure-1 schema at scale with the node kernels
on vs off.

On this CPU container the fused path is the jitted XLA formulation of the
same one-dispatch fusion (Pallas interpret timing is Python-level and
meaningless off-TPU; kernel correctness is covered by tests/test_kernels).
The unfused baseline already includes the ``jax.ops.segment_sum`` fallback
upgrade, so the speedup is fusion, not a strawman.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.factorize import FactorizedEngine, _View
from repro.core.relation import Relation
from repro.core.store import Store
from repro.core.variable_order import VariableOrder
from repro.data.synthetic import figure1_schema
from repro.launch.roofline import traversal_node_terms

from .common import emit, timeit

NODE_SHAPES = (  # (n_rows, k feats below, groups) — the degree-2 hot path
    (65536, 4, 256),
    (262144, 4, 1024),
    (262144, 8, 1024),
    (524288, 8, 2048),
)


def _node_fixture(n: int, k: int, g: int, seed: int = 0):
    """A store whose fact relation has ``n`` rows grouped into ``g`` keys,
    plus a synthetic degree-2 view with ``k`` features already below the
    node — the state the engine is in when it reaches a feature node."""
    rng = np.random.default_rng(seed)
    gids = rng.integers(0, g, n).astype(np.int32)
    rel = Relation.from_columns(
        "R", {"g": gids}, {"x": rng.standard_normal(n)}
    )
    store = Store([rel])
    vorder = VariableOrder.intercept(
        [
            VariableOrder(
                "g", [VariableOrder("x", [VariableOrder.leaf("R")])]
            )
        ]
    )
    kw = dict(backend="jax", use_view_cache=False)
    eng_u = FactorizedEngine(store, vorder, ["x"], use_node_kernels=False, **kw)
    eng_f = FactorizedEngine(store, vorder, ["x"], use_node_kernels=True, **kw)
    view = _View(
        keys={"g": gids, "x": eng_u.encoded[("R", "x")]},
        c=jnp.asarray(rng.standard_normal(n).astype(np.float32)),
        l=jnp.asarray(rng.standard_normal((n, k)).astype(np.float32)),
        q=jnp.asarray(rng.standard_normal((n, k, k)).astype(np.float32)),
        feats=[f"z{i}" for i in range(k)],
        degree=2,
    )
    return eng_u, eng_f, view


def run_nodes(shapes=NODE_SHAPES, repeats: int = 5) -> list:
    rows = []
    for n, k, g in shapes:
        eng_u, eng_f, view = _node_fixture(n, k, g)

        def unfused():
            v = eng_u._aggregate_out(
                eng_u._extend_with_feature(view, "x", 2),
                "x",
                frozenset(),
                2,
            )
            return (v.c, v.l, v.q)

        def fused():
            v = eng_f._extend_and_group(view, "x", frozenset(), 2)
            return (v.c, v.l, v.q)

        for a, b in zip(unfused(), fused()):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4
            )
        t_u = timeit(unfused, repeats=repeats)
        t_f = timeit(fused, repeats=repeats)
        terms = traversal_node_terms(n, k, g, degree=2, dtype_bytes=4)
        rows.append(
            {
                "n_rows": n,
                "k": k,
                "groups": g,
                "unfused_s": t_u,
                "fused_s": t_f,
                "node_fusion_speedup": t_u / t_f,
                "predicted_speedup": terms.predicted_speedup,
                "achieved_gbs": terms.achieved_gbs(t_f),
                "bw_bound_fraction": terms.achieved_fraction(t_f),
            }
        )
    emit("traversal_nodes", rows)
    return rows


def run_end_to_end(
    scales=((20, 20, 20, 10), (50, 40, 30, 20)), repeats: int = 3
) -> list:
    """Whole-traversal cofactors over Figure 1 at scale, kernels on/off."""
    rows = []
    for n_loc, n_prod, n_sales, n_comp in scales:
        bundle = figure1_schema(
            n_locations=n_loc,
            n_products_per_loc=n_prod,
            n_sales_per_product=n_sales,
            n_competitors_per_loc=n_comp,
        )
        feats = bundle.features + [bundle.label]
        kw = dict(backend="jax", use_view_cache=False)
        eng_u = FactorizedEngine(
            bundle.store, bundle.vorder, feats, use_node_kernels=False, **kw
        )
        eng_f = FactorizedEngine(
            bundle.store, bundle.vorder, feats, use_node_kernels=True, **kw
        )
        a, b = eng_u.cofactors(), eng_f.cofactors()
        np.testing.assert_allclose(a.quad, b.quad, rtol=1e-5, atol=1e-4)
        assert eng_u.node_visits == eng_f.node_visits
        t_u = timeit(eng_u.cofactors, repeats=repeats)
        t_f = timeit(eng_f.cofactors, repeats=repeats)
        rows.append(
            {
                "sales_rows": n_loc * n_prod * n_sales,
                "unfused_s": t_u,
                "fused_s": t_f,
                "traversal_speedup": t_u / t_f,
            }
        )
    emit("traversal_end_to_end", rows)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run_nodes(shapes=((8192, 4, 64),), repeats=3)
        run_end_to_end(scales=((8, 6, 5, 4),), repeats=2)
    else:
        run_nodes()
        run_end_to_end()


if __name__ == "__main__":
    main()
