"""Shared benchmark utilities: timing, CSV emission, result directory.

Every suite's timing/emission scaffolding lives here — ``timeit`` for
median-of-repeats micro timings, ``stopwatch`` for one-shot phase timings
(the manual ``t0 = perf_counter(); ...; dt = perf_counter() - t0`` pattern
that used to be copy-pasted across suites), ``emit`` for the CSV print +
JSON artifact every suite produces.

JAX dispatch is asynchronous: a timed region that merely *launches* device
work measures dispatch latency, not the kernel.  ``timeit`` therefore
blocks on the callable's return value before stopping the clock, and
``stopwatch.block`` is the same barrier for ``with``-style regions —
suites timing device work should route outputs through one of them.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List


def block(value):
    """Wait for any JAX arrays inside ``value`` (an arbitrary pytree) to
    finish computing, then return it.  Host-only values pass through, and
    so does everything when JAX is absent — safe to call unconditionally
    inside timed regions."""
    try:
        import jax

        jax.block_until_ready(value)
    except ImportError:  # pragma: no cover - jax is a hard dep in this repo
        pass
    return value


RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds over ``repeats`` calls.  The clock stops only
    after ``fn``'s return value is device-complete (see :func:`block`), so
    kernel-path timings measure execution, not dispatch."""
    for _ in range(warmup):
        block(fn())
    times = []
    for _ in range(repeats):
        with stopwatch() as sw:
            block(fn())
        times.append(sw.seconds)
    times.sort()
    return times[len(times) // 2]


class stopwatch:
    """One-shot wall-clock context manager:

        with stopwatch() as sw:
            sw.block(work())   # block() the outputs of device work
        rows.append({"work_s": sw.seconds})

    ``seconds`` is set on exit — including an exception exit, so a failing
    suite still reports how long it ran.  ``block`` is :func:`block`
    re-exported as a method so timed regions barrier on device work
    without an extra import.
    """

    seconds: float = float("nan")

    def __enter__(self) -> "stopwatch":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        self.seconds = time.perf_counter() - self._t0
        return False

    @staticmethod
    def block(value):
        return block(value)


def emit(name: str, rows: List[Dict]) -> None:
    """Print a small CSV block and persist JSON under results/."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    if not rows:
        print(f"[{name}] (no rows)")
        return
    cols = list(rows[0])
    print(f"== {name} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
