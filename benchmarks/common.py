"""Shared benchmark utilities: timing, CSV emission, result directory."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timeit(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds over ``repeats`` calls."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, rows: List[Dict]) -> None:
    """Print a small CSV block and persist JSON under results/."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    if not rows:
        print(f"[{name}] (no rows)")
        return
    cols = list(rows[0])
    print(f"== {name} ==")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
