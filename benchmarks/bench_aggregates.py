"""Paper Figures 2–3: COUNT(*) and SUM(Sale·Competitor) over the
factorized join of the running example (Fig. 1 schema), versus the flat
join — one pass over O(factorization) vs O(join).
"""

from __future__ import annotations

import numpy as np

from repro.core import FactorizedEngine
from repro.data.synthetic import figure1_schema

from .common import emit, timeit


def run(fanouts=(4, 8, 16, 32)) -> list:
    rows = []
    for f in fanouts:
        bundle = figure1_schema(
            n_locations=f,
            n_products_per_loc=f,
            n_sales_per_product=f,
            n_competitors_per_loc=f,
        )
        # use_view_cache=False: this suite times the TRAVERSAL (one pass
        # over O(factorization)); warm cross-batch reuse is bench_view_cache's
        # subject and would reduce the repeats here to cache hits.
        eng = FactorizedEngine(
            bundle.store, bundle.vorder,
            ["Sale", "Competitor"], backend="numpy", use_view_cache=False,
        )
        joined = bundle.store.materialize_join()
        flat_rows = joined.num_rows
        fact_size = sum(r.num_rows for r in bundle.store.relations())

        count_fact = eng.sum_product([])
        sum_fact = eng.sum_product(["Sale", "Competitor"])
        count_flat = float(flat_rows)
        sum_flat = float(
            np.sum(
                joined.column("Sale").astype(np.float64)
                * joined.column("Competitor").astype(np.float64)
            )
        )
        assert count_fact == count_flat
        np.testing.assert_allclose(sum_fact, sum_flat, rtol=1e-9)

        t_fact = timeit(lambda: eng.cofactors(), repeats=3)
        t_flat = timeit(
            lambda: np.sum(
                joined.column("Sale").astype(np.float64)
                * joined.column("Competitor").astype(np.float64)
            ),
            repeats=3,
        )
        rows.append(
            {
                "fanout": f,
                "flat_rows": flat_rows,
                "fact_tuples": fact_size,
                "compression": flat_rows / max(fact_size, 1),
                "count": count_fact,
                "sum_sale_competitor": sum_fact,
                "fact_all_aggs_s": t_fact,
                "flat_one_agg_s": t_flat,
            }
        )
    emit("figure23_aggregates", rows)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(fanouts=(4, 8))
    else:
        run()


if __name__ == "__main__":
    main()
