"""Kernel-layer benchmark: the factorized engine's hot aggregates.

On this CPU container the Pallas kernels run in interpret mode (Python-level
— their timing is meaningless); what CAN be measured honestly here is the
XLA-compiled jnp formulation that the kernels replace, plus arithmetic-
intensity bookkeeping for the §Roofline narrative.  Pallas correctness is
covered by tests/test_kernels.py; TPU wall-time belongs to real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit, timeit


def run(gram_shapes=((4096, 16), (65536, 16), (65536, 64)),
        seg_shapes=((65536, 16, 64),), attn_shapes=((8, 1024, 64),)) -> list:
    rows = []
    key = jax.random.key(0)
    for m, k in gram_shapes:
        x = jax.random.normal(key, (m, k), jnp.float32)
        gram = jax.jit(ref.gram_ref)
        t = timeit(lambda: jax.block_until_ready(gram(x)), repeats=5)
        flops = 2.0 * m * k * k
        rows.append(
            {
                "op": "gram(X^T X)",
                "shape": f"{m}x{k}",
                "sec": t,
                "gflops_s": flops / t / 1e9,
                "arith_intensity": flops / (4.0 * (m * k + k * k)),
            }
        )
    for m, k, g in seg_shapes:
        x = jax.random.normal(key, (m, k), jnp.float32)
        seg = jax.random.randint(key, (m,), 0, g)
        sg = jax.jit(lambda x, s, g=g: ref.segment_gram_ref(x, s, g))
        t = timeit(lambda: jax.block_until_ready(sg(x, seg)), repeats=5)
        flops = 2.0 * m * k * k
        rows.append(
            {
                "op": "segment_gram",
                "shape": f"{m}x{k}x{g}",
                "sec": t,
                "gflops_s": flops / t / 1e9,
                "arith_intensity": flops / (4.0 * (m * k + g * k * k)),
            }
        )
    for bh, s, d in attn_shapes:
        q = jax.random.normal(key, (bh, s, d), jnp.float32)
        fl = jax.jit(lambda q: ref.flash_ref(q, q, q, causal=True))
        t = timeit(lambda: jax.block_until_ready(fl(q)), repeats=3)
        flops = 4.0 * bh * s * s * d
        rows.append(
            {
                "op": "attention(dense ref)",
                "shape": f"{bh}x{s}x{d}",
                "sec": t,
                "gflops_s": flops / t / 1e9,
                "arith_intensity": flops / (4.0 * 3 * bh * s * d),
            }
        )
    emit("kernel_hotspots", rows)
    return rows


def run_segment_view_sweep(
    shape=(262144, 8, 2048),
    budgets=(None, 1 << 19, 1 << 17, 1 << 15),
    repeats: int = 5,
) -> list:
    """How ``segment_view``'s group chunking (the VMEM-budget spill path)
    costs on wall time: each halving of the budget multiplies the number of
    passes over the N input rows, so chunked runs bound the TPU worst case
    where ``num_groups * (k+2)^2`` overflows the accumulator budget."""
    m, k, g = shape
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    x = jnp.asarray(rng.standard_normal(m).astype(np.float32))
    l = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((m, k, k)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, g, m).astype(np.int32))
    rows = []
    group_bytes = (k + 2) * (k + 2) * 4
    for budget in budgets:
        eff = min(budget or ops.VMEM_ACC_BYTES, ops.VMEM_ACC_BYTES)
        g_chunk = max(1, min(g, eff // group_bytes - 1))
        t = timeit(
            lambda b=budget: ops.segment_view(
                c, x, l, q, seg, g, degree=2, vmem_budget=b
            ),
            repeats=repeats,
        )
        rows.append(
            {
                "op": "segment_view",
                "shape": f"{m}x{k}x{g}",
                "vmem_budget": "default" if budget is None else budget,
                "chunks": -(-g // g_chunk),
                "sec": t,
            }
        )
    emit("segment_view_chunks", rows)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(
            gram_shapes=((4096, 16),),
            seg_shapes=((4096, 16, 16),),
            attn_shapes=((2, 256, 64),),
        )
        run_segment_view_sweep(
            shape=(8192, 4, 128), budgets=(None, 1 << 14), repeats=3
        )
    else:
        run()
        run_segment_view_sweep()


if __name__ == "__main__":
    main()
