"""Retrain-after-append: incremental cofactor maintenance vs recompute.

Extends the paper's Fig. 9 axis (engine comparison for one training run)
over a stream of update batches, the AC/DC setting: after each append of
``delta_rows`` fact rows, retrain the model three ways —

  incremental  — ``Store.append`` folds delta cofactors into the cache
                 (cost O(delta factorization)); the warm retrain rescales
                 the cached aggregates and runs GD on the p×p matrix.
  fact-full    — factorized from-scratch recompute over ALL current rows.
  noPre-full   — flat join + full design-matrix Gram, rebuilt every time.

The incremental column should stay flat as the accumulated data grows while
both full-recompute columns scale with total (join) size — that gap is the
point of maintaining cofactors close to the data.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import VERSIONS, RegressionConfig, linear_regression
from repro.core.relation import Relation
from repro.data.synthetic import favorita_like

from .common import emit, stopwatch


def _delta(rng, n_rows, n_dates, n_stores, n_items):
    return Relation.from_columns(
        "delta",
        {
            "date": rng.integers(0, n_dates, n_rows).astype(np.int32),
            "store_nbr": rng.integers(0, n_stores, n_rows).astype(np.int32),
            "item_nbr": rng.integers(0, n_items, n_rows).astype(np.int32),
        },
        {
            "unit_sales": rng.normal(10, 2, n_rows),
            "onpromotion": rng.integers(0, 2, n_rows).astype(np.float64),
        },
    )


def run(
    n_dates: int = 128,
    n_stores: int = 32,
    n_items: int = 64,
    sales_fraction: float = 0.5,
    n_batches: int = 6,
    delta_rows: int = 2_000,
) -> list:
    rng = np.random.default_rng(11)
    bundle = favorita_like(
        n_dates=n_dates, n_stores=n_stores, n_items=n_items,
        sales_fraction=sales_fraction,
    )
    # closed-form solver + numpy engine: the solve is O(p³) and identical
    # for every path, so the measured difference is purely cofactor
    # (re)computation vs delta maintenance — no jit retrace noise as the
    # appended shapes grow.
    cfg = dataclasses.replace(VERSIONS["closed"], backend="numpy")
    kw = dict(config=cfg)

    # initial training run seeds the cofactor cache
    warm_cfg = dataclasses.replace(cfg, use_cache=True)
    linear_regression(bundle.store, bundle.vorder, bundle.features,
                      bundle.label, config=warm_cfg)

    rows = []
    for batch in range(n_batches):
        delta = _delta(rng, delta_rows, n_dates, n_stores, n_items)

        with stopwatch() as sw_inc:
            bundle.store.append("SalesF", delta)  # pays delta maintenance
            res_inc = linear_regression(
                bundle.store, bundle.vorder, bundle.features, bundle.label,
                config=warm_cfg,
            )

        with stopwatch() as sw_fact:
            res_fact = linear_regression(
                bundle.store, bundle.vorder, bundle.features, bundle.label,
                **kw,
            )

        with stopwatch() as sw_nopre:
            res_nopre = linear_regression(
                bundle.store, None, bundle.features, bundle.label,
                config=RegressionConfig(
                    name="noPre closed", factorized=False,
                    solver="closed_form", theta0_mode="exact",
                ),
            )
        t_inc, t_fact, t_nopre = (
            sw_inc.seconds, sw_fact.seconds, sw_nopre.seconds
        )

        np.testing.assert_allclose(  # maintained path stays correct
            res_inc.theta, res_fact.theta, rtol=1e-6, atol=1e-6
        )
        np.testing.assert_allclose(
            res_inc.theta, res_nopre.theta, rtol=1e-3, atol=1e-3
        )
        rows.append(
            {
                "batch": batch,
                "total_fact_rows": bundle.store.get("SalesF").num_rows,
                "incremental_s": t_inc,
                "fact_full_s": t_fact,
                "nopre_full_s": t_nopre,
                "speedup_vs_fact": t_fact / max(t_inc, 1e-9),
                "speedup_vs_nopre": t_nopre / max(t_inc, 1e-9),
            }
        )
    emit("incremental_retrain_after_append", rows)
    med = sorted(r["speedup_vs_nopre"] for r in rows)[len(rows) // 2]
    print(f"-- incremental vs noPre full recompute (median): {med:.2f}x")
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(n_dates=32, n_stores=8, n_items=16, n_batches=2, delta_rows=200)
    else:
        run()


if __name__ == "__main__":
    main()
