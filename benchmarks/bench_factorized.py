"""Paper Table 2: factorized vs non-factorized linear regression, v1–v6.

Reproduces the benchmark matrix on the schema-faithful synthetic Favorita
(the Kaggle original is not redistributable offline — DESIGN.md §7).  The
reproduction target is the RATIO fact/noPre and the error ordering across
versions, not absolute seconds (different data scale + hardware).

Paper claims checked here (HyPer column, Table 2b):
  * fact is ~3.5x faster than noPre end-to-end (1m38s vs 5m41s),
  * v3 (eps=1e-8) ≈ v1 accuracy, no runtime penalty,
  * v4 (alpha revert) most accurate,
  * v5/v6 (theta0 via conversion) notably worse error.
"""

from __future__ import annotations

from repro.core import VERSIONS, linear_regression
from repro.data.synthetic import favorita_like

from .common import emit


def run(n_dates: int = 384, n_stores: int = 64, n_items: int = 96,
        sales_fraction: float = 0.9, versions=None) -> list:
    """Scale matters: the paper's effect (cofactors decouple GD cost from
    data size) only shows once the join is large relative to the p×p
    matrix.  ~2M join rows here (the Kaggle original has 125M).  Each
    version runs twice and reports the second run so jit compilation (paid
    once per shape in production) doesn't pollute the comparison."""
    bundle = favorita_like(
        n_dates=n_dates, n_stores=n_stores, n_items=n_items,
        sales_fraction=sales_fraction,
    )
    rows = []
    for key in versions or ("v1", "v2", "v3", "v4", "v5", "v6", "closed"):
        cfg = VERSIONS[key]
        res = None
        for _ in range(2):  # second run = warm jit caches
            res = linear_regression(
                bundle.store,
                bundle.vorder,
                bundle.features,
                bundle.label,
                config=cfg,
            )
        err = res.evaluate(bundle.store, bundle.features, bundle.label)
        rows.append(
            {
                "version": cfg.name,
                "runtime_s": res.seconds_total,
                "scale_s": res.seconds_scale,
                "cofactor_s": res.seconds_cofactor,
                "gd_s": res.seconds_gd,
                "iterations": res.iterations,
                "avg_abs_err": err["avg_abs_err"],
                "avg_rel_err": err["avg_rel_err"],
            }
        )
    emit("table2_factorized_versions", rows)
    v1 = next(r for r in rows if r["version"].startswith("v1"))
    v2 = next(r for r in rows if r["version"].startswith("v2"))
    print(
        f"-- fact vs noPre speedup (paper: ~3.5x on HyPer): "
        f"{v2['runtime_s'] / max(v1['runtime_s'], 1e-9):.2f}x"
    )
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(n_dates=16, n_stores=4, n_items=8, sales_fraction=0.5,
            versions=("v1", "v2", "closed"))
    else:
        run()


if __name__ == "__main__":
    main()
