"""Categorical cofactors: sparse group-by algebra vs one-hot materialization.

The AC/DC claim (PAPERS.md): as the categorical domain D grows, one-hot
materialization pays O(join_rows · (k + ΣD)²) for a Gram whose categorical
blocks are mostly zeros, while the grouped algebra computes exactly the
nonzero aggregates — per-category counts/sums and sparse co-occurrence —
in O(factorization) + O(nnz).  This benchmark sweeps the domain size of
``item_nbr`` on the synthetic Favorita schema and reports both paths for

  * the full cofactor matrix (least squares sufficient statistics), and
  * logistic regression on ``onpromotion`` (compressed IRLS vs dense
    one-hot Newton — same optimum, checked).

Acceptance target: factorized-categorical beats one-hot materialization at
every D ≥ 100.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import VERSIONS, linear_regression, solve_cofactor
from repro.core.categorical import (
    cat_cofactors_factorized,
    cat_cofactors_per_pass,
    onehot_design_matrix,
)
from repro.core.glm import (
    GLMConfig,
    compressed_design_factorized,
    fit_glm,
    fit_glm_onehot,
    glm_regression,
)
from repro.data.synthetic import fd_star_schema, favorita_like, many_cat_schema

from .common import emit, timeit

CONT = ["transactions"]
CAT = ["store_nbr", "item_nbr"]
LABEL = "unit_sales"
GLM_LABEL = "onpromotion"


def run(n_categories=(16, 64, 128, 256), n_dates: int = 48,
        n_stores: int = 12, repeats: int = 3) -> list:
    rows = []
    for d in n_categories:
        bundle = favorita_like(
            n_dates=n_dates, n_stores=n_stores, n_items=d, seed=7
        )
        store = bundle.store
        # this suite compares COMPUTATION strategies (grouped algebra vs
        # one-hot); cross-batch view reuse would collapse the factorized
        # repeats to cache hits — bench_view_cache owns that axis.
        store.view_cache.enabled = False
        joined = store.materialize_join()
        m = joined.num_rows
        doms = {c: store.attr_domain(c) for c in CAT}
        cont = CONT + [LABEL]

        t_fact = timeit(
            lambda: cat_cofactors_factorized(store, bundle.vorder, cont, CAT),
            repeats=repeats,
        )

        def onehot_path():
            x, _ = onehot_design_matrix(joined, cont, CAT, doms)
            z = np.concatenate([np.ones((x.shape[0], 1)), x], axis=1)
            return z.T @ z

        t_onehot = timeit(onehot_path, repeats=repeats)

        sparse = cat_cofactors_factorized(store, bundle.vorder, cont, CAT)
        dense = onehot_path()
        np.testing.assert_allclose(  # same matrix, assembled sparsely
            sparse.matrix(), dense, rtol=1e-9, atol=1e-9
        )
        # same model: closed-form ridge solve on either matrix
        mat, _ = sparse.regression_matrix(LABEL)
        theta = solve_cofactor(mat, ridge=0.006)

        # GLM leg: compressed IRLS vs dense one-hot Newton
        design = compressed_design_factorized(
            store, bundle.vorder, CONT, CAT, GLM_LABEL
        )
        cfg = GLMConfig(family="logistic", ridge=1e-3)
        t_glm_c = timeit(lambda: fit_glm(design, cfg), repeats=1, warmup=0)
        x_glm, _ = onehot_design_matrix(joined, CONT, CAT, doms)
        y = joined.column(GLM_LABEL).astype(np.float64)
        t_glm_d = timeit(
            lambda: fit_glm_onehot(x_glm, y, cfg), repeats=1, warmup=0
        )
        th_c = fit_glm(design, cfg).theta
        th_d = fit_glm_onehot(x_glm, y, cfg).theta
        np.testing.assert_allclose(th_c, th_d, rtol=1e-5, atol=1e-5)

        rows.append(
            {
                "categories": d,
                "join_rows": m,
                "params": sparse.num_params,
                "sparse_nnz": sparse.nnz(),
                "dense_entries": sparse.num_params ** 2,
                "fact_cofactor_s": t_fact,
                "onehot_cofactor_s": t_onehot,
                "speedup_vs_onehot": t_onehot / max(t_fact, 1e-9),
                "glm_compressed_s": t_glm_c,
                "glm_onehot_s": t_glm_d,
                "glm_speedup": t_glm_d / max(t_glm_c, 1e-9),
                "theta_norm": float(np.linalg.norm(theta[:-1])),
            }
        )
    emit("categorical_vs_onehot", rows)
    big = [r for r in rows if r["categories"] >= 100]
    if big:
        worst = min(r["speedup_vs_onehot"] for r in big)
        print(
            f"-- factorized-categorical vs one-hot at >=100 categories: "
            f"worst {worst:.2f}x (target > 1)"
        )
    return rows


def run_sweep(
    n_cats=(2, 4, 8, 16),
    domain: int = 24,
    n_rows: int = 3000,
    repeats: int = 3,
) -> list:
    """Sweep the NUMBER of categorical attributes: fused single-pass plan
    vs the per-pass baseline (one traversal per attribute + pair).

    The per-pass path runs 1 + n + n(n−1)/2 full engine traversals; the
    fused plan runs exactly one, sharing the join descent and the
    per-node view cache across the whole batch, so its time should stay
    roughly flat in |cat| while the baseline grows quadratically.
    Acceptance target: ≥ 2x at |cat| = 8.
    """
    rows = []
    for n in n_cats:
        bundle = many_cat_schema(
            n_cat=n, domain=domain, n_rows=n_rows, seed=11
        )
        store, vorder = bundle.store, bundle.vorder
        store.view_cache.enabled = False  # measure traversal fusion, not reuse
        cat = [f"c{i}" for i in range(n)]
        cont = ["x", "y"]

        t_fused = timeit(
            lambda: cat_cofactors_factorized(store, vorder, cont, cat),
            repeats=repeats,
        )
        t_pp = timeit(
            lambda: cat_cofactors_per_pass(store, vorder, cont, cat),
            repeats=repeats,
        )
        stats = {}
        fused = cat_cofactors_factorized(store, vorder, cont, cat,
                                         stats=stats)
        per_pass = cat_cofactors_per_pass(store, vorder, cont, cat)
        np.testing.assert_allclose(  # the fused plan changes nothing
            fused.matrix(), per_pass.matrix(), rtol=1e-12, atol=1e-12
        )
        assert stats["passes"] == 1, stats
        rows.append(
            {
                "n_cat": n,
                "params": fused.num_params,
                "passes_fused": stats["passes"],
                "node_visits_fused": stats["node_visits"],
                "passes_per_pass": 1 + n + n * (n - 1) // 2,
                "fused_s": t_fused,
                "per_pass_s": t_pp,
                "speedup_vs_per_pass": t_pp / max(t_fused, 1e-9),
            }
        )
    emit("categorical_fused_sweep", rows)
    at8 = [r for r in rows if r["n_cat"] == 8]
    if at8:
        print(
            f"-- fused single-pass vs per-pass at |cat| = 8: "
            f"{at8[0]['speedup_vs_per_pass']:.2f}x (target >= 2)"
        )
    return rows


def run_fd(
    n_cats=(2, 4, 8),
    domain: int = 96,
    dep_domain: int = 48,
    n_rows: int = 4000,
    repeats: int = 3,
) -> list:
    """FD on/off sweep: train linear + logistic models over a star schema
    with planted ``c_i → d_i`` dependencies, with and without FD-aware
    solving.

    FD-on drops every ``d_i`` before the engine traversal — the fused
    batch shrinks from ``1 + 2n + n(2n−1)`` queries to
    ``1 + n + n(n−1)/2`` — solves over the reduced Gram (p shrinks by
    ``n·dep_domain``) under the generalized per-root ridge, and recovers
    the dropped coefficients in closed form.  Both paths must produce the
    SAME coefficients (asserted at 1e-10 per the acceptance criterion);
    the sweep reports cofactor-build and solve time separately plus the
    GLM IRLS leg.  Acceptance target: FD-on beats FD-off on cofactor
    build + solve at every n.
    """
    cfg = VERSIONS["closed"]
    glm_cfg = GLMConfig(family="logistic", ridge=1e-3)
    rows = []
    for n in n_cats:
        bundle = fd_star_schema(
            n_cat=n, domain=domain, dep_domain=dep_domain,
            n_rows=n_rows, seed=13,
        )
        store, vorder = bundle.store, bundle.vorder
        # FD on/off must both pay their traversals — with the view cache
        # on, the second arm would ride the first arm's subtree views and
        # the ratio would measure cache luck instead of the reduction.
        store.view_cache.enabled = False
        inferred = store.infer_fds()
        assert len(inferred) >= n, inferred  # every c_i → d_i discovered
        cat = [f"c{i}" for i in range(n)] + [f"d{i}" for i in range(n)]
        feats = ["x"] + cat
        red = store.fd_reduction(cat)

        def train(use_fds):
            run_cfg = dataclasses.replace(
                cfg, backend="numpy", categorical=tuple(cat), use_fds=use_fds
            )
            return linear_regression(store, vorder, feats, "y", run_cfg)

        # the acceptance identity: FD-reduced ≡ full to 1e-10
        off_res, on_res = train(False), train(True)
        assert off_res.names == on_res.names
        np.testing.assert_allclose(
            on_res.theta, off_res.theta, rtol=0, atol=1e-10
        )

        def med(times):
            times.sort()
            return times[len(times) // 2]

        cof_off, cof_on, solve_off, solve_on = [], [], [], []
        for _ in range(repeats):
            r_off, r_on = train(False), train(True)
            cof_off.append(r_off.seconds_cofactor)
            solve_off.append(r_off.seconds_gd)
            cof_on.append(r_on.seconds_cofactor)
            solve_on.append(r_on.seconds_gd)
        t_cof_off, t_cof_on = med(cof_off), med(cof_on)
        t_sol_off, t_sol_on = med(solve_off), med(solve_on)

        stats_full, stats_red = {}, {}
        cat_cofactors_factorized(
            store, vorder, ["x", "y"], cat, backend="numpy", stats=stats_full
        )
        cat_cofactors_factorized(
            store, vorder, ["x", "y"], red.kept, backend="numpy",
            stats=stats_red,
        )

        t_glm_off = timeit(
            lambda: glm_regression(
                store, vorder, ["x"], cat, "promo", glm_cfg,
                backend="numpy", use_fds=False,
            ),
            repeats=repeats, warmup=0,
        )
        t_glm_on = timeit(
            lambda: glm_regression(
                store, vorder, ["x"], cat, "promo", glm_cfg,
                backend="numpy", use_fds=True,
            ),
            repeats=repeats, warmup=0,
        )

        rows.append(
            {
                "n_cat": n,
                "params_full": len(off_res.theta),
                "params_reduced": len(off_res.theta)
                - sum(red.domains[d] for d in red.dropped),
                "queries_full": 1 + 2 * n + (2 * n) * (2 * n - 1) // 2,
                "queries_reduced": 1 + n + n * (n - 1) // 2,
                "node_visits_full": stats_full["node_visits"],
                "node_visits_reduced": stats_red["node_visits"],
                "fd_off_cofactor_s": t_cof_off,
                "fd_on_cofactor_s": t_cof_on,
                "fd_off_solve_s": t_sol_off,
                "fd_on_solve_s": t_sol_on,
                "glm_off_s": t_glm_off,
                "glm_on_s": t_glm_on,
                "fd_cofactor_speedup": t_cof_off / max(t_cof_on, 1e-9),
                "fd_solve_speedup": t_sol_off / max(t_sol_on, 1e-9),
                "fd_total_speedup": (t_cof_off + t_sol_off)
                / max(t_cof_on + t_sol_on, 1e-9),
                "glm_fd_speedup": t_glm_off / max(t_glm_on, 1e-9),
            }
        )
    emit("categorical_fd_sweep", rows)
    worst = min(r["fd_total_speedup"] for r in rows)
    print(
        f"-- FD-reduced vs full (cofactor build + solve): worst "
        f"{worst:.2f}x (target > 1)"
    )
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        run(n_categories=(8, 32), n_dates=12, n_stores=4, repeats=1)
        run_sweep(n_cats=(2, 4), domain=8, n_rows=400, repeats=1)
        run_fd(n_cats=(1, 2), domain=8, dep_domain=3, n_rows=400, repeats=1)
    else:
        run()
        run_sweep()
        run_fd()


if __name__ == "__main__":
    main()
