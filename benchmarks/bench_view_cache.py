"""Persistent view cache: cold/warm/append sweep.

Two legs audit the store-owned cross-batch view cache
(``repro.core.view_cache``) against the invalidate-everything behavior it
replaces:

* ``run_warm``   — repeated *overlapping* categorical cofactor batches
  (rotating attribute windows, the per-attribute-sweep / FD-on-off /
  IRLS-re-solve access pattern).  The cold store disables the cache
  (``view_cache_bytes=0``): every batch re-descends the join tree.  The
  warm store reuses finished subtree views across batches — audited by
  ``node_visits`` (a fully-warm batch must report ZERO view evaluations
  on unchanged subtrees).  Target: ≥3x warm-over-cold.
* ``run_append`` — retrain-after-append on a star schema with heavy
  dimension subtrees.  Baseline (cache off) pays a full re-descent of
  every dimension subtree inside each delta fold; the cached store folds
  only the appended relation's root path, dimension views stay warm
  across the version bump.  Target: ≥2x.

Both legs assert cached ≡ uncached results exactly before any timing is
trusted, and surface ``view_cache_bytes`` / ``view_cache_evictions`` in
the emitted rows so the nightly artifact tracks the budget.  The
``warm_speedup`` / ``append_retrain_speedup`` fields are gated by
``benchmarks/compare.py`` in the nightly workflow.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import VERSIONS, linear_regression
from repro.core.categorical import cat_cofactors_factorized
from repro.core.relation import Relation
from repro.core.store import Store
from repro.core.variable_order import VariableOrder
from repro.data.synthetic import many_cat_schema

from .common import emit, stopwatch

CONT = ["x", "y"]


def _windows(n_cat: int, window: int, n_batches: int):
    """Rotating overlapping attribute windows: batch i covers
    c_i..c_{i+window-1} (mod n_cat) — consecutive batches share all but
    one attribute, the overlap regime the cache is built for."""
    return [
        [f"c{(i + j) % n_cat}" for j in range(window)]
        for i in range(n_batches)
    ]


def run_warm(
    n_cat: int = 8,
    domain: int = 24,
    n_rows: int = 30_000,
    window: int = 4,
    n_batches: int = 8,
    seed: int = 7,
) -> list:
    bundle = many_cat_schema(
        n_cat=n_cat, domain=domain, n_rows=n_rows, seed=seed
    )
    rels = bundle.store.relations()
    cold_store = Store(rels, view_cache_bytes=0)  # the no-reuse baseline
    warm_store = Store(rels)
    vorder = bundle.vorder
    batches = _windows(n_cat, window, n_batches)

    # correctness first: cached ≡ uncached on every batch, exactly
    for cat in batches:
        a = cat_cofactors_factorized(warm_store, vorder, CONT, cat)
        b = cat_cofactors_factorized(cold_store, vorder, CONT, cat)
        np.testing.assert_allclose(a.matrix(), b.matrix(), rtol=0, atol=0)

    # timed sweeps: the warm store was primed by the correctness sweep
    # above (that IS the warm scenario — batches repeat); the cold store
    # has no cache to prime.
    cold_store.reset_counters()
    warm_store.reset_counters()
    with stopwatch() as sw_cold:
        for cat in batches:
            cat_cofactors_factorized(cold_store, vorder, CONT, cat)
    with stopwatch() as sw_warm:
        for cat in batches:
            cat_cofactors_factorized(warm_store, vorder, CONT, cat)

    info = warm_store.cache_info()
    rows = [
        {
            "n_cat": n_cat,
            "fact_rows": n_rows,
            "n_batches": n_batches,
            "window": window,
            "cold_s": sw_cold.seconds,
            "warm_s": sw_warm.seconds,
            "warm_speedup": sw_cold.seconds / max(sw_warm.seconds, 1e-9),
            "cold_node_visits": cold_store.node_visits,
            "warm_node_visits": warm_store.node_visits,
            "view_cache_entries": info["view_cache_entries"],
            "view_cache_bytes": info["view_cache_bytes"],
            "view_cache_evictions": info["view_cache_evictions"],
        }
    ]
    emit("view_cache_warm", rows)
    r = rows[0]
    print(
        f"-- warm repeated batches vs cold: {r['warm_speedup']:.2f}x "
        f"(target >= 3), node visits {r['cold_node_visits']} -> "
        f"{r['warm_node_visits']}"
    )
    return rows


def _heavy_star(
    n_dims: int, domain: int, fact_rows: int, dim_rows: int, seed: int
):
    """Fact(c0..c_{n-1}, x, y) ⋈ Dim_i(c_i, w_i) with HEAVY dimensions
    (``dim_rows`` ≫ fact delta) and a hand-built bushy order

        T → c0 → {w0 → [Dim0], c1 → {w1 → [Dim1], ... , x → y → [Fact]}}

    so each dimension hangs in its own subtree: an append to Fact leaves
    every Dim subtree untouched — exactly the shape where delta-path view
    maintenance beats invalidate-everything."""
    rng = np.random.default_rng(seed)
    keys = {
        f"c{i}": rng.integers(0, domain, fact_rows).astype(np.int32)
        for i in range(n_dims)
    }
    x = rng.normal(0, 2.0, fact_rows)
    y = 0.5 * x + rng.normal(0, 0.5, fact_rows)
    for i in range(n_dims):
        y = y + rng.normal(0, 1.0, domain)[keys[f"c{i}"]]
    rels = [
        Relation.from_columns(
            "Fact", keys, {"x": x, "y": y},
            {f"c{i}": domain for i in range(n_dims)},
        )
    ]
    for i in range(n_dims):
        rels.append(
            Relation.from_columns(
                f"Dim{i}",
                {f"c{i}": rng.integers(0, domain, dim_rows).astype(np.int32)},
                {f"w{i}": rng.normal(0, 1.0, dim_rows)},
                {f"c{i}": domain},
            )
        )
    node = VariableOrder("x", [VariableOrder("y", [VariableOrder.leaf("Fact")])])
    for i in reversed(range(n_dims)):
        w = VariableOrder(f"w{i}", [VariableOrder.leaf(f"Dim{i}")])
        node = VariableOrder(f"c{i}", [w, node])
    return rels, VariableOrder.intercept([node])


def _delta(rng, n_dims: int, domain: int, n_rows: int) -> Relation:
    return Relation.from_columns(
        "delta",
        {
            f"c{i}": rng.integers(0, domain, n_rows).astype(np.int32)
            for i in range(n_dims)
        },
        {
            "x": rng.normal(0, 2.0, n_rows),
            "y": rng.normal(0, 1.0, n_rows),
        },
    )


def run_append(
    n_dims: int = 3,
    domain: int = 64,
    fact_rows: int = 6_000,
    dim_rows: int = 200_000,
    n_batches: int = 4,
    delta_rows: int = 400,
    seed: int = 11,
) -> list:
    rels, vorder = _heavy_star(n_dims, domain, fact_rows, dim_rows, seed)
    base_store = Store(rels, view_cache_bytes=0)  # invalidate-everything
    warm_store = Store(rels)
    feats = ["x"]
    cfg = dataclasses.replace(
        VERSIONS["closed"], backend="numpy", use_cache=True
    )
    kw = dict(config=cfg)

    # seed both cofactor caches (and the warm store's view cache) — the
    # initial training run is identical in both arms and not timed.
    linear_regression(base_store, vorder, feats, "y", **kw)
    linear_regression(warm_store, vorder, feats, "y", **kw)

    rng = np.random.default_rng(seed + 1)
    rows = []
    t_base_total = t_warm_total = 0.0
    for batch in range(n_batches):
        delta = _delta(rng, n_dims, domain, delta_rows)
        with stopwatch() as sw_base:
            base_store.append("Fact", delta)
            res_base = linear_regression(base_store, vorder, feats, "y", **kw)
        with stopwatch() as sw_warm:
            warm_store.append("Fact", delta)
            res_warm = linear_regression(warm_store, vorder, feats, "y", **kw)
        np.testing.assert_allclose(  # both arms retrain the same model
            res_warm.theta, res_base.theta, rtol=1e-9, atol=1e-9
        )
        t_base_total += sw_base.seconds
        t_warm_total += sw_warm.seconds
        info = warm_store.cache_info()
        rows.append(
            {
                "batch": batch,
                "fact_rows": base_store.get("Fact").num_rows,
                "dim_rows": dim_rows,
                "baseline_s": sw_base.seconds,
                "cached_s": sw_warm.seconds,
                "append_retrain_speedup": sw_base.seconds
                / max(sw_warm.seconds, 1e-9),
                "view_cache_bytes": info["view_cache_bytes"],
                "view_cache_evictions": info["view_cache_evictions"],
            }
        )
    emit("view_cache_append", rows)
    total = t_base_total / max(t_warm_total, 1e-9)
    print(
        f"-- retrain-after-append, delta-maintained views vs "
        f"invalidate-everything: {total:.2f}x total (target >= 2)"
    )
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        # dims must stay heavy relative to the delta even in smoke: at toy
        # sizes the fold bookkeeping rivals the saved descents and the
        # speedup fields would gate on noise.
        run_warm(n_cat=4, domain=8, n_rows=2_000, window=3, n_batches=3)
        run_append(
            n_dims=3, domain=16, fact_rows=2_000, dim_rows=40_000,
            n_batches=2, delta_rows=150,
        )
    else:
        run_warm()
        run_append()


if __name__ == "__main__":
    main()
