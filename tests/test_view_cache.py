"""Persistent cross-batch view cache: auditing, maintenance, equivalence.

The acceptance contract of the store-owned view cache
(``repro.core.view_cache`` + ``Store`` + ``FactorizedEngine``):

* a warm ``run_batch`` over an overlapping query set reports ZERO
  ``node_visits`` on unchanged subtrees (counter-based audit);
* ``append`` recomputes only the appended relation's root path — sibling
  subtree entries survive the version bump and the delta descent is
  audited to be far smaller than a cold traversal;
* ``put`` invalidates exactly the entries whose subtree covers the
  replaced relation;
* cached ≡ uncached to 1e-12 under arbitrary append/put/drop_fd
  interleavings (hypothesis property + deterministic mirror);
* the cache is bytes-accounted with LRU eviction and stays correct when
  entries are evicted.
"""

import numpy as np
import pytest

from repro.core import VERSIONS, linear_regression
from repro.core.categorical import cat_cofactors_factorized
from repro.core.factorize import AggregateQuery, FactorizedEngine, cofactors_factorized
from repro.core.relation import Relation
from repro.core.store import Store
from repro.core.view_cache import ViewCache, ViewKey
from repro.data.synthetic import (
    fd_star_schema,
    many_cat_schema,
    random_acyclic_schema,
)

CONT = ["x", "y"]


def _delta_for(rel: Relation, rng, n_rows: int, grow: bool = False) -> Relation:
    """Random delta with the same attribute sets as ``rel``; ``grow=True``
    pushes one key column past the current domain (unseen category ids)."""
    keys = {}
    for i, (a, _col) in enumerate(rel.keys.items()):
        dom = int(rel.domains[a])
        ids = rng.integers(0, dom, n_rows).astype(np.int32)
        if grow and i == 0 and n_rows:
            ids[0] = dom  # one id past the current dictionary
        keys[a] = ids
    values = {
        a: rng.normal(0, 2.0, n_rows) for a in rel.values
    }
    return Relation.from_columns("delta", keys, values)


# ---------------------------------------------------------------------------
# Counter-based audits
# ---------------------------------------------------------------------------

def test_warm_batch_zero_node_visits():
    b = many_cat_schema(n_cat=4, domain=8, n_rows=400, seed=1)
    cat = [f"c{i}" for i in range(4)]
    s1, s2 = {}, {}
    cold = cat_cofactors_factorized(b.store, b.vorder, CONT, cat, stats=s1)
    warm = cat_cofactors_factorized(b.store, b.vorder, CONT, cat, stats=s2)
    assert s1["node_visits"] > 0 and s1["vc_misses"] > 0
    assert s2["node_visits"] == 0  # every view answered cross-batch
    assert s2["vc_hits"] > 0 and s2["vc_misses"] == 0
    np.testing.assert_allclose(warm.matrix(), cold.matrix(), rtol=0, atol=0)


def test_overlapping_query_sets_share_subtrees():
    """A batch over a DIFFERENT but overlapping attribute subset reuses the
    first batch's views wherever live subsets coincide."""
    b = many_cat_schema(n_cat=5, domain=8, n_rows=400, seed=2)
    cat = [f"c{i}" for i in range(5)]
    cat_cofactors_factorized(b.store, b.vorder, CONT, cat[:4])
    s = {}
    out = cat_cofactors_factorized(b.store, b.vorder, CONT, cat[1:5], stats=s)
    assert s["vc_hits"] > 0
    ref = cat_cofactors_factorized(
        b.store, b.vorder, CONT, cat[1:5], use_view_cache=False
    )
    np.testing.assert_allclose(out.matrix(), ref.matrix(), rtol=0, atol=0)


def test_degree_trimming_from_cached_views():
    """A degree-2 cached view answers later degree-0/1 requests by block
    slicing — no re-descent."""
    b = many_cat_schema(n_cat=3, domain=6, n_rows=300, seed=3)
    eng = FactorizedEngine(b.store, b.vorder, CONT, backend="numpy")
    eng.run_batch([AggregateQuery("base", (), 2)])
    eng2 = FactorizedEngine(b.store, b.vorder, CONT, backend="numpy")
    out = eng2.run_batch([AggregateQuery("cnt", (), 0)])["cnt"]
    assert eng2.node_visits == 0 and eng2.vc_hits > 0
    assert out.lin is None and out.quad is None
    ref = FactorizedEngine(
        b.store, b.vorder, CONT, backend="numpy", use_view_cache=False
    ).run_batch([AggregateQuery("cnt", (), 0)])["cnt"]
    np.testing.assert_allclose(out.count, ref.count, rtol=0, atol=0)


def _bushy_star(n_dims: int = 3, domain: int = 8, fact_rows: int = 400,
                dim_rows: int = 600, seed: int = 4):
    """Fact(c0..c_{n-1}, x, y) ⋈ Dim_i(c_i, w_i) under a hand-built bushy
    order — each dimension in its own subtree, so "sibling subtrees are
    not re-descended under append" is visible in the visit counters (a
    chain order would put every node on the fact leaf's root path)."""
    from repro.core.variable_order import VariableOrder

    rng = np.random.default_rng(seed)
    keys = {
        f"c{i}": rng.integers(0, domain, fact_rows).astype(np.int32)
        for i in range(n_dims)
    }
    rels = [
        Relation.from_columns(
            "Fact", keys,
            {"x": rng.normal(0, 2, fact_rows), "y": rng.normal(0, 1, fact_rows)},
            {f"c{i}": domain for i in range(n_dims)},
        )
    ]
    for i in range(n_dims):
        rels.append(
            Relation.from_columns(
                f"Dim{i}",
                {f"c{i}": rng.integers(0, domain, dim_rows).astype(np.int32)},
                {f"w{i}": rng.normal(0, 1, dim_rows)},
                {f"c{i}": domain},
            )
        )
    node = VariableOrder(
        "x", [VariableOrder("y", [VariableOrder.leaf("Fact")])]
    )
    for i in reversed(range(n_dims)):
        w = VariableOrder(f"w{i}", [VariableOrder.leaf(f"Dim{i}")])
        node = VariableOrder(f"c{i}", [w, node])
    return Store(rels), VariableOrder.intercept([node])


def test_append_folds_root_path_only():
    """After an append + flush, a warm batch still reports zero visits
    (the drain folded every affected entry), and the fold itself visited
    only the appended relation's root path — the dimension subtrees'
    views were served from the cache, not re-descended."""
    store, vorder = _bushy_star()
    cat = ["c0", "c1", "c2"]
    cat_cofactors_factorized(store, vorder, CONT, cat)
    cold_visits = store.node_visits
    assert cold_visits > 0

    rng = np.random.default_rng(0)
    delta = _delta_for(store.get("Fact"), rng, 40)
    store.reset_counters()
    store.append("Fact", delta)
    assert store.node_visits == 0  # lazy write path: O(delta), no folds
    store.flush()
    append_visits = store.node_visits
    # only nodes covering Fact (root path + Fact leaf) are re-evaluated;
    # every w_i/Dim_i subtree view is a cache hit during the delta folds
    assert 0 < append_visits < cold_visits
    assert store.view_cache.hits > 0

    s = {}
    out = cat_cofactors_factorized(store, vorder, CONT, cat, stats=s)
    assert s["node_visits"] == 0  # maintenance kept the whole batch warm
    ref = cat_cofactors_factorized(
        store, vorder, CONT, cat, use_view_cache=False
    )
    np.testing.assert_allclose(
        out.matrix(), ref.matrix(), rtol=1e-12, atol=1e-9
    )


def test_append_with_unseen_category_ids():
    """Dictionary growth: a delta introducing unseen ids extends the
    append-only dictionaries without renumbering — folded views match a
    cold recompute exactly."""
    b = many_cat_schema(n_cat=3, domain=6, n_rows=300, seed=5)
    cat = [f"c{i}" for i in range(3)]
    cat_cofactors_factorized(b.store, b.vorder, CONT, cat)
    rng = np.random.default_rng(1)
    delta = _delta_for(b.store.get("Fact"), rng, 30, grow=True)
    b.store.append("Fact", delta)
    out = cat_cofactors_factorized(b.store, b.vorder, CONT, cat)
    ref = cat_cofactors_factorized(
        b.store, b.vorder, CONT, cat, use_view_cache=False
    )
    np.testing.assert_allclose(
        out.matrix(), ref.matrix(), rtol=1e-12, atol=1e-9
    )


def test_put_invalidates_covering_subtrees_only():
    b = many_cat_schema(n_cat=3, domain=6, n_rows=300, seed=6)
    cat = [f"c{i}" for i in range(3)]
    cat_cofactors_factorized(b.store, b.vorder, CONT, cat)
    before = len(b.store.view_cache)
    assert before > 0
    b.store.put(b.store.get("Dim0"))
    after = len(b.store.view_cache)
    assert 0 < after < before
    for _key, entry in b.store.view_cache.items():
        assert "Dim0" not in entry.relations
    out = cat_cofactors_factorized(b.store, b.vorder, CONT, cat)
    ref = cat_cofactors_factorized(
        b.store, b.vorder, CONT, cat, use_view_cache=False
    )
    np.testing.assert_allclose(out.matrix(), ref.matrix(), rtol=0, atol=0)


def test_unified_counters_and_reset():
    """The bugfix contract: ``passes``/``node_visits`` accumulate over
    every engine path uniformly (plain cofactors included — previously
    only categorical paths counted), and ``reset_counters()`` zeroes all
    of them so callers stop depending on call order."""
    b = many_cat_schema(n_cat=2, domain=6, n_rows=200, seed=7)
    cofactors_factorized(b.store, b.vorder, CONT, backend="numpy")
    info = b.store.cache_info()
    assert info["passes"] == 1 and info["node_visits"] > 0
    assert info["cat_passes"] == 0  # plain path: unified counters only
    b.store.cat_cofactors(b.vorder, CONT, ["c0"])
    info = b.store.cache_info()
    assert info["passes"] == 2 and info["cat_passes"] == 1
    b.store.reset_counters()
    info = b.store.cache_info()
    assert info["passes"] == 0 and info["node_visits"] == 0
    assert info["cat_passes"] == 0 and info["cat_node_visits"] == 0
    assert info["view_cache_hits"] == 0 and info["view_cache_misses"] == 0


# ---------------------------------------------------------------------------
# Eviction / bytes accounting
# ---------------------------------------------------------------------------

def test_lru_eviction_bounded_and_correct():
    b = many_cat_schema(n_cat=4, domain=8, n_rows=600, seed=8)
    rels = b.store.relations()
    tiny = Store(rels, view_cache_bytes=20_000)  # force evictions
    cat = [f"c{i}" for i in range(4)]
    out = cat_cofactors_factorized(tiny, b.vorder, CONT, cat)
    info = tiny.cache_info()
    assert info["view_cache_bytes"] <= 20_000
    assert info["view_cache_evictions"] > 0
    ref = cat_cofactors_factorized(
        tiny, b.vorder, CONT, cat, use_view_cache=False
    )
    np.testing.assert_allclose(out.matrix(), ref.matrix(), rtol=0, atol=0)
    # disabled cache stores nothing
    off = Store(rels, view_cache_bytes=0)
    cat_cofactors_factorized(off, b.vorder, CONT, cat)
    assert off.cache_info()["view_cache_entries"] == 0


def test_view_cache_unit_lru():
    vc = ViewCache(max_bytes=100)

    class _V:  # minimal view stub
        def __init__(self):
            self.keys = {}
            self.c = np.zeros(5)  # 40 bytes
            self.l = None
            self.q = None

    def key(i, degree=0):
        return ViewKey(("sig",), "numpy", "float64", i, (), frozenset(), degree)

    vc.put(key(0), _V(), frozenset({"R"}), version=0)
    vc.put(key(1), _V(), frozenset({"S"}), version=0)
    assert len(vc) == 2 and vc.bytes == 80
    vc.get(key(0), 0)  # refresh 0 — key(1) becomes LRU
    vc.put(key(2), _V(), frozenset({"T"}), version=0)
    assert vc.evictions == 1 and len(vc) == 2
    assert vc.get(key(1), 0) is None  # evicted
    assert vc.get(key(0), 0) is not None
    # version mismatch drops the entry (backstop)
    assert vc.get(key(2), 99) is None
    assert len(vc) == 1
    # a higher-degree put subsumes the lower-degree entry at the same key
    vc.put(key(0, degree=2), _V(), frozenset({"R"}), version=0)
    assert vc.get(key(0, degree=0), 0) is None  # replaced, not duplicated
    vc.invalidate_relation("R")
    assert len(vc) == 0 and vc.bytes == 0


# ---------------------------------------------------------------------------
# cached ≡ uncached under mutation interleavings
# ---------------------------------------------------------------------------

def _assert_cached_equals_uncached(store, vorder, cont, cat):
    cached = cat_cofactors_factorized(store, vorder, cont, cat)
    fresh = cat_cofactors_factorized(
        store, vorder, cont, cat, use_view_cache=False
    )
    scale = max(1.0, float(np.abs(fresh.matrix()).max()))
    np.testing.assert_allclose(
        cached.matrix(), fresh.matrix(), rtol=1e-12, atol=1e-12 * scale
    )


def _apply_op(store, op: int, rng) -> None:
    names = store.names()
    name = names[op % len(names)]
    rel = store.get(name)
    kind = (op // len(names)) % 3
    if kind == 0:  # append (occasionally with unseen ids)
        store.append(name, _delta_for(rel, rng, int(rng.integers(1, 8)),
                                      grow=bool(op % 2)))
    elif kind == 1:  # put: replace with a perturbed copy
        values = {
            a: c + rng.normal(0, 0.1, len(c)) for a, c in rel.values.items()
        }
        store.put(Relation(rel.name, dict(rel.keys), values, dict(rel.domains)))
    else:  # FD churn
        store.infer_fds()
        fds = store.fds()
        if fds:
            fd = fds[int(rng.integers(0, len(fds)))]
            store.drop_fd(fd.lhs, fd.rhs)


def test_cached_equals_uncached_interleavings_deterministic():
    """Deterministic mirror of the hypothesis property below."""
    for seed in range(6):
        b = random_acyclic_schema(seed, n_branches=(seed % 3) + 1)
        cat = ["k0"] + [f"k{i + 1}" for i in range(len(b.features) // 2)]
        cont = b.features + [b.label]
        rng = np.random.default_rng(seed)
        _assert_cached_equals_uncached(b.store, b.vorder, cont, cat)
        for _op in range(5):
            _apply_op(b.store, int(rng.integers(0, 30)), rng)
            _assert_cached_equals_uncached(b.store, b.vorder, cont, cat)


def test_store_cofactors_warm_after_mutations():
    """The result-level caches stay exact riding on the maintained view
    layer: warm retrains equal from-scratch retrains after appends."""
    b = fd_star_schema(n_cat=2, domain=8, dep_domain=3, n_rows=300, seed=9)
    b.store.infer_fds()
    cfg = VERSIONS["closed"]
    kw = dict(config=cfg, backend="numpy")
    warm = linear_regression(b.store, b.vorder, ["x"], "y", use_cache=True, **kw)
    rng = np.random.default_rng(2)
    for _ in range(3):
        delta = _delta_for(b.store.get("Fact"), rng, 25)
        b.store.append("Fact", delta)
        warm = linear_regression(
            b.store, b.vorder, ["x"], "y", use_cache=True, **kw
        )
        fresh = linear_regression(b.store, b.vorder, ["x"], "y", **kw)
        np.testing.assert_allclose(warm.theta, fresh.theta, rtol=1e-8, atol=1e-8)


def test_append_after_mixed_degree_batches():
    """Regression: delta folds at different degrees must not share memo
    entries — a degree-1 fold's descendant views (no quad block) served
    to a degree-2 fold crashed the whole append."""
    b = many_cat_schema(n_cat=2, domain=6, n_rows=250, seed=11)
    e1 = FactorizedEngine(b.store, b.vorder, CONT, backend="numpy")
    e1.run_batch([AggregateQuery("g", ("c0",), 1)])  # degree-1 entries first
    e2 = FactorizedEngine(b.store, b.vorder, CONT, backend="numpy")
    e2.run_batch([AggregateQuery("base", (), 2)])  # degree-2 entries after
    rng = np.random.default_rng(4)
    delta = _delta_for(b.store.get("Fact"), rng, 25)
    b.store.append("Fact", delta)  # must fold both degrees cleanly
    out = cat_cofactors_factorized(b.store, b.vorder, CONT, ["c0"])
    ref = cat_cofactors_factorized(
        b.store, b.vorder, CONT, ["c0"], use_view_cache=False
    )
    np.testing.assert_allclose(out.matrix(), ref.matrix(), rtol=1e-12, atol=1e-9)


def test_stale_engine_does_not_poison_cache():
    """Regression: an engine constructed BEFORE a catalog mutation holds a
    snapshot of the old encodings; running it afterwards must neither
    publish its stale views (silent wrong results for later queries) nor
    serve entries from the moved-on cache."""
    b = many_cat_schema(n_cat=2, domain=6, n_rows=250, seed=12)
    stale = FactorizedEngine(b.store, b.vorder, CONT, backend="numpy")
    rel = b.store.get("Fact")
    rng = np.random.default_rng(5)
    values = {a: c + rng.normal(0, 1, len(c)) for a, c in rel.values.items()}
    b.store.put(Relation(rel.name, dict(rel.keys), values, dict(rel.domains)))
    stale.run_batch([AggregateQuery("base", (), 2)])  # snapshot semantics
    fresh = cofactors_factorized(b.store, b.vorder, CONT, backend="numpy")
    ref = cofactors_factorized(
        b.store, b.vorder, CONT, backend="numpy", use_view_cache=False
    )
    np.testing.assert_allclose(fresh.quad, ref.quad, rtol=0, atol=0)
    np.testing.assert_allclose(fresh.lin, ref.lin, rtol=0, atol=0)


def test_replace_respects_byte_budget():
    """Regression: growth through ``replace`` (delta folds) must re-run
    eviction — the budget is a bound, not a suggestion."""
    vc = ViewCache(max_bytes=100)

    class _V:
        def __init__(self, n):
            self.keys = {}
            self.c = np.zeros(n)
            self.l = None
            self.q = None

    def key(i):
        return ViewKey(("sig",), "numpy", "float64", i, (), frozenset(), 0)

    vc.put(key(0), _V(5), frozenset({"R"}), version=0)  # 40 bytes
    vc.put(key(1), _V(5), frozenset({"S"}), version=0)  # 40 bytes
    vc.replace(key(1), _V(11))  # grows to 88 bytes -> over budget
    assert vc.bytes <= vc.max_bytes
    assert vc.evictions == 1 and vc.get(key(0), 0) is None
    assert vc.get(key(1), 0) is not None  # the folded entry survived


def test_sharded_fold_agrees_with_store_maintenance():
    """Sharded paths keep correctness with the cache on or off: folding a
    delta through ``incremental_sharded_cat_cofactors`` (host fp64 and
    1-device mesh) lands on the same cofactors as the store's view-cache-
    maintained entry, and a cache-off store agrees bit-for-bit."""
    import jax

    from repro.core.distributed import incremental_sharded_cat_cofactors

    b = many_cat_schema(n_cat=2, domain=6, n_rows=250, seed=10)
    rels = b.store.relations()
    off_store = Store(rels, view_cache_bytes=0)
    cat = ["c0", "c1"]
    base_on = b.store.cat_cofactors(b.vorder, CONT, cat)
    base_off = off_store.cat_cofactors(b.vorder, CONT, cat)
    np.testing.assert_allclose(
        base_on.matrix(), base_off.matrix(), rtol=0, atol=0
    )

    rng = np.random.default_rng(3)
    delta = _delta_for(b.store.get("Fact"), rng, 30)
    # array-level fold of the delta's contribution to the join: the delta
    # fact rows joined against the (dimension-free) schema are the rows
    # themselves, so extract columns directly
    x_delta = np.stack(
        [delta.values["x"], delta.values["y"]], axis=1
    ).astype(np.float64)
    ids_delta = np.stack(
        [delta.keys["c0"], delta.keys["c1"]], axis=1
    ).astype(np.int64)
    folded_host = incremental_sharded_cat_cofactors(
        base_on, x_delta, ids_delta
    )
    mesh = jax.make_mesh((1,), ("data",))
    folded_mesh = incremental_sharded_cat_cofactors(
        base_on, x_delta, ids_delta, mesh=mesh
    )

    b.store.append("Fact", delta)
    off_store.append("Fact", delta)
    maintained_on = b.store.cat_cofactors(b.vorder, CONT, cat)
    maintained_off = off_store.cat_cofactors(b.vorder, CONT, cat)
    np.testing.assert_allclose(
        maintained_on.matrix(), maintained_off.matrix(), rtol=1e-12, atol=1e-9
    )
    np.testing.assert_allclose(
        folded_host.matrix(), maintained_on.matrix(), rtol=1e-9, atol=1e-9
    )
    np.testing.assert_allclose(  # mesh path accumulates fp32
        folded_mesh.matrix(), maintained_on.matrix(), rtol=1e-4, atol=1e-2
    )


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 40),
        n_branches=st.integers(1, 3),
        ops=st.lists(st.integers(0, 63), min_size=0, max_size=6),
    )
    def test_cached_equals_uncached_property(seed, n_branches, ops):
        """Over random acyclic joins with random append/put/drop_fd
        interleavings, every batch served through the persistent view
        cache equals a fresh uncached evaluation to 1e-12."""
        b = random_acyclic_schema(seed, n_branches=n_branches)
        cat = ["k0"] + [f"k{i + 1}" for i in range(len(b.features) // 2)]
        cont = b.features + [b.label]
        rng = np.random.default_rng(seed)
        _assert_cached_equals_uncached(b.store, b.vorder, cont, cat)
        for op in ops:
            _apply_op(b.store, op, rng)
            _assert_cached_equals_uncached(b.store, b.vorder, cont, cat)
else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_cached_equals_uncached_property():
        pass
