"""Fused traversal-node kernels wired into the engine.

The acceptance contract of ``FactorizedEngine(use_node_kernels=...)``
(ISSUE 10): the fused ``segment_view`` / ``segment_blocks`` /
device-grouping paths are drop-in — fused ≡ unfused cofactors at 1e-12
over random acyclic schemas, ``passes``/``node_visits`` counters unchanged,
grouped key layouts byte-identical — plus the two satellite fixes:
``_segment_sum``'s ``jax.ops.segment_sum`` fallback equivalence and the
``_merge_views``/``_group_rows`` canonical sorted-key layout surviving
delta folds after multi-key appends.
"""

import numpy as np
import pytest

from repro.core import VERSIONS, linear_regression
from repro.core.categorical import cat_cofactors_factorized
from repro.core.factorize import (
    AggregateQuery,
    FactorizedEngine,
    cofactors_factorized,
)
from repro.core.regression import RegressionConfig
from repro.core.relation import Relation
from repro.core.store import Store
from repro.data.synthetic import (
    figure1_schema,
    many_cat_schema,
    random_acyclic_schema,
)

CONT = ["x", "y"]


def _pair(bundle, **kw):
    """Fused + unfused engines over the same bundle (cache off so both
    actually traverse)."""
    cols = bundle.features + [bundle.label]
    mk = dict(backend="jax", use_view_cache=False, **kw)
    return (
        FactorizedEngine(
            bundle.store, bundle.vorder, cols, use_node_kernels=False, **mk
        ),
        FactorizedEngine(
            bundle.store, bundle.vorder, cols, use_node_kernels=True, **mk
        ),
    )


def _assert_cof_close(a, b, atol=1e-10):
    np.testing.assert_allclose(
        np.asarray(a.matrix()), np.asarray(b.matrix()), rtol=1e-12, atol=atol
    )


# ---------------------------------------------------------------------------
# fused ≡ unfused over random schemas, counters unchanged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 7, 13, 42])
def test_fused_matches_unfused_random_schema(seed):
    bundle = random_acyclic_schema(seed, n_branches=2, max_fanout=4,
                                   max_rows=12)
    eng_u, eng_f = _pair(bundle)
    cof_u, cof_f = eng_u.cofactors(), eng_f.cofactors()
    _assert_cof_close(cof_u, cof_f)
    # identical traversal structure: fusion changes dispatches, not visits
    assert eng_u.passes == eng_f.passes
    assert eng_u.node_visits == eng_f.node_visits


def test_fused_matches_numpy_oracle():
    bundle = figure1_schema()
    cols = bundle.features + [bundle.label]
    oracle = cofactors_factorized(
        bundle.store, bundle.vorder, cols, backend="numpy",
        use_view_cache=False,
    )
    fused = cofactors_factorized(
        bundle.store, bundle.vorder, cols, backend="jax",
        use_node_kernels=True, use_view_cache=False,
    )
    # jax runs fp32; oracle fp64
    np.testing.assert_allclose(
        np.asarray(fused.matrix()), oracle.matrix(), rtol=5e-4, atol=1e-3
    )


def test_fused_grouped_keys_byte_identical():
    """GROUP BY queries: fused grouping must produce the SAME group rows
    in the SAME order — key arrays byte-identical, blocks at 1e-12."""
    b = many_cat_schema(n_cat=3, domain=8, n_rows=500, seed=3)
    queries = [
        AggregateQuery("base", (), 2),
        AggregateQuery("g1", ("c0",), 1),
        AggregateQuery("g2", ("c1", "c2"), 1),
    ]
    eng_u, eng_f = _pair(
        type("B", (), {
            "store": b.store, "vorder": b.vorder,
            "features": CONT[:1], "label": CONT[1],
        })()
    )
    out_u = eng_u.run_batch(queries)
    out_f = eng_f.run_batch(queries)
    for name in ("base", "g1", "g2"):
        bu, bf = out_u[name], out_f[name]
        assert list(bu.keys) == list(bf.keys)
        for a in bu.keys:
            np.testing.assert_array_equal(bu.keys[a], bf.keys[a])
        np.testing.assert_allclose(
            np.asarray(bu.count), np.asarray(bf.count),
            rtol=1e-12, atol=1e-8,
        )
        if bu.lin is not None:
            np.testing.assert_allclose(
                np.asarray(bu.lin), np.asarray(bf.lin),
                rtol=1e-12, atol=1e-8,
            )


def test_fused_device_grouping_matches_host():
    """Force the device sort-based grouping path (gated off on CPU by
    default) — ids, group order, and results must match the host path."""
    b = many_cat_schema(n_cat=2, domain=16, n_rows=600, seed=5)
    cols = CONT
    kw = dict(backend="jax", use_view_cache=False)
    eng_host = FactorizedEngine(
        b.store, b.vorder, cols, use_node_kernels=True, **kw
    )
    assert not eng_host.device_grouping  # CPU container default
    eng_dev = FactorizedEngine(
        b.store, b.vorder, cols, use_node_kernels=True, **kw
    )
    eng_dev.device_grouping = True
    out_h = eng_host.run_batch([AggregateQuery("g", ("c0", "c1"), 2)])["g"]
    out_d = eng_dev.run_batch([AggregateQuery("g", ("c0", "c1"), 2)])["g"]
    for a in out_h.keys:
        np.testing.assert_array_equal(out_h.keys[a], out_d.keys[a])
    np.testing.assert_allclose(
        np.asarray(out_h.quad), np.asarray(out_d.quad), rtol=1e-6, atol=1e-5
    )


def test_default_on_for_jax_backend_only():
    b = figure1_schema()
    cols = b.features + [b.label]
    assert FactorizedEngine(b.store, b.vorder, cols,
                            backend="jax").use_node_kernels
    assert not FactorizedEngine(b.store, b.vorder, cols,
                                backend="numpy").use_node_kernels
    # explicit request on numpy backend is ignored (kernels are jnp-only)
    assert not FactorizedEngine(
        b.store, b.vorder, cols, backend="numpy", use_node_kernels=True
    ).use_node_kernels


def test_regression_config_plumbing():
    """use_node_kernels threads linear_regression → engine; theta parity."""
    import dataclasses

    b = figure1_schema()
    res_u = linear_regression(
        b.store, b.vorder, b.features, b.label,
        dataclasses.replace(VERSIONS["closed"], use_node_kernels=False),
    )
    res_f = linear_regression(
        b.store, b.vorder, b.features, b.label,
        dataclasses.replace(VERSIONS["closed"], use_node_kernels=True),
    )
    np.testing.assert_allclose(res_f.theta, res_u.theta, rtol=1e-5,
                               atol=1e-6)


def test_fused_categorical_matches_unfused():
    b = many_cat_schema(n_cat=3, domain=8, n_rows=400, seed=9)
    cat = [f"c{i}" for i in range(3)]
    kw = dict(use_view_cache=False)
    cu = cat_cofactors_factorized(
        b.store, b.vorder, CONT, cat, use_node_kernels=False, **kw
    )
    cf = cat_cofactors_factorized(
        b.store, b.vorder, CONT, cat, use_node_kernels=True, **kw
    )
    np.testing.assert_allclose(
        np.asarray(cf.matrix()), np.asarray(cu.matrix()),
        rtol=1e-12, atol=1e-6,
    )


# ---------------------------------------------------------------------------
# satellite 1: jax.ops.segment_sum fallback equivalence
# ---------------------------------------------------------------------------

def test_segment_sum_fallback_equivalence():
    """The jax-backend `_segment_sum` (now jax.ops.segment_sum) ≡ the
    numpy np.add.at path, for every block rank the traversal produces."""
    b = figure1_schema()
    cols = b.features + [b.label]
    eng_j = FactorizedEngine(b.store, b.vorder, cols, backend="jax",
                             use_node_kernels=False, use_view_cache=False)
    eng_n = FactorizedEngine(b.store, b.vorder, cols, backend="numpy",
                             use_view_cache=False)
    rng = np.random.default_rng(0)
    n, g = 257, 9
    seg = rng.integers(0, g, n).astype(np.int32)
    for shape in [(n,), (n, 4), (n, 3, 3)]:
        data = rng.standard_normal(shape).astype(np.float32)
        out_j = np.asarray(eng_j._segment_sum(data, seg, g))
        out_n = eng_n._segment_sum(data, seg, g)
        np.testing.assert_allclose(out_j, out_n, rtol=1e-6, atol=1e-5)


# ---------------------------------------------------------------------------
# satellite 2: canonical key order survives delta folds
# ---------------------------------------------------------------------------

def _two_branch_bundle(n_rows=300, seed=11):
    """A schema whose ROOT view is multi-keyed via two intercept children —
    the shape where first-seen (join) key order used to diverge from
    _merge_views' sorted regroup order."""
    return many_cat_schema(n_cat=3, domain=6, n_rows=n_rows, seed=seed)


def test_cached_views_sorted_key_layout():
    """Every persisted multi-key view uses the canonical sorted-key
    layout, before AND after a delta fold."""
    b = _two_branch_bundle()
    cat = ["c0", "c1", "c2"]
    cat_cofactors_factorized(b.store, b.vorder, CONT, cat)

    def assert_canonical():
        seen_multi = 0
        for _key, entry in b.store.view_cache.items():
            keys = list(entry.view.keys)
            assert keys == sorted(keys), keys
            seen_multi += len(keys) > 1
        return seen_multi

    assert assert_canonical() > 0  # the fixture does cache multi-key views

    rng = np.random.default_rng(1)
    fact = b.store.get("Fact")
    keys = {a: rng.integers(0, int(fact.domains[a]), 40).astype(np.int32)
            for a in fact.keys}
    values = {a: rng.normal(0, 2.0, 40) for a in fact.values}
    b.store.append("Fact", Relation.from_columns("delta", keys, values))
    b.store.flush()
    assert assert_canonical() > 0


def test_delta_fold_preserves_layout_after_multikey_append():
    """Regression for the _merge_views/_group_rows key-order asymmetry:
    a delta fold after an append touching a multi-key relation must leave
    cached views in the same layout a fresh compute produces — same key
    dict order, same group rows, values at 1e-12."""
    b = _two_branch_bundle()
    cat = ["c0", "c1", "c2"]
    warm = cat_cofactors_factorized(b.store, b.vorder, CONT, cat)
    rng = np.random.default_rng(2)
    fact = b.store.get("Fact")
    keys = {a: rng.integers(0, int(fact.domains[a]), 60).astype(np.int32)
            for a in fact.keys}
    values = {a: rng.normal(0, 2.0, 60) for a in fact.values}
    b.store.append("Fact", Relation.from_columns("delta", keys, values))

    stats = {}
    folded = cat_cofactors_factorized(b.store, b.vorder, CONT, cat,
                                      stats=stats)
    fresh = cat_cofactors_factorized(b.store, b.vorder, CONT, cat,
                                     use_view_cache=False)
    assert stats["node_visits"] == 0  # served from folded cache entries
    np.testing.assert_allclose(
        np.asarray(folded.matrix()), np.asarray(fresh.matrix()),
        rtol=1e-12, atol=1e-6,
    )
    assert warm.matrix().shape == fresh.matrix().shape


# ---------------------------------------------------------------------------
# property test: fused ≡ unfused over random acyclic schemas
# ---------------------------------------------------------------------------

try:  # property tests ride along only where hypothesis is installed;
    # the deterministic seeds above stay unconditional
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover
    settings = None

if settings is not None:
    SET = settings(
        max_examples=25,
        deadline=None,
        derandomize=True,
        suppress_health_check=[HealthCheck.too_slow],
    )

    schema_params = st.builds(
        random_acyclic_schema,
        seed=st.integers(0, 10_000),
        n_branches=st.integers(1, 3),
        max_fanout=st.integers(1, 5),
        max_rows=st.integers(1, 15),
    )

    @SET
    @given(bundle=schema_params)
    def test_fused_equals_unfused_property(bundle):
        eng_u, eng_f = _pair(bundle)
        cof_u, cof_f = eng_u.cofactors(), eng_f.cofactors()
        _assert_cof_close(cof_u, cof_f)
        assert eng_u.node_visits == eng_f.node_visits

    @SET
    @given(bundle=schema_params)
    def test_fused_device_grouping_property(bundle):
        eng_u, eng_f = _pair(bundle)
        eng_f.device_grouping = True
        _assert_cof_close(eng_u.cofactors(), eng_f.cofactors())
