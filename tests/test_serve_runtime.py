"""Concurrent service runtime: threads, deadlines, backpressure, shutdown.

The invariants this layer is held to:

* **No wedged tickets** — whatever interleaving of submissions, worker
  cycles, background folds, shutdown, and direct store mutation runs,
  every ticket ever admitted ends resolved or failed with a typed error.
* **Threaded ≡ sequential** — every result a threaded run produces is
  explainable by SOME serial drain schedule: each read matches (at
  1e-12) the oracle computed from one of the catalog states the store
  passes through, and the terminal store state equals the sequential
  oracle exactly.
* **Exact accounting survives concurrency** — per-tenant counters still
  sum to store totals to the unit after threaded runs.

The stress tests run N tenant threads (train / score / cofactors /
append through the service) against a mutator thread doing direct
``put`` / ``add_fd`` / ``drop_fd`` on the shared store — the
catalog-mutation-during-traversal race the two-lock scheme exists for.
All appends push the SAME fixed delta, so the catalog state space is
exactly (appends-so-far, dim-variant) and every intermediate state has a
precomputable oracle.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.factorize import cofactors_factorized
from repro.core.relation import Relation
from repro.core.store import Store
from repro.core.variable_order import VariableOrder
from repro.serve import (
    FactorizedService,
    RuntimeConfig,
    ServiceOverloaded,
    ServiceStopped,
    ServiceTimeout,
)

DOMAIN = 6
FEATSETS = [("w0", "x", "y"), ("w1", "x", "y"), ("x", "y")]
SCORE_FS = ("x", "y")  # theta = [intercept, x-coef, -1 on label]
THETA = np.array([0.1, 0.5, -1.0])


def _relations(seed, dim0_variant=False):
    """Fact(c0, c1, x, y) ⋈ Dim_i(c_i, …, w_i).  Dim0 carries a
    *determined* key ``d0 = c0 % 3`` (unique c0 keys), so ``c0 → d0`` is
    a real FD the mutator thread can add/drop.  ``dim0_variant`` swaps
    Dim0's payload — the mutator's ``put`` alternates the two."""
    rng = np.random.default_rng(seed)
    n = 240
    keys = {
        f"c{i}": rng.integers(0, DOMAIN, n).astype(np.int32)
        for i in range(2)
    }
    x = rng.normal(0, 2.0, n)
    y = 0.5 * x + rng.normal(0, 0.5, n)
    rels = [
        Relation.from_columns(
            "Fact", keys, {"x": x, "y": y}, {f"c{i}": DOMAIN for i in range(2)}
        )
    ]
    c = np.arange(DOMAIN, dtype=np.int32)
    w0 = rng.normal(0, 1.0, DOMAIN)
    if dim0_variant:
        w0 = w0 + 10.0  # decisively different payload
    rels.append(
        Relation.from_columns(
            "Dim0",
            {"c0": c, "d0": (c % 3).astype(np.int32)},
            {"w0": w0},
            {"c0": DOMAIN, "d0": 3},
        )
    )
    rels.append(
        Relation.from_columns(
            "Dim1",
            {"c1": c.copy()},
            {"w1": rng.normal(0, 1.0, DOMAIN)},
            {"c1": DOMAIN},
        )
    )
    return rels


def _vorder():
    node = VariableOrder(
        "x", [VariableOrder("y", [VariableOrder.leaf("Fact")])]
    )
    w1 = VariableOrder("w1", [VariableOrder.leaf("Dim1")])
    node = VariableOrder("c1", [w1, node])
    d0 = VariableOrder(
        "d0", [VariableOrder("w0", [VariableOrder.leaf("Dim0")])]
    )
    node = VariableOrder("c0", [d0, node])
    return VariableOrder.intercept([node])


def _fixed_delta(seed=77, n_rows=20):
    rng = np.random.default_rng(seed)
    return Relation.from_columns(
        "delta",
        {
            f"c{i}": rng.integers(0, DOMAIN, n_rows).astype(np.int32)
            for i in range(2)
        },
        {"x": rng.normal(0, 2.0, n_rows), "y": rng.normal(0, 1.0, n_rows)},
    )


def _oracles(seed, max_appends):
    """oracle[(k, variant)][featset] = cofactor matrix of the catalog
    after k appends of the fixed delta with Dim0 in the given variant —
    the full state space a run can observe."""
    vorder = _vorder()
    delta = _fixed_delta()
    out = {}
    for variant in (False, True):
        rels = _relations(seed, dim0_variant=variant)
        store = Store(rels)
        for k in range(max_appends + 1):
            if k:
                store.append("Fact", delta)
            store.flush()
            out[(k, variant)] = {
                fs: cofactors_factorized(
                    store, vorder, list(fs), backend="numpy",
                    use_view_cache=False,
                ).matrix()
                for fs in FEATSETS
            }
    return out


def _matches(mat, oracle_mat):
    scale = max(1.0, float(np.abs(oracle_mat).max()))
    return np.allclose(mat, oracle_mat, rtol=1e-12, atol=1e-12 * scale)


def _assert_explainable(kind, fs, value, oracles):
    """A threaded result must equal SOME reachable catalog state's
    oracle at 1e-12 (linearizability against the state-space oracle)."""
    cands = [o[fs] for o in oracles.values()]
    if kind == "score":
        ok = any(
            np.isclose(
                value.sse, float(THETA @ m @ THETA),
                rtol=1e-12, atol=1e-9,
            )
            for m in cands
        )
    else:  # cofactors
        ok = any(_matches(value.matrix(), m) for m in cands)
    assert ok, f"{kind} result over {fs} matches no reachable state"


def _run_threaded(seed, n_tenants, ops_per_tenant, mutator_flips, window,
                  sanitizer=None):
    """One threaded stress run; returns (store, outcomes, service info).

    ``sanitizer`` (a ``repro.analysis.LockSanitizer``) is installed after
    construction and before any thread starts, so every lock the run takes
    is a wrapped, order-checked one."""
    rels = _relations(seed)
    store = Store(rels)
    store.add_fd("c0", "d0")
    vorder = _vorder()
    delta = _fixed_delta()
    svc = FactorizedService(store, backend="numpy", window=window)
    if sanitizer is not None:
        sanitizer.install(service=svc)
    svc.start(RuntimeConfig(poll_interval=0.002, fold_interval=0.004))
    outcomes = []  # (kind, featset, ticket)
    out_lock = threading.Lock()
    dim0_orig = _relations(seed)[1]
    dim0_alt = _relations(seed, dim0_variant=True)[1]

    def tenant(tid):
        rng = np.random.default_rng(1000 + tid)
        mine = []
        for i in range(ops_per_tenant):
            roll = rng.integers(0, 5)
            if roll == 0:
                t = svc.append(f"t{tid}", "Fact", delta)
                mine.append(("append", None, t))
            elif roll == 1:
                t = svc.score(
                    f"t{tid}", vorder, ["x"], label="y", theta=THETA
                )
                mine.append(("score", SCORE_FS, t))
            elif roll == 2:
                t = svc.train(f"t{tid}", vorder, ["x"], "y")
                mine.append(("train", None, t))
            else:
                fs = FEATSETS[int(rng.integers(0, len(FEATSETS)))]
                t = svc.cofactors(f"t{tid}", vorder, list(fs))
                mine.append(("cofactors", fs, t))
            if i % 2:
                time.sleep(0.001)
        with out_lock:
            outcomes.extend(mine)

    def mutator():
        for i in range(mutator_flips):
            store.put(dim0_alt if i % 2 == 0 else dim0_orig)
            store.drop_fd("c0", "d0")
            time.sleep(0.002)
            store.add_fd("c0", "d0")
        if mutator_flips % 2:  # always end on the original payload
            store.put(dim0_orig)

    threads = [
        threading.Thread(target=tenant, args=(tid,))
        for tid in range(n_tenants)
    ] + [threading.Thread(target=mutator)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    svc.stop(drain=True, timeout=30)
    info = svc.cache_info()
    return store, outcomes, info


def _check_run(seed, store, outcomes, info):
    n_appends = sum(1 for kind, _, _ in outcomes if kind == "append")
    oracles = _oracles(seed, n_appends)
    for kind, fs, ticket in outcomes:
        assert ticket.done, "wedged ticket after stop()"
        value = ticket.result()  # raises if any request failed
        if kind == "append":
            continue
        if kind == "train":  # solved against SOME consistent snapshot
            assert np.isfinite(value.theta).all()
            continue
        _assert_explainable(kind, fs, value, oracles)
    # terminal state ≡ the sequential oracle (same ops in ANY serial
    # order land here: appends commute, mutator ended on the original)
    store.flush()
    final = cofactors_factorized(
        store, _vorder(), list(FEATSETS[0]), backend="numpy",
        use_view_cache=False,
    ).matrix()
    expect = oracles[(n_appends, False)][FEATSETS[0]]
    assert _matches(final, expect)
    assert store.cache_info()["pending_rows"] == 0
    # exact accounting survived the threading.  (vc_bytes is NOT summed
    # here: the mutator's direct put() invalidates covering entries
    # outside any request bracket, legitimately dropping store-level
    # bytes below the sum of per-tenant contributions.)
    tenants = info["tenants"].values()
    for field in ("passes", "node_visits"):
        assert sum(t[field] for t in tenants) == info[field]
    assert sum(t["vc_hits"] for t in tenants) == info["view_cache_hits"]
    assert sum(t["vc_misses"] for t in tenants) == info["view_cache_misses"]


# ---------------------------------------------------------------------------
# threaded ≡ sequential stress
# ---------------------------------------------------------------------------

def test_threaded_stress_matches_sequential_oracle():
    seed = 5
    store, outcomes, info = _run_threaded(
        seed, n_tenants=4, ops_per_tenant=6, mutator_flips=6, window=3
    )
    _check_run(seed, store, outcomes, info)


def test_threaded_stress_unwindowed():
    seed = 11
    store, outcomes, info = _run_threaded(
        seed, n_tenants=3, ops_per_tenant=5, mutator_flips=4, window=None
    )
    _check_run(seed, store, outcomes, info)


def test_hypothesis_schedule_variant():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(
        max_examples=5,
        deadline=None,
        suppress_health_check=list(HealthCheck),
    )
    @given(seed=st.integers(min_value=0, max_value=10**6))
    def inner(seed):
        store, outcomes, info = _run_threaded(
            seed % 97, n_tenants=3, ops_per_tenant=4,
            mutator_flips=seed % 5, window=2,
        )
        _check_run(seed % 97, store, outcomes, info)

    inner()


# ---------------------------------------------------------------------------
# tickets: timeout, deadlines
# ---------------------------------------------------------------------------

def test_result_timeout_raises_typed_error():
    store = Store(_relations(0))
    svc = FactorizedService(store, backend="numpy")
    t = svc.cofactors("a", _vorder(), ["x", "y"])
    with pytest.raises(ServiceTimeout):
        t.result(timeout=0.05)
    svc.drain()
    assert t.result(timeout=0.05).count > 0


def test_sync_result_without_timeout_still_raises_runtimeerror():
    store = Store(_relations(0))
    svc = FactorizedService(store, backend="numpy")
    t = svc.cofactors("a", _vorder(), ["x", "y"])
    with pytest.raises(RuntimeError, match="not served yet"):
        t.result()


def test_deadline_expiry_fails_one_ticket_not_its_window():
    store = Store(_relations(0))
    svc = FactorizedService(store, backend="numpy")
    vorder = _vorder()
    doomed = svc.cofactors("a", vorder, ["x", "y"], deadline=0.001)
    healthy = svc.cofactors("b", vorder, ["w0", "x", "y"])
    time.sleep(0.01)
    svc.drain()
    assert healthy.done and doomed.done
    with pytest.raises(ServiceTimeout):
        doomed.result()
    assert healthy.result().count > 0
    info = svc.cache_info()
    assert info["tenants"]["a"]["failures"] == 1
    assert info["tenants"]["b"]["failures"] == 0


def test_default_deadline_applies_to_unmarked_requests():
    store = Store(_relations(0))
    svc = FactorizedService(store, backend="numpy", default_deadline=0.001)
    t = svc.cofactors("a", _vorder(), ["x", "y"])
    time.sleep(0.01)
    svc.drain()
    with pytest.raises(ServiceTimeout):
        t.result()


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_backpressure_reject_raises_at_submit():
    store = Store(_relations(0))
    svc = FactorizedService(
        store, backend="numpy", max_queue=2, backpressure="reject"
    )
    vorder = _vorder()
    svc.cofactors("a", vorder, ["x", "y"])
    svc.cofactors("a", vorder, ["x", "y"])
    with pytest.raises(ServiceOverloaded):
        svc.cofactors("a", vorder, ["x", "y"])
    assert svc.run() == 2


def test_backpressure_shed_oldest_fails_oldest_read():
    store = Store(_relations(0))
    svc = FactorizedService(
        store, backend="numpy", max_queue=2, backpressure="shed_oldest"
    )
    vorder = _vorder()
    t1 = svc.cofactors("a", vorder, ["x", "y"])
    t2 = svc.cofactors("b", vorder, ["x", "y"])
    t3 = svc.cofactors("c", vorder, ["w0", "x", "y"])  # sheds t1
    assert t1.done
    with pytest.raises(ServiceOverloaded):
        t1.result()
    svc.run()
    assert t2.result().count > 0 and t3.result().count > 0
    info = svc.cache_info()
    assert info["shed"] == 1
    assert info["tenants"]["a"]["failures"] == 1


def test_backpressure_block_times_out_without_a_drainer():
    store = Store(_relations(0))
    svc = FactorizedService(
        store, backend="numpy", max_queue=1, backpressure="block",
        admission_timeout=0.05,
    )
    svc.cofactors("a", _vorder(), ["x", "y"])
    with pytest.raises(ServiceOverloaded):
        svc.cofactors("a", _vorder(), ["x", "y"])


def test_backpressure_block_admits_under_runtime():
    store = Store(_relations(0))
    svc = FactorizedService(
        store, backend="numpy", max_queue=1, backpressure="block",
        admission_timeout=10.0,
    )
    svc.start(RuntimeConfig(poll_interval=0.002))
    vorder = _vorder()
    tickets = [svc.cofactors("a", vorder, ["x", "y"]) for _ in range(6)]
    for t in tickets:
        assert t.result(timeout=10).count > 0
    svc.stop()


# ---------------------------------------------------------------------------
# runtime lifecycle
# ---------------------------------------------------------------------------

def test_stop_drains_and_resolves_everything():
    store = Store(_relations(0))
    svc = FactorizedService(store, backend="numpy", window=1)
    svc.start(RuntimeConfig(poll_interval=0.002, fold_interval=0.004))
    vorder = _vorder()
    tickets = [svc.cofactors("a", vorder, ["x", "y"]) for _ in range(8)]
    tickets.append(svc.append("w", "Fact", _fixed_delta()))
    svc.stop(drain=True, timeout=30)
    assert all(t.done for t in tickets)
    for t in tickets:
        t.result()  # none failed: drain served them all
    with pytest.raises(ServiceStopped):
        svc.cofactors("a", vorder, ["x", "y"])


def test_stop_without_drain_fails_pending_with_service_stopped():
    store = Store(_relations(0))
    svc = FactorizedService(store, backend="numpy")
    vorder = _vorder()
    tickets = [svc.cofactors("a", vorder, ["x", "y"]) for _ in range(3)]
    svc.stop(drain=False)  # never started: queue is untouched
    for t in tickets:
        assert t.done
        with pytest.raises(ServiceStopped):
            t.result()
    info = svc.cache_info()
    assert info["tenants"]["a"]["failures"] == 3


def test_restart_after_stop_serves_again():
    store = Store(_relations(0))
    svc = FactorizedService(store, backend="numpy")
    svc.start()
    svc.stop()
    svc.start(RuntimeConfig(poll_interval=0.002))
    t = svc.cofactors("a", _vorder(), ["x", "y"])
    assert t.result(timeout=10).count > 0
    svc.stop()


def test_background_fold_thread_services_delta_debt():
    store = Store(_relations(0))  # lazy maintenance by default
    # seed the caches so the append leaves real fold debt; the seeding
    # read is not a service request, so zero counters before auditing
    store.cofactors(_vorder(), ["x", "y"], backend="numpy")
    store.reset_counters()
    svc = FactorizedService(store, backend="numpy", flush_policy="never")
    svc.start(RuntimeConfig(poll_interval=0.002, fold_interval=0.004))
    t = svc.append("w", "Fact", _fixed_delta())
    t.result(timeout=10)
    assert svc.fold_debt_rows() > 0 or store.cache_info()["drains"] > 0
    deadline = time.monotonic() + 10
    while svc.fold_debt_rows() > 0 and time.monotonic() < deadline:
        time.sleep(0.01)
    svc.stop()
    assert svc.fold_debt_rows() == 0  # the fold thread paid the debt
    assert store.cache_info()["drains"] >= 1
    # fold cost was charged to the writer, so sums still audit
    info = svc.cache_info()
    tenants = info["tenants"].values()
    assert sum(t["node_visits"] for t in tenants) == info["node_visits"]


def test_worker_survives_poisoned_cycle():
    store = Store(_relations(0))
    svc = FactorizedService(store, backend="numpy")
    svc.start(RuntimeConfig(poll_interval=0.002))
    bad_vorder = VariableOrder.intercept(
        [VariableOrder("zz", [VariableOrder.leaf("Nope")])]
    )
    bad = svc.cofactors("a", bad_vorder, ["zz"])
    # noqa-reason: any propagated error proves the poisoned cycle failed
    # the request instead of wedging the worker; the type is incidental
    with pytest.raises(Exception):  # noqa: B017
        bad.result(timeout=10)
    good = svc.cofactors("a", _vorder(), ["x", "y"])
    assert good.result(timeout=10).count > 0  # worker thread survived
    svc.stop()


# ---------------------------------------------------------------------------
# lockset-sanitized stress (nightly `sanitize` leg; repro.analysis.sanitizer)
# ---------------------------------------------------------------------------

@pytest.mark.sanitize
def test_threaded_stress_sanitized_windowed():
    from repro.analysis import LockSanitizer

    seed = 5
    san = LockSanitizer()
    store, outcomes, info = _run_threaded(
        seed, n_tenants=4, ops_per_tenant=6, mutator_flips=6, window=3,
        sanitizer=san,
    )
    _check_run(seed, store, outcomes, info)  # sanitizer must not perturb
    san.assert_clean()  # no empty locksets, no order/wait violations
    # the run actually went through the wrapped locks and the probes
    assert san.acquisitions.get("Store._mutate_lock", 0) > 0
    assert san.acquisitions.get("FactorizedService._cycle_lock", 0) > 0
    assert san.acquisitions.get("FactorizedService._lock", 0) > 0
    assert san.accesses > 0


@pytest.mark.sanitize
def test_threaded_stress_sanitized_unwindowed():
    from repro.analysis import LockSanitizer

    seed = 11
    san = LockSanitizer()
    store, outcomes, info = _run_threaded(
        seed, n_tenants=3, ops_per_tenant=5, mutator_flips=4, window=None,
        sanitizer=san,
    )
    _check_run(seed, store, outcomes, info)
    san.assert_clean()
    writes = san.field_stats().get("FactorizedService._reads", (0, 0))[1]
    assert writes > 0  # queue probes fired under the wrapped queue lock
