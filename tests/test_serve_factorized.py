"""Multi-tenant service: snapshot isolation, batch coalescing, accounting.

The three claims this PR is held to:

* **Snapshot isolation** — a reader holding a ``StoreSnapshot`` (or any
  ``FactorizedEngine``, which freezes one at construction) observes
  BIT-identical results whether or not an ``append`` / ``put`` /
  ``drop_fd`` lands mid-request (the store's mutations are copy-on-write).
* **Coalescing correctness** — merged multi-request traversals scatter
  back per-request results ≡ private sequential engines at 1e-12
  (summation order is the only difference).
* **Exact accounting** — per-tenant counter shares in
  ``FactorizedService.cache_info()`` sum to the store-level totals.
"""

import numpy as np
import pytest

from repro.core.factorize import (
    AggregateQuery,
    BatchPart,
    FactorizedEngine,
    cofactors_factorized,
    merge_batches,
    scatter_results,
)
from repro.core.regression import VERSIONS, linear_regression
from repro.core.relation import Relation
from repro.core.store import Store
from repro.core.variable_order import VariableOrder
from repro.data.synthetic import fd_star_schema
from repro.serve import FactorizedService

CAT2 = ["c0", "c1"]


def _star(n_dims=3, domain=8, fact_rows=300, dim_rows=40, seed=0):
    """Fact(c*, x, y) ⋈ Dim_i(c_i, w_i), bushy order, one subtree per
    dimension — the service's natural shape (feature pool {w_i} ∪ {x})."""
    rng = np.random.default_rng(seed)
    keys = {
        f"c{i}": rng.integers(0, domain, fact_rows).astype(np.int32)
        for i in range(n_dims)
    }
    x = rng.normal(0, 2.0, fact_rows)
    y = 0.5 * x + rng.normal(0, 0.5, fact_rows)
    rels = [
        Relation.from_columns(
            "Fact", keys, {"x": x, "y": y},
            {f"c{i}": domain for i in range(n_dims)},
        )
    ]
    for i in range(n_dims):
        rels.append(
            Relation.from_columns(
                f"Dim{i}",
                {f"c{i}": rng.integers(0, domain, dim_rows).astype(np.int32)},
                {f"w{i}": rng.normal(0, 1.0, dim_rows)},
                {f"c{i}": domain},
            )
        )
    node = VariableOrder(
        "x", [VariableOrder("y", [VariableOrder.leaf("Fact")])]
    )
    for i in reversed(range(n_dims)):
        w = VariableOrder(f"w{i}", [VariableOrder.leaf(f"Dim{i}")])
        node = VariableOrder(f"c{i}", [w, node])
    return rels, VariableOrder.intercept([node])


def _fact_delta(rng, n_dims=3, domain=8, n_rows=25):
    return Relation.from_columns(
        "delta",
        {
            f"c{i}": rng.integers(0, domain, n_rows).astype(np.int32)
            for i in range(n_dims)
        },
        {
            "x": rng.normal(0, 2.0, n_rows),
            "y": rng.normal(0, 1.0, n_rows),
        },
    )


def _allclose_tight(a, b, scale=None):
    s = float(np.abs(b).max()) if scale is None else scale
    np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12 * max(1.0, s))


# ---------------------------------------------------------------------------
# Layer 1: snapshot isolation
# ---------------------------------------------------------------------------

def test_snapshot_reader_bit_identical_across_append():
    rels, vorder = _star(seed=1)
    store = Store(rels)
    cols = ["w0", "x", "y"]
    oracle = cofactors_factorized(
        store, vorder, cols, backend="numpy", use_view_cache=False
    )
    snap = store.snapshot()
    rng = np.random.default_rng(2)
    store.append("Fact", _fact_delta(rng))
    assert not snap.is_current and snap.live_version == store.version
    held = FactorizedEngine(snap, vorder, cols, backend="numpy").cofactors()
    np.testing.assert_allclose(  # bit-identical: same data, same op order
        held.matrix(), oracle.matrix(), rtol=0, atol=0
    )
    fresh = cofactors_factorized(store, vorder, cols, backend="numpy")
    assert fresh.count > oracle.count  # live store did move


def test_snapshot_reader_bit_identical_across_put():
    rels, vorder = _star(seed=3)
    store = Store(rels)
    cols = ["w1", "x", "y"]
    oracle = cofactors_factorized(
        store, vorder, cols, backend="numpy", use_view_cache=False
    )
    snap = store.snapshot()
    dim = store.get("Dim1")
    rng = np.random.default_rng(4)
    store.put(
        Relation.from_columns(
            "Dim1",
            {"c1": dim.keys["c1"][:10]},
            {"w1": rng.normal(0, 1.0, 10)},
            dict(dim.domains),
        )
    )
    held = FactorizedEngine(snap, vorder, cols, backend="numpy").cofactors()
    np.testing.assert_allclose(held.matrix(), oracle.matrix(), rtol=0, atol=0)
    fresh = cofactors_factorized(store, vorder, cols, backend="numpy")
    assert fresh.count != oracle.count


def test_snapshot_fd_catalog_frozen_across_drop_fd():
    bundle = fd_star_schema(n_cat=2, seed=5)
    store, vorder = bundle.store, bundle.vorder
    store.infer_fds()
    cat = CAT2 + ["d0", "d1"]
    snap = store.snapshot()
    before = snap.fd_reduction(cat).signature()
    oracle = snap.cat_cofactors(
        vorder, ["x", "y"], cat, backend="numpy", reduce_fds=True
    )
    store.drop_fd("c0", "d0")
    assert not snap.is_current  # FD mutation breaks currency, not version
    assert snap.fd_reduction(cat).signature() == before
    assert store.fd_reduction(cat).signature() != before
    held = snap.cat_cofactors(
        vorder, ["x", "y"], cat, backend="numpy", reduce_fds=True
    )
    assert list(held.cat) == list(oracle.cat)  # d0 still reduced away
    np.testing.assert_allclose(
        held.matrix(), oracle.matrix(), rtol=0, atol=0
    )


def test_engine_holds_snapshot_across_mid_request_append():
    """An engine constructed before a mutation keeps serving the frozen
    catalog: batch 2 on the same engine ≡ batch 1, bit for bit."""
    rels, vorder = _star(seed=6)
    store = Store(rels)
    cols = ["w0", "w2", "x", "y"]
    eng = FactorizedEngine(
        store, vorder, cols, backend="numpy", use_view_cache=False
    )
    first = eng.cofactors()
    store.append("Fact", _fact_delta(np.random.default_rng(7)))
    second = eng.cofactors()  # mid-request mutation landed between batches
    np.testing.assert_allclose(
        second.matrix(), first.matrix(), rtol=0, atol=0
    )


def test_stale_snapshot_engine_stays_out_of_view_cache():
    rels, vorder = _star(seed=8)
    store = Store(rels)
    cols = ["w0", "x", "y"]
    snap = store.snapshot()
    store.append("Fact", _fact_delta(np.random.default_rng(9)))
    eng = FactorizedEngine(snap, vorder, cols, backend="numpy")
    eng.cofactors()
    assert eng.vc_hits == 0  # stale engine must neither probe...
    info = store.cache_info()
    assert info["view_cache_entries"] == 0  # ...nor publish


# ---------------------------------------------------------------------------
# Layer 2: merge_batches / scatter
# ---------------------------------------------------------------------------

def test_merge_batches_unions_and_dedupes():
    parts = [
        BatchPart(
            rid=1,
            features=("x", "w0"),
            queries=(
                AggregateQuery("cof", (), 2),
                AggregateQuery("g", ("c0", "c1"), 1),
            ),
        ),
        BatchPart(
            rid=2,
            features=("w1", "x"),
            queries=(
                AggregateQuery("cof", (), 1),
                AggregateQuery("p", ("c1", "c0"), 0),
            ),
        ),
    ]
    merged = merge_batches(parts)
    assert merged.features == ["x", "w0", "w1"]  # union, first-seen order
    # () and {c0,c1} each collapse to one query at the max degree
    assert [(q.group_by, q.degree) for q in merged.queries] == [
        ((), 2),
        (("c0", "c1"), 1),
    ]
    assert merged.assignments[(1, "cof")] == merged.assignments[(2, "cof")]
    assert merged.assignments[(1, "g")] == merged.assignments[(2, "p")]


def test_merge_batches_rejects_duplicate_names_within_request():
    with pytest.raises(ValueError, match="duplicate query name"):
        merge_batches(
            [
                BatchPart(
                    rid=1,
                    features=("x",),
                    queries=(
                        AggregateQuery("q", (), 2),
                        AggregateQuery("q", ("c0",), 1),
                    ),
                )
            ]
        )


def test_scatter_matches_private_engines():
    rels, vorder = _star(seed=10)
    store = Store(rels, view_cache_bytes=0)
    parts = [
        BatchPart(
            rid="a",
            features=("w0", "x"),
            queries=(
                AggregateQuery("cof", (), 2),
                AggregateQuery("g", ("c1",), 1),
            ),
        ),
        BatchPart(
            rid="b",
            features=("x", "w1", "w2"),
            queries=(AggregateQuery("cof", (), 2),),
        ),
    ]
    merged = merge_batches(parts)
    shared = FactorizedEngine(
        store, vorder, merged.features, backend="numpy"
    ).run_batch(merged.queries)
    out = scatter_results(merged, parts, shared)
    for part in parts:
        private = FactorizedEngine(
            store, vorder, list(part.features), backend="numpy"
        ).run_batch(list(part.queries))
        for q in part.queries:
            mine, ref = out[part.rid][q.name], private[q.name]
            assert mine.features == list(part.features if q.degree else ())
            perm = [mine.features.index(f) for f in ref.features]
            _allclose_tight(mine.count, ref.count)
            if q.degree >= 1:
                _allclose_tight(mine.lin[:, perm], ref.lin)
            if q.degree == 2:
                _allclose_tight(
                    mine.quad[:, perm][:, :, perm], ref.quad
                )


# ---------------------------------------------------------------------------
# Layer 3: the service
# ---------------------------------------------------------------------------

def test_service_train_matches_linear_regression():
    rels, vorder = _star(seed=11)
    store = Store(rels)
    svc = FactorizedService(store)
    feats = ["w0", "x"]
    t = svc.train("alice", vorder, feats, "y")
    svc.run()
    ref = linear_regression(
        store, vorder, feats, "y", VERSIONS["closed"], backend="numpy",
        use_cache=True,
    )
    np.testing.assert_allclose(
        t.result().theta, ref.theta, rtol=1e-9, atol=1e-9
    )
    s = svc.score("alice", vorder, feats, "y", t.result().theta)
    svc.run()
    assert s.result().rmse < 1.0  # the model genuinely fits the planted y


def test_service_window_reads_see_pre_write_snapshot():
    """Reads admitted in the same cycle as a write all see the pre-write
    catalog; the write is visible from the next cycle on."""
    rels, vorder = _star(seed=12)
    store = Store(rels)
    svc = FactorizedService(store)
    cols = ["x", "y"]
    oracle = cofactors_factorized(
        Store(rels), vorder, cols, backend="numpy", use_view_cache=False
    )
    t1 = svc.cofactors("a", vorder, cols)
    tw = svc.append("w", "Fact", _fact_delta(np.random.default_rng(13)))
    t2 = svc.cofactors("b", vorder, cols)  # queued BEFORE the drain
    svc.drain()
    np.testing.assert_allclose(
        t1.result().matrix(), oracle.matrix(), rtol=0, atol=0
    )
    np.testing.assert_allclose(
        t2.result().matrix(), oracle.matrix(), rtol=0, atol=0
    )
    assert tw.result().num_rows == 325  # 300 base fact rows + 25 appended
    t3 = svc.cofactors("a", vorder, cols)  # next cycle: append visible
    svc.drain()
    assert t3.result().count > oracle.count


def test_service_failed_requests_resolve_with_errors():
    rels, vorder = _star(seed=14)
    svc = FactorizedService(Store(rels))
    bad = svc.append("t", "Nope", _fact_delta(np.random.default_rng(0)))
    ok = svc.cofactors("t", vorder, ["x", "y"])
    svc.run()
    assert ok.result().count > 0  # one bad request never wedges the cycle
    with pytest.raises(KeyError):
        bad.result()
    with pytest.raises(RuntimeError, match="not served yet"):
        FactorizedService(Store(rels)).cofactors(
            "t", vorder, ["x"]
        ).result()


def _run_schedule(seed, coalesce, n_ops=14):
    """One deterministic random schedule against a fresh store; returns
    resolved ticket values in submission order."""
    rels, vorder = _star(seed=100)  # schema fixed; schedule varies by seed
    rng = np.random.default_rng(seed)
    store = Store(rels)
    svc = FactorizedService(store, coalesce=coalesce)
    pool = ["w0", "w1", "w2", "x"]
    tickets = []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.18:
            tickets.append(
                svc.append(
                    "writer", "Fact", _fact_delta(rng, n_rows=int(rng.integers(5, 30)))
                )
            )
        elif r < 0.30:
            svc.drain()
        else:
            tenant = f"t{int(rng.integers(0, 3))}"
            feats = sorted(
                rng.choice(pool, size=int(rng.integers(1, 4)), replace=False)
            )
            if rng.random() < 0.5:
                tickets.append(
                    svc.cofactors(tenant, vorder, feats + ["y"])
                )
            else:
                tickets.append(
                    svc.aggregates(
                        tenant,
                        vorder,
                        feats,
                        [
                            AggregateQuery("cof", (), 2),
                            AggregateQuery(
                                "g", (f"c{int(rng.integers(0, 3))}",), 1
                            ),
                        ],
                    )
                )
    svc.run()
    return [t.result() for t in tickets], svc


def _assert_schedules_equivalent(seed):
    got, svc_c = _run_schedule(seed, coalesce=True)
    ref, svc_s = _run_schedule(seed, coalesce=False)
    assert svc_c.cache_info()["coalesced_batches"] >= 0
    for g, r in zip(got, ref):
        if isinstance(g, Relation):  # append result
            assert g.num_rows == r.num_rows
        elif isinstance(g, dict):  # aggregates
            for name, blk in r.items():
                mine = g[name]
                _allclose_tight(mine.count, blk.count)
                for attr in blk.keys:
                    np.testing.assert_array_equal(
                        mine.keys[attr], blk.keys[attr]
                    )
                if blk.lin is not None:
                    _allclose_tight(mine.lin, blk.lin)
        else:  # cofactors
            _allclose_tight(g.matrix(), r.matrix())


def test_coalesced_equals_sequential_deterministic():
    for seed in (0, 1, 2, 3):
        _assert_schedules_equivalent(seed)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(
        max_examples=12,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(seed=st.integers(0, 60))
    def test_coalesced_equals_sequential_property(seed):
        """Random request/mutation schedules: coalesced ≡ sequential
        per-request results at 1e-12, whatever interleaving lands."""
        _assert_schedules_equivalent(seed)

else:  # pragma: no cover - optional dependency

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_coalesced_equals_sequential_property():
        pass


def test_per_tenant_counters_sum_to_store_totals():
    rels, vorder = _star(seed=15)
    store = Store(rels)
    svc = FactorizedService(store)
    rng = np.random.default_rng(16)
    svc.cofactors("a", vorder, ["w0", "x", "y"])
    svc.cofactors("b", vorder, ["w1", "x", "y"])
    svc.train("c", vorder, ["w0", "w1"], "y")
    svc.drain()
    svc.append("w", "Fact", _fact_delta(rng))
    svc.cofactors("a", vorder, ["w0", "x", "y"])  # warm + post-append read
    svc.run()
    info = svc.cache_info()
    tenants = info["tenants"].values()
    assert {"a", "b", "c", "w"} == set(info["tenants"])
    vc = store.view_cache
    assert sum(t["passes"] for t in tenants) == info["passes"]
    assert sum(t["node_visits"] for t in tenants) == info["node_visits"]
    assert sum(t["vc_hits"] for t in tenants) == vc.hits
    assert sum(t["vc_misses"] for t in tenants) == vc.misses
    assert sum(t["vc_bytes"] for t in tenants) == info["view_cache_bytes"]
    # every tenant's activity is on the books (integer fair-split may
    # round a rider's share of one shared pass down to 0, so request
    # counts — not pass shares — carry the per-rider guarantee)
    assert all(t["requests"] + t["appends"] > 0 for t in tenants)


# ---------------------------------------------------------------------------
# Satellite: cross-dtype view reuse
# ---------------------------------------------------------------------------

def test_fp32_warm_path_casts_fp64_views_zero_node_visits():
    rels, vorder = _star(seed=17)
    store = Store(rels)
    cols = ["w0", "w1", "x", "y"]
    ref = cofactors_factorized(store, vorder, cols, backend="numpy")
    store.reset_counters()
    eng = FactorizedEngine(store, vorder, cols, backend="jax")  # fp32
    got = eng.cofactors()
    assert eng.node_visits == 0  # served entirely by casting fp64 views
    assert store.node_visits == 0
    assert eng.vc_hits > 0
    scale = float(np.abs(ref.matrix()).max())
    np.testing.assert_allclose(
        got.matrix(), ref.matrix(), rtol=2e-5, atol=2e-5 * max(1.0, scale)
    )


def test_fp32_service_requests_reuse_fp64_views():
    rels, vorder = _star(seed=18)
    store = Store(rels)
    svc = FactorizedService(store)
    cols = ["w2", "x", "y"]
    t64 = svc.cofactors("a", vorder, cols)  # numpy/fp64, populates views
    svc.drain()
    store.reset_counters()
    t32 = svc.cofactors("b", vorder, cols, backend="jax")
    svc.drain()
    assert store.node_visits == 0
    info = svc.cache_info()
    assert info["tenants"]["b"]["node_visits"] == 0
    assert info["tenants"]["b"]["vc_hits"] > 0
    scale = float(np.abs(t64.result().matrix()).max())
    np.testing.assert_allclose(
        t32.result().matrix(),
        t64.result().matrix(),
        rtol=2e-5,
        atol=2e-5 * max(1.0, scale),
    )
