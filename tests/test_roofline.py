"""Roofline extraction: HLO collective parsing + term math + model FLOPs."""

import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import HW
from repro.launch.roofline import (
    RooflineTerms,
    collective_bytes,
    model_flops,
    total_collective_bytes,
)

HLO_SAMPLE = """
HloModule jit_step
ENTRY %main {
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = bf16[64,64]{1,0} all-gather(bf16[32,64]{1,0} %y), dimensions={0}
  %rs = f32[8]{0} reduce-scatter(f32[64]{0} %z), dimensions={0}
  %cp = s32[4,4]{1,0} collective-permute(s32[4,4]{1,0} %w)
  %a2a = f32[16]{0} all-to-all(f32[16]{0} %v), dimensions={0}
  %ars = (f32[10]{0}, f32[10]{0}) all-reduce-start(f32[10]{0} %u)
  %ard = f32[10]{0} all-reduce-done((f32[10]{0}, f32[10]{0}) %ars)
  %plain = f32[999]{0} add(f32[999]{0} %p, f32[999]{0} %q)
}
"""


def test_collective_bytes_parses_all_kinds():
    got = collective_bytes(HLO_SAMPLE)
    assert got["all-gather"] == 64 * 64 * 2
    assert got["reduce-scatter"] == 8 * 4
    assert got["collective-permute"] == 4 * 4 * 4
    assert got["all-to-all"] == 16 * 4
    # all-reduce: the plain op + the -start tuple (2x 10 floats)
    assert got["all-reduce"] == 128 * 256 * 4 + 2 * 10 * 4
    # the plain add must NOT be counted
    assert sum(got.values()) < 999 * 4 + sum(got.values())


def test_total_collective_weights_allreduce_2x():
    per_kind = {"all-reduce": 100, "all-gather": 100}
    assert total_collective_bytes(per_kind) == 300.0


def test_roofline_terms_math():
    t = RooflineTerms(
        arch="a", shape="s", mesh="m", chips=256,
        hlo_flops=197e12,          # per-shard == 1 second of compute
        hlo_bytes=819e9,           # == 1 second of HBM
        coll_bytes=50e9,           # == 1 second of ICI
        coll_by_kind={},
        model_flops=197e12 * 256,  # exactly the useful amount
    )
    np.testing.assert_allclose(t.t_compute, 1.0)
    np.testing.assert_allclose(t.t_memory, 1.0)
    np.testing.assert_allclose(t.t_collective, 1.0)
    np.testing.assert_allclose(t.useful_ratio, 1.0)
    np.testing.assert_allclose(t.roofline_fraction, 1.0)
    t2 = RooflineTerms(
        arch="a", shape="s", mesh="m", chips=4,
        hlo_flops=4.0, hlo_bytes=8e20, coll_bytes=0.0,
        coll_by_kind={}, model_flops=16.0,
    )
    assert t2.bottleneck == "memory"
    assert t2.roofline_fraction < 1e-6


def test_model_flops_shapes():
    cfg = ARCHS["smollm-135m"]
    train = model_flops(cfg, SHAPES["train_4k"])
    dec = model_flops(cfg, SHAPES["decode_32k"])
    counts = cfg.param_counts()
    assert train == 6.0 * counts["active"] * 256 * 4096
    assert dec == 2.0 * counts["active"] * 128
    # MoE: active params drive the number, not total
    moe = ARCHS["mixtral-8x7b"]
    mc = moe.param_counts()
    assert model_flops(moe, SHAPES["train_4k"]) == \
        6.0 * mc["active"] * 256 * 4096


def test_hw_constants_match_assignment():
    assert HW.peak_flops_bf16 == 197e12
    assert HW.hbm_bw == 819e9
    assert HW.ici_bw == 50e9


def test_traversal_node_terms_math():
    from repro.launch.roofline import traversal_node_terms

    n, k, g, b = 1000, 4, 32, 4
    t = traversal_node_terms(n, k, g, degree=2, dtype_bytes=b)
    blk = 1 + k + k * k  # c + l + q elements per row
    ext = 1 + (k + 1) + (k + 1) * (k + 1)
    assert t.packed_width == (k + 2) * (k + 2)
    assert t.bytes_in == n * (blk + 1) * b + n * 4
    assert t.bytes_fused == t.bytes_in + g * (k + 2) * (k + 2) * b
    # the unfused path round-trips the extended [N, k+1, k+1] blocks
    assert t.bytes_unfused == t.bytes_in + 2 * n * ext * b + n * b + g * ext * b
    assert t.flops_fused == n * (k + 2) + n * t.packed_width
    # the whole point: fusion wins on bytes, and the node is memory-bound
    assert t.predicted_speedup > 1.5
    assert t.arith_intensity < 2.0  # FLOPs/byte far under machine balance


def test_traversal_node_terms_degree1():
    from repro.launch.roofline import traversal_node_terms

    t = traversal_node_terms(500, 3, 10, degree=1)
    assert t.packed_width == 5
    assert t.predicted_speedup > 1.0
    import pytest as _pytest

    with _pytest.raises(ValueError):
        traversal_node_terms(10, 2, 2, degree=3)


def test_traversal_node_terms_achieved():
    from repro.launch.roofline import traversal_node_terms

    t = traversal_node_terms(65536, 4, 256)
    # at exactly the memory-bound time, the achieved fraction is 1.0 and
    # achieved bandwidth equals the HBM figure
    sec = t.t_memory_fused
    np.testing.assert_allclose(t.achieved_fraction(sec), 1.0)
    np.testing.assert_allclose(t.achieved_gbs(sec) * 1e9, HW.hbm_bw)
    assert t.achieved_fraction(0.0) == 0.0
    j = t.to_json()
    assert j["predicted_speedup"] == t.predicted_speedup
    assert j["n_rows"] == 65536
