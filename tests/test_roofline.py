"""Roofline extraction: HLO collective parsing + term math + model FLOPs."""

import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.launch.mesh import HW
from repro.launch.roofline import (
    RooflineTerms,
    collective_bytes,
    model_flops,
    total_collective_bytes,
)

HLO_SAMPLE = """
HloModule jit_step
ENTRY %main {
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = bf16[64,64]{1,0} all-gather(bf16[32,64]{1,0} %y), dimensions={0}
  %rs = f32[8]{0} reduce-scatter(f32[64]{0} %z), dimensions={0}
  %cp = s32[4,4]{1,0} collective-permute(s32[4,4]{1,0} %w)
  %a2a = f32[16]{0} all-to-all(f32[16]{0} %v), dimensions={0}
  %ars = (f32[10]{0}, f32[10]{0}) all-reduce-start(f32[10]{0} %u)
  %ard = f32[10]{0} all-reduce-done((f32[10]{0}, f32[10]{0}) %ars)
  %plain = f32[999]{0} add(f32[999]{0} %p, f32[999]{0} %q)
}
"""


def test_collective_bytes_parses_all_kinds():
    got = collective_bytes(HLO_SAMPLE)
    assert got["all-gather"] == 64 * 64 * 2
    assert got["reduce-scatter"] == 8 * 4
    assert got["collective-permute"] == 4 * 4 * 4
    assert got["all-to-all"] == 16 * 4
    # all-reduce: the plain op + the -start tuple (2x 10 floats)
    assert got["all-reduce"] == 128 * 256 * 4 + 2 * 10 * 4
    # the plain add must NOT be counted
    assert sum(got.values()) < 999 * 4 + sum(got.values())


def test_total_collective_weights_allreduce_2x():
    per_kind = {"all-reduce": 100, "all-gather": 100}
    assert total_collective_bytes(per_kind) == 300.0


def test_roofline_terms_math():
    t = RooflineTerms(
        arch="a", shape="s", mesh="m", chips=256,
        hlo_flops=197e12,          # per-shard == 1 second of compute
        hlo_bytes=819e9,           # == 1 second of HBM
        coll_bytes=50e9,           # == 1 second of ICI
        coll_by_kind={},
        model_flops=197e12 * 256,  # exactly the useful amount
    )
    np.testing.assert_allclose(t.t_compute, 1.0)
    np.testing.assert_allclose(t.t_memory, 1.0)
    np.testing.assert_allclose(t.t_collective, 1.0)
    np.testing.assert_allclose(t.useful_ratio, 1.0)
    np.testing.assert_allclose(t.roofline_fraction, 1.0)
    t2 = RooflineTerms(
        arch="a", shape="s", mesh="m", chips=4,
        hlo_flops=4.0, hlo_bytes=8e20, coll_bytes=0.0,
        coll_by_kind={}, model_flops=16.0,
    )
    assert t2.bottleneck == "memory"
    assert t2.roofline_fraction < 1e-6


def test_model_flops_shapes():
    cfg = ARCHS["smollm-135m"]
    train = model_flops(cfg, SHAPES["train_4k"])
    dec = model_flops(cfg, SHAPES["decode_32k"])
    counts = cfg.param_counts()
    assert train == 6.0 * counts["active"] * 256 * 4096
    assert dec == 2.0 * counts["active"] * 128
    # MoE: active params drive the number, not total
    moe = ARCHS["mixtral-8x7b"]
    mc = moe.param_counts()
    assert model_flops(moe, SHAPES["train_4k"]) == \
        6.0 * mc["active"] * 256 * 4096


def test_hw_constants_match_assignment():
    assert HW.peak_flops_bf16 == 197e12
    assert HW.hbm_bw == 819e9
    assert HW.ici_bw == 50e9
