"""The benchmark runner must gate: any raising suite → non-zero exit."""

import json
import os

import pytest

from benchmarks import run as run_mod
from benchmarks.run import run_suites


@pytest.fixture(autouse=True)
def isolated_results_dir(tmp_path, monkeypatch):
    """Redirect summary.json away from benchmarks/results/ so test runs
    never clobber real benchmark artifacts."""
    monkeypatch.setattr(run_mod, "RESULTS_DIR", str(tmp_path))
    return tmp_path


def _ok(smoke=False):
    pass


def _boom(smoke=False):
    raise RuntimeError("intentional benchmark failure")


def test_all_green_exits_zero():
    assert run_suites([("a", _ok), ("b", _ok)], smoke=True) == 0


def test_any_failure_exits_nonzero(capsys):
    code = run_suites([("good", _ok), ("bad", _boom)], smoke=True)
    assert code == 1
    out = capsys.readouterr().out
    assert "bad: FAILED" in out
    assert "1/2 suites ok" in out


def test_failure_recorded_in_summary_artifact(isolated_results_dir):
    run_suites([("bad", _boom)], smoke=True)
    with open(os.path.join(isolated_results_dir, "summary.json")) as f:
        summary = json.load(f)
    assert summary["smoke"] is True
    (suite,) = summary["suites"]
    assert suite["status"] == "failed"
    assert "intentional benchmark failure" in suite["error"]


def test_smoke_flag_reaches_suites():
    seen = {}

    def probe(smoke=False):
        seen["smoke"] = smoke

    assert run_suites([("probe", probe)], smoke=True) == 0
    assert seen["smoke"] is True
