"""The benchmark runner must gate: any raising suite → non-zero exit."""

import json
import os

import pytest

from benchmarks import run as run_mod
from benchmarks.run import run_suites


@pytest.fixture(autouse=True)
def isolated_results_dir(tmp_path, monkeypatch):
    """Redirect summary.json away from benchmarks/results/ so test runs
    never clobber real benchmark artifacts."""
    monkeypatch.setattr(run_mod, "RESULTS_DIR", str(tmp_path))
    return tmp_path


def _ok(smoke=False):
    pass


def _boom(smoke=False):
    raise RuntimeError("intentional benchmark failure")


def test_all_green_exits_zero():
    assert run_suites([("a", _ok), ("b", _ok)], smoke=True) == 0


def test_any_failure_exits_nonzero(capsys):
    code = run_suites([("good", _ok), ("bad", _boom)], smoke=True)
    assert code == 1
    out = capsys.readouterr().out
    assert "bad: FAILED" in out
    assert "1/2 suites ok" in out


def test_failure_recorded_in_summary_artifact(isolated_results_dir):
    run_suites([("bad", _boom)], smoke=True)
    with open(os.path.join(isolated_results_dir, "summary.json")) as f:
        summary = json.load(f)
    assert summary["smoke"] is True
    (suite,) = summary["suites"]
    assert suite["status"] == "failed"
    assert "intentional benchmark failure" in suite["error"]


def test_smoke_flag_reaches_suites():
    seen = {}

    def probe(smoke=False):
        seen["smoke"] = smoke

    assert run_suites([("probe", probe)], smoke=True) == 0
    assert seen["smoke"] is True


# ---------------------------------------------------------------------------
# --suite filter
# ---------------------------------------------------------------------------

def test_suite_filter_selects_named_suites():
    suites = run_mod.default_suites(only=["kernels"])
    assert [name for name, _ in suites] == ["kernel hotspots"]
    pair = run_mod.default_suites(only=["serve", "kernels"])
    assert [name for name, _ in pair] == [
        "multi-tenant serve coalescing",
        "kernel hotspots",
    ]


def test_suite_filter_unknown_name_lists_valid(capsys):
    with pytest.raises(ValueError) as exc:
        run_mod.default_suites(only=["nope"])
    msg = str(exc.value)
    assert "nope" in msg
    for slug in run_mod.suite_names():
        assert slug in msg
    # the CLI surfaces it as exit code 2 without running anything
    assert run_mod.main(["--suite", "nope"]) == 2
    assert "valid suites" in capsys.readouterr().err


def test_suite_filter_runs_only_selected(isolated_results_dir, monkeypatch):
    calls = []
    import benchmarks.bench_kernels as bk

    monkeypatch.setattr(bk, "main", lambda smoke=False: calls.append(smoke))
    assert run_mod.main(["--suite", "kernels", "--smoke"]) == 0
    assert calls == [True]
    with open(os.path.join(isolated_results_dir, "summary.json")) as f:
        summary = json.load(f)
    assert [s["suite"] for s in summary["suites"]] == ["kernel hotspots"]


def test_serve_suite_registered():
    """bench_serve must ride in the default sweep (smoke + nightly gate)."""
    assert "serve" in run_mod.suite_names()


def test_ingest_suite_registered():
    """bench_ingest must ride in the default sweep (smoke + nightly gate)."""
    assert "ingest" in run_mod.suite_names()


# ---------------------------------------------------------------------------
# benchmarks.compare — the nightly regression detector
# ---------------------------------------------------------------------------

def _write_artifact(path, summary, suites):
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, "summary.json"), "w") as f:
        json.dump(summary, f)
    for name, rows in suites.items():
        with open(os.path.join(path, f"{name}.json"), "w") as f:
            json.dump(rows, f)


def test_compare_missing_baseline_is_ok(tmp_path):
    from benchmarks.compare import compare_dirs

    new = tmp_path / "new"
    _write_artifact(str(new), {"suites": []}, {})
    assert compare_dirs(str(tmp_path / "nope"), str(new)) == 0


def test_compare_clean_run_passes(tmp_path):
    from benchmarks.compare import compare_dirs

    summary = {"suites": [{"suite": "a", "status": "ok", "seconds": 1.0}]}
    rows = [{"size": 10, "fact_s": 1.0, "speedup_vs_onehot": 3.0}]
    _write_artifact(str(tmp_path / "base"), summary, {"a": rows})
    _write_artifact(str(tmp_path / "new"), summary, {"a": rows})
    assert compare_dirs(str(tmp_path / "base"), str(tmp_path / "new")) == 0


def test_compare_detects_time_regression(tmp_path, capsys):
    from benchmarks.compare import compare_dirs

    summary = {"suites": [{"suite": "a", "status": "ok", "seconds": 1.0}]}
    base = [{"size": 10, "fact_s": 1.0}]
    slow = [{"size": 10, "fact_s": 2.0}]  # 2x > 1.5x threshold + slack
    _write_artifact(str(tmp_path / "base"), summary, {"a": base})
    _write_artifact(str(tmp_path / "new"), summary, {"a": slow})
    assert (
        compare_dirs(str(tmp_path / "base"), str(tmp_path / "new"), 0.5) == 1
    )
    assert "REGRESSION" in capsys.readouterr().out


def test_compare_tolerates_noise_within_threshold(tmp_path):
    from benchmarks.compare import compare_dirs

    summary = {"suites": [{"suite": "a", "status": "ok", "seconds": 1.0}]}
    base = [{"size": 10, "fact_s": 1.0, "speedup_vs_onehot": 3.0}]
    noisy = [{"size": 10, "fact_s": 1.3, "speedup_vs_onehot": 2.5}]
    _write_artifact(str(tmp_path / "base"), summary, {"a": base})
    _write_artifact(str(tmp_path / "new"), summary, {"a": noisy})
    assert (
        compare_dirs(str(tmp_path / "base"), str(tmp_path / "new"), 0.5) == 0
    )


def test_compare_gates_staleness_smaller_better(tmp_path, capsys):
    """``staleness`` fields are smaller-better: growth past the threshold
    is a regression, shrinkage never is."""
    from benchmarks.compare import compare_dirs

    summary = {"suites": [{"suite": "a", "status": "ok", "seconds": 1.0}]}
    base = [{"cached_queries": 4, "staleness": 0.2}]
    worse = [{"cached_queries": 4, "staleness": 0.5}]  # 2.5x > 1.5x
    better = [{"cached_queries": 4, "staleness": 0.05}]
    _write_artifact(str(tmp_path / "base"), summary, {"a": base})
    _write_artifact(str(tmp_path / "worse"), summary, {"a": worse})
    _write_artifact(str(tmp_path / "better"), summary, {"a": better})
    assert (
        compare_dirs(str(tmp_path / "base"), str(tmp_path / "worse"), 0.5)
        == 1
    )
    assert "REGRESSION" in capsys.readouterr().out
    assert (
        compare_dirs(str(tmp_path / "base"), str(tmp_path / "better"), 0.5)
        == 0
    )


def test_compare_gates_retention_bigger_better(tmp_path, capsys):
    """``*_retention`` fields (bench_serve fault sweep) are bigger-better:
    a drop past the threshold is a regression, growth never is, and the
    0.01 absolute guard keeps near-equal ratios quiet."""
    from benchmarks.compare import compare_dirs

    summary = {"suites": [{"suite": "a", "status": "ok", "seconds": 1.0}]}
    base = [{"fault_rate": 1, "throughput_retention": 0.8}]
    worse = [{"fault_rate": 1, "throughput_retention": 0.3}]  # < 0.8/1.5
    better = [{"fault_rate": 1, "throughput_retention": 0.95}]
    jitter = [{"fault_rate": 1, "throughput_retention": 0.795}]
    for tag, rows in (
        ("worse", worse), ("better", better), ("jitter", jitter)
    ):
        _write_artifact(str(tmp_path / tag), summary, {"a": rows})
    _write_artifact(str(tmp_path / "base"), summary, {"a": base})
    assert (
        compare_dirs(str(tmp_path / "base"), str(tmp_path / "worse"), 0.5)
        == 1
    )
    assert "throughput_retention" in capsys.readouterr().out
    assert (
        compare_dirs(str(tmp_path / "base"), str(tmp_path / "better"), 0.5)
        == 0
    )
    assert (
        compare_dirs(str(tmp_path / "base"), str(tmp_path / "jitter"), 0.5)
        == 0
    )


def test_compare_retention_missing_in_new_run_skipped(tmp_path, capsys):
    """A gated retention field the new run no longer emits is
    reported-and-skipped (shape drift), never a crash."""
    from benchmarks.compare import compare_dirs

    summary = {"suites": []}
    base = [{"fault_rate": 1, "throughput_retention": 0.8}]
    new = [{"fault_rate": 1}]
    _write_artifact(str(tmp_path / "base"), summary, {"a": base})
    _write_artifact(str(tmp_path / "new"), summary, {"a": new})
    assert compare_dirs(str(tmp_path / "base"), str(tmp_path / "new")) == 0
    out = capsys.readouterr().out
    assert "throughput_retention" in out and "skipped" in out


def test_compare_detects_new_suite_failure(tmp_path, capsys):
    from benchmarks.compare import compare_dirs

    ok = {"suites": [{"suite": "a", "status": "ok", "seconds": 1.0}]}
    bad = {
        "suites": [
            {"suite": "a", "status": "failed", "seconds": 1.0, "error": "x"}
        ]
    }
    _write_artifact(str(tmp_path / "base"), ok, {})
    _write_artifact(str(tmp_path / "new"), bad, {})
    assert compare_dirs(str(tmp_path / "base"), str(tmp_path / "new")) == 1
    assert "ok in baseline" in capsys.readouterr().out


def test_compare_baseline_missing_field_skipped(tmp_path, capsys):
    """A time/speedup field present in the baseline but gone from the new
    run (suite changed since the last green run) is reported-and-skipped,
    never a KeyError/crash."""
    from benchmarks.compare import compare_dirs

    summary = {"suites": []}
    base = [{"size": 10, "old_metric_s": 1.0, "gone_speedup": 2.0}]
    new = [{"size": 10, "fresh_metric_s": 1.0}]
    _write_artifact(str(tmp_path / "base"), summary, {"a": base})
    _write_artifact(str(tmp_path / "new"), summary, {"a": new})
    assert compare_dirs(str(tmp_path / "base"), str(tmp_path / "new")) == 0
    out = capsys.readouterr().out
    assert "old_metric_s" in out and "skipped" in out


def test_compare_malformed_summary_entries_skipped(tmp_path, capsys):
    """Summary entries without suite/status (older runner, partial write)
    must not crash the gate."""
    from benchmarks.compare import compare_dirs

    base = {"suites": [{"name": "legacy-shape"}, "not-even-a-dict"]}
    new = {"suites": [{"suite": "a", "status": "ok", "seconds": 1.0}]}
    _write_artifact(str(tmp_path / "base"), base, {})
    _write_artifact(str(tmp_path / "new"), new, {})
    assert compare_dirs(str(tmp_path / "base"), str(tmp_path / "new")) == 0
    out = capsys.readouterr().out
    assert "malformed" in out
    assert "not in baseline summary" in out  # suite 'a' has no baseline row


def test_compare_corrupt_baseline_is_bootstrap_not_crash(tmp_path):
    """Unparseable baseline JSON ≡ missing baseline: exit 0 with a notice
    (first nightly after an artifact corruption must still go green)."""
    from benchmarks.compare import compare_dirs

    base = tmp_path / "base"
    os.makedirs(base)
    with open(base / "summary.json", "w") as f:
        f.write("{truncated")
    _write_artifact(
        str(tmp_path / "new"),
        {"suites": [{"suite": "a", "status": "ok", "seconds": 1.0}]},
        {},
    )
    assert compare_dirs(str(base), str(tmp_path / "new")) == 0


def test_compare_corrupt_baseline_suite_file_skipped(tmp_path, capsys):
    from benchmarks.compare import compare_dirs

    summary = {"suites": []}
    _write_artifact(str(tmp_path / "base"), summary, {})
    with open(tmp_path / "base" / "a.json", "w") as f:
        f.write("[{]")
    _write_artifact(
        str(tmp_path / "new"), summary, {"a": [{"size": 1, "t_s": 1.0}]}
    )
    assert compare_dirs(str(tmp_path / "base"), str(tmp_path / "new")) == 0
    assert "unreadable baseline JSON" in capsys.readouterr().out


def test_compare_non_dict_rows_skipped(tmp_path, capsys):
    from benchmarks.compare import compare_dirs

    summary = {"suites": []}
    _write_artifact(
        str(tmp_path / "base"), summary, {"a": [[1, 2, 3], {"x_s": 1.0}]}
    )
    _write_artifact(
        str(tmp_path / "new"), summary, {"a": [[1, 2], {"x_s": 1.1}]}
    )
    assert compare_dirs(str(tmp_path / "base"), str(tmp_path / "new")) == 0
    assert "not an object" in capsys.readouterr().out


def test_compare_micro_timings_stay_quiet(tmp_path):
    """Sub-ms rows double all the time on shared runners — the absolute
    slack must keep them below the gate."""
    from benchmarks.compare import compare_dirs

    summary = {"suites": []}
    base = [{"size": 1, "kernel_s": 0.0004}]
    new = [{"size": 1, "kernel_s": 0.0011}]
    _write_artifact(str(tmp_path / "base"), summary, {"k": base})
    _write_artifact(str(tmp_path / "new"), summary, {"k": new})
    assert compare_dirs(str(tmp_path / "base"), str(tmp_path / "new")) == 0


def test_traversal_suite_registered():
    """bench_traversal rides smoke + nightly through the SUITES registry;
    its node_fusion_speedup field is auto-gated by compare.py's _speedup
    suffix rule."""
    suites = run_mod.default_suites(only=["traversal"])
    assert [name for name, _ in suites] == ["fused traversal nodes (roofline)"]
    assert "traversal" in run_mod.suite_names()
