"""GLMs over the compressed factorized join vs the dense one-hot oracle."""

import numpy as np
import pytest

from repro.core.categorical import onehot_design_matrix
from repro.core.glm import (
    GLMConfig,
    compressed_design_factorized,
    compressed_design_materialized,
    fit_glm,
    fit_glm_onehot,
    glm_predict_raw,
    glm_regression,
)
from repro.data.synthetic import favorita_like

CONT = ["transactions", "dcoilwtico"]
CAT = ["store_nbr", "item_nbr"]
LABEL = "onpromotion"  # 0/1 — a true Bernoulli target in the schema


@pytest.fixture(scope="module")
def favorita():
    return favorita_like(n_dates=8, n_stores=4, n_items=6, seed=3)


@pytest.fixture(scope="module")
def design(favorita):
    return compressed_design_factorized(
        favorita.store, favorita.vorder, CONT, CAT, LABEL
    )


@pytest.fixture(scope="module")
def onehot(favorita):
    joined = favorita.store.materialize_join()
    doms = {c: favorita.store.attr_domain(c) for c in CAT}
    x, _ = onehot_design_matrix(joined, CONT, CAT, doms)
    y = joined.column(LABEL).astype(np.float64)
    return x, y


def test_compression_paths_agree(favorita, design):
    mat = compressed_design_materialized(favorita.store, CONT, CAT, LABEL)
    joined = favorita.store.materialize_join()
    assert design.total_rows == joined.num_rows
    assert design.num_rows == mat.num_rows
    np.testing.assert_allclose(sorted(design.counts), sorted(mat.counts))
    np.testing.assert_allclose(sorted(design.ysum), sorted(mat.ysum))


@pytest.mark.parametrize("family", ["logistic", "poisson"])
def test_compressed_irls_matches_onehot_oracle(design, onehot, family):
    """Acceptance criterion: compressed GLM == dense one-hot within 1e-5."""
    x, y = onehot
    cfg = GLMConfig(family=family, ridge=1e-3)
    compressed = fit_glm(design, cfg)
    dense = fit_glm_onehot(x, y, cfg)
    assert compressed.converged and dense.converged
    np.testing.assert_allclose(
        compressed.theta, dense.theta, rtol=1e-5, atol=1e-5
    )


def test_gd_solver_agrees_on_predictions(design):
    """The fp32 GD path reaches the same model up to fp32 resolution —
    compared on predictions, which are insensitive to the near-collinear
    one-hot/intercept direction that θ itself is free to slide along."""
    irls = fit_glm(design, GLMConfig(family="logistic", ridge=1e-3))
    gd = fit_glm(
        design,
        GLMConfig(family="logistic", ridge=1e-3, solver="gd",
                  gd_max_iter=20_000),
    )
    p_irls = glm_predict_raw(irls.theta, design.cont, design.cat_ids, design,
                           irls.config.family)
    p_gd = glm_predict_raw(gd.theta, design.cont, design.cat_ids, design,
                         gd.config.family)
    np.testing.assert_allclose(p_gd, p_irls, atol=5e-3)


def test_glm_regression_pipeline(favorita):
    res = glm_regression(
        favorita.store, favorita.vorder, CONT, CAT, LABEL,
        GLMConfig(family="logistic", ridge=1e-3),
    )
    assert res.converged
    assert res.names[0] == "intercept"
    assert len(res.names) == res.theta.shape[0]
    res_mat = glm_regression(
        favorita.store, None, CONT, CAT, LABEL,
        GLMConfig(family="logistic", ridge=1e-3), factorized=False,
    )
    np.testing.assert_allclose(res.theta, res_mat.theta, rtol=1e-8, atol=1e-8)


def test_predictions_in_range(design):
    res = fit_glm(design, GLMConfig(family="logistic", ridge=1e-3))
    mu = glm_predict_raw(res.theta, design.cont, design.cat_ids, design,
                         res.config.family)
    assert np.all((mu > 0) & (mu < 1))
    # the fit separates promoted rows better than the base rate
    base = design.ysum.sum() / design.total_rows
    pred_rate = (design.counts @ mu) / design.total_rows
    np.testing.assert_allclose(pred_rate, base, atol=0.05)


def test_unknown_family_and_solver_rejected(design):
    with pytest.raises(ValueError, match="family"):
        fit_glm(design, GLMConfig(family="probit"))
    with pytest.raises(ValueError, match="solver"):
        fit_glm(design, GLMConfig(solver="adam"))


def test_continuous_only_glm(favorita):
    """No categorical features: compression still works (groups by the
    continuous tuple) and matches the dense fit."""
    design = compressed_design_factorized(
        favorita.store, favorita.vorder, CONT, [], LABEL
    )
    assert design.cat_ids.shape[1] == 0
    joined = favorita.store.materialize_join()
    x = np.stack([joined.column(f).astype(float) for f in CONT], axis=1)
    y = joined.column(LABEL).astype(np.float64)
    cfg = GLMConfig(family="logistic", ridge=1e-3)
    a = fit_glm(design, cfg)
    b = fit_glm_onehot(x, y, cfg)
    np.testing.assert_allclose(a.theta, b.theta, rtol=1e-6, atol=1e-6)


def test_gd_pairs_accumulation_beats_fp32_at_fixed_budget():
    """Mixed-precision GD: two-float (hi, lo) accumulation of the NLL and
    gradient reductions resolves descent far below the fp32 NLL floor, so
    at an identical iteration budget the "pairs" path lands much closer to
    the IRLS optimum than plain fp32 — the ROADMAP's fp32-floor gap."""
    from repro.core.glm import CompressedDesign, _family_stats, _penalty

    rng = np.random.default_rng(0)
    G, k = 8192, 3
    cont = rng.normal(0, 1.0, (G, k))
    counts = rng.integers(5, 60, G).astype(np.float64)
    eta = 0.8 + 0.5 * cont[:, 0] - 0.3 * cont[:, 1] + 0.1 * cont[:, 2]
    ysum = rng.binomial(
        counts.astype(int), 1.0 / (1.0 + np.exp(-eta))
    ).astype(np.float64)
    design = CompressedDesign(
        cont=cont,
        cat_ids=np.zeros((G, 0), dtype=np.int64),
        counts=counts,
        ysum=ysum,
        cont_names=["a", "b", "c"],
        cat_names=[],
        domains={},
        label="y",
    )

    def final_nll(res):
        _, _, nll = _family_stats(
            "logistic", design.linpred(res.theta), counts, ysum
        )
        return nll + _penalty(res.config, res.theta)

    budget = dict(
        family="logistic", ridge=1e-3, solver="gd",
        gd_max_iter=1500, gd_eps=0.0,
    )
    irls = final_nll(fit_glm(design, GLMConfig(family="logistic", ridge=1e-3)))
    f32 = final_nll(fit_glm(design, GLMConfig(**budget)))
    prs = final_nll(fit_glm(design, GLMConfig(**budget, gd_accum="pairs")))
    # fp32 stalls at its NLL floor; pairs closes >90% of the remaining gap
    assert prs < f32
    assert (prs - irls) < 0.1 * (f32 - irls)


def test_gd_accum_rejected(design):
    with pytest.raises(ValueError, match="gd_accum"):
        fit_glm(design, GLMConfig(solver="gd", gd_accum="fp16"))
