"""Factorized vs materialized cofactors (paper §3.4, Prop. 4.1)."""

import numpy as np
import pytest

from repro.core import (
    FactorizedEngine,
    cofactors_factorized,
    cofactors_materialized,
    cofactors_row_engine,
    design_matrix,
)
from repro.core.distributed import partitioned_cofactors_host
from repro.data.synthetic import favorita_like, figure1_schema, random_acyclic_schema


@pytest.fixture(scope="module")
def fig1():
    return figure1_schema()


@pytest.fixture(scope="module")
def favorita():
    return favorita_like(n_dates=8, n_stores=4, n_items=6, seed=3)


@pytest.mark.parametrize("bundle_name", ["fig1", "favorita"])
def test_factorized_equals_materialized(bundle_name, fig1, favorita):
    b = fig1 if bundle_name == "fig1" else favorita
    cols = b.features + [b.label]
    fact = cofactors_factorized(b.store, b.vorder, cols, backend="numpy")
    flat = cofactors_row_engine(b.store, cols)
    assert fact.count == flat.count
    np.testing.assert_allclose(fact.lin, flat.lin, rtol=1e-10)
    np.testing.assert_allclose(fact.quad, flat.quad, rtol=1e-10)


def test_jax_backend_matches_numpy(favorita):
    b = favorita
    cols = b.features + [b.label]
    f32 = cofactors_factorized(b.store, b.vorder, cols, backend="jax")
    f64 = cofactors_factorized(b.store, b.vorder, cols, backend="numpy")
    np.testing.assert_allclose(f32.quad, f64.quad, rtol=1e-4)
    np.testing.assert_allclose(f32.lin, f64.lin, rtol=1e-4)


def test_materialized_gram_matches_row_engine(favorita):
    b = favorita
    cols = b.features + [b.label]
    fast = cofactors_materialized(b.store, cols)
    slow = cofactors_row_engine(b.store, cols)
    np.testing.assert_allclose(fast.quad, slow.quad, rtol=1e-4)


def test_cofactor_symmetry(fig1):
    cols = fig1.features + [fig1.label]
    cof = cofactors_factorized(fig1.store, fig1.vorder, cols, backend="numpy")
    np.testing.assert_allclose(cof.quad, cof.quad.T)
    mat = cof.matrix()
    np.testing.assert_allclose(mat, mat.T)


def test_commutativity_with_union(favorita):
    """Prop 4.1: cofactors of a disjoint partition sum to the global ones."""
    b = favorita
    cols = b.features + [b.label]
    joined = b.store.materialize_join()
    z = design_matrix(joined, cols)
    whole = partitioned_cofactors_host(z, cols, 1)
    parts = partitioned_cofactors_host(z, cols, 7)
    np.testing.assert_allclose(whole.quad, parts.quad, rtol=1e-12)
    np.testing.assert_allclose(whole.lin, parts.lin, rtol=1e-12)
    assert whole.count == parts.count


def test_commutativity_with_projection(favorita):
    b = favorita
    cols = b.features + [b.label]
    cof = cofactors_factorized(b.store, b.vorder, cols, backend="numpy")
    sub = cof.project([b.features[0], b.label])
    full_entry = cof.quad[
        cof.features.index(b.features[0]), cof.features.index(b.label)
    ]
    np.testing.assert_allclose(sub.quad[0, 1], full_entry)


def test_sum_product_aggregates(fig1):
    """Paper Figures 2–3: COUNT and SUM(Sale·Competitor) via factorization."""
    eng = FactorizedEngine(
        fig1.store,
        fig1.vorder,
        ["Sale", "Competitor", "Inventory"],
        backend="numpy",
    )
    joined = fig1.store.materialize_join()
    sale = joined.column("Sale").astype(float)
    comp = joined.column("Competitor").astype(float)
    assert eng.sum_product([]) == joined.num_rows
    np.testing.assert_allclose(eng.sum_product(["Sale"]), sale.sum())
    np.testing.assert_allclose(
        eng.sum_product(["Sale", "Competitor"]), (sale * comp).sum()
    )


def test_random_schemas_fact_equals_flat():
    for seed in range(12):
        b = random_acyclic_schema(seed, n_branches=(seed % 3) + 1)
        cols = b.features + [b.label]
        fact = cofactors_factorized(b.store, b.vorder, cols, backend="numpy")
        joined = b.store.materialize_join()
        z = design_matrix(joined, cols)
        np.testing.assert_allclose(fact.count, z.shape[0])
        np.testing.assert_allclose(fact.lin, z.sum(0), rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(fact.quad, z.T @ z, rtol=1e-9, atol=1e-9)


def test_group_key_matches_composite_when_in_range():
    from repro.core.relation import composite_key, group_key

    rng = np.random.default_rng(0)
    cols = [rng.integers(0, d, 50).astype(np.int32) for d in (4, 7, 3)]
    a = composite_key(cols, [4, 7, 3])
    b = group_key(cols, [4, 7, 3])
    np.testing.assert_array_equal(a, b)


def test_group_key_survives_radix_overflow():
    """16 attributes × domain 1000 overflows the strict mixed-radix
    product; group_key must keep grouping correctly (same partition as
    np.unique over the stacked tuples)."""
    from repro.core.relation import composite_key, group_key

    rng = np.random.default_rng(1)
    doms = [1000] * 16
    cols = [rng.integers(0, 5, 200).astype(np.int32) for _ in doms]
    with pytest.raises(OverflowError):
        composite_key(cols, doms)
    key = group_key(cols, doms)
    _, inv_key = np.unique(key, return_inverse=True)
    _, inv_ref = np.unique(np.stack(cols, 1), axis=0, return_inverse=True)
    # identical partitions (group labels may differ, the mapping must not)
    assert len(set(zip(inv_key.tolist(), inv_ref.tolist()))) == len(
        set(inv_ref.tolist())
    )
