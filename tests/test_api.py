"""Public-API consolidation: the ``StoreReads`` protocol, the
``sufficient_stats`` entry point, and the ``RegressionConfig`` migration
of ``linear_regression``'s legacy keyword flags.

The shims are held to an identity standard: a legacy call must produce
the SAME result object as the config-field spelling (not merely a close
one), and each legacy keyword warns exactly once per process.
"""

import dataclasses
import warnings

import numpy as np
import pytest

import repro.core.regression as regmod
from repro.core import (
    VERSIONS,
    RegressionConfig,
    Store,
    StoreReads,
    linear_regression,
)
from repro.data.synthetic import favorita_like, many_cat_schema

CONT = ["x", "y"]


# ---------------------------------------------------------------------------
# StoreReads protocol
# ---------------------------------------------------------------------------

def test_store_and_snapshot_satisfy_store_reads():
    b = many_cat_schema(n_cat=2, domain=8, n_rows=100, seed=1)
    assert isinstance(b.store, StoreReads)
    assert isinstance(b.store.snapshot(), StoreReads)


def test_engine_accepts_snapshot_as_store_reads():
    """The annotation change is real: the engine runs against either side
    of the protocol and returns identical answers on identical data."""
    from repro.core.factorize import FactorizedEngine

    b = many_cat_schema(n_cat=2, domain=8, n_rows=100, seed=2)
    live = FactorizedEngine(b.store, b.vorder, CONT, backend="numpy")
    snap = FactorizedEngine(
        b.store.snapshot(), b.vorder, CONT, backend="numpy"
    )
    np.testing.assert_allclose(
        live.cofactors().matrix(), snap.cofactors().matrix(), rtol=0, atol=0
    )


# ---------------------------------------------------------------------------
# sufficient_stats: the consolidated read entry point
# ---------------------------------------------------------------------------

def test_sufficient_stats_routes_continuous():
    b = many_cat_schema(n_cat=2, domain=8, n_rows=120, seed=3)
    via = b.store.sufficient_stats(b.vorder, ["x"], "y", backend="numpy")
    direct = b.store.cofactors(b.vorder, ["x", "y"], backend="numpy")
    assert via is direct  # same cache entry, not merely equal


def test_sufficient_stats_routes_categorical():
    b = many_cat_schema(n_cat=2, domain=8, n_rows=120, seed=4)
    via = b.store.sufficient_stats(
        b.vorder, ["x", "c0"], "y", categorical=["c0"]
    )
    direct = b.store.cat_cofactors(b.vorder, ["x", "y"], ["c0"])
    assert via is direct


def test_sufficient_stats_on_snapshot():
    b = many_cat_schema(n_cat=2, domain=8, n_rows=120, seed=5)
    snap = b.store.snapshot()
    out = snap.sufficient_stats(b.vorder, ["x"], "y", backend="numpy")
    ref = b.store.sufficient_stats(b.vorder, ["x"], "y", backend="numpy")
    np.testing.assert_allclose(out.matrix(), ref.matrix(), rtol=1e-12,
                               atol=1e-9)


# ---------------------------------------------------------------------------
# linear_regression legacy-keyword shims
# ---------------------------------------------------------------------------

@pytest.fixture()
def fresh_warnings():
    regmod._LEGACY_WARNED.clear()
    yield
    regmod._LEGACY_WARNED.clear()


def _theta(bundle, **kw):
    return linear_regression(
        bundle.store, bundle.vorder, bundle.features, bundle.label, **kw
    ).theta


def test_legacy_backend_kwarg_identity(fresh_warnings):
    b = favorita_like(n_dates=12, n_stores=4, n_items=6)
    cfg = VERSIONS["closed"]
    with pytest.warns(DeprecationWarning, match="backend"):
        legacy = _theta(b, config=cfg, backend="numpy")
    modern = _theta(b, config=dataclasses.replace(cfg, backend="numpy"))
    np.testing.assert_allclose(legacy, modern, rtol=0, atol=0)


def test_legacy_use_cache_and_fds_identity(fresh_warnings):
    b = many_cat_schema(n_cat=2, domain=8, n_rows=150, seed=6)
    b.store.infer_fds()
    cfg = dataclasses.replace(VERSIONS["closed"], backend="numpy")
    with pytest.warns(DeprecationWarning):
        legacy = linear_regression(
            b.store, b.vorder, ["x", "c0"], "y",
            config=cfg, categorical=["c0"], use_cache=True, use_fds=False,
        )
    modern = linear_regression(
        b.store, b.vorder, ["x", "c0"], "y",
        config=dataclasses.replace(
            cfg, categorical=("c0",), use_cache=True, use_fds=False
        ),
    )
    np.testing.assert_allclose(legacy.theta, modern.theta, rtol=0, atol=0)
    assert legacy.names == modern.names


def test_legacy_kwargs_warn_once_per_process(fresh_warnings):
    b = favorita_like(n_dates=12, n_stores=4, n_items=6)
    cfg = VERSIONS["closed"]
    with pytest.warns(DeprecationWarning, match="backend"):
        _theta(b, config=cfg, backend="numpy")
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a second warning would raise
        _theta(b, config=cfg, backend="numpy")
    # a DIFFERENT legacy kwarg still gets its own (single) warning
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        _theta(b, config=cfg, backend="numpy", use_kernel=False)


def test_config_fields_cover_all_legacy_flags():
    cfg = RegressionConfig(name="t", factorized=True, solver="closed_form")
    for field in ("backend", "use_kernel", "use_cache", "categorical",
                  "use_fds"):
        assert hasattr(cfg, field)
