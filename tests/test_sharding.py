"""Sharding policy resolution: rule precedence, divisibility fallback,
duplicate-axis dedup, leaf-path mapping (params, optimizer state, caches)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs import get_config
from repro.models import model
from repro.train import TrainHParams, init_state


class FakeMesh:
    """Only .shape is consulted by ShardingPolicy.spec."""

    def __init__(self, **axes):
        self.shape = dict(axes)


POL = shd.ShardingPolicy(FakeMesh(data=16, model=16), shd.TRAIN_RULES)
POL_POD = shd.ShardingPolicy(
    FakeMesh(pod=2, data=16, model=16), shd.TRAIN_RULES
)
POL_SERVE = shd.ShardingPolicy(FakeMesh(data=16, model=16), shd.SERVE_RULES)


def test_batch_spans_pod_and_data_on_multipod():
    assert POL_POD.spec(("batch", "seq"), (256, 4096)) == P(("pod", "data"))
    assert POL.spec(("batch", "seq"), (256, 4096)) == P("data")


def test_divisibility_fallback_replicates():
    # 9 heads cannot shard over 16 -> replicated
    assert POL.spec(("fsdp", "heads", "head_dim"), (576, 9, 64)) == P("data")
    # 64 heads can
    assert POL.spec(("fsdp", "heads", "head_dim"), (8192, 64, 128)) == P(
        "data", "model"
    )


def test_duplicate_mesh_axis_dedup():
    # expert takes model; ffn would also want model -> falls to None
    spec = POL.spec(("expert", "fsdp", "ffn"), (16, 8192, 24576))
    assert spec == P("model", "data")
    # 60 experts don't divide 16 -> expert drops, ffn gets model
    spec = POL.spec(("expert", "fsdp", "ffn"), (60, 2048, 1408))
    assert spec == P(None, "data", "model")


def test_serve_rules_differ_from_train():
    # weights are not FSDP-sharded when serving
    assert POL_SERVE.spec(("fsdp", "ffn"), (4096, 14336)) == P(None, "model")
    # decode cache seq dim shards over model (SP)
    assert POL_SERVE.spec(
        ("batch", "kv_seq", "kv_heads", "head_dim"), (128, 32768, 8, 128)
    ) == P("data", "model")


def test_rule_override():
    rules = shd.AxisRules(shd.SERVE_RULES).override(
        kv_seq=("data", "model")
    )
    pol = shd.ShardingPolicy(FakeMesh(data=16, model=16), rules)
    spec = pol.spec(("batch", "kv_seq"), (1, 524288))
    # batch=1 unshardable; kv_seq takes both axes
    assert spec == P(None, ("data", "model"))


def _real_policy(rules=shd.TRAIN_RULES):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return shd.ShardingPolicy(mesh, rules)


def test_leaf_logical_param_paths():
    cfg = get_config("mixtral-8x7b", smoke=True)
    params = jax.eval_shape(lambda: model.init_params(jax.random.key(0), cfg))
    shardings = shd.param_specs(params, _real_policy())
    flat = dict(
        (jax.tree_util.keystr(p), s)
        for p, s in jax.tree_util.tree_flatten_with_path(shardings)[0]
    )
    # stacked period weights get a leading replicated (periods) dim
    wq = [v for k, v in flat.items() if "wq" in k][0]
    assert wq.spec[0] is None  # periods axis replicated
    emb = [v for k, v in flat.items() if k == "['embed']"][0]
    assert emb.spec == P("model", "data")  # vocab x fsdp


def test_optimizer_state_specs_follow_params():
    cfg = get_config("deepseek-67b", smoke=True)  # adafactor
    hp = TrainHParams()
    state = jax.eval_shape(
        lambda: init_state(jax.random.key(0), cfg, hp)
    )
    shardings = shd.state_specs(state, _real_policy())
    flat = dict(
        (jax.tree_util.keystr(p), s)
        for p, s in jax.tree_util.tree_flatten_with_path(shardings)[0]
    )
    # adafactor factored stats: vr drops the last axis of the param spec
    vr = [v for k, v in flat.items() if "w_gate" in k and "vr" in k]
    vc = [v for k, v in flat.items() if "w_gate" in k and "vc" in k]
    assert vr and vc


def test_constrain_noop_without_policy():
    x = jnp.zeros((4, 4))
    assert shd.constrain(x, ("batch", "seq")) is x


def test_constrain_applies_on_real_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = shd.ShardingPolicy(mesh, shd.TRAIN_RULES)
    with shd.use_policy(pol):
        y = jax.jit(lambda x: shd.constrain(x, ("batch", "seq")))(
            jnp.ones((4, 4))
        )
    assert y.shape == (4, 4)


def test_tree_specs_unknown_leaves_replicate():
    tree = {"mystery": jax.ShapeDtypeStruct((3, 5), jnp.float32)}
    specs = shd.tree_logical_specs(tree, _real_policy(), shd.PARAM_AXES)
    assert specs["mystery"].spec == P()
