"""Hypothesis property tests over the system's invariants.

The paper's algebra (Prop. 4.1) gives the exact invariants a correct
factorized engine must satisfy on ANY acyclic schema:

* factorized == materialized cofactors (element-exact vs float64 oracle)
* symmetry of the cofactor matrix
* commutativity with union (the distribution rule)
* commutativity with projection
* scaling preserves equi-joins (x = y  <=>  (x-a)/b = (y-a)/b)

Plus substrate invariants: quantization error bounds, token-pipeline
determinism/shardability, polynomial degree-2 consistency with the
quadratic engine.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (
    VERSIONS,
    GLMConfig,
    cofactors_factorized,
    cofactors_materialized,
    design_matrix,
    glm_regression,
    linear_regression,
)
from repro.core.categorical import (
    cat_cofactors_factorized,
    cat_cofactors_per_pass,
    onehot_design_matrix,
)
from repro.core.polynomial import polynomial_cofactors
from repro.core.relation import (
    composite_key,
    hash_join_keys,
    sort_merge_join,
)
from repro.data.synthetic import fd_star_schema, random_acyclic_schema
from repro.data.tokens import TokenPipeline
from repro.train import compression as comp

SET = settings(
    max_examples=25,
    deadline=None,
    derandomize=True,  # reproducible examples: CI runs match local runs
    suppress_health_check=[HealthCheck.too_slow],
)

schema_params = st.builds(
    random_acyclic_schema,
    seed=st.integers(0, 10_000),
    n_branches=st.integers(1, 3),
    max_fanout=st.integers(1, 5),
    max_rows=st.integers(1, 15),
)


@SET
@given(bundle=schema_params)
def test_factorized_equals_materialized_random_schema(bundle):
    cols = bundle.features + [bundle.label]
    fact = cofactors_factorized(
        bundle.store, bundle.vorder, cols, backend="numpy"
    )
    flat = cofactors_materialized(bundle.store, cols)
    # the materialized path's Gram runs fp32 on-device; fp32-scale rtol
    np.testing.assert_allclose(fact.matrix(), flat.matrix(), rtol=5e-4,
                               atol=1e-3)


@SET
@given(bundle=schema_params)
def test_cofactor_matrix_symmetric(bundle):
    cols = bundle.features + [bundle.label]
    m = cofactors_factorized(
        bundle.store, bundle.vorder, cols, backend="numpy"
    ).matrix()
    np.testing.assert_allclose(m, m.T, rtol=0, atol=0)


@SET
@given(bundle=schema_params, parts=st.integers(2, 5))
def test_union_commutativity_random(bundle, parts):
    cols = bundle.features + [bundle.label]
    joined = bundle.store.materialize_join()
    z = design_matrix(joined, cols)
    full = cofactors_materialized(bundle.store, cols)
    # partition rows, sum cofactors
    total = None
    for chunk in np.array_split(z, parts, axis=0):
        ones = np.ones((chunk.shape[0], 1))
        zz = np.concatenate([ones, chunk], axis=1)
        g = zz.T @ zz
        total = g if total is None else total + g
    np.testing.assert_allclose(total, full.matrix(), rtol=5e-4, atol=1e-3)


@SET
@given(bundle=schema_params)
def test_projection_commutativity_random(bundle):
    cols = bundle.features + [bundle.label]
    if len(cols) < 2:
        return
    keep = cols[::2] or cols[:1]
    full = cofactors_factorized(
        bundle.store, bundle.vorder, cols, backend="numpy"
    )
    sub = full.project(keep)
    direct = cofactors_materialized(bundle.store, keep)
    np.testing.assert_allclose(
        sub.matrix(), direct.matrix(), rtol=5e-4, atol=1e-3
    )


@SET
@given(bundle=schema_params)
def test_categorical_sparse_equals_onehot_oracle(bundle):
    """The sparse categorical cofactor matrix — assembled from grouped
    aggregates, never from one-hot columns — equals the Gram of the dense
    one-hot design matrix on ANY random acyclic join.  The join keys (k0
    and the branch keys) double as the categorical features; the value
    columns stay continuous."""
    cat = ["k0"] + [f"k{i + 1}" for i in range(len(bundle.features) // 2)]
    cont = bundle.features + [bundle.label]
    sparse = cat_cofactors_factorized(
        bundle.store, bundle.vorder, cont, cat, backend="numpy"
    )
    joined = bundle.store.materialize_join()
    doms = {c: bundle.store.attr_domain(c) for c in cat}
    x, names = onehot_design_matrix(joined, cont, cat, doms)
    z = np.concatenate([np.ones((x.shape[0], 1)), x], axis=1)
    np.testing.assert_allclose(
        sparse.matrix(), z.T @ z, rtol=1e-9, atol=1e-9
    )
    assert sparse.column_names() == ["intercept"] + names


@SET
@given(bundle=schema_params)
def test_fused_single_pass_equals_per_pass_equals_onehot(bundle):
    """Three-way equivalence on ANY random acyclic join: the fused
    multi-output plan (ONE engine traversal for the whole cofactor batch)
    == the PR 2 per-pass path (one traversal per attribute + pair) to
    1e-12, and both == the one-hot Gram oracle.  A deterministic mirror
    (no hypothesis dependency) lives in
    tests/test_categorical.py::test_random_schemas_sparse_equals_onehot."""
    cat = ["k0"] + [f"k{i + 1}" for i in range(len(bundle.features) // 2)]
    cont = bundle.features + [bundle.label]
    stats = {}
    fused = cat_cofactors_factorized(
        bundle.store, bundle.vorder, cont, cat, backend="numpy", stats=stats
    )
    assert stats["passes"] == 1  # however many attributes / pairs
    per_pass = cat_cofactors_per_pass(
        bundle.store, bundle.vorder, cont, cat, backend="numpy"
    )
    np.testing.assert_allclose(
        fused.matrix(), per_pass.matrix(), rtol=1e-12, atol=1e-12
    )
    joined = bundle.store.materialize_join()
    doms = {c: bundle.store.attr_domain(c) for c in cat}
    x, _ = onehot_design_matrix(joined, cont, cat, doms)
    z = np.concatenate([np.ones((x.shape[0], 1)), x], axis=1)
    np.testing.assert_allclose(
        fused.matrix(), z.T @ z, rtol=1e-9, atol=1e-9
    )


fd_schema_params = st.builds(
    fd_star_schema,
    seed=st.integers(0, 10_000),
    n_cat=st.integers(1, 2),
    domain=st.integers(3, 8),
    dep_domain=st.integers(2, 4),
    n_rows=st.integers(10, 60),
)


@SET
@given(bundle=fd_schema_params)
def test_fd_reduced_solve_equals_full_solve(bundle):
    """On ANY random join with planted FDs (c_i → d_i, plus whatever
    accidental FDs the tiny data happens to satisfy — those are true FDs
    of the data, so exploiting them must be just as exact): FD-reduced
    training ≡ the full solve, coefficients to 1e-10, identical layout,
    for both least squares (closed form) and logistic IRLS."""
    store, vorder = bundle.store, bundle.vorder
    n_cat = sum(1 for a in store.get("Fact").keys)
    cat = [f"c{i}" for i in range(n_cat)] + [f"d{i}" for i in range(n_cat)]
    feats = ["x"] + cat
    inferred = store.infer_fds()
    assert {(f"c{i}", f"d{i}") for i in range(n_cat)} <= set(inferred)
    assert not store.fd_reduction(cat).is_trivial

    full = linear_regression(
        store, vorder, feats, "y", VERSIONS["closed"], backend="numpy",
        categorical=cat, use_fds=False,
    )
    red = linear_regression(
        store, vorder, feats, "y", VERSIONS["closed"], backend="numpy",
        categorical=cat, use_fds=True,
    )
    assert full.names == red.names
    np.testing.assert_allclose(red.theta, full.theta, rtol=0, atol=1e-10)

    cfg = GLMConfig(family="logistic", ridge=1e-3, tol=1e-14)
    gf = glm_regression(
        store, vorder, ["x"], cat, "promo", cfg, backend="numpy",
        use_fds=False,
    )
    gr = glm_regression(
        store, vorder, ["x"], cat, "promo", cfg, backend="numpy",
        use_fds=True,
    )
    assert gf.names == gr.names
    np.testing.assert_allclose(gr.theta, gf.theta, rtol=0, atol=1e-10)


@SET
@given(
    seed=st.integers(0, 10_000),
    n_attr=st.integers(1, 4),
    nl=st.integers(0, 40),
    nr=st.integers(0, 30),
)
def test_hash_join_equals_composite_join(seed, n_attr, nl, nr):
    """Below the radix limit both key codings must enumerate exactly the
    same matching (left, right) pairs on any inputs — the hash-join
    fallback changes the encoding, never the join result."""
    rng = np.random.default_rng(seed)
    doms = [int(rng.integers(1, 7)) for _ in range(n_attr)]
    lcols = [rng.integers(0, d, nl).astype(np.int32) for d in doms]
    rcols = [rng.integers(0, d, nr).astype(np.int32) for d in doms]

    def pairs(lk, rk):
        il, ir = sort_merge_join(lk, rk)
        return sorted(zip(il.tolist(), ir.tolist()))

    via_composite = pairs(
        composite_key(lcols, doms), composite_key(rcols, doms)
    )
    via_hash = pairs(*hash_join_keys(lcols, rcols))
    assert via_composite == via_hash


@SET
@given(bundle=schema_params)
def test_polynomial_degree1_matches_quadratic_engine(bundle):
    """The beyond-paper degree-d engine at d=1 must equal the paper's
    degree-≤2 cofactor engine (same monomial set: features + label)."""
    # the polynomial engine enumerates monomials over SORTED features —
    # align the quadratic engine's column order to match
    cols = sorted(bundle.features) + [bundle.label]
    quad = cofactors_factorized(
        bundle.store, bundle.vorder, cols, backend="numpy"
    )
    poly = polynomial_cofactors(
        bundle.store, bundle.vorder, bundle.features, bundle.label, degree=1
    )
    np.testing.assert_allclose(
        poly.matrix(), quad.matrix(), rtol=1e-5, atol=1e-5
    )


@SET
@given(
    data=st.lists(
        st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=200
    )
)
def test_int8_quantization_error_bound(data):
    import jax.numpy as jnp

    x = jnp.asarray(np.asarray(data, np.float32))
    q, scale = comp.quantize_int8(x)
    err = np.abs(np.asarray(comp.dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


@SET
@given(
    seed=st.integers(0, 1000),
    step=st.integers(0, 50),
    shards=st.sampled_from([1, 2, 4]),
)
def test_token_pipeline_deterministic_and_shardable(seed, step, shards):
    pipe = TokenPipeline(vocab=97, seq_len=16, global_batch=8, seed=seed)
    full = pipe.batch_at(step)
    again = pipe.batch_at(step)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    parts = [
        pipe.batch_at(step, shard=s, num_shards=shards)["tokens"]
        for s in range(shards)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full["tokens"])
    # labels are next-token aligned
    np.testing.assert_array_equal(
        full["tokens"][:, 1:], full["labels"][:, :-1]
    )
