"""Hash-join fallback for wide shared-attribute joins.

``composite_key`` packs join keys into one int64 by mixed-radix encoding
and raises ``OverflowError`` once the shared-attribute domain product
exceeds the int64 budget.  ``join_keys`` keeps the strict composite path
when it fits and silently switches to the dictionary-encoded hash join
(``hash_join_keys``) past the limit — both must enumerate exactly the
same matching pairs.
"""

import numpy as np
import pytest

from repro.core.relation import (
    Relation,
    composite_key,
    hash_join_keys,
    join_keys,
    radix_fits,
    sort_merge_join,
)
from repro.core.store import Store

RNG = np.random.default_rng(7)


def _pairs(lk, rk):
    il, ir = sort_merge_join(lk, rk)
    return sorted(zip(il.tolist(), ir.tolist()))


def _brute_force(lcols, rcols):
    lt = list(zip(*[c.tolist() for c in lcols]))
    rt = list(zip(*[c.tolist() for c in rcols]))
    return sorted(
        (i, j)
        for i in range(len(lt))
        for j in range(len(rt))
        if lt[i] == rt[j]
    )


def test_radix_fits_boundary():
    assert radix_fits([2**20, 2**20, 2**20])  # 2^60 < 2^63 // 4
    assert not radix_fits([2**31, 2**31, 2**31])
    assert radix_fits([1, 1, 1])


def test_join_keys_uses_composite_below_limit():
    lcols = [RNG.integers(0, 5, 30).astype(np.int32) for _ in range(3)]
    rcols = [RNG.integers(0, 5, 20).astype(np.int32) for _ in range(3)]
    doms = [5, 5, 5]
    lk, rk = join_keys(lcols, rcols, doms)
    np.testing.assert_array_equal(lk, composite_key(lcols, doms))
    np.testing.assert_array_equal(rk, composite_key(rcols, doms))


def test_hash_join_equals_composite_below_limit():
    for seed in range(5):
        rng = np.random.default_rng(seed)
        n_attr = int(rng.integers(1, 4))
        doms = [int(rng.integers(1, 7)) for _ in range(n_attr)]
        nl = int(rng.integers(1, 40))
        lcols = [rng.integers(0, d, nl).astype(np.int32) for d in doms]
        rcols = [
            rng.integers(0, d, 25).astype(np.int32) for d in doms
        ]
        ck = _pairs(*join_keys(lcols, rcols, doms))
        hk = _pairs(*hash_join_keys(lcols, rcols))
        assert ck == hk == _brute_force(lcols, rcols)


def test_hash_join_past_radix_limit_matches_oracle():
    # 10 attrs × domain 128 → 128^10 = 2^70: composite_key overflows
    n_attr, dom = 10, 128
    doms = [dom] * n_attr
    assert not radix_fits(doms)
    with pytest.raises(OverflowError):
        composite_key(
            [RNG.integers(0, dom, 4).astype(np.int32)] * n_attr, doms
        )
    lcols = [RNG.integers(0, dom, 200).astype(np.int32) for _ in range(n_attr)]
    # force overlap: right side reuses a prefix of the left tuples
    rcols = [
        np.concatenate(
            [lc[:80], RNG.integers(0, dom, 40).astype(np.int32)]
        )
        for lc in lcols
    ]
    got = _pairs(*join_keys(lcols, rcols, doms))
    assert got == _brute_force(lcols, rcols)
    assert len(got) >= 80


def test_store_join_survives_wide_shared_attributes():
    """ROADMAP item: a natural join on many wide shared attributes used to
    die in ``composite_key`` with OverflowError (relation.py)."""
    n_attr, dom, rows = 9, 256, 120
    keys = {
        f"k{i}": RNG.integers(0, dom, rows).astype(np.int32)
        for i in range(n_attr)
    }
    r1 = Relation.from_columns(
        "A", keys, {"v": RNG.normal(0, 1, rows)},
        {f"k{i}": dom for i in range(n_attr)},
    )
    sub = {f"k{i}": keys[f"k{i}"][:50] for i in range(n_attr)}
    r2 = Relation.from_columns(
        "B", sub, {"w": RNG.normal(0, 1, 50)},
        {f"k{i}": dom for i in range(n_attr)},
    )
    joined = Store([r1, r2]).materialize_join()
    # every B row matches its originating A row at least once
    assert joined.num_rows >= 50
    # spot-check value alignment: joined rows satisfy v's row ↔ key tuple
    lt = list(zip(*[keys[f"k{i}"].tolist() for i in range(n_attr)]))
    jt = list(
        zip(*[joined.keys[f"k{i}"].tolist() for i in range(n_attr)])
    )
    v = r1.values["v"]
    for row, val in zip(jt, joined.values["v"].tolist()):
        assert any(
            lt[i] == row and np.isclose(v[i], val) for i in range(rows)
        )
