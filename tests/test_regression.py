"""End-to-end regression pipeline: GD versions, scaling, θ rescale (§3–§4)."""

import numpy as np
import pytest

from repro.core import (
    GDConfig,
    VERSIONS,
    bgd_cofactor,
    bgd_data,
    compute_scale_factors,
    design_matrix,
    linear_regression,
    rescale_theta,
    solve_cofactor,
)
from repro.data.synthetic import favorita_like, figure1_schema


@pytest.fixture(scope="module")
def favorita():
    return favorita_like(n_dates=12, n_stores=5, n_items=6, seed=7)


@pytest.fixture(scope="module")
def lstsq_theta(favorita):
    joined = favorita.store.materialize_join()
    x = design_matrix(joined, favorita.features)
    y = joined.column(favorita.label).astype(np.float64)
    a = np.concatenate([np.ones((len(y), 1)), x], axis=1)
    theta, *_ = np.linalg.lstsq(a, y, rcond=None)
    return theta


@pytest.mark.parametrize("version", ["v1", "v3", "v4"])
def test_bgd_converges_to_lstsq(favorita, lstsq_theta, version):
    r = linear_regression(
        favorita.store,
        favorita.vorder,
        favorita.features,
        favorita.label,
        VERSIONS[version],
    )
    n = len(favorita.features)
    np.testing.assert_allclose(r.theta[: n + 1], lstsq_theta, rtol=2e-2, atol=2e-2)


def test_fact_equals_nopre_theta(favorita):
    """Paper Table 2: fact and noPre agree to many digits (same math)."""
    r1 = linear_regression(
        favorita.store, favorita.vorder, favorita.features, favorita.label,
        VERSIONS["v1"],
    )
    r2 = linear_regression(
        favorita.store, None, favorita.features, favorita.label, VERSIONS["v2"]
    )
    np.testing.assert_allclose(r1.theta, r2.theta, rtol=1e-3, atol=1e-3)


def test_closed_form_matches_lstsq(favorita, lstsq_theta):
    r = linear_regression(
        favorita.store, favorita.vorder, favorita.features, favorita.label,
        VERSIONS["closed"],
    )
    n = len(favorita.features)
    np.testing.assert_allclose(r.theta[: n + 1], lstsq_theta, rtol=1e-3, atol=1e-3)


def test_v5_theta0_produces_large_error(favorita):
    """Paper: versions 5/6 'lead to a huge error' — θ0 off by ~label mean."""
    good = linear_regression(
        favorita.store, favorita.vorder, favorita.features, favorita.label,
        VERSIONS["v4"],
    ).evaluate(favorita.store, favorita.features, favorita.label)
    bad = linear_regression(
        favorita.store, favorita.vorder, favorita.features, favorita.label,
        VERSIONS["v5"],
    ).evaluate(favorita.store, favorita.features, favorita.label)
    assert bad["avg_abs_err"] > 3 * good["avg_abs_err"]


def test_v4_converges_no_slower(favorita):
    r1 = linear_regression(
        favorita.store, favorita.vorder, favorita.features, favorita.label,
        VERSIONS["v1"],
    )
    r4 = linear_regression(
        favorita.store, favorita.vorder, favorita.features, favorita.label,
        VERSIONS["v4"],
    )
    assert r4.iterations <= r1.iterations * 1.5


def test_paper_table1_scaling_example():
    """Paper Table 1: exact avg/max values of the worked example."""
    x1 = np.array([0.01, 0.03, -0.05, -0.01, 0.02])
    x2 = np.array([20000.0, 0.0, -19500.0, 10000.0, -7000.0])
    assert np.isclose(x1.mean(), 0.0)
    assert np.isclose(np.abs(x1).max(), 0.05)
    assert np.isclose(x2.mean(), 700.0)
    assert np.isclose(np.abs(x2).max(), 20000.0)
    conv1 = (x1 - x1.mean()) / np.abs(x1).max()
    conv2 = (x2 - x2.mean()) / np.abs(x2).max()
    np.testing.assert_allclose(conv1, [0.2, 0.6, -1.0, -0.2, 0.4])
    np.testing.assert_allclose(conv2, [0.965, -0.035, -1.01, 0.465, -0.385])


def test_paper_section33_theta_rescale_example():
    """Paper §3.3 worked example: θ rescaling yields 200·x1 + 0.1·x2."""
    from repro.core.scaling import ScaleFactors

    factors = ScaleFactors(
        avg={"x1": 0.0, "x2": 700.0, "y": 0.0},
        max={"x1": 0.05, "x2": 20000.0, "y": 1.0},
        features=["x1", "x2"],
        label="y",
    )
    theta_conv = np.array([70.0, 10.0, 2000.0, -1.0])
    theta = rescale_theta(theta_conv, factors, mode="theta0_conv")
    np.testing.assert_allclose(theta[1], 200.0)
    np.testing.assert_allclose(theta[2], 0.1)
    np.testing.assert_allclose(theta[0], 70.0 - (200.0 * 0.0 + 0.1 * 700.0))


def test_rescale_exact_mode_preserves_predictions():
    """§3.3 identity: predictions in conv space == predictions in original."""
    rng = np.random.default_rng(0)
    m, n = 50, 3
    x = rng.normal(0, 5, size=(m, n))
    y = x @ np.array([1.0, -2.0, 0.5]) + 3.0 + rng.normal(0, 0.1, m)
    avg = {f"f{j}": float(x[:, j].mean()) for j in range(n)}
    mx = {f"f{j}": float(np.abs(x[:, j]).max()) for j in range(n)}
    avg["y"], mx["y"] = float(y.mean()), 1.0
    from repro.core.scaling import ScaleFactors

    factors = ScaleFactors(
        avg=avg, max=mx, features=[f"f{j}" for j in range(n)], label="y"
    )
    xc = np.stack(
        [(x[:, j] - avg[f"f{j}"]) / mx[f"f{j}"] for j in range(n)], axis=1
    )
    yc = y - avg["y"]
    a = np.concatenate([np.ones((m, 1)), xc], axis=1)
    theta_conv_t, *_ = np.linalg.lstsq(a, yc, rcond=None)
    theta_conv = np.concatenate([theta_conv_t, [-1.0]])
    theta = rescale_theta(theta_conv, factors, mode="exact")
    pred_conv = a @ theta_conv_t + avg["y"]
    pred_orig = theta[0] + x @ theta[1 : n + 1]
    np.testing.assert_allclose(pred_conv, pred_orig, rtol=1e-8)


def test_gd_respects_iteration_cap():
    cof = np.array([[4.0, 1.0, 2.0], [1.0, 3.0, 1.0], [2.0, 1.0, 5.0]])
    res = bgd_cofactor(cof, GDConfig(max_iter=5))
    assert res.iterations == 5


def test_gd_cofactor_equals_gd_data():
    """fact and noPre run the *same* update — trajectories must agree."""
    rng = np.random.default_rng(1)
    z = rng.normal(0, 1, size=(200, 4))
    cfg = GDConfig(max_iter=500)
    a = bgd_cofactor(z.T @ z, cfg)
    b = bgd_data(z, cfg)
    np.testing.assert_allclose(a.theta, b.theta, rtol=1e-3, atol=1e-4)


def test_solve_cofactor_ridge():
    rng = np.random.default_rng(2)
    z = rng.normal(0, 1, size=(300, 5))
    theta = solve_cofactor(z.T @ z, ridge=0.0)
    # stationarity: C_tt θ_t = C_t,label
    cof = z.T @ z
    np.testing.assert_allclose(cof[:4, :4] @ theta[:4], cof[:4, 4], rtol=1e-8)
