"""Serving engine: decode == forward (greedy), batching, stopping."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.serve import Engine, Request, ServeConfig

KEY = jax.random.key(0)

# one arch per cache family: full attention, SWA ring, recurrent, hybrid+moe
FAMILIES = ["smollm-135m", "mixtral-8x7b", "xlstm-1.3b", "jamba-1.5-large-398b"]


def greedy_reference(params, cfg, prompt, n_new):
    """Re-run the full forward for every generated token (oracle)."""
    serve_cfg = dataclasses.replace(cfg, moe_capacity=cfg.moe_capacity_serve)
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = model.forward(
            params, {"tokens": jnp.asarray([toks], jnp.int32)}, serve_cfg
        )
        toks.append(int(jnp.argmax(logits[0, -1, : cfg.vocab])))
    return toks[len(prompt):]


@pytest.mark.parametrize("name", FAMILIES)
def test_engine_matches_full_forward_greedy(name):
    cfg = get_config(name, smoke=True)
    params = model.init_params(KEY, cfg)
    prompt = [int(t) for t in np.random.RandomState(0).randint(1, cfg.vocab, 7)]
    ref = greedy_reference(params, cfg, prompt, 5)
    eng = Engine(params, cfg, ServeConfig(slots=2, prefill_len=8, max_len=32))
    eng.submit(Request(uid=0, tokens=prompt, max_new_tokens=5))
    (res,) = eng.run()
    assert res.tokens == ref


def test_engine_continuous_batching_mixed_lengths():
    cfg = get_config("smollm-135m", smoke=True)
    params = model.init_params(KEY, cfg)
    rng = np.random.RandomState(1)
    eng = Engine(params, cfg, ServeConfig(slots=2, prefill_len=8, max_len=64))
    wants = {}
    for uid in range(5):  # more requests than slots -> queueing
        plen = int(rng.randint(3, 8))
        prompt = [int(t) for t in rng.randint(1, cfg.vocab, plen)]
        n_new = int(rng.randint(2, 6))
        wants[uid] = greedy_reference(params, cfg, prompt, n_new)
        eng.submit(Request(uid=uid, tokens=prompt, max_new_tokens=n_new))
    results = eng.run()
    assert len(results) == 5
    for r in results:
        assert r.tokens == wants[r.uid], r.uid


def test_engine_eos_stops_early():
    cfg = get_config("smollm-135m", smoke=True)
    params = model.init_params(KEY, cfg)
    prompt = [1, 2, 3]
    ref = greedy_reference(params, cfg, prompt, 1)
    eos = ref[0]  # first generated token == eos -> stop at length 1
    eng = Engine(params, cfg, ServeConfig(slots=1, prefill_len=8, max_len=32))
    eng.submit(Request(uid=0, tokens=prompt, max_new_tokens=10, eos=eos))
    (res,) = eng.run()
    assert res.tokens == [eos]


def test_engine_temperature_sampling_runs():
    cfg = get_config("smollm-135m", smoke=True)
    params = model.init_params(KEY, cfg)
    eng = Engine(
        params, cfg,
        ServeConfig(slots=2, prefill_len=8, max_len=32, temperature=1.0),
    )
    eng.submit(Request(uid=0, tokens=[1, 2, 3], max_new_tokens=4))
    (res,) = eng.run()
    assert len(res.tokens) == 4
    assert all(0 <= t < cfg.vocab for t in res.tokens)
